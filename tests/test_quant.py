"""Quantization subsystem: int8/fp8 representations, quantized kernels
vs their fake-quant oracles, dtype-aware schedules, and fp8/w8 serving."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.quant import (AbsMaxCalibrator, QuantizedTensor,
                         dequantize_params, fake_quant, logit_report,
                         quantize, quantize_params, quantized_bytes)


def _cfg(arch: str):
    return dataclasses.replace(get_reduced(arch), dtype=jnp.float32)


# ===================== representations & round trips ========================


def test_quantize_int8_per_channel_error_bound():
    """|fake_quant(x) - x| <= scale/2 per output channel (round-to-
    nearest with absmax scales)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 32)) * 3.0, jnp.float32)
    qt = quantize(x, "int8")
    assert qt.q.dtype == jnp.int8 and qt.scale.shape == (1, 32)
    err = np.abs(np.asarray(qt.dequant()) - np.asarray(x))
    bound = 0.5 * np.asarray(qt.scale) + 1e-6
    assert (err <= bound).all()


def test_quantize_fp8_and_per_tensor():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    qt = quantize(x, "fp8")
    assert qt.q.dtype == jnp.float8_e4m3fn
    # e4m3 has ~2 decimal digits: relative error well under 10%
    np.testing.assert_allclose(np.asarray(qt.dequant()), np.asarray(x),
                               rtol=0.1, atol=1e-3)
    pt = quantize(x, "int8", reduce_axis=None)
    assert np.asarray(pt.scale).size == 1
    fq = fake_quant(x, "int8", reduce_axis=None)
    assert fq.dtype == x.dtype
    with pytest.raises(ValueError):
        quantize(x, "int4")


def test_quantized_tensor_is_a_pytree():
    """jit / scan must treat QuantizedTensor like any other leaf pair —
    that is what lets quantized params drop into the engines unchanged."""
    rng = np.random.default_rng(2)
    stacked = jnp.asarray(rng.normal(size=(3, 8, 4)), jnp.float32)
    qt = quantize(stacked, "int8")            # (3, 1, 4) per-group scales
    assert qt.scale.shape == (3, 1, 4)

    def body(carry, w):                       # w: sliced QuantizedTensor
        assert isinstance(w, QuantizedTensor)
        return carry, w.dequant()

    _, deq = jax.lax.scan(body, 0.0, qt)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(qt.dequant()),
                               rtol=1e-6, atol=1e-6)
    out = jax.jit(lambda q: q.dequant().sum())(qt)
    assert np.isfinite(float(out))


def test_calibrator_absmax_and_ema():
    cal = AbsMaxCalibrator()
    cal.observe({"h": jnp.asarray([1.0, -2.0])})
    cal.observe({"h": jnp.asarray([0.5, 4.0])})
    s = cal.scales("int8")
    np.testing.assert_allclose(float(s["h"]), 4.0 / 127.0, rtol=1e-5)
    ema = AbsMaxCalibrator(momentum=0.5)
    ema.observe({"h": jnp.asarray([2.0])})
    ema.observe({"h": jnp.asarray([4.0])})
    np.testing.assert_allclose(float(ema.scales("int8")["h"]),
                               3.0 / 127.0, rtol=1e-5)
    with pytest.raises(ValueError):
        AbsMaxCalibrator(momentum=1.5)
    with pytest.raises(ValueError):
        AbsMaxCalibrator().scales()


# ========================= quantized kernels ================================


@pytest.mark.parametrize("per_channel", [True, False])
def test_matmul_w8_kernel_matches_oracle(per_channel):
    from repro.kernels import ops
    from repro.kernels.matmul_q import matmul_w8_ref
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    w_q = jnp.asarray(rng.integers(-127, 128, size=(64, 48)), jnp.int8)
    scale = (jnp.asarray(rng.uniform(0.01, 0.1, size=(48,)), jnp.float32)
             if per_channel else jnp.float32(0.02))
    out = ops.matmul_w8(a, w_q, scale, tiles=(8, 16, 16), interpret=True)
    ref = matmul_w8_ref(a, w_q, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_matmul_w8_ragged_falls_back_to_oracle():
    from repro.kernels import ops
    from repro.kernels.matmul_q import matmul_w8_ref
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.normal(size=(30, 64)), jnp.float32)   # 30 % 8 != 0
    w_q = jnp.asarray(rng.integers(-127, 128, size=(64, 48)), jnp.int8)
    scale = jnp.asarray(rng.uniform(0.01, 0.1, size=(48,)), jnp.float32)
    out = ops.matmul_w8(a, w_q, scale, tiles=(8, 16, 16), interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(matmul_w8_ref(a, w_q, scale)),
                               rtol=1e-5, atol=1e-5)


def test_quantized_linear_matches_fake_quant_reference():
    """ops.linear on a QuantizedTensor == x @ dequant(w), on both the
    dequant path and the blocked matmul_w8 kernel path."""
    from repro.kernels import ops
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(2, 8, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 32)) * 0.1, jnp.float32)
    qt = quantize(w, "int8")
    ref = x @ qt.dequant(jnp.float32)
    out = ops.linear(x, qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    with ops.blocked_linear():                # kernel path (interpret)
        out_k = ops.linear(x, qt)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window,logit_cap", [(None, None), (7, None),
                                              (None, 30.0)])
def test_flash_decode_fp8_kernel_matches_oracle(window, logit_cap):
    """fp8-page Pallas kernel (interpret) == fp32-dequant dense oracle
    over ragged lengths, shuffled block tables and per-head scales."""
    from repro.kernels.flash_decode import (flash_decode_fp8,
                                            paged_attention_fp8_ref)
    rng = np.random.default_rng(6)
    B, hkv, G, D, page, nb = 3, 2, 3, 16, 8, 4
    n_pages = B * nb + 1
    q = jnp.asarray(rng.normal(size=(B, hkv, G, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, page, hkv, D)),
                     jnp.float8_e4m3fn)
    vp = jnp.asarray(rng.normal(size=(n_pages, page, hkv, D)),
                     jnp.float8_e4m3fn)
    ks = jnp.asarray(rng.uniform(0.5, 2.0, size=(hkv,)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.5, 2.0, size=(hkv,)), jnp.float32)
    bt = jnp.asarray(1 + rng.permutation(B * nb).reshape(B, nb), jnp.int32)
    lengths = jnp.asarray([1, 13, 32], jnp.int32)
    out = flash_decode_fp8(q, kp, vp, ks, vs, bt, lengths, window=window,
                           logit_cap=logit_cap, interpret=True)
    ref = paged_attention_fp8_ref(q, kp, vp, ks, vs, bt, lengths,
                                  window=window, logit_cap=logit_cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_paged_attention_routes_fp8_pools():
    """ops.paged_attention on a 1-byte pool: unit-scale kernel output ==
    the plain oracle on cast pages (the dense-path fp8 semantics)."""
    from repro.kernels import ops
    from repro.kernels.flash_decode import paged_attention_ref
    rng = np.random.default_rng(7)
    B, hkv, G, D, page, nb = 2, 2, 2, 8, 4, 3
    q = jnp.asarray(rng.normal(size=(B, hkv * G, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(B * nb + 1, page, hkv, D)),
                     jnp.float8_e4m3fn)
    vp = jnp.asarray(rng.normal(size=(B * nb + 1, page, hkv, D)),
                     jnp.float8_e4m3fn)
    bt = jnp.asarray(1 + rng.permutation(B * nb).reshape(B, nb), jnp.int32)
    lengths = jnp.asarray([5, 11], jnp.int32)
    out = ops.paged_attention(q, kp, vp, bt, lengths, use_kernel=True,
                              interpret=True)
    ref = paged_attention_ref(q.reshape(B, hkv, G, D), kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.reshape(B, hkv * G, D)),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError):
        wide = jnp.zeros((B * nb + 1, page, hkv, D), jnp.float32)
        ops.paged_attention(q, wide, wide, bt, lengths,
                            k_scale=jnp.ones(hkv))


# ====================== quantized parameter trees ===========================


def test_quantize_params_tree_walk():
    """Projections quantize (incl. scan-stacked groups), norms /
    embeddings / MoE banks / recurrent mixers stay wide."""
    cfg = _cfg("recurrentgemma-9b")           # hybrid: attn + recurrent
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    qparams = quantize_params(params)
    stacked = qparams["layers"][0]
    found = []
    for g in qparams["layers"]:
        for key, leaf in g["mixer"].items():
            if isinstance(leaf, QuantizedTensor):
                found.append(key)
    assert "wq" in found and "wo" in found    # attention group quantized
    assert not any(isinstance(v, QuantizedTensor)
                   for g in qparams["layers"]
                   for v in g["norm1"].values())
    assert not isinstance(qparams["embed"]["embedding"], QuantizedTensor)
    # stacked weights carry per-(group, channel) scales
    wq = next(g["mixer"]["wq"] for g in qparams["layers"]
              if isinstance(g["mixer"].get("wq"), QuantizedTensor))
    assert wq.scale.shape == (wq.q.shape[0], 1, wq.q.shape[2])
    qb, db = quantized_bytes(qparams)
    assert qb < db                            # the containers save bytes

    moe = _cfg("phi3.5-moe-42b-a6.6b")
    mo_params = T.init_params(moe, jax.random.PRNGKey(0))
    mo_q = quantize_params(mo_params)
    ffn = mo_q["layers"][0]["ffn"]
    assert not any(isinstance(v, QuantizedTensor) for v in ffn.values())

    # round trip: dequantize_params restores a plain-array tree
    widened = dequantize_params(qparams, jnp.float32)
    assert not any(isinstance(x, QuantizedTensor)
                   for x in jax.tree.leaves(
                       widened,
                       is_leaf=lambda x: isinstance(x, QuantizedTensor)))


def test_quantized_model_tracks_fp_logits():
    """logit_report: w8 weights keep top-1 agreement on the reduced
    config — the fake-quant accuracy gate."""
    cfg = _cfg("granite-3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    qparams = quantize_params(params)
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab, (2, 12)).astype(np.int32)
    rep = logit_report(cfg, params, qparams, tokens)
    assert rep["top1_agreement"] >= 0.9
    assert rep["rel_err"] < 0.05


# ======================== quantized serving path ============================


def test_w8_engine_matches_fake_quant_reference_tokens():
    """DecodeEngine with QuantizedTensor weights == the same engine on
    the dequantized (fake-quant) tree, token for token."""
    from repro.serve.engine import DecodeEngine, ServeConfig
    cfg = _cfg("granite-3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    qparams = quantize_params(params)
    fq = dequantize_params(qparams, jnp.float32)
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab, (2, 6)).astype(np.int32)
    ref = DecodeEngine(cfg, fq, ServeConfig(max_seq=24)).generate(
        prompts, 5)
    got = DecodeEngine(cfg, qparams, ServeConfig(max_seq=24)).generate(
        prompts, 5)
    np.testing.assert_array_equal(ref, got)


def test_fp8_paged_engine_token_exact_vs_fp8_dense():
    """Acceptance: fp8 paged decode (Pallas fp8 kernel forced on) stays
    token-exact against the fp8 dense path."""
    from repro.serve.engine import (DecodeEngine, PagedEngine,
                                    PagedServeConfig, ServeConfig)
    cfg = dataclasses.replace(_cfg("granite-3-8b"),
                              kv_cache_dtype=jnp.float8_e4m3fn)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
               for L in (5, 9)]
    dense = DecodeEngine(cfg, params, ServeConfig(max_seq=32))
    ref = [dense.generate(p[None, :], 6)[0] for p in prompts]
    paged = PagedEngine(cfg, params, PagedServeConfig(
        max_seq=32, max_batch=2, page_size=8, decode_chunk=3,
        use_kernel=True, interpret=True))
    out = paged.generate(prompts, 6)
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(a, b)


def test_choose_page_size_uses_fp8_schedule_key(tmp_path):
    """An fp8 KV cache sizes its pages under "flash_decode_fp8" — a
    tuned fp8 entry must dictate the layout while the wide key's entry
    is ignored."""
    from repro.serve import kv_cache as KV
    from repro.tune import OpSpec, Schedule, ScheduleCache
    cfg = _cfg("granite-3-8b")
    g = cfg.n_heads // cfg.n_kv_heads
    cache = ScheduleCache(str(tmp_path / "schedules.json"))
    dims = (g, 64, cfg.head_dim)
    cache.store(Schedule(OpSpec("flash_decode", dims, "float32"), (16,),
                         source="measured"))
    cache.store(Schedule(OpSpec("flash_decode_fp8", dims, "float32"), (32,),
                         source="measured"))
    assert KV.choose_page_size(cfg, 64, cache=cache) == 16
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype=jnp.float8_e4m3fn)
    assert KV.choose_page_size(cfg8, 64, cache=cache) == 32


# ===================== kv_cache_dtype validation ============================


def test_kv_cache_dtype_validated_at_construction():
    cfg = _cfg("granite-3-8b")
    # the launch/dryrun.py --kv8 path: replace() must revalidate and pass
    ok = dataclasses.replace(cfg, kv_cache_dtype=jnp.float8_e4m3fn)
    assert jnp.dtype(ok.kv_cache_dtype).itemsize == 1
    for good in (jnp.float8_e5m2, jnp.bfloat16, jnp.float16, jnp.float32):
        dataclasses.replace(cfg, kv_cache_dtype=good)
    for bad in (jnp.int8, jnp.int32, jnp.float64, "not-a-dtype", object()):
        with pytest.raises(ValueError, match="kv_cache_dtype"):
            dataclasses.replace(cfg, kv_cache_dtype=bad)
