"""Per-arch smoke tests + decode consistency (reduced configs, CPU)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_reduced
from repro.models import transformer as T
from repro.models.base import build
from repro.models.config import ModelConfig

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg: ModelConfig, b=2, s=16, rng_seed=3):
    rng = np.random.default_rng(rng_seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                              jnp.int32),
    }
    if cfg.is_encdec:
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)) * 0.1,
            cfg.dtype)
    if cfg.prefix_tokens:
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.prefix_tokens, cfg.d_model)) * 0.1,
            cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_loss(arch):
    """One forward/loss step on the reduced config: shapes + finiteness."""
    cfg = get_reduced(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    h, aux = T.forward(cfg, params, batch["tokens"],
                       prefix_embeds=batch.get("prefix_embeds"),
                       enc_embeds=batch.get("enc_embeds"))
    expect_s = 16 + (cfg.prefix_tokens or 0)
    assert h.shape == (2, expect_s, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))
    loss, metrics = T.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step_no_nan(arch):
    from repro.optim import adamw
    from repro.train.loop import TrainConfig, make_train_step
    cfg = get_reduced(arch)
    if cfg.n_experts:  # avoid capacity-drop nondeterminism in grads
        cfg = dataclasses.replace(cfg, capacity_factor=4.0)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_state(params)
    step = make_train_step(cfg, TrainConfig())
    params, opt, metrics = step(params, opt, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch):
    """prefill + token-by-token decode == full forward (f32)."""
    cfg = dataclasses.replace(get_reduced(arch), dtype=jnp.float32,
                              capacity_factor=8.0)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    B, S, S0 = 2, 12, 6
    MAX = 16 + cfg.prefix_tokens
    batch = _batch(cfg, B, S)
    toks = batch["tokens"]
    extra = {k: batch[k] for k in ("enc_embeds", "prefix_embeds")
             if k in batch}
    h, _ = T.forward(cfg, params, toks, **{
        "prefix_embeds": extra.get("prefix_embeds"),
        "enc_embeds": extra.get("enc_embeds")})
    full_logits = T.logits_fn(cfg, params, h)
    if cfg.prefix_tokens:
        full_logits = full_logits[:, cfg.prefix_tokens:]
    logits, cache = T.prefill(cfg, params, toks[:, :S0], MAX,
                              prefix_embeds=extra.get("prefix_embeds"),
                              enc_embeds=extra.get("enc_embeds"))
    errs = [float(jnp.max(jnp.abs(logits - full_logits[:, S0 - 1, :])))]
    for t in range(S0, S):
        logits, cache = T.decode_step(cfg, params, toks[:, t], cache,
                                      jnp.int32(t + cfg.prefix_tokens))
        errs.append(float(jnp.max(jnp.abs(
            logits - full_logits[:, t, :]))))
    assert max(errs) < 5e-4, (arch, errs)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_spec_tree_matches_shape_tree(arch):
    """Shapes/specs built from the same defs can never diverge — but the
    full configs must also have every sharded dim divisible."""
    cfg = get_config(arch)
    for model_ax in (16,):
        shapes = T.param_shapes(cfg, model_ax)
        specs = T.param_specs(cfg, model_ax)
        from jax.sharding import PartitionSpec
        flat_sh = jax.tree.leaves(shapes)
        flat_sp, _ = jax.tree_util.tree_flatten(
            specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
        assert len(flat_sh) == len(flat_sp)
        axis_sizes = {"model": 16, "data": 16}
        for s, p in zip(flat_sh, flat_sp):
            for dim, ax in zip(s.shape, tuple(p) + (None,) * 10):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                div = 1
                for a in axes:
                    div *= axis_sizes[a]
                assert dim % div == 0, (arch, s.shape, tuple(p))


def test_param_count_within_family_budget():
    """Sanity: full-config parameter counts are in the advertised range."""
    expect = {
        "granite-3-8b": (7e9, 10e9),
        "glm4-9b": (8e9, 11e9),
        "granite-34b": (30e9, 38e9),
        "gemma2-9b": (8e9, 11.5e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "recurrentgemma-9b": (7.5e9, 11e9),
        "phi-3-vision-4.2b": (3.5e9, 4.6e9),
        "seamless-m4t-medium": (0.5e9, 1.8e9),  # backbone only (stub
                                                 # frontend per assignment)
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n:.3e}")


def test_moe_active_params_less_than_total():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert cfg.active_param_count() < 0.2 * cfg.param_count()


def test_gemma2_softcap_bounds_logits():
    cfg = dataclasses.replace(get_reduced("gemma2-9b"), dtype=jnp.float32)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    h, _ = T.forward(cfg, params, batch["tokens"] )
    logits = T.logits_fn(cfg, params, h)
    assert float(jnp.max(jnp.abs(logits))) <= 30.0 + 1e-3


def test_local_window_masks_context():
    """gemma2 local layer must ignore tokens beyond the window."""
    cfg = dataclasses.replace(
        get_reduced("gemma2-9b"), dtype=jnp.float32,
        layer_pattern=("local",), n_layers=1, window=4)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    t1 = jnp.asarray(np.arange(12)[None] % cfg.vocab, jnp.int32)
    t2 = t1.at[:, 0].set(7)  # perturb a token far outside any window
    h1, _ = T.forward(cfg, params, t1)
    h2, _ = T.forward(cfg, params, t2)
    # position 11 attends to positions 8..11 only -> unaffected
    np.testing.assert_allclose(h1[:, 11], h2[:, 11], atol=1e-5)


def test_fp8_kv_cache_decode_close_to_bf16():
    """§Perf it.4: fp8 KV storage must stay close to the f32 decode path
    (it's a cache quantization, not a recompute change)."""
    base = dataclasses.replace(get_reduced("granite-3-8b"),
                               dtype=jnp.float32)
    quant = dataclasses.replace(base, kv_cache_dtype=jnp.float8_e4m3fn)
    params = T.init_params(base, jax.random.PRNGKey(1))
    B, S0, MAX = 2, 6, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 10), 0,
                              base.vocab)
    log_b, cache_b = T.prefill(base, params, toks[:, :S0], MAX)
    log_q, cache_q = T.prefill(quant, params, toks[:, :S0], MAX)
    for t in range(S0, 10):
        log_b, cache_b = T.decode_step(base, params, toks[:, t], cache_b,
                                       jnp.int32(t))
        log_q, cache_q = T.decode_step(quant, params, toks[:, t], cache_q,
                                       jnp.int32(t))
    # fp8 e4m3 has ~2 decimal digits; logits must track within ~5%
    denom = jnp.maximum(jnp.max(jnp.abs(log_b)), 1.0)
    rel = float(jnp.max(jnp.abs(log_b - log_q)) / denom)
    assert rel < 0.05, rel
    # and the cache really is fp8
    leaf = jax.tree.leaves(cache_q["layers"][0]["k"])[0] \
        if isinstance(cache_q["layers"][0]["k"], dict) \
        else cache_q["layers"][0]["k"]
    assert leaf.dtype == jnp.float8_e4m3fn
