"""Gradient-oracle harness: jax.grad through the blocked ops vs the jnp
oracles (interpret mode).

Every op in ``repro.kernels.ops`` carries a custom_vjp whose backward is
itself a Pallas kernel under a tuned schedule; these tests pin both the
forward values and the VJP cotangents against the references, on

* clean-tiling shapes (the Pallas fwd AND bwd kernels run),
* ragged shapes (the oracle fallbacks must engage on either side), and
* strided convs (dgrad's input dilation, wgrad's strided patches),

and finish with a reduced-config train step end-to-end through the
blocked VJPs (the ISSUE 2 acceptance gate).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.conv2d_bwd import conv2d_dgrad, conv2d_wgrad
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul_bwd import matmul_dgrad_a, matmul_dgrad_b

RNG = np.random.default_rng(7)
TOL = dict(rtol=1e-4, atol=1e-4)


def rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


def grads_match(f_kernel, f_ref, args, tol=TOL):
    out1, out2 = f_kernel(*args), f_ref(*args)
    np.testing.assert_allclose(out1, out2, **tol)
    argnums = tuple(range(len(args)))
    g1 = jax.grad(lambda *a: jnp.sum(f_kernel(*a) ** 2), argnums)(*args)
    g2 = jax.grad(lambda *a: jnp.sum(f_ref(*a) ** 2), argnums)(*args)
    for got, want in zip(g1, g2):
        np.testing.assert_allclose(got, want, **tol)


# ------------------------------- matmul ------------------------------------


@pytest.mark.parametrize("m,k,n", [
    (64, 128, 64),     # clean tiling -> dgrad Pallas kernels
    (32, 32, 32),
    (257, 64, 64),     # ragged M -> oracle fallback fwd AND bwd
    (64, 65, 33),      # ragged everything
])
def test_matmul_grad_vs_oracle(m, k, n):
    a, b = rand((m, k)), rand((k, n))
    grads_match(lambda a, b: ops.matmul(a, b, interpret=True),
                ref.matmul_ref, (a, b))


def test_matmul_dgrad_kernels_direct():
    """The NT/TN kernels against plain transposed GEMMs."""
    g, b = rand((64, 32)), rand((48, 32))
    da = matmul_dgrad_a(g, b, bm=32, br=32, bo=16, interpret=True)
    np.testing.assert_allclose(da, g @ b.T, **TOL)
    a, g2 = rand((64, 48)), rand((64, 32))
    db = matmul_dgrad_b(a, g2, bk=16, br=32, bn=32, interpret=True)
    np.testing.assert_allclose(db, a.T @ g2, **TOL)


def test_matmul_vjp_cotangents():
    """Explicit jax.vjp cotangents, not just grad-of-scalar."""
    a, b = rand((32, 64)), rand((64, 32))
    g = rand((32, 32))
    _, vjp_k = jax.vjp(lambda a, b: ops.matmul(a, b, interpret=True), a, b)
    _, vjp_r = jax.vjp(ref.matmul_ref, a, b)
    for got, want in zip(vjp_k(g), vjp_r(g)):
        np.testing.assert_allclose(got, want, **TOL)


# -------------------------------- conv2d -----------------------------------


@pytest.mark.parametrize("n,h,w,c,k,fh,fw,stride", [
    (2, 10, 10, 4, 8, 3, 3, 1),    # clean channels -> Pallas bwd
    (1, 8, 8, 4, 8, 1, 1, 1),      # 1x1 conv == GEMM nest
    (1, 14, 14, 4, 8, 3, 3, 2),    # strided: dilated dgrad, strided wgrad
    (1, 11, 11, 4, 8, 3, 3, 2),    # strided WITH remainder rows/cols
    (2, 9, 9, 3, 5, 2, 2, 1),      # ragged channels -> oracle fallback
])
def test_conv2d_grad_vs_oracle(n, h, w, c, k, fh, fw, stride):
    x = rand((n, h, w, c))
    wgt = rand((fh, fw, c, k), scale=0.5)
    grads_match(lambda x, w: ops.conv2d(x, w, stride=stride, interpret=True),
                lambda x, w: ref.conv2d_ref(x, w, stride), (x, wgt))


@pytest.mark.parametrize("stride", [1, 2])
def test_conv2d_wgrad_driver_vs_ref(stride):
    x = rand((2, 12, 12, 4))
    oh = (12 - 3) // stride + 1
    g = rand((2, oh, oh, 8))
    got = conv2d_wgrad(x, g, 3, 3, stride=stride, interpret=True)
    want = ref.conv2d_wgrad_ref(x, g, (3, 3, 4, 8), stride)
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("stride", [1, 2])
def test_conv2d_dgrad_driver_vs_ref(stride):
    w = rand((3, 3, 4, 8), scale=0.5)
    oh = (12 - 3) // stride + 1
    g = rand((2, oh, oh, 8))
    got = conv2d_dgrad(g, w, (2, 12, 12, 4), stride=stride, interpret=True)
    want = ref.conv2d_dgrad_ref(g, w, (2, 12, 12, 4), stride)
    np.testing.assert_allclose(got, want, **TOL)


def test_conv2d_wgrad_spatially_tiled():
    """Pinned spatial tiles force the level-1 reduction loop (4 tiles)."""
    x = rand((1, 14, 14, 4))
    g = rand((1, 12, 12, 8))
    got = conv2d_wgrad(x, g, 3, 3, tiles=(6, 6, 4, 8), interpret=True)
    want = ref.conv2d_wgrad_ref(x, g, (3, 3, 4, 8))
    np.testing.assert_allclose(got, want, **TOL)


# ------------------------------- attention ---------------------------------


@pytest.mark.parametrize("sq,skv,causal,window,cap", [
    (32, 32, True, None, None),
    (32, 32, False, None, None),
    (16, 64, True, None, None),     # decode-ish kv_offset
    (32, 32, True, 16, None),       # sliding window
    (32, 32, True, None, 20.0),     # gemma-2 softcap
])
def test_flash_attention_grad_vs_oracle(sq, skv, causal, window, cap):
    q, k, v = rand((sq, 16)), rand((skv, 16)), rand((skv, 16))
    grads_match(
        lambda q, k, v: flash_attention(q, k, v, causal=causal,
                                        window=window, logit_cap=cap,
                                        block_q=8, block_kv=16,
                                        interpret=True),
        lambda q, k, v: ref.attention_ref(q, k, v, causal=causal,
                                          window=window, logit_cap=cap),
        (q, k, v), tol=dict(rtol=2e-3, atol=2e-4))


def test_ops_attention_grad_gqa():
    """Batched GQA attention: grads flow through the vmapped Pallas VJP."""
    q, k, v = rand((2, 16, 4, 8)), rand((2, 16, 2, 8)), rand((2, 16, 2, 8))

    def f_kernel(q, k, v):
        return jnp.sum(ops.attention(q, k, v, tiles=(8, 8),
                                     interpret=True) ** 2)

    def f_ref(q, k, v):
        outs = []
        for bi in range(2):
            for h in range(4):
                outs.append(ref.attention_ref(q[bi, :, h], k[bi, :, h // 2],
                                              v[bi, :, h // 2]))
        return sum(jnp.sum(o ** 2) for o in outs)

    g1 = jax.grad(f_kernel, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, (0, 1, 2))(q, k, v)
    for got, want in zip(g1, g2):
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=3e-4)


def test_flash_attention_grad_ragged_falls_back():
    """ops.attention on a non-tiling Skv takes the jnp path — grads must
    still exist and match the oracle."""
    q, k, v = rand((1, 24, 2, 8)), rand((1, 24, 2, 8)), rand((1, 24, 2, 8))

    def f(q, k, v):
        return jnp.sum(ops.attention(q, k, v, tiles=(16, 16),
                                     interpret=True) ** 2)

    def fr(q, k, v):
        outs = [ref.attention_ref(q[0, :, h], k[0, :, h], v[0, :, h])
                for h in range(2)]
        return sum(jnp.sum(o ** 2) for o in outs)

    g1 = jax.grad(f, (0, 1, 2))(q, k, v)
    g2 = jax.grad(fr, (0, 1, 2))(q, k, v)
    for got, want in zip(g1, g2):
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=3e-4)


# ----------------------- blocked training smoke ----------------------------


def test_train_step_through_blocked_vjps():
    """One reduced-config train step with tc.blocked_linear: projections
    and attention run the Pallas kernels fwd AND bwd (interpret mode),
    and the resulting update matches the plain-XLA step."""
    from repro.data.pipeline import make_batch
    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.optim import adamw
    from repro.train.loop import TrainConfig, make_train_step

    cfg = ModelConfig(name="tiny-blocked", family="dense", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab=128, dtype=jnp.float32)
    batch = make_batch(cfg, 16, 2, 0)

    losses = {}
    grads = {}
    for blocked in (True, False):
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init_state(params)
        step = jax.jit(make_train_step(
            cfg, TrainConfig(blocked_linear=blocked)))
        params, opt, m = step(params, opt, batch)
        losses[blocked] = float(m["loss"])
        grads[blocked] = float(m.get("grad_norm", 0.0))
        assert np.isfinite(losses[blocked])
    assert abs(losses[True] - losses[False]) < 1e-3, losses
    assert abs(grads[True] - grads[False]) < 1e-2, grads
