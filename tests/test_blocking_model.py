"""Unit tests for the analytical blocking model (paper §3)."""

import math

import pytest

from repro.core import (BlockingString, Dim, Loop, Problem, analyze,
                        energy_custom, energy_fixed, diannao_hierarchy,
                        xeon_hierarchy, place_buffers, table2_refetch_rate,
                        access_energy_pj, Operand, optimize_exhaustive,
                        make_objective, cache_accesses)
from repro.core.validate import simulate_fills

SMALL = Problem(X=4, Y=4, C=4, K=8, Fw=3, Fh=3)


def test_parse_roundtrip():
    s = BlockingString.parse("Fw3 Fh3 X2 Y2 C2 K2 X4 Y4 C4 K8", SMALL)
    assert repr(s) == "Fw3 Fh3 X2 Y2 C2 K2 X4 Y4 C4 K8"
    assert s.total_iterations() == SMALL.macs // 1


def test_validation_rejects_partial_coverage():
    with pytest.raises(ValueError):
        BlockingString.parse("Fw3 Fh3 X4 Y4 C4 K4", SMALL)  # K only to 4


def test_validation_rejects_non_multiple():
    with pytest.raises(ValueError):
        BlockingString.parse("Fw3 Fh3 X3 Y4 C4 K8 X4", SMALL)


def test_buffer_placement_rules():
    s = BlockingString.parse("Fw3 Fh3 X2 Y2 C2 K2 X4 Y4 C4 K8", SMALL)
    bufs = {b.name: b for b in place_buffers(s)}
    # K2 loop (pos 5) must have placed an input buffer below it
    assert any(b.operand == Operand.INPUT and b.pos == 5
               for b in bufs.values())
    # C2 loop (pos 4) -> output buffer
    assert any(b.operand == Operand.OUTPUT and b.pos == 4
               for b in bufs.values())
    # X4 loop (pos 6) -> kernel buffer
    assert any(b.operand == Operand.WEIGHT and b.pos == 6
               for b in bufs.values())


def test_table2_kb_refetch_rate():
    # KB refetch at an X loop = X_i / X_{i-1} (paper Table 2)
    s = BlockingString.parse("Fw3 Fh3 X2 Y4 C4 K8 X4", SMALL)
    rr = table2_refetch_rate(s, 6, Operand.WEIGHT)
    assert rr == 4 / 2


def test_table2_ob_refetch_rate():
    s = BlockingString.parse("Fw3 Fh3 X4 Y4 C2 K8 C4", SMALL)
    rr = table2_refetch_rate(s, 6, Operand.OUTPUT)
    assert rr == 2 * 4 / 2


@pytest.mark.parametrize("text,problem", [
    ("Fw3 Fh3 X2 Y2 C2 K2 X4 Y4 C4 K8", SMALL),
    ("X2 C2 K2 Fw3 Fh3 Y4 X4 C4 K8", SMALL),
    ("Fw3 Fh3 K8 C4 Y4 X4", SMALL),
    ("C2 X3 K2 C4 X6 K4 N2",
     Problem(X=6, Y=1, C=4, K=4, Fw=1, Fh=1, N=2)),
    ("Fw2 K2 Fh2 C2 Y2 X2 K4 C4 X4 Y4 K8",
     Problem(X=4, Y=4, C=4, K=8, Fw=2, Fh=2)),
])
def test_access_model_matches_simulation(text, problem):
    """The closed-form access counts must equal observed eviction events."""
    s = BlockingString.parse(text, problem)
    rep = analyze(s)
    sim = simulate_fills(s)
    for bt in rep.per_buffer:
        if bt.buffer.pos < 0:
            continue
        sf, sw = sim[bt.buffer.name]
        assert sf == bt.fills, (bt.buffer.name, sf, bt.fills)
        assert sw == bt.writebacks, (bt.buffer.name, sw, bt.writebacks)


def test_dram_accesses_at_least_compulsory():
    """DRAM traffic can never go below one visit per element."""
    s = BlockingString.parse("Fw3 Fh3 X4 Y4 C4 K8", SMALL)
    rep = analyze(s)
    assert rep.dram_accesses_by_operand[Operand.WEIGHT] >= \
        SMALL.weight_elems
    assert rep.dram_accesses_by_operand[Operand.OUTPUT] >= \
        SMALL.output_elems


def test_energy_table_monotone_in_size():
    sizes = [512, 2**10, 2**13, 2**17, 2**20, 2**23]
    es = [access_energy_pj(s) for s in sizes]
    assert all(a <= b * 1.0001 for a, b in zip(es, es[1:])), es


def test_energy_dram_plateau():
    assert access_energy_pj(64 * 1024 * 1024) == 320.0


def test_optimizer_beats_naive_schedule():
    p = Problem(X=16, Y=16, C=16, K=32, Fw=3, Fh=3)
    naive = BlockingString.parse("Fw3 Fh3 X16 Y16 C16 K32", p)
    naive_e = energy_custom(naive).total_pj
    best = optimize_exhaustive(p, make_objective("custom"), n_levels=2,
                               top=1, max_orders=8)[0]
    assert best.report.total_pj <= naive_e


def test_fixed_hierarchy_packing():
    s = BlockingString.parse("Fw3 Fh3 X2 Y2 C2 K2 X4 Y4 C4 K8", SMALL)
    counts = cache_accesses(s, xeon_hierarchy())
    assert counts["L1"] > counts["L2"] >= 0
    assert counts["DRAM"] > 0


def test_diannao_hierarchy_shape():
    levels = diannao_hierarchy()
    assert [l.name for l in levels] == ["IBuf", "KBuf", "OBuf", "DRAM"]
