"""Per-kernel roofline + energy profiler: kernel-exact byte accounting,
energy pricing, the model-fidelity gate, and the training-loop telemetry
threading (docs/observability.md).

The hypothesis sweep over (shape, tile) space lives in
test_property_profile.py; the equality cases here are deterministic so
the invariant stays covered on minimal installs too.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from repro import tune
from repro.configs import get_reduced
from repro.core.energy import DRAM_PJ_PER_16B
from repro.obs import (DramLedger, KernelProfiler, MetricsRegistry, Obs,
                       StepTracer, kernel_hbm_bytes, read_miss_log)
from repro.obs.energy import op_energy_pj
from repro.profile import CorruptScheduleCache
from repro.tune import level0_dram_bytes
from repro.tune.schedule import OpSpec


# ================ kernel accounting == model level-0 traffic ================


@pytest.mark.parametrize("op,dims,dtype,tiles", [
    ("matmul", (256, 512, 256), "float32", (64, 128, 256)),
    ("matmul", (128, 256, 512), "bfloat16", (128, 64, 64)),
    ("matmul_dgrad", (512, 512, 512), "bfloat16", (256, 512, 128)),
    ("matmul_fused", (256, 512, 256), "bfloat16", (64, 64, 512)),
    ("qkv_fused", (128, 64, 256, 4), "bfloat16", (64, 128, 64)),
    ("qkv_fused", (256, 128, 256, 2), "float32", (128, 256, 128)),
    ("flash_decode", (8, 1024, 128), "bfloat16", (128,)),
    ("flash_decode", (4, 2048, 64), "float32", (512,)),
    ("flash_decode_fp8", (8, 1024, 128), "bfloat16", (256,)),
])
def test_kernel_bytes_equal_model_level0(op, dims, dtype, tiles):
    """The kernels' exported grid-transfer accounting and the core
    model's level-0 DRAM traffic agree exactly on dividing tiles — the
    contract the profiler's fidelity gate rests on."""
    spec = OpSpec(op, dims, dtype)
    assert kernel_hbm_bytes(spec, tiles) == level0_dram_bytes(spec, tiles)


def test_w8_kernel_bytes_exceed_model_by_scale_row_only():
    """matmul_w8 streams a per-N fp32 dequant scale row the model's
    operand set doesn't contain; everything else must match."""
    M, N, K = 256, 512, 256
    spec = OpSpec("matmul_w8", (M, N, K), "bfloat16")
    for tiles in [(64, 128, 256), (256, 256, 512), (128, 64, 128)]:
        gm, gn = M // tiles[0], N // tiles[2]
        scale = N * 4 * (gm if gn > 1 else 1)
        assert kernel_hbm_bytes(spec, tiles) - scale == \
            level0_dram_bytes(spec, tiles)


def test_kernel_bytes_none_on_fallback_tiles():
    assert kernel_hbm_bytes(OpSpec("matmul", (128, 128, 128)),
                            (96, 64, 64)) is None


# ============================ energy pricing ================================


def test_op_energy_pj_components_and_units():
    spec = OpSpec("matmul", (256, 256, 256), "bfloat16")
    tiles = (128, 128, 128)
    dram_b = kernel_hbm_bytes(spec, tiles)
    e = op_energy_pj(spec, tiles, dram_b)
    # DRAM term prices the measured bytes at 320 pJ per 16-bit word
    assert e["dram_pj"] == pytest.approx(dram_b / 2.0 * DRAM_PJ_PER_16B)
    assert e["sram_pj"] >= 0.0 and e["mac_pj"] > 0.0
    assert e["total_pj"] == pytest.approx(
        e["dram_pj"] + e["sram_pj"] + e["mac_pj"])
    assert e["pj_per_mac"] == pytest.approx(e["total_pj"] / spec.problem().macs)
    # per-MAC cost is bounded below by the MAC energy itself
    assert e["pj_per_mac"] > 1.0
    assert op_energy_pj(spec, (96, 64, 64), None) is None


# ===================== profiler roofline aggregation ========================


def test_profiler_rooflines_observed_resolutions():
    reg = MetricsRegistry()
    prof = KernelProfiler(registry=reg)
    with prof.scope("gemm[64]"):        # first execution traces: resolution
        tune.best_schedule("matmul", (64, 64, 64))
    with prof.scope("gemm[64]"):        # steady state: no re-resolution
        pass
    prof.end_step([0])
    rep = prof.roofline_report()
    (key,) = rep["per_op"]
    assert key.startswith("matmul/m64n64k64/")
    row = rep["per_op"][key]
    # one dispatch site per trace x two scope executions
    assert row["dispatches"] == 2
    assert row["hbm_bytes"] == 2 * kernel_hbm_bytes(
        OpSpec("matmul", (64, 64, 64)), tuple(row["tiles"]))
    assert row["flops"] == 2 * (64 ** 3) * 2
    assert row["intensity_flops_per_byte"] > 0
    assert row["energy_pj"] > 0
    # analytic resolution: resolved tiles ARE the model winner
    assert row["source"] == "analytic"
    assert row["fidelity_ratio"] == pytest.approx(1.0)
    assert rep["fidelity_misses"] == []
    assert row["time_us"] > 0 and row["bound"] in ("memory", "compute")
    assert 0 <= row["peak_frac"] <= 1.0   # host-only scope: ~0 of peak
    t = rep["totals"]
    assert t["dispatches"] == 2 and t["hbm_bytes"] == row["hbm_bytes"]
    assert t["energy_uj"] == pytest.approx(row["energy_pj"] / 1e6, abs=1e-3)
    # the full report nests the ledger view plus the roofline, JSON-safe
    full = prof.report()
    assert full["per_op"][key]["ratio"] == pytest.approx(1.0)
    json.dumps(full)
    text = prof.format_roofline()
    assert key in text and "TOTAL" in text


def test_format_roofline_empty_profiler_is_safe():
    assert isinstance(KernelProfiler().format_roofline(), str)


# ========================= model-fidelity gate ==============================


def test_fidelity_gate_routes_corrupt_schedule_to_miss_log(tmp_path, capsys):
    miss = tmp_path / "miss.jsonl"
    prof = KernelProfiler(miss_log=str(miss), fidelity_threshold=0.05)
    spec = OpSpec("matmul_fused", (8, 1024, 256))
    bad = CorruptScheduleCache("matmul").lookup(spec)
    assert bad is not None and bad.source == "cache"
    with prof.scope("decode[8]"):
        prof.record(spec, bad)
    rep = prof.roofline_report()
    (key,) = rep["fidelity_misses"]
    assert key.startswith("matmul_fused/m8n1024k256/")
    assert rep["per_op"][key]["fidelity_ratio"] > 1.05
    prof.close()
    # the miss-log line keeps the corrupt tiles and cache provenance
    (line,) = [json.loads(l) for l in miss.read_text().splitlines()]
    assert line["source"] == "cache"
    assert tuple(line["fallback_tiles"]) == bad.tiles
    # ...and replays as a tuning target through the normal loop
    assert read_miss_log(str(miss)) == [
        {"op": "matmul_fused", "dims": [8, 1024, 256],
         "dtype": "float32", "stride": 1}]
    from repro.tune.__main__ import main as tune_main
    tune_main(["--from-telemetry", str(miss), "--dry-run"])
    assert "would tune matmul_fused/" in capsys.readouterr().out


def test_fidelity_gate_quiet_on_analytic_resolutions(tmp_path):
    miss = tmp_path / "miss.jsonl"
    prof = KernelProfiler(miss_log=str(miss), fidelity_threshold=0.05)
    with prof.scope("gemm"):
        tune.best_schedule("matmul", (64, 64, 64))
    assert prof.roofline_report()["fidelity_misses"] == []
    prof.close()
    # the plain cache-miss line still lands (base-ledger behavior)...
    targets = read_miss_log(str(miss))
    assert [t["op"] for t in targets] == ["matmul"]
    # ...exactly once: the gate never double-appends an analytic op
    assert len(miss.read_text().splitlines()) == 1


def test_set_default_cache_swaps_and_restores():
    spec_dims = (8, 1024, 256)
    prev = tune.set_default_cache(CorruptScheduleCache("matmul"))
    try:
        s = tune.best_schedule("matmul_fused", spec_dims)
        assert s.source == "cache"
        top = tune.candidates(OpSpec("matmul_fused", spec_dims))[0]
        assert s.tiles != top.tiles
    finally:
        tune.set_default_cache(prev)
    assert tune.best_schedule("matmul_fused", spec_dims).source != "cache"


# ====================== training-loop telemetry =============================


def _train_cfg():
    return dataclasses.replace(
        get_reduced("granite-3-8b"), dtype=jnp.float32, d_model=64,
        n_layers=2, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)


def _run_train(cfg, tmp_path, tag, obs=None, steps=4):
    from repro.data.pipeline import make_batch
    from repro.optim.adamw import AdamWConfig
    from repro.train.loop import TrainConfig, train
    tc = TrainConfig(
        opt=AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=steps),
        ckpt_dir=str(tmp_path / f"ckpt_{tag}"), ckpt_every=2)
    batches = (make_batch(cfg, 16, 2, step) for step in range(steps))
    return train(cfg, tc, batches, log=lambda *_: None, obs=obs)


def test_train_loop_telemetry_is_observation_not_perturbation(tmp_path):
    """Traced and untraced training produce bit-identical loss
    trajectories; the trace carries step/grad/checkpoint spans and the
    registry the loss/throughput/step-time series."""
    cfg = _train_cfg()
    r_off = _run_train(cfg, tmp_path, "off")

    trace = tmp_path / "train_trace.json"
    reg = MetricsRegistry()
    obs = Obs(registry=reg, trace=StepTracer(str(trace)), dram=DramLedger())
    r_on = _run_train(cfg, tmp_path, "on", obs=obs)
    obs.close()

    assert r_on["history"] == r_off["history"]
    events = json.loads(trace.read_text())
    names = {e["name"] for e in events}
    assert {"step 0", "step 3", "grad", "checkpoint", "train"} <= names
    # every grad span nests inside its step span
    steps = [e for e in events if e["name"].startswith("step ")]
    for g in (e for e in events if e["name"] == "grad"):
        assert any(s["ts"] - 1e-6 <= g["ts"] and
                   g["ts"] + g["dur"] <= s["ts"] + s["dur"] + 1e-6
                   for s in steps)
    ck = [e for e in events if e["name"] == "checkpoint"]
    assert [e["args"]["step"] for e in ck] == [2, 4]
    snap = reg.snapshot()
    assert snap["train"]["steps"] == 4
    assert snap["train"]["loss"] == pytest.approx(r_on["history"][-1])
    assert snap["train"]["tokens_per_s"] > 0
    assert snap["train"]["step_us"]["count"] == 4
