"""Substrate tests: optimizer, compression, checkpointing, data pipeline,
fault tolerance, elastic planning, serving."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, TokenStream, make_batch
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.compress import compress, compress_tree, decompress
from repro.train import checkpoint as ckpt
from repro.train.loop import StepWatchdog, TrainConfig, make_train_step


def test_adamw_minimizes_quadratic():
    c = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=200)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.apply_updates(c, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adamw_grad_clipping():
    c = adamw.AdamWConfig(lr=0.1, clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init_state(params)
    _, _, m = adamw.apply_updates(c, params, {"w": jnp.full(3, 1e6)}, state)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


def test_schedule_warmup_and_cosine():
    c = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                          min_lr_frac=0.1)
    assert float(adamw.schedule(c, jnp.int32(5))) == pytest.approx(0.5)
    assert float(adamw.schedule(c, jnp.int32(10))) == pytest.approx(1.0)
    assert float(adamw.schedule(c, jnp.int32(110))) == pytest.approx(0.1)


def test_compression_roundtrip_bounded():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)))
    q, scale = compress(g)
    err = jnp.max(jnp.abs(decompress(q, scale) - g))
    assert float(err) <= float(scale) * 0.5 + 1e-9


def test_compression_error_feedback_preserves_signal():
    """With error feedback, the SUM of applied gradients converges to the
    sum of true gradients (no permanent signal loss)."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(16)
    applied_sum = np.zeros(16)
    res = None
    for _ in range(50):
        g = {"w": jnp.asarray(rng.normal(size=16) * 1e-3)}
        true_sum += np.asarray(g["w"])
        deq, res = compress_tree(g, res)
        applied_sum += np.asarray(deq["w"])
    # residual carries the remaining difference
    gap = np.abs(true_sum - applied_sum - np.asarray(res["w"]))
    assert gap.max() < 1e-6


def test_compression_error_feedback_converges_sub_quantum_signal():
    """Residual accumulation over many steps CONVERGES: a constant
    gradient far below the quantization quantum (set by a dominant
    coordinate) emits zero on every single step without feedback, yet
    the error-feedback accumulator must deliver its full sum — cumulative
    applied = N * g up to ONE quantum, with the deficit live in the
    residual at every step (never growing, never lost)."""
    big, small, steps = 1.0, 1e-3, 200
    g = {"w": jnp.asarray([big, small, -small, 0.0], jnp.float32)}
    quantum = big / 127.0                     # per-tensor scale * 1 LSB
    assert small < 0.5 * quantum              # genuinely sub-quantum

    # no feedback: the small coords round to zero every step
    no_fb, _ = compress_tree(g, jax.tree.map(jnp.zeros_like, g))
    assert float(no_fb["w"][1]) == 0.0

    applied = np.zeros(4)
    res = None
    for step in range(1, steps + 1):
        deq, res = compress_tree(g, res)
        applied += np.asarray(deq["w"])
        # the residual stays bounded by one quantum at every step —
        # the accumulator converges instead of drifting
        assert np.abs(np.asarray(res["w"])).max() <= quantum + 1e-6, step
    target = np.asarray(g["w"]) * steps
    assert np.abs(applied - target).max() <= quantum + 1e-6
    # the sub-quantum coordinate actually came through (150+ quanta)
    assert applied[1] > 0.9 * small * steps


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.asarray(7, dtype=np.int32)}}
    ckpt.save(str(tmp_path), 5, tree)
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 5
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])


def test_checkpoint_corruption_falls_back(tmp_path):
    tree = {"a": np.zeros(4, np.float32)}
    ckpt.save(str(tmp_path), 1, tree)
    ckpt.save(str(tmp_path), 2, tree)
    # corrupt the newest payload
    with open(os.path.join(tmp_path, "step_00000002", "arrays.npz"),
              "r+b") as f:
        f.seek(10)
        f.write(b"\xde\xad")
    assert ckpt.latest_valid(str(tmp_path)) == 1


def test_checkpoint_prunes_old(tmp_path):
    tree = {"a": np.zeros(2, np.float32)}
    for s in range(6):
        ckpt.save(str(tmp_path), s, tree, keep=3)
    assert ckpt.latest_valid(str(tmp_path)) == 5
    assert len(os.listdir(tmp_path)) == 3


def test_pipeline_seekable_deterministic():
    dc = DataConfig(vocab=100, seq_len=32, global_batch=4, seed=7)
    s1, s2 = TokenStream(dc), TokenStream(dc)
    for step in (0, 3, 17):
        b1, b2 = s1.batch_at(step), s2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s1.batch_at(0)["tokens"],
                              s1.batch_at(1)["tokens"])


def test_pipeline_labels_shifted():
    dc = DataConfig(vocab=100, seq_len=16, global_batch=2, seed=0)
    b = TokenStream(dc).batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_restart_reproduces_trajectory(tmp_path):
    """Fault tolerance: train 6 steps; crash; restore at 3; steps 3-5 must
    produce bit-identical losses."""
    cfg = get_reduced("granite-3-8b")
    tc = TrainConfig(opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=0,
                                           total_steps=10))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_state(params)
    step_fn = jax.jit(make_train_step(cfg, tc))
    losses = []
    for step in range(6):
        batch = make_batch(cfg, 16, 4, step)
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
        if step == 2:
            ckpt.save(str(tmp_path), 3, {"params": params, "opt": opt})
    state, start = ckpt.restore(str(tmp_path),
                                {"params": params, "opt": opt})
    params2, opt2 = state["params"], state["opt"]
    for step in range(start, 6):
        batch = make_batch(cfg, 16, 4, step)
        params2, opt2, m = step_fn(params2, opt2, batch)
        assert float(m["loss"]) == pytest.approx(losses[step], abs=1e-6)


def test_watchdog_flags_stragglers():
    w = StepWatchdog(factor=3.0)
    for i in range(10):
        assert not w.observe(i, 0.1)
    assert w.observe(10, 1.0)
    assert w.flags == [10]


def test_elastic_mesh_plan():
    from repro.launch.elastic import plan_mesh
    from repro.configs import get_config
    cfg = get_config("granite-3-8b")
    full = plan_mesh(cfg, 256)
    assert full.shape == (16, 16)
    degraded = plan_mesh(cfg, 128)   # lost half the devices
    assert degraded.data * degraded.model == 128
    odd = plan_mesh(cfg, 7)          # pathological: prime count
    assert odd.data * odd.model == 7


def test_serve_engine_greedy_deterministic():
    from repro.serve.engine import DecodeEngine, ServeConfig
    cfg = dataclasses.replace(get_reduced("granite-3-8b"),
                              dtype=jnp.float32)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, ServeConfig(max_seq=64))
    prompts = np.arange(8, dtype=np.int32).reshape(2, 4) % cfg.vocab
    out1 = eng.generate(prompts, 8)
    out2 = eng.generate(prompts, 8)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (2, 8)
    assert (out1 >= 0).all() and (out1 < cfg.vocab).all()


def test_serve_matches_argmax_of_forward():
    """Greedy generation must equal argmax over the forward logits chain."""
    cfg = dataclasses.replace(get_reduced("mamba2-780m"),
                              dtype=jnp.float32)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    from repro.serve.engine import DecodeEngine, ServeConfig
    eng = DecodeEngine(cfg, params, ServeConfig(max_seq=32))
    prompts = np.asarray([[5, 9, 2, 11]], np.int32)
    out = eng.generate(prompts, 4)
    # replay: forward over growing sequence, take argmax each time
    seq = list(prompts[0])
    for i in range(4):
        h, _ = T.forward(cfg, params, jnp.asarray([seq], jnp.int32))
        logits = T.logits_fn(cfg, params, h)[0, -1, :cfg.vocab]
        nxt = int(jnp.argmax(logits))
        assert nxt == out[0, i], (i, nxt, out[0])
        seq.append(nxt)
