"""Serving invariants: decode-priority scheduler properties and
token-exactness of chunked prefill / speculative decode.

Two layers:

* **Scheduler properties** — a pure host-side simulation drives
  ``Scheduler.admit``/``plan_step``/``evict`` with random traces (no
  model, no device) and asserts the contracts the engine relies on:
  no page leaks, no decode starvation, no double-admission, and that
  aging eventually admits every queued request.  The hypothesis
  versions explore random traces (derandomized in CI via the conftest
  profile); deterministic twins keep the same assertions exercised on
  minimal installs where hypothesis is absent.

* **Token exactness** — chunked prefill and draft-verify speculative
  decode must be *byte-identical* to the whole-prompt-join greedy paged
  engine across the architecture families, including the fused and
  quantized compositions.  Chunking and speculation change scheduling
  and cost, never tokens.

* **Prefix sharing** — a second simulation layer attaches a
  :class:`~repro.serve.kv_cache.PrefixCache` and stamps page *contents*
  host-side, so random traces can assert the sharing contracts: every
  page's refcount equals its owning requests plus the tree's reference,
  no write ever lands on a shared or cached page (the frozen-blocks
  rule), tree spans stay page-aligned, the scratch page never enters
  the tree, and nothing leaks once the tree itself is dropped.  The
  engine-level differential tests then prove ``prefix_cache=True``
  generates byte-identical tokens to the unshared engine.

* **Preemption with restore** — a third simulation layer gives the sim
  deterministic per-(rid, position) emitted tokens, so random
  preempt-at-step-k schedules can assert the restored stream is
  byte-identical to the unpreempted run; the engine-level differential
  proves the same bar with device tokens across the arch families
  (docs/robustness.md).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve import kv_cache as KV
from repro.serve.engine import PagedEngine, PagedServeConfig
from repro.serve.scheduler import Request, Scheduler


def _cfg(arch: str):
    return dataclasses.replace(get_reduced(arch), dtype=jnp.float32)


# ===================== scheduler simulation harness =========================


class _Sim:
    """Drives a Scheduler the way the engine does — admit, plan, advance
    prefill/decode by the planned amounts, evict — while checking every
    step-level invariant.  Pure bookkeeping: no model runs."""

    def __init__(self, max_batch, page_size, n_pages, max_seq,
                 decode_chunk=4, prefill_chunk=4, age_limit=4):
        self.alloc = KV.PageAllocator(n_pages)
        self.sched = Scheduler(max_batch, page_size, self.alloc, max_seq,
                               age_limit=age_limit)
        self.decode_chunk = decode_chunk
        self.prefill_chunk = prefill_chunk
        self.admitted_rids: list[int] = []
        self.finished_rids: list[int] = []

    def submit(self, rid, prompt_len, max_new):
        self.sched.submit(
            Request(rid, np.zeros(prompt_len, np.int32), max_new))

    def step(self):
        for req in self.sched.admit():
            assert req.slot >= 0
            assert len(req.pages) == self.sched.pages_needed(req)
            # no double-admission: an admitted rid never reappears
            assert req.rid not in self.admitted_rids, "double admission"
            self.admitted_rids.append(req.rid)
        plan = self.sched.plan_step(self.decode_chunk, self.prefill_chunk)
        # no decode starvation: every decode-ready slot decodes NOW
        ready = {s for s, r in self.sched.running.items()
                 if r.decode_ready}
        assert set(plan.decode_slots) == ready, "decode-ready slot skipped"
        # prefill chunks only target admitted, unfinished-prefill slots
        for s in plan.prefill_slots:
            assert not self.sched.running[s].prefill_done
        if any(not r.prefill_done for r in self.sched.running.values()):
            assert plan.prefill_slots, "prefill starved at full load"
        # advance the simulated engine
        for s in plan.decode_slots:
            r = self.sched.running[s]
            r.generated += min(self.decode_chunk,
                               r.max_new_tokens - r.generated)
        for s in plan.prefill_slots:
            r = self.sched.running[s]
            r.prefilled += min(self.prefill_chunk,
                               r.prompt_len - r.prefilled)
            if r.prefill_done and r.generated == 0:
                r.generated = 1      # final chunk samples the first token
        for s in [s for s, r in self.sched.running.items() if r.done]:
            self.finished_rids.append(self.sched.evict(s).rid)
        self.check_pages()

    def check_pages(self):
        owned = [p for r in self.sched.running.values() for p in r.pages]
        assert len(owned) == len(set(owned)), "page double-owned"
        assert KV.SCRATCH_PAGE not in owned, "scratch page owned"
        assert self.alloc.in_use() == len(owned), "page leak"
        assert len(self.sched.running) <= self.sched.max_batch

    def drain(self, max_steps):
        """Run to completion; liveness bound = the aging guarantee."""
        steps = 0
        while self.sched.has_work:
            self.step()
            steps += 1
            assert steps <= max_steps, (
                f"scheduler failed to drain in {max_steps} steps: "
                f"waiting={[r.rid for r in self.sched.waiting]} "
                f"running={sorted(self.sched.running)}")
        assert self.alloc.available() == self.alloc.capacity, "leak at drain"


def _random_trace(rng, n_requests=12, max_batch=3, page_size=4,
                  n_pages=9, max_seq=24, **kw):
    sim = _Sim(max_batch, page_size, n_pages, max_seq, **kw)
    rid = 0
    for _ in range(n_requests):
        L = int(rng.integers(1, max_seq // 2 + 1))
        n = int(rng.integers(1, max_seq - L + 1))
        sim.submit(rid, L, n)
        rid += 1
        if rng.random() < 0.7:
            sim.step()
    sim.drain(max_steps=40 * n_requests)
    # aging/liveness: every submitted request was admitted and finished
    assert sorted(sim.finished_rids) == list(range(rid))
    return sim


# ------------------------- deterministic twins ------------------------------


def test_scheduler_trace_deterministic():
    """Random-trace properties under fixed seeds (runs everywhere, no
    hypothesis needed): leaks, starvation, double admission, liveness."""
    for seed in range(8):
        _random_trace(np.random.default_rng(seed))


def test_decode_priority_under_prefill_pressure():
    """A decode-ready slot keeps decoding every step while a long prompt
    chunk-prefills beside it."""
    sim = _Sim(max_batch=2, page_size=4, n_pages=20, max_seq=40,
               decode_chunk=2, prefill_chunk=4)
    sim.submit(0, prompt_len=4, max_new=20)     # quick to prefill
    sim.step()                                   # rid0 admitted + chunked
    while not sim.sched.running[0].prefill_done:
        sim.step()
    sim.submit(1, prompt_len=20, max_new=4)     # long prefill arrives
    gen_before = sim.sched.running[0].generated
    for _ in range(3):
        sim.step()
        if 0 not in sim.sched.running:           # rid0 finished
            break
        gen = sim.sched.running[0].generated
        assert gen > gen_before, "decode starved by prefill"
        gen_before = gen
    sim.drain(max_steps=100)


def test_aging_admits_starving_head():
    """A big request stuck behind page pressure is eventually admitted:
    once its age passes the limit, backfill stops stealing its pages."""
    sim = _Sim(max_batch=2, page_size=4, n_pages=9, max_seq=32,
               decode_chunk=1, prefill_chunk=4, age_limit=3)
    # 8 usable pages; the hog takes 6, the big head needs 8
    sim.submit(0, prompt_len=8, max_new=16)      # 6 pages
    sim.step()
    sim.submit(1, prompt_len=16, max_new=16)     # 8 pages: must wait
    small_done = 0
    for rid in range(2, 10):                     # stream of small fillers
        sim.submit(rid, prompt_len=2, max_new=2)  # 1 page each
    sim.drain(max_steps=400)
    assert sorted(sim.finished_rids) == list(range(10))
    # the big request did not come last by luck: it beat some fillers
    assert sim.finished_rids.index(1) < len(sim.finished_rids) - 1


def test_backfill_admits_past_blocked_head():
    """Head doesn't fit, a younger request does: the younger one is
    admitted (throughput), the head stays queued (not dropped)."""
    sim = _Sim(max_batch=2, page_size=4, n_pages=9, max_seq=32)
    sim.submit(0, prompt_len=8, max_new=16)      # 6 of 8 pages
    sim.step()
    sim.submit(1, prompt_len=16, max_new=16)     # 8 pages: blocked
    sim.submit(2, prompt_len=2, max_new=2)       # 1 page: fits
    sim.step()
    assert 1 in [r.rid for r in sim.sched.waiting]
    assert 2 in sim.admitted_rids
    sim.drain(max_steps=200)


# --------------------------- hypothesis layer -------------------------------


def test_scheduler_invariants_property():
    """Hypothesis-driven random traces over the full admit/plan/advance/
    evict cycle (CI runs this derandomized via the conftest profile)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(data=st.data())
    def run(data):
        page_size = data.draw(st.sampled_from([2, 4]))
        n_pages = data.draw(st.integers(4, 12))
        max_batch = data.draw(st.integers(1, 4))
        max_seq = page_size * (n_pages - 1)
        sim = _Sim(max_batch, page_size, n_pages, max_seq,
                   decode_chunk=data.draw(st.integers(1, 4)),
                   prefill_chunk=data.draw(st.sampled_from(
                       [page_size, 2 * page_size])),
                   age_limit=data.draw(st.integers(1, 4)))
        rid = 0
        for _ in range(data.draw(st.integers(1, 10))):
            L = data.draw(st.integers(1, max(1, max_seq // 2)))
            n = data.draw(st.integers(1, max_seq - L))
            sim.submit(rid, L, n)
            rid += 1
            if data.draw(st.booleans()):
                sim.step()
        sim.drain(max_steps=60 * max(rid, 1))
        assert sorted(sim.finished_rids) == list(range(rid))

    run()


# ===================== prefix sharing simulation ============================


class _SimPrefix(_Sim):
    """_Sim with a PrefixCache attached and page *contents* modelled
    host-side: every simulated K/V write stamps (page, slot) with its
    token, so shared pages can be checked to hold exactly the span the
    tree promised, and the frozen-blocks rule (no write to a shared or
    cached page) is asserted at write time rather than inferred."""

    def __init__(self, max_batch, page_size, n_pages, max_seq,
                 decode_chunk=4, prefill_chunk=4, age_limit=4):
        self.alloc = KV.PageAllocator(n_pages)
        self.tree = KV.PrefixCache(self.alloc, page_size)
        self.sched = Scheduler(max_batch, page_size, self.alloc, max_seq,
                               age_limit=age_limit,
                               prefix_cache=self.tree)
        self.decode_chunk = decode_chunk
        self.prefill_chunk = prefill_chunk
        self.admitted_rids: list[int] = []
        self.finished_rids: list[int] = []
        self.contents: dict[int, list] = {}     # page -> page_size slots
        self.hits = 0

    def submit_tokens(self, rid, prompt, max_new):
        self.sched.submit(
            Request(rid, np.asarray(prompt, np.int32), max_new))

    def _write(self, r, lo, hi):
        """One span of simulated K/V writes.  The invariant: a written
        page is always privately owned (refcount 1) and outside the
        tree — shared and cached pages are frozen."""
        p = self.sched.page_size
        for pos in range(lo, min(hi, self.sched.max_seq)):
            page = r.pages[pos // p]
            assert page not in self.tree.pages(), \
                f"write to cached page {page}"
            assert self.alloc.refcount(page) == 1, \
                f"write to shared page {page}"
            tok = int(r.prompt[pos]) if pos < r.prompt_len \
                else self._gen_tok(r, pos)
            self.contents.setdefault(page, [None] * p)[pos % p] = tok

    def _gen_tok(self, r, pos):
        """Simulated sampled token for generated position ``pos``."""
        return -(r.rid + 1)

    def _on_finish(self, req):
        self.finished_rids.append(req.rid)

    def step(self):
        p = self.sched.page_size
        for req in self.sched.admit():
            assert req.slot >= 0
            assert len(req.pages) == self.sched.pages_needed(req)
            assert req.rid not in self.admitted_rids, "double admission"
            self.admitted_rids.append(req.rid)
            if req.cow_fork:
                src, dst = req.cow_fork
                assert dst == req.pages[req.cached_tokens // p - 1]
                assert self.alloc.refcount(dst) == 1, "fork page shared"
                self.contents[dst] = list(self.contents[src])  # page copy
            if req.cached_tokens:
                self.hits += 1
                assert req.cached_tokens % p == 0, "unaligned match"
                assert req.prefilled >= req.cached_tokens - 1
                for b in range(req.cached_tokens // p):
                    span = [int(t) for t in req.prompt[b * p:(b + 1) * p]]
                    assert self.contents.get(req.pages[b]) == span, \
                        "shared page holds the wrong span"
        plan = self.sched.plan_step(self.decode_chunk, self.prefill_chunk)
        ready = {s for s, r in self.sched.running.items()
                 if r.decode_ready}
        assert set(plan.decode_slots) == ready, "decode-ready slot skipped"
        for s in plan.decode_slots:
            r = self.sched.running[s]
            lo = r.prompt_len + r.generated
            r.generated += min(self.decode_chunk,
                               r.max_new_tokens - r.generated)
            self._write(r, lo, r.prompt_len + r.generated)
        for s in plan.prefill_slots:
            r = self.sched.running[s]
            lo = r.prefilled
            r.prefilled += min(self.prefill_chunk,
                               r.prompt_len - r.prefilled)
            self._write(r, lo, r.prefilled)
            if r.prefill_done:
                if r.generated == 0:
                    r.generated = 1
                    self._write(r, r.prompt_len, r.prompt_len + 1)
                self.sched.register_prefix(r)   # mirror the engine hook
        for s in [s for s, r in self.sched.running.items() if r.done]:
            self._on_finish(self.sched.evict(s))
        self.check_pages()

    def check_pages(self):
        from collections import Counter
        owners = Counter(pg for r in self.sched.running.values()
                         for pg in r.pages)
        tree_pages = self.tree.pages()
        assert KV.SCRATCH_PAGE not in owners, "scratch page owned"
        assert KV.SCRATCH_PAGE not in tree_pages, "scratch page cached"
        for page in set(owners) | tree_pages:
            assert self.alloc.refcount(page) == \
                owners[page] + (page in tree_pages), (
                    f"page {page}: refcount {self.alloc.refcount(page)} "
                    f"!= {owners[page]} owners + "
                    f"{int(page in tree_pages)} tree refs")
        # the converse: every held page is owned or cached (no leak)
        assert self.alloc.in_use() == len(set(owners) | tree_pages), \
            "page leak"
        for page, node in self.tree._pages.items():
            assert len(node.key) == self.sched.page_size, "unaligned span"
            assert node.page == page
        assert len(self.sched.running) <= self.sched.max_batch

    def drain(self, max_steps, drop_tree=True):
        steps = 0
        while self.sched.has_work:
            self.step()
            steps += 1
            assert steps <= max_steps, (
                f"scheduler failed to drain in {max_steps} steps: "
                f"waiting={[r.rid for r in self.sched.waiting]} "
                f"running={sorted(self.sched.running)}")
        # only the tree holds pages now; dropping it must return them all
        assert self.alloc.in_use() == len(self.tree), "leak at drain"
        if drop_tree:
            assert self.tree.evict(len(self.tree)) == len(self.tree) \
                or len(self.tree) == 0
            assert len(self.tree) == 0
            assert self.alloc.available() == self.alloc.capacity, \
                "leak after tree drop"


def _prefix_trace(rng, n_requests=14, max_batch=3, page_size=4,
                  n_pages=16, max_seq=24, **kw):
    """Random trace over a small template pool so real matches (and the
    occasional exact-match CoW fork, tail length 0) actually occur."""
    sim = _SimPrefix(max_batch, page_size, n_pages, max_seq, **kw)
    pool = [rng.integers(0, 97, (page_size * int(k),)).astype(np.int32)
            for k in (1, 2, 2)]
    rid = 0
    for _ in range(n_requests):
        pre = pool[int(rng.integers(len(pool)))]
        tail = rng.integers(0, 97, (int(rng.integers(0, page_size)),))
        prompt = np.concatenate([pre, tail.astype(np.int32)])
        n = int(rng.integers(1, max_seq - len(prompt) + 1))
        sim.submit_tokens(rid, prompt, n)
        rid += 1
        if rng.random() < 0.7:
            sim.step()
    sim.drain(max_steps=60 * n_requests)
    assert sorted(sim.finished_rids) == list(range(rid))
    return sim


def test_prefix_sharing_trace_deterministic():
    """Random sharing traces under fixed seeds: refcount accounting,
    frozen-blocks, span alignment, scratch exclusion, drain leak —
    and the pool is templated enough that matches really happen."""
    hits = 0
    for seed in range(8):
        hits += _prefix_trace(np.random.default_rng(seed)).hits
    assert hits > 0, "template pool never produced a prefix hit"


def test_tree_eviction_unblocks_admission():
    """Eviction-starvation regression: a tree grown to fill the pool
    must not block non-matching prompts — admission reclaims LRU
    leaves (never a live request's page) and the aging liveness
    guarantee from the plain scheduler survives sharing."""
    rng = np.random.default_rng(3)
    sim = _SimPrefix(max_batch=2, page_size=4, n_pages=8, max_seq=16,
                     age_limit=3)
    rid = 0
    for _ in range(3):                  # distinct prompts fill the tree
        sim.submit_tokens(rid, rng.integers(100, 200, (8,)), 2)
        rid += 1
    while sim.sched.has_work:
        sim.step()
    assert len(sim.tree) == 6           # 2 full pages cached per prompt
    assert sim.alloc.available() == 1   # the tree holds nearly everything
    for _ in range(4):                  # non-matching stream: must evict
        sim.submit_tokens(rid, rng.integers(300, 400, (8,)), 2)
        rid += 1
    sim.drain(max_steps=200, drop_tree=False)
    assert sorted(sim.finished_rids) == list(range(rid))
    sim.drain(max_steps=1)              # final leak check drops the tree


def test_prefix_sharing_invariants_property():
    """Hypothesis-driven sharing traces: same template-pool shape as the
    deterministic twin, wider parameter space."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(data=st.data())
    def run(data):
        page_size = data.draw(st.sampled_from([2, 4]))
        n_pages = data.draw(st.integers(6, 14))
        max_batch = data.draw(st.integers(1, 3))
        max_seq = page_size * (n_pages - 1)
        sim = _SimPrefix(max_batch, page_size, n_pages, max_seq,
                         decode_chunk=data.draw(st.integers(1, 4)),
                         prefill_chunk=data.draw(st.sampled_from(
                             [page_size, 2 * page_size])),
                         age_limit=data.draw(st.integers(1, 4)))
        pool = [np.asarray(data.draw(st.lists(
                    st.integers(0, 50), min_size=page_size * k,
                    max_size=page_size * k)), np.int32)
                for k in (1, 2)]
        rid = 0
        for _ in range(data.draw(st.integers(1, 10))):
            pre = pool[data.draw(st.integers(0, len(pool) - 1))]
            tail = data.draw(st.lists(st.integers(0, 50), min_size=0,
                                      max_size=page_size - 1))
            prompt = np.concatenate([pre, np.asarray(tail, np.int32)])
            if len(prompt) >= max_seq:
                prompt = prompt[:max_seq - 1]
            n = data.draw(st.integers(1, max_seq - len(prompt)))
            sim.submit_tokens(rid, prompt, n)
            rid += 1
            if data.draw(st.booleans()):
                sim.step()
        sim.drain(max_steps=80 * max(rid, 1))
        assert sorted(sim.finished_rids) == list(range(rid))

    run()


# ===================== preemption-with-restore simulation ===================


class _SimPreempt(_SimPrefix):
    """_SimPrefix plus preempt-with-restore (docs/robustness.md).

    Generated tokens become a deterministic function of
    (rid, emission index) — the sim's stand-in for greedy decode of a
    fixed model — so a restored request's full emitted stream can be
    checked byte-identical to what the unpreempted run would produce.
    Restore bookkeeping (prompt extension, budget telescoping,
    re-admission through the tree) is the only thing that can break the
    identity, which is exactly what the property is after.  The
    engine-level differential with device tokens is
    ``test_preempt_restore_token_exact`` below.
    """

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.n_preempts = 0

    def _gen_tok(self, r, pos):
        # emission index counts from the ORIGINAL prompt end — restored
        # prompts carry the prior emissions, so ``pos`` keeps advancing
        # through the same per-rid stream across preemptions
        return -int((r.rid * 1009 + (pos - r.orig_prompt_len) * 31 + 7)
                    % 97) - 1

    def preempt_now(self, rng) -> bool:
        """Preempt a random running request with budget left; assert
        the restore identity on the replacement."""
        cands = [(s, r) for s, r in self.sched.running.items()
                 if r.max_new_tokens - r.generated > 0]
        if not cands:
            return False
        slot, victim = cands[int(rng.integers(len(cands)))]
        emitted = np.array([self._gen_tok(victim, victim.prompt_len + j)
                            for j in range(victim.generated)], np.int32)
        plen, rid, count = victim.prompt_len, victim.rid, \
            victim.preempt_count
        new = self.sched.preempt(slot, emitted)
        assert new.rid == rid
        assert np.array_equal(new.prompt[plen:], emitted)
        # the budget telescopes back to the original request's
        assert new.prompt_len + new.max_new_tokens == \
            new.orig_prompt_len + new.orig_max_new
        assert new.preempt_count == count + 1
        self.admitted_rids.remove(rid)   # re-admission is legal now
        self.n_preempts += 1
        self.check_pages()
        return True

    def _on_finish(self, req):
        super()._on_finish(req)
        assert req.done, "sim requests only finish by exhausting budget"
        got = [int(t) for t in req.prompt[req.orig_prompt_len:]] + \
              [self._gen_tok(req, req.prompt_len + j)
               for j in range(req.generated)]
        want = [self._gen_tok(req, req.orig_prompt_len + j)
                for j in range(req.orig_max_new)]
        assert got == want, (
            f"rid {req.rid}: restored stream diverged after "
            f"{req.preempt_count} preemption(s)")


def _preempt_trace(rng, n_requests=12, max_batch=3, page_size=4,
                   n_pages=16, max_seq=24, **kw):
    sim = _SimPreempt(max_batch, page_size, n_pages, max_seq, **kw)
    pool = [rng.integers(0, 97, (page_size * int(k),)).astype(np.int32)
            for k in (1, 2, 2)]
    rid = 0
    for _ in range(n_requests):
        pre = pool[int(rng.integers(len(pool)))]
        tail = rng.integers(0, 97, (int(rng.integers(0, page_size)),))
        prompt = np.concatenate([pre, tail.astype(np.int32)])
        n = int(rng.integers(1, max_seq - len(prompt) + 1))
        sim.submit_tokens(rid, prompt, n)
        rid += 1
        if rng.random() < 0.7:
            sim.step()
        if rng.random() < 0.4:
            sim.preempt_now(rng)
    steps = 0
    while sim.sched.has_work:
        sim.step()
        steps += 1
        # keep preempting during the drain (bounded, so it still ends)
        if sim.n_preempts < 3 * n_requests and rng.random() < 0.25:
            sim.preempt_now(rng)
        assert steps <= 80 * n_requests, "preempt trace failed to drain"
    sim.drain(max_steps=1)              # leak checks + tree drop
    assert sorted(sim.finished_rids) == list(range(rid))
    return sim


def test_preempt_restore_trace_deterministic():
    """Random preempt/restore traces under fixed seeds: every finished
    request's emitted stream is byte-identical to the unpreempted run
    (asserted at eviction), refcounts/leaks/liveness all hold, and the
    seeds actually preempt."""
    preempts = 0
    for seed in range(8):
        preempts += _preempt_trace(np.random.default_rng(seed)).n_preempts
    assert preempts > 0, "no trace ever preempted"


def test_preempt_restore_invariants_property():
    """Hypothesis: ANY preempt-at-step-k/restore schedule yields
    emitted streams byte-identical to the unpreempted run, with the
    sharing invariants intact (sim-level; the per-arch engine
    differential is test_preempt_restore_token_exact)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(data=st.data())
    def run(data):
        page_size = data.draw(st.sampled_from([2, 4]))
        # capacity must cover one max_seq request (8 pages + scratch)
        n_pages = data.draw(st.integers(9, 16))
        max_batch = data.draw(st.integers(1, 3))
        max_seq = page_size * 8
        sim = _SimPreempt(max_batch, page_size, n_pages, max_seq,
                          age_limit=data.draw(st.integers(2, 5)))
        # hypothesis draws the structure; numpy supplies the unbounded
        # in-loop randomness from a drawn seed (keeps examples small)
        rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 16)))
        pool = [rng.integers(0, 97, (page_size * k,)).astype(np.int32)
                for k in (1, 2)]
        rid = 0
        for _ in range(data.draw(st.integers(1, 8))):
            pre = pool[int(rng.integers(len(pool)))]
            tail = rng.integers(0, 97,
                                (int(rng.integers(0, page_size)),))
            prompt = np.concatenate([pre, tail.astype(np.int32)])
            if len(prompt) >= max_seq:
                prompt = prompt[:max_seq - 1]
            n = int(rng.integers(1, max_seq - len(prompt) + 1))
            sim.submit_tokens(rid, prompt, n)
            rid += 1
            if data.draw(st.booleans()):
                sim.step()
            if data.draw(st.booleans()):
                sim.preempt_now(rng)
        steps = 0
        while sim.sched.has_work:
            sim.step()
            steps += 1
            if sim.n_preempts < 24 and rng.random() < 0.25:
                sim.preempt_now(rng)
            assert steps <= 100 * max(rid, 1), "failed to drain"
        sim.drain(max_steps=1)
        assert sorted(sim.finished_rids) == list(range(rid))

    run()


# ===================== token exactness: chunk + spec ========================

ARCHS = ["granite-3-8b", "gemma2-9b", "recurrentgemma-9b", "mamba2-780m"]


def _prompts(cfg, seed=0, lens=(5, 11, 9)):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
            for L in lens]


def _generate(cfg, params, prompts, gen=8, **kw):
    eng = PagedEngine(cfg, params, PagedServeConfig(
        max_seq=64, max_batch=2, page_size=8, decode_chunk=4, **kw))
    return eng.generate(prompts, gen), eng


@pytest.mark.parametrize("arch", ARCHS)
def test_chunked_prefill_token_exact(arch):
    """Chunked prefill == whole-prompt joins, token for token (hybrid
    stacks gate chunking off and must still agree, trivially)."""
    cfg = _cfg(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    prompts = _prompts(cfg, seed=1)
    ref, _ = _generate(cfg, params, prompts, prefill_chunk=0)
    out, eng = _generate(cfg, params, prompts, prefill_chunk=8)
    np.testing.assert_array_equal(ref, out)
    attn_only = all(p in ("global", "local") for p in cfg.layer_pattern)
    assert (eng.prefill_chunk == 8) == attn_only    # hybrid gates off


@pytest.mark.parametrize("arch", ARCHS)
def test_speculative_decode_token_exact(arch):
    """Draft-verify speculative decode == plain greedy decode, token for
    token — acceptance compares against the argmax chain, so emitted
    tokens cannot diverge (hybrid stacks gate speculation off)."""
    cfg = _cfg(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(2))
    prompts = _prompts(cfg, seed=2)
    ref, _ = _generate(cfg, params, prompts, prefill_chunk=0,
                       spec_decode=0)
    out, eng = _generate(cfg, params, prompts, prefill_chunk=8,
                         spec_decode=3)
    np.testing.assert_array_equal(ref, out)
    attn_only = all(p in ("global", "local") for p in cfg.layer_pattern)
    assert (eng.spec == 3) == attn_only
    if attn_only:
        st = eng.spec_stats()
        assert st["verify_calls"] > 0
        assert st["tokens"] >= st["verify_calls"]   # >= 1 token per call


def test_chunk_and_spec_token_exact_fused():
    """--fuse composition: chunked + speculative fused engine ==
    whole-prompt fused engine (the span path swaps the oproj-fused
    attention for the unfused pair; QKV/MLP fusion still applies)."""
    cfg = _cfg("gemma2-9b")
    params = T.init_params(cfg, jax.random.PRNGKey(3))
    prompts = _prompts(cfg, seed=3)
    ref, _ = _generate(cfg, params, prompts, fuse=True, prefill_chunk=0)
    out, _ = _generate(cfg, params, prompts, fuse=True, prefill_chunk=8,
                       spec_decode=2)
    np.testing.assert_array_equal(ref, out)


def test_chunk_and_spec_token_exact_w8():
    """--quantize w8 composition: int8 projection weights under chunked
    prefill + speculative decode stay token-exact."""
    from repro.quant import quantize_params
    cfg = _cfg("granite-3-8b")
    params = quantize_params(T.init_params(cfg, jax.random.PRNGKey(4)))
    prompts = _prompts(cfg, seed=4)
    ref, _ = _generate(cfg, params, prompts, prefill_chunk=0)
    out, _ = _generate(cfg, params, prompts, prefill_chunk=8,
                       spec_decode=2)
    np.testing.assert_array_equal(ref, out)


def test_chunk_and_spec_token_exact_fp8kv():
    """--quantize fp8kv composition: chunked prefill and speculative
    verify write/read the fp8 page pool exactly like plain decode."""
    cfg = dataclasses.replace(_cfg("granite-3-8b"),
                              kv_cache_dtype=jnp.float8_e4m3fn)
    params = T.init_params(cfg, jax.random.PRNGKey(5))
    prompts = _prompts(cfg, seed=5)
    ref, _ = _generate(cfg, params, prompts, prefill_chunk=0)
    out, _ = _generate(cfg, params, prompts, prefill_chunk=8,
                       spec_decode=2)
    np.testing.assert_array_equal(ref, out)


# ===================== token exactness: prefix caching ======================


def _reuse_prompts(cfg, seed=7):
    """A 16-token shared prefix with distinct tails, plus two identical
    prompts of exactly that prefix (2 full pages at page_size 8) — the
    second one exercises the exact-full-match CoW fork path."""
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab, (16,)).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab, (k,)).astype(np.int32)
             for k in (5, 3, 7)]
    return [np.concatenate([pre, t]) for t in tails] + [pre.copy(),
                                                        pre.copy()]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefix_cache_token_exact(arch):
    """Shared-prefix paged generation with ``prefix_cache=True`` ==
    the unshared engine, byte for byte — sharing changes which pages
    admission touches, never tokens (hybrid stacks gate the cache off
    and must agree trivially)."""
    cfg = _cfg(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(7))
    prompts = _reuse_prompts(cfg)
    ref, _ = _generate(cfg, params, prompts, prefill_chunk=8)
    out, eng = _generate(cfg, params, prompts, prefill_chunk=8,
                         prefix_cache=True)
    np.testing.assert_array_equal(ref, out)
    attn_only = all(p in ("global", "local") for p in cfg.layer_pattern)
    assert eng.prefix_caching == attn_only
    if attn_only:
        st = eng.prefix_stats()
        assert st["hits"] > 0, "reuse workload never hit the cache"
        assert st["tokens_saved"] > 0


def test_prefix_cache_token_exact_fused():
    """--fuse composition: prefix sharing over the fused hot path stays
    byte-identical to the fused unshared engine."""
    cfg = _cfg("gemma2-9b")
    params = T.init_params(cfg, jax.random.PRNGKey(8))
    prompts = _reuse_prompts(cfg, seed=8)
    ref, _ = _generate(cfg, params, prompts, fuse=True, prefill_chunk=8)
    out, eng = _generate(cfg, params, prompts, fuse=True, prefill_chunk=8,
                         prefix_cache=True)
    np.testing.assert_array_equal(ref, out)
    assert eng.prefix_stats()["hits"] > 0


def test_prefix_cache_token_exact_w8():
    """--quantize w8 composition: int8 projection weights under prefix
    sharing stay token-exact."""
    from repro.quant import quantize_params
    cfg = _cfg("granite-3-8b")
    params = quantize_params(T.init_params(cfg, jax.random.PRNGKey(9)))
    prompts = _reuse_prompts(cfg, seed=9)
    ref, _ = _generate(cfg, params, prompts, prefill_chunk=8)
    out, _ = _generate(cfg, params, prompts, prefill_chunk=8,
                       prefix_cache=True)
    np.testing.assert_array_equal(ref, out)


def test_prefix_cache_token_exact_fp8kv():
    """--quantize fp8kv composition: shared fp8 pages (and the CoW fork
    page copy) read back exactly what the unshared engine wrote."""
    cfg = dataclasses.replace(_cfg("granite-3-8b"),
                              kv_cache_dtype=jnp.float8_e4m3fn)
    params = T.init_params(cfg, jax.random.PRNGKey(10))
    prompts = _reuse_prompts(cfg, seed=10)
    ref, _ = _generate(cfg, params, prompts, prefill_chunk=8)
    out, _ = _generate(cfg, params, prompts, prefill_chunk=8,
                       prefix_cache=True)
    np.testing.assert_array_equal(ref, out)


# ===================== token exactness: preempt + restore ===================


@pytest.mark.parametrize("arch", ARCHS)
def test_preempt_restore_token_exact(arch):
    """Forced preempt-at-step-k + restore == the undisturbed run, byte
    for byte, across the arch families.  With the prefix cache the
    attention stacks replay only the victim's unshared tail; hybrid
    stacks gate the cache off, replay in full, and must still agree
    (docs/robustness.md)."""
    from repro.serve.lifecycle import RequestStatus
    cfg = _cfg(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(11))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
               for n in (11, 7, 14)]
    ref, _ = _generate(cfg, params, prompts, gen=10)
    eng = PagedEngine(cfg, params, PagedServeConfig(
        max_seq=64, max_batch=2, page_size=8, decode_chunk=4,
        preempt=True, prefix_cache=True))
    for k in (1, 2, 3):
        rids = [eng.submit(p, 10) for p in prompts]
        done: dict[int, Request] = {}
        steps, target = 0, None
        while eng.has_work:
            steps += 1
            for r in eng.step():
                done[r.rid] = r
            if steps >= k and target is None:
                cands = [r for r in eng.scheduler.running.values()
                         if r.max_new_tokens - r.generated > 0]
                if cands:
                    target = max(cands, key=lambda r: r.rid).rid
                    assert eng.preempt(target)
            assert steps < 300, "preempt schedule failed to drain"
        assert target is not None, "no preemption candidate ever ran"
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(done[rid].output, ref[i])
        assert done[target].status is RequestStatus.PREEMPTED_RETRIED
        assert done[target].preempt_count >= 1
        assert eng.scheduler.allocator.in_use() == \
            (len(eng.prefix_cache) if eng.prefix_caching else 0), \
            "pages leaked past the prefix tree"


# ===================== prefix cache unit properties =========================


def test_reuse_priced_page_size():
    """Share-vs-stream pricing: no reuse recovers the tuned flash-decode
    block; rising reuse never widens pages (finer pages share more);
    the answer always tiles max_seq or is the tuned block itself."""
    assert KV.reuse_priced_page(64, 64, 0.0) == 64
    prev = None
    for rr in (0.0, 0.25, 0.5, 1.0):
        page = KV.reuse_priced_page(64, 64, rr)
        assert 64 % page == 0
        if prev is not None:
            assert page <= prev, "more reuse chose a coarser page"
        prev = page
    assert KV.reuse_priced_page(64, 64, 0.5) < 64


def test_choose_page_size_reuse_hint():
    cfg = _cfg("granite-3-8b")
    base = KV.choose_page_size(cfg, 64)
    assert KV.choose_page_size(cfg, 64, reuse_rate=0.0) == base
    shared = KV.choose_page_size(cfg, 64, reuse_rate=0.5)
    assert shared <= base
    assert 64 % shared == 0


def test_scratch_page_never_shared_or_cached():
    """The scratch page is un-shareable and un-evictable by
    construction: PageAllocator refuses to share it and PrefixCache
    refuses to cache it (alongside the span-shape checks)."""
    alloc = KV.PageAllocator(4)
    tree = KV.PrefixCache(alloc, 2)
    with pytest.raises(ValueError, match="share"):
        alloc.share(KV.SCRATCH_PAGE)
    page = alloc.alloc()
    with pytest.raises(ValueError, match="scratch"):
        tree.insert(np.array([1, 2], np.int32), [KV.SCRATCH_PAGE])
    with pytest.raises(ValueError, match="aligned"):
        tree.insert(np.array([1, 2, 3], np.int32), [page, page])
    with pytest.raises(ValueError, match="pages"):
        tree.insert(np.array([1, 2], np.int32), [page, page])
    tree.insert(np.array([5, 6], np.int32), [page])
    with pytest.raises(ValueError, match="another span"):
        tree.insert(np.array([7, 8], np.int32), [page])
    assert tree.evict(1) == 0           # the live owner pins the page
    alloc.free(page)                    # owner gone; only the tree's ref
    assert tree.evict(1) == 1
    assert len(tree) == 0
    assert alloc.available() == alloc.capacity


def test_chunked_prefill_interleaves_with_decode():
    """End-to-end scheduling shape: with one request decoding and one
    chunk-prefilling, both make progress in the same engine step."""
    cfg = _cfg("granite-3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(6))
    rng = np.random.default_rng(6)
    eng = PagedEngine(cfg, params, PagedServeConfig(
        max_seq=64, max_batch=2, page_size=8, decode_chunk=2,
        prefill_chunk=8))
    eng.submit(rng.integers(0, cfg.vocab, (4,)).astype(np.int32), 16)
    while not any(r.decode_ready for r in eng.scheduler.running.values()):
        eng.step()
    eng.submit(rng.integers(0, cfg.vocab, (24,)).astype(np.int32), 4)
    eng.step()                                   # admits + first chunk
    r0 = next(r for r in eng.scheduler.running.values() if r.rid == 0)
    r1 = next(r for r in eng.scheduler.running.values() if r.rid == 1)
    g0 = r0.generated
    assert 0 < r1.prefilled < r1.prompt_len      # chunking, not a join
    eng.step()
    assert r0.generated > g0                     # decode kept moving
    assert r1.prefilled > 8                      # prefill kept moving
    while eng.has_work:
        eng.step()


def test_spec_decode_rejects_sampling():
    cfg = _cfg("granite-3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="greedy"):
        PagedEngine(cfg, params, PagedServeConfig(
            max_seq=32, max_batch=1, temperature=0.5, spec_decode=2))
