"""Multi-device tests (subprocess: device count must be set pre-jax-init).

Covers the shard_map MoE dispatch vs the dense reference, sharded
train-step lowering on a small mesh, and the fsdp-vs-tp axis mappings.
"""

import os
import subprocess
import sys
import textwrap

import pytest

# every case here spawns a subprocess that compiles sharded jax programs
# (minutes, not seconds): fast-lane runs skip them with -m "not slow"
pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_shardmap_moe_matches_dense_reference():
    run_py("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.models import layers as L
        from repro.models.base import build
        from repro.models.sharding import set_axis_mapping

        cfg = dataclasses.replace(get_reduced('qwen3-moe-235b-a22b'),
                                  dtype=jnp.float32, capacity_factor=8.0)
        params = build(L.moe_defs(cfg, 2), 'init', jax.random.PRNGKey(0))
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        set_axis_mapping({'data': ('data',), 'model': 'model'})
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model),
                              jnp.float32)
        ref_out, _ = L._moe_apply_ref(cfg, params, x)
        with mesh:
            out, aux = jax.jit(lambda p, x: L.moe_apply(cfg, p, x))(
                params, x)
        err = float(jnp.max(jnp.abs(out - ref_out)))
        assert err < 1e-4, err
        print('OK', err)
    """)


def test_sharded_train_step_lowers_and_runs():
    """A REAL sharded train step (not just lower): 2x2 mesh, reduced arch,
    runs one step and checks finite loss + sharded params."""
    run_py("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_reduced
        from repro.models import transformer as T
        from repro.models.sharding import set_axis_mapping, translate_tree
        from repro.optim import adamw
        from repro.train.loop import TrainConfig, make_train_step
        from repro.data.pipeline import make_batch

        cfg = dataclasses.replace(
            get_reduced('granite-3-8b'), d_model=64, n_heads=4,
            n_kv_heads=2, d_ff=128)
        mesh = jax.make_mesh((2, 2), ('data', 'model'))
        mapping = {'data': ('data',), 'model': 'model'}
        set_axis_mapping(mapping)
        specs = translate_tree(T.param_specs(cfg, 2), mapping)
        shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        with mesh:
            params = jax.jit(
                lambda k: T.init_params(cfg, k, 2),
                out_shardings=shardings)(jax.random.PRNGKey(0))
            opt = adamw.init_state(params)
            step = jax.jit(make_train_step(cfg, TrainConfig()))
            batch = make_batch(cfg, 32, 4, 0)
            params, opt, m = step(params, opt, batch)
        assert np.isfinite(float(m['loss']))
        print('OK', float(m['loss']))
    """)


def test_dryrun_single_cell_small_mesh():
    """The dry-run machinery end-to-end on an 8-device (4,2) mesh with a
    reduced config (fast): lower + compile + artifact fields."""
    run_py("""
        import dataclasses, jax
        from repro.configs import get_reduced, SHAPES, ARCHS
        from repro.launch import shapes as S
        from repro.models.sharding import set_axis_mapping
        import repro.launch.dryrun as dr

        cfg = get_reduced('gemma2-9b')
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        shape = dataclasses.replace(SHAPES['train_4k'], seq_len=64,
                                    global_batch=8)
        mapping = S.axis_mapping(cfg, shape, mesh)
        set_axis_mapping(mapping)
        import repro.configs as C
        C.SHAPES['tiny_train'] = dataclasses.replace(
            shape, name='tiny_train')
        low = S.input_specs(cfg, 'tiny_train', mesh, model_ax=2)
        with mesh:
            compiled = jax.jit(low.fn, in_shardings=low.in_shardings,
                               out_shardings=low.out_shardings
                               ).lower(*low.args_shapes).compile()
        coll = dr.collective_bytes(compiled.as_text())
        assert sum(coll.values()) > 0  # TP all-reduces must exist
        print('OK', coll)
    """)


def test_fsdp_mapping_removes_tp_collectives():
    """fsdp parallelism must produce strictly fewer collective bytes than
    tp_fsdp on the same tiny dense cell (the §Perf it.1 claim, in CI)."""
    out = run_py("""
        import dataclasses, jax
        from repro.configs import get_reduced, SHAPES
        from repro.launch import shapes as S
        from repro.models.sharding import set_axis_mapping
        import repro.configs as C
        import repro.launch.dryrun as dr

        cfg = get_reduced('granite-3-8b')
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        C.SHAPES['tiny_train'] = dataclasses.replace(
            SHAPES['train_4k'], name='tiny_train', seq_len=64,
            global_batch=8)
        totals = {}
        for par in ('tp_fsdp', 'fsdp'):
            shape = C.SHAPES['tiny_train']
            set_axis_mapping(S.axis_mapping(cfg, shape, mesh, par))
            low = S.input_specs(cfg, 'tiny_train', mesh, parallelism=par)
            with mesh:
                comp = jax.jit(low.fn, in_shardings=low.in_shardings,
                               out_shardings=low.out_shardings
                               ).lower(*low.args_shapes).compile()
            totals[par] = sum(dr.collective_bytes(comp.as_text()).values())
        assert totals['fsdp'] < totals['tp_fsdp'], totals
        print('OK', totals)
    """)
    assert "OK" in out
