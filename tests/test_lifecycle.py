"""Request lifecycle hardening (docs/robustness.md): terminal
statuses, wall deadlines and TTLs, cancellation, the NaN/Inf guard's
blast-radius, bounded admission retries, and the graceful-degradation
ladder — each with the byte-exactness contract the statuses promise
(OK/PREEMPTED_RETRIED outputs equal the undisturbed run, everything
else is a byte-exact prefix of it).

The preempt-with-restore differential across the architecture families
and its hypothesis-driven sim-level property live with the rest of the
scheduler invariants in ``test_serve_invariants.py``; the randomized
fault schedules live in ``test_chaos.py``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.obs.metrics import MetricsRegistry
from repro.serve.engine import PagedEngine, PagedServeConfig
from repro.serve.lifecycle import (DegradationController, DegradeThresholds,
                                   RequestStatus, replay_cost_tokens)


def _cfg(arch: str):
    return dataclasses.replace(get_reduced(arch), dtype=jnp.float32)


def _mk(cfg, params, **kw):
    return PagedEngine(cfg, params, PagedServeConfig(
        max_seq=64, max_batch=2, page_size=8, decode_chunk=4, **kw))


# -- pure units --------------------------------------------------------------


def test_replay_cost_tokens():
    """The preempt-and-recompute price: with a tree only the tail past
    the last page boundary replays (plus the one position whose sampled
    token never had its K/V written); without one everything does."""
    assert replay_cost_tokens(13, 8, shared=False) == 14
    assert replay_cost_tokens(13, 8, shared=True) == 6
    assert replay_cost_tokens(16, 8, shared=True) == 1   # page-aligned
    assert replay_cost_tokens(0, 8, shared=True) == 1
    # shared replay never exceeds unshared, and the expected tail the
    # reuse_priced_page boundary-slack term models is (page - 1) / 2
    costs = [replay_cost_tokens(c, 4, shared=True) for c in range(4, 12)]
    assert all(1 <= c <= 4 for c in costs)
    assert np.isclose(np.mean([c - 1 for c in costs]), (4 - 1) / 2)


def test_degradation_controller_hysteresis():
    """The ladder escalates only under sustained pressure, steps down
    only after a sustained recovery, and counts every transition."""
    reg = MetricsRegistry()
    ctl = DegradationController(reg, DegradeThresholds(
        free_page_frac=0.25, queue_depth=4, sustain=2, recover=3))
    q = reg.gauge("sched.queue_depth")
    cap, use = reg.gauge("pages.capacity"), reg.gauge("pages.in_use")
    cap.set(16)
    assert ctl.update() == 0                  # no pressure
    q.set(10)                                 # queue-depth signal
    assert ctl.update() == 0                  # sustain=2: not yet
    assert ctl.update() == 1                  # no_spec
    assert ctl.spec_disabled and not ctl.shrink_chunk
    assert ctl.update() == 1
    assert ctl.update() == 2                  # small_chunk
    assert ctl.shrink_chunk and not ctl.allow_preempt
    use.set(15)
    q.set(1)                                  # free-page watermark signal
    assert ctl.update() == 2
    assert ctl.update() == 3                  # preempt
    assert ctl.allow_preempt
    assert reg.counter("degrade.escalations").value == 3
    use.set(0)
    q.set(0)                                  # pressure clears
    assert ctl.update() == 3                  # recover=3 hysteresis
    assert ctl.update() == 3
    assert ctl.update() == 2                  # one rung down
    assert reg.counter("degrade.recoveries").value == 1
    assert reg.gauge("degrade.level").value == 2


def test_preempt_rejects_sampling():
    cfg = _cfg("granite-3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="greedy"):
        PagedEngine(cfg, params, PagedServeConfig(
            max_seq=32, max_batch=1, temperature=0.5, preempt=True))


# -- engine lifecycle --------------------------------------------------------


def test_terminal_statuses_ok_truncated_expired():
    """One run, four outcomes: an undisturbed request is OK and
    byte-exact; a cancelled one is TRUNCATED with a byte-exact prefix;
    a TTL'd one queued behind a full batch is DEADLINE_EXCEEDED; an
    already-expired wall deadline never runs at all."""
    cfg = _cfg("granite-3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
               for n in (9, 12, 7, 10)]
    ref = _mk(cfg, params).generate(prompts, 8)

    eng = _mk(cfg, params)
    rid_ok = eng.submit(prompts[0], 8)
    rid_cancel = eng.submit(prompts[1], 8)
    rid_ttl = eng.submit(prompts[2], 8, ttl_steps=1)     # queued: expires
    rid_dead = eng.submit(prompts[3], 8, deadline_s=0.0)  # already past
    done: dict[int, object] = {}
    cancelled = False
    steps = 0
    while eng.has_work:
        steps += 1
        for r in eng.step():
            done[r.rid] = r
        if not cancelled and any(
                r.rid == rid_cancel and r.decode_ready
                for r in eng.scheduler.running.values()):
            assert eng.cancel(rid_cancel)
            cancelled = True
        assert steps < 200, "lifecycle schedule failed to drain"
    assert cancelled

    assert done[rid_ok].status is RequestStatus.OK
    np.testing.assert_array_equal(done[rid_ok].output, ref[0])

    out = done[rid_cancel].output
    assert done[rid_cancel].status is RequestStatus.TRUNCATED
    assert 0 < len(out) < 8
    np.testing.assert_array_equal(out, ref[1][:len(out)])

    for rid, i in ((rid_ttl, 2), (rid_dead, 3)):
        req = done[rid]
        assert req.status is RequestStatus.DEADLINE_EXCEEDED
        np.testing.assert_array_equal(req.output, ref[i][:len(req.output)])

    stats = eng.lifecycle_stats()
    assert stats["ok"] == 1 and stats["truncated"] == 1
    assert stats["deadline_exceeded"] == 2
    assert eng.scheduler.allocator.in_use() == 0, "pages leaked"


def test_nan_guard_isolates_poisoned_request():
    """A non-finite logit fails exactly the poisoned request — its
    clean tokens survive as a byte-exact prefix, and every other
    request in the batch finishes OK and byte-exact."""
    cfg = _cfg("granite-3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
               for n in (10, 13, 8)]
    ref = _mk(cfg, params).generate(prompts, 8)

    eng = _mk(cfg, params, nan_guard=True)
    rids = [eng.submit(p, 8) for p in prompts]
    done: dict[int, object] = {}
    poisoned = False
    steps = 0
    while eng.has_work:
        steps += 1
        for r in eng.step():
            done[r.rid] = r
        if not poisoned and any(
                r.rid == rids[0] and r.decode_ready
                for r in eng.scheduler.running.values()):
            eng.inject_logit_fault(rids[0])
            poisoned = True
        assert steps < 200
    assert poisoned

    bad = done[rids[0]]
    assert bad.status is RequestStatus.FAILED
    assert len(bad.output) < 8
    np.testing.assert_array_equal(bad.output, ref[0][:len(bad.output)])
    for i in (1, 2):
        assert done[rids[i]].status is RequestStatus.OK
        np.testing.assert_array_equal(done[rids[i]].output, ref[i])
    assert eng.lifecycle_stats()["nan_guard_trips"] >= 1


def test_bounded_retries_fail_hopeless_requests():
    """With ``max_retries`` set, requests that keep losing the
    admission probe to a long-running page hog go FAILED instead of
    waiting forever; the hog itself is untouched."""
    cfg = _cfg("granite-3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    hog = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    ref = _mk(cfg, params).generate([hog], 48)

    # capacity 8 pages; the hog reserves 7, leaving 1 — the 3-page
    # followers can never fit while it runs (and it runs ~12 steps)
    eng = _mk(cfg, params, n_pages=9, max_retries=2)
    rid_hog = eng.submit(hog, 48)
    rids = [eng.submit(rng.integers(0, cfg.vocab, (9,)).astype(np.int32), 8)
            for _ in range(3)]
    done: dict[int, object] = {}
    steps = 0
    while eng.has_work:
        steps += 1
        for r in eng.step():
            done[r.rid] = r
        assert steps < 300
    assert done[rid_hog].status is RequestStatus.OK
    np.testing.assert_array_equal(done[rid_hog].output, ref[0])
    for rid in rids:
        assert done[rid].status is RequestStatus.FAILED
        assert done[rid].retries > 2
        assert len(done[rid].output) == 0
    assert eng.lifecycle_stats()["failed"] == 3


def test_degradation_ladder_escalates_and_stays_exact():
    """A queue-heavy workload pushes the ladder up at least one rung —
    and because every rung changes scheduling, never sampling, the
    tokens stay byte-identical to an unpressured engine."""
    cfg = _cfg("granite-3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(5))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, (int(n),)).astype(np.int32)
               for n in rng.integers(6, 12, 14)]
    ref = _mk(cfg, params).generate(prompts, 8)
    eng = _mk(cfg, params, degrade=True)
    out = eng.generate(prompts, 8)
    for o, r in zip(out, ref):
        np.testing.assert_array_equal(o, r)
    stats = eng.lifecycle_stats()
    assert stats["degrade_escalations"] >= 1, \
        "the queue-heavy workload never pressured the ladder"
    # the top rung may preempt-and-restore — still byte-exact, just a
    # different (equally successful) terminal status
    assert stats["ok"] + stats["preempted_retried"] == len(prompts)


def test_shutdown_drains_and_frees_everything():
    """The Ctrl-C path: shutdown() cancels all in-flight work, every
    request reaches TRUNCATED with a byte-exact prefix, and the page
    pool returns to empty."""
    cfg = _cfg("granite-3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(6))
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
               for n in (9, 11)]
    ref = _mk(cfg, params).generate(prompts, 16)

    eng = _mk(cfg, params, prefix_cache=True)
    for p in prompts:
        eng.submit(p, 16)
    for _ in range(3):                       # partial progress
        eng.step()
    reqs = eng.shutdown()
    assert not eng.has_work
    assert eng.scheduler.allocator.in_use() == 0, "pages leaked"
    by_rid = {r.rid: r for r in reqs}
    for i, rid in enumerate(sorted(by_rid)):
        req = by_rid[rid]
        assert req.status in (RequestStatus.TRUNCATED, RequestStatus.OK)
        np.testing.assert_array_equal(req.output,
                                      ref[i][:len(req.output)])
