"""Property tests (hypothesis): kernel grid-transfer accounting equals the
core blocking model's level-0 traffic.

Every kernel in ``repro.kernels`` exports ``hbm_bytes`` — the block
transfers its Pallas grid issues, DMA elision included.  The profiler
(``repro.obs.profile``) prices dispatches through those formulas; the
tuner ranks candidates through the core model.  These tests pin the two
accountings to each other exactly: on any exact-divisor (shape, tile)
pair, ``kernel_hbm_bytes(spec, tiles)`` must equal
``tune.level0_dram_bytes(spec, tiles)`` bit for bit — across the GEMM
family, the fused qkv projection, and decode attention, in both wide
and narrow dtypes.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.obs.profile import kernel_hbm_bytes
from repro.tune import level0_dram_bytes
from repro.tune.schedule import OpSpec


def _divisors(n: int, lo: int = 8) -> list[int]:
    return [d for d in range(lo, n + 1) if n % d == 0]


_SIZES = [64, 128, 256, 512]


@st.composite
def gemm_case(draw):
    op = draw(st.sampled_from(
        ["matmul", "matmul_dgrad", "matmul_fused", "matmul_w8"]))
    M = draw(st.sampled_from(_SIZES))
    N = draw(st.sampled_from(_SIZES))
    K = draw(st.sampled_from(_SIZES))
    dtype = draw(st.sampled_from(["float32", "bfloat16"]))
    tiles = (draw(st.sampled_from(_divisors(M))),
             draw(st.sampled_from(_divisors(K))),
             draw(st.sampled_from(_divisors(N))))
    return OpSpec(op, (M, N, K), dtype=dtype), tiles


@settings(max_examples=80, deadline=None)
@given(case=gemm_case())
def test_gemm_kernel_bytes_equal_model_level0(case):
    """INVARIANT: for every GEMM-family op on exact-divisor tiles, the
    kernel's grid-transfer count == the model's level-0 DRAM traffic.
    (matmul_w8 streams one extra fp32 scale row per N-block pass — an
    implementation detail outside the model's operand set, subtracted.)"""
    spec, tiles = case
    kb = kernel_hbm_bytes(spec, tiles)
    assert kb is not None
    if spec.op == "matmul_w8":
        M, N, K = spec.dims
        bm, _, bn = tiles
        gm, gn = M // bm, N // bn
        kb -= N * 4 * (gm if gn > 1 else 1)
    assert kb == level0_dram_bytes(spec, tiles)


@st.composite
def qkv_case(draw):
    G = draw(st.sampled_from([2, 4, 8]))
    Nkv = draw(st.sampled_from([32, 64, 128]))
    M = draw(st.sampled_from([64, 128, 256]))
    K = draw(st.sampled_from([128, 256, 512]))
    dtype = draw(st.sampled_from(["float32", "bfloat16"]))
    tiles = (draw(st.sampled_from(_divisors(M))),
             draw(st.sampled_from(_divisors(K))),
             draw(st.sampled_from(_divisors(Nkv))))
    return OpSpec("qkv_fused", (M, Nkv, K, G), dtype=dtype), tiles


@settings(max_examples=40, deadline=None)
@given(case=qkv_case())
def test_qkv_fused_kernel_bytes_equal_model_level0(case):
    spec, tiles = case
    kb = kernel_hbm_bytes(spec, tiles)
    assert kb is not None
    assert kb == level0_dram_bytes(spec, tiles)


@st.composite
def decode_case(draw):
    op = draw(st.sampled_from(["flash_decode", "flash_decode_fp8"]))
    G = draw(st.sampled_from([1, 4, 8]))
    S = draw(st.sampled_from([512, 1024, 2048]))
    D = draw(st.sampled_from([64, 128]))
    dtype = draw(st.sampled_from(["float32", "bfloat16"]))
    bkv = draw(st.sampled_from(_divisors(S, lo=32)))
    return OpSpec(op, (G, S, D), dtype=dtype), (bkv,)


@settings(max_examples=40, deadline=None)
@given(case=decode_case())
def test_flash_decode_kernel_bytes_equal_model_level0(case):
    """Decode attention decomposes into two chained nests (scores = q@K^T,
    out = P@V); the model prices each and the sum must match the kernel's
    single-grid accounting, including the fp8 variant's per-nest scale
    scalars."""
    spec, tiles = case
    kb = kernel_hbm_bytes(spec, tiles)
    assert kb is not None
    assert kb == level0_dram_bytes(spec, tiles)


def test_nondividing_tiles_are_rejected_symmetrically():
    spec = OpSpec("matmul", (128, 128, 128))
    assert kernel_hbm_bytes(spec, (96, 64, 64)) is None
    with pytest.raises(ValueError):
        level0_dram_bytes(spec, (96, 64, 64))
