"""Paper §3.3 multicore model + §2.2/§5.1 GEMM-lowering comparison."""

import pytest

from repro.configs import PAPER_LAYERS
from repro.core import (BlockingString, Problem, best_scheme,
                        evaluate_multicore, make_objective,
                        optimize_exhaustive, xeon_hierarchy,
                        direct_blocking_accesses, gemm_lowering_accesses)


@pytest.fixture(scope="module")
def conv1_schedule():
    p = PAPER_LAYERS["Conv1"]
    res = optimize_exhaustive(p, make_objective("custom"), n_levels=2,
                              top=1, max_orders=6)
    return res[0].string


def test_multicore_energy_decreases_with_cores(conv1_schedule):
    """Fig. 9: with the right unrolling, energy/op falls as cores grow."""
    reports = [best_scheme(conv1_schedule, c) for c in (1, 2, 4, 8)]
    pj = [r.pj_per_mac for r in reports]
    assert pj[3] <= pj[0] * 1.05, pj


def test_schemes_agree_at_one_core(conv1_schedule):
    """With a single core there is no partition/broadcast: both schemes
    must evaluate to the same energy."""
    k1 = evaluate_multicore(conv1_schedule, "K", 1)
    xy1 = evaluate_multicore(conv1_schedule, "XY", 1)
    assert abs(k1.total_pj - xy1.total_pj) / k1.total_pj < 1e-9


def test_best_scheme_is_min_and_broadcast_grows_with_shared_traffic(
        conv1_schedule):
    """best_scheme returns the cheaper partitioning, and the broadcast
    surcharge applies to the SHARED buffer's served reads only (paper
    §3.3/§5.3: the partitioned buffers get cheaper, the shared one pays
    the die-wide broadcast)."""
    k8 = evaluate_multicore(conv1_schedule, "K", 8)
    xy8 = evaluate_multicore(conv1_schedule, "XY", 8)
    best = best_scheme(conv1_schedule, 8)
    assert best.total_pj == min(k8.total_pj, xy8.total_pj)
    assert k8.broadcast_pj > 0 and xy8.broadcast_pj > 0


def test_partitioning_conserves_work(conv1_schedule):
    """Per-core problem x cores == whole problem (no work lost)."""
    for scheme in ("K", "XY"):
        r = evaluate_multicore(conv1_schedule, scheme, 4)
        assert r.string.problem.macs * 4 == conv1_schedule.problem.macs


@pytest.mark.parametrize("layer", ["Conv3", "Conv4", "Conv5"])
def test_direct_blocking_beats_gemm_lowering(layer):
    """Figs. 3-4: direct blocking does fewer L2+L3 accesses than
    im2col+GEMM for every conv benchmark (gap shrinks Conv1->Conv5)."""
    p = PAPER_LAYERS[layer]
    levels = xeon_hierarchy()
    ours = direct_blocking_accesses(p, levels)
    for quality in ("mkl", "atlas"):
        theirs = gemm_lowering_accesses(p, levels, quality).cache_counts
        assert theirs["L2"] + theirs["L3"] > ours["L2"] + ours["L3"], \
            (layer, quality, ours, theirs)


def test_lowering_replicates_data():
    """im2col replication factor == Fw*Fh (the waste GEMM pays)."""
    p = PAPER_LAYERS["Conv4"]
    rep = gemm_lowering_accesses(p, xeon_hierarchy())
    assert rep.lowering_write_elems == p.X * p.Y * p.C * p.Fw * p.Fh
    assert rep.gemm.C == p.C * p.Fw * p.Fh
