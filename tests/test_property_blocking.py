"""Property-based tests (hypothesis) for the blocking model invariants."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (BlockingString, Dim, Loop, Problem, analyze,
                        energy_custom, Operand, place_buffers)
from repro.core.validate import simulate_fills


@st.composite
def small_problem(draw):
    return Problem(
        X=draw(st.sampled_from([2, 3, 4, 6])),
        Y=draw(st.sampled_from([1, 2, 4])),
        C=draw(st.sampled_from([1, 2, 4])),
        K=draw(st.sampled_from([2, 4, 8])),
        Fw=draw(st.sampled_from([1, 2, 3])),
        Fh=draw(st.sampled_from([1, 2])),
    )


@st.composite
def blocking_string(draw, problem: Problem):
    """A random VALID multi-level blocking of the problem."""
    import random
    dims = [Dim.X, Dim.Y, Dim.C, Dim.K, Dim.FW, Dim.FH]
    loops = []
    cur = {d: 1 for d in dims}
    n_rounds = draw(st.integers(1, 3))
    rng = random.Random(draw(st.integers(0, 10_000)))
    for _ in range(n_rounds):
        order = dims[:]
        rng.shuffle(order)
        for d in order:
            full = problem.full_extent(d)
            divs = [v for v in range(cur[d], full + 1)
                    if full % v == 0 and v % cur[d] == 0]
            ext = rng.choice(divs)
            if ext > cur[d]:
                loops.append(Loop(d, ext))
                cur[d] = ext
    # close every dim to full extent
    for d in dims:
        if cur[d] != problem.full_extent(d):
            loops.append(Loop(d, problem.full_extent(d)))
    return BlockingString(loops, problem)


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_model_equals_simulation(data):
    """INVARIANT: closed-form fill counts == simulated eviction events,
    for arbitrary valid loop orders and split sizes."""
    p = data.draw(small_problem())
    hypothesis.assume(p.macs <= 40_000)
    s = data.draw(blocking_string(p))
    rep = analyze(s)
    sim = simulate_fills(s)
    for bt in rep.per_buffer:
        if bt.buffer.pos < 0:
            continue
        sf, sw = sim[bt.buffer.name]
        assert sf == bt.fills, (repr(s), bt.buffer.name, sf, bt.fills)
        assert sw == bt.writebacks, (repr(s), bt.buffer.name, sw,
                                     bt.writebacks)


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_buffer_sizes_nested(data):
    """INVARIANT: per-operand buffer sizes are strictly increasing
    inner -> outer (placement only materializes strictly-larger buffers)."""
    p = data.draw(small_problem())
    s = data.draw(blocking_string(p))
    last: dict = {}
    for b in place_buffers(s):
        if b.pos < 0:
            continue
        if b.operand in last:
            assert b.size_elems > last[b.operand]
        last[b.operand] = b.size_elems


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_compulsory_traffic_bound(data):
    """INVARIANT: DRAM traffic >= one visit per element of each operand
    (weights/outputs; inputs can go below only if fully bufferable...
    they can't: outermost input buffer <= problem, so >= once)."""
    p = data.draw(small_problem())
    s = data.draw(blocking_string(p))
    rep = analyze(s)
    assert rep.dram_accesses_by_operand[Operand.WEIGHT] >= p.weight_elems
    assert rep.dram_accesses_by_operand[Operand.OUTPUT] >= p.output_elems
    assert rep.dram_accesses_by_operand[Operand.INPUT] >= \
        p.X * p.Y * p.C  # at least the non-halo interior once


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_energy_positive_and_finite(data):
    p = data.draw(small_problem())
    s = data.draw(blocking_string(p))
    rep = energy_custom(s)
    assert rep.total_pj > 0
    assert rep.mem_pj >= 0
    assert all(v >= 0 for v in rep.per_buffer_pj.values())


# -------- backward-op + serving schedules (ISSUE 2/3 nests) ----------------


@st.composite
def backward_spec(draw):
    """A random non-forward OpSpec (backward nests, the serving
    flash_decode nest, and the quantized matmul_w8/flash_decode_fp8
    variants) the tune pipeline must produce valid schedules for."""
    from repro.tune import OpSpec
    op = draw(st.sampled_from(["matmul_dgrad", "conv2d_dgrad",
                               "conv2d_wgrad", "flash_decode",
                               "matmul_w8", "flash_decode_fp8"]))
    if op in ("flash_decode", "flash_decode_fp8"):
        dims = (draw(st.sampled_from([1, 2, 4, 8])),        # GQA groups
                draw(st.sampled_from([64, 256, 1024, 4096])),  # KV length
                draw(st.sampled_from([16, 64, 128, 256])))  # head dim
        return OpSpec(op, dims)
    if op in ("matmul_dgrad", "matmul_w8"):
        dims = (draw(st.sampled_from([8, 64, 96, 256])),
                draw(st.sampled_from([32, 128, 384])),
                draw(st.sampled_from([16, 64, 512])))
        return OpSpec(op, dims)
    dims = (draw(st.sampled_from([6, 13, 26, 28])),
            draw(st.sampled_from([6, 13, 26, 28])),
            draw(st.sampled_from([3, 16, 32, 64])),
            draw(st.sampled_from([4, 8, 32, 128])),
            draw(st.sampled_from([1, 3])),
            draw(st.sampled_from([1, 3])))
    stride = 1 if op == "conv2d_dgrad" else draw(st.sampled_from([1, 2]))
    return OpSpec(op, dims, stride=stride)


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_backward_schedules_divide_and_fit_vmem(data):
    """INVARIANT: every scored schedule emitted for a backward op divides
    the problem dims (no silent oracle fallback) and fits the kernel's
    own vmem_bytes_required within the budget."""
    from repro.tune import candidates
    from repro.tune.lowering import divides, fits_vmem, vmem_budget
    spec = data.draw(backward_spec())
    budget = vmem_budget()
    cands = candidates(spec)
    assert cands, spec
    for s in cands:
        if s.predicted_dram_accesses is None:
            continue  # explicit fallback candidate: ops takes the oracle
        assert divides(spec, s.tiles), (spec, s.tiles)
        assert fits_vmem(spec, s.tiles, budget), (spec, s.tiles)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_backward_cache_round_trip(data):
    """INVARIANT: the cache round-trips every backward op key losslessly
    (spec, tiles, provenance metadata)."""
    import tempfile, os
    from repro.tune import Schedule, ScheduleCache
    from repro.tune.schedule import TILE_RANK
    spec = data.draw(backward_spec())
    tiles = tuple(data.draw(st.sampled_from([1, 2, 8, 64]))
                  for _ in range(TILE_RANK[spec.op]))
    sched = Schedule(spec, tiles, source="measured",
                     predicted_dram_accesses=data.draw(
                         st.integers(1, 10**9)),
                     measured_us=4.25)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "schedules.json")
        key = ScheduleCache(path).store(sched, device="cpu")
        assert key.startswith(spec.op + "/")
        got = ScheduleCache(path).lookup(spec, device="cpu")
    assert got is not None
    assert got.spec == spec
    assert got.tiles == tiles
    assert got.predicted_dram_accesses == sched.predicted_dram_accesses
    assert got.measured_us == sched.measured_us


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_narrower_dtype_never_shrinks_level0_tile(data):
    """INVARIANT (dtype-aware blocking): under a fixed SRAM budget,
    shrinking bytes-per-element never shrinks the level-0 tile the
    kernel can hold.  Concretely: every tile that fits the budget at
    the wide op (matmul / flash_decode) still fits at its quantized
    variant (matmul_w8 / flash_decode_fp8, 1-byte weight/KV stream), so
    the largest admissible tile is monotone non-decreasing — and at any
    shared tile the predicted DRAM *bytes* only go down."""
    from repro.core.loopnest import divisors
    from repro.tune import OpSpec, predicted_dram_bytes
    from repro.tune.lowering import divides, fits_vmem

    budget = data.draw(st.sampled_from([64 * 1024, 256 * 1024,
                                        1024 * 1024]))
    if data.draw(st.booleans()):
        M = data.draw(st.sampled_from([32, 64, 256]))
        N = data.draw(st.sampled_from([64, 128, 512]))
        K = data.draw(st.sampled_from([64, 256, 1024]))
        wide = OpSpec("matmul", (M, N, K), "bfloat16")
        narrow = OpSpec("matmul_w8", (M, N, K), "bfloat16")
        tiles = [(bm, bk, bn)
                 for bm in divisors(M)[-4:]
                 for bk in divisors(K)[-4:]
                 for bn in divisors(N)[-4:]]
    else:
        G = data.draw(st.sampled_from([1, 4, 8]))
        S = data.draw(st.sampled_from([256, 1024, 8192]))
        D = data.draw(st.sampled_from([64, 128, 256]))
        wide = OpSpec("flash_decode", (G, S, D), "bfloat16")
        narrow = OpSpec("flash_decode_fp8", (G, S, D), "bfloat16")
        tiles = [(bkv,) for bkv in divisors(S)]

    def volume(t):
        v = 1
        for x in t:
            v *= x
        return v

    fit_wide = [t for t in tiles if fits_vmem(wide, t, budget)]
    fit_narrow = [t for t in tiles if fits_vmem(narrow, t, budget)]
    for t in fit_wide:
        assert t in fit_narrow, (wide.op, t, budget)
    if fit_wide:
        assert max(map(volume, fit_narrow)) >= max(map(volume, fit_wide))
    for t in fit_wide:
        if divides(wide, t):
            assert predicted_dram_bytes(narrow, t, budget) <= \
                predicted_dram_bytes(wide, t, budget), (wide.op, t)


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_gemm_degenerate_case(data):
    """FC layers (Fw=Fh=Y=1): input footprint has no halo, and the model
    reduces to plain matmul blocking."""
    M = data.draw(st.sampled_from([4, 8]))
    N = data.draw(st.sampled_from([4, 8]))
    K = data.draw(st.sampled_from([4, 16]))
    p = Problem.gemm(M=M, N_cols=N, K_reduce=K)
    s = data.draw(blocking_string(p))
    rep = analyze(s)
    sim = simulate_fills(s)
    for bt in rep.per_buffer:
        if bt.buffer.pos < 0:
            continue
        assert sim[bt.buffer.name][0] == bt.fills
