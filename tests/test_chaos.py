"""Chaos harness (docs/robustness.md): injector units plus the
randomized fault schedules from ``repro.chaos.runner`` — the tier-1
home of the acceptance bar ``python -m repro.chaos --schedules 200``
(zero page leaks, every request terminal, survivors byte-exact)."""

import numpy as np
import pytest

from repro.chaos import FlakyAllocator, PlanChaos, run_schedules
from repro.chaos.runner import oracle
from repro.serve import kv_cache as KV
from repro.serve.scheduler import Request, Scheduler


# -- injector units ----------------------------------------------------------


def test_oracle_streams_compose():
    """The stand-in for greedy decode must be a pure function of
    (rid, position): splitting a stream cannot change it."""
    whole = oracle(5, 0, 10)
    split = np.concatenate([oracle(5, 0, 4), oracle(5, 4, 10)])
    np.testing.assert_array_equal(whole, split)
    assert not np.array_equal(oracle(5, 0, 10), oracle(6, 0, 10))


def test_flaky_allocator_lie_triggers_rollback():
    """An alloc that reneges mid-admission must roll back completely:
    zero leaked pages, the request still queued, and the very next
    round admits it."""
    alloc = FlakyAllocator(6, np.random.default_rng(0))
    sched = Scheduler(2, 2, alloc, 8)
    sched.submit(Request(0, np.zeros(3, np.int32), 2))
    alloc.fail_next = 1
    assert sched.admit() == []
    assert alloc.lies == 1
    assert alloc.in_use() == 0, "rollback leaked pages"
    assert sched._m_rollbacks.value == 1
    assert [r.rid for r in sched.waiting] == [0]
    assert [r.rid for r in sched.admit()] == [0]


def test_flaky_allocator_hostages_really_hold_pages():
    alloc = FlakyAllocator(6, np.random.default_rng(0))
    assert alloc.take_hostages(3) == 3
    assert alloc.in_use() == 3 and len(alloc.hostages) == 3
    assert alloc.take_hostages(99) == 2          # pool runs dry first
    assert alloc.release_hostages() == 5
    assert alloc.in_use() == 0 and not alloc.hostages
    assert alloc.available() == alloc.capacity


def test_plan_chaos_duplicates_and_drops():
    alloc = KV.PageAllocator(8)
    sched = Scheduler(2, 2, alloc, 8)
    for rid in range(2):
        sched.submit(Request(rid, np.zeros(2, np.int32), 4))
    assert len(sched.admit()) == 2
    for r in sched.running.values():             # force decode-ready
        r.prefilled = r.prompt_len
        r.generated = 1
    dup = PlanChaos(sched, np.random.default_rng(0), dup_rate=1.0)
    plan = dup.plan_step(2, 2)
    assert dup.dups == 2 and len(plan.decode_slots) == 4
    drop = PlanChaos(sched, np.random.default_rng(0), drop_rate=1.0)
    plan = drop.plan_step(2, 2)
    assert drop.drops == 2 and plan.decode_slots == []


# -- randomized schedules ----------------------------------------------------


def test_chaos_schedules_fast_batch():
    """A CI-sized batch of randomized fault schedules; every schedule
    asserts the full invariant set internally, and the batch must not
    be vacuously clean — each injector has to have fired."""
    stats = run_schedules(30, seed=1000)
    assert stats["schedules"] == 30
    for arm in ("lies", "preempts", "cancels", "dups", "drops",
                "hostage_rounds", "rollbacks"):
        assert stats[arm] > 0, f"fault arm {arm!r} never fired"


@pytest.mark.slow
def test_chaos_schedules_acceptance_bar():
    """The ISSUE acceptance criterion: 200 randomized fault schedules
    with zero page leaks, every request terminal, and survivors
    byte-exact (asserted inside each schedule)."""
    stats = run_schedules(200, seed=0)
    assert stats["schedules"] == 200
    for arm in ("lies", "preempts", "cancels", "ttl", "dups", "drops",
                "hostage_rounds", "rejected", "rollbacks"):
        assert stats[arm] > 0, f"fault arm {arm!r} never fired"


@pytest.mark.slow
def test_engine_chaos_smoke():
    """The real-engine schedule from ``repro.chaos --smoke``: NaN
    poisoning, forced preemption, TTL expiry and a clean survivor in
    one run, differential against the fault-free engine."""
    from repro.chaos.runner import engine_smoke
    out = engine_smoke(seed=0)
    assert out["nan_trips"] >= 1
    assert "failed" in out["statuses"].values()
    assert "preempted_retried" in out["statuses"].values()
