"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import _blocked_ref, flash_attention
from repro.kernels.matmul_blocked import matmul_blocked
from repro.kernels.conv2d_blocked import conv2d_block

RNG = np.random.default_rng(42)


def rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


TOL = {jnp.float32: dict(rtol=2e-3, atol=2e-4),
       jnp.bfloat16: dict(rtol=8e-2, atol=8e-2)}


@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (64, 64, 64, 32, 64, 32),
    (128, 256, 64, 64, 128, 64),
    (256, 128, 512, 8, 128, 256),
    (8, 128, 128, 8, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_blocked(m, k, n, bm, bk, bn, dtype):
    a, b = rand((m, k), dtype), rand((k, n), dtype)
    out = matmul_blocked(a, b, bm=bm, bk=bk, bn=bn, interpret=True)
    expect = ref.matmul_ref(a, b)
    np.testing.assert_allclose(out.astype(np.float32),
                               expect.astype(np.float32), **TOL[dtype])


@pytest.mark.parametrize("h,w,c,k,fh,fw,bc,bk,stride", [
    (8, 8, 4, 8, 3, 3, 4, 8, 1),
    (12, 10, 8, 16, 3, 3, 4, 8, 1),
    (9, 9, 2, 4, 2, 2, 2, 4, 1),
    (14, 14, 4, 8, 3, 3, 2, 4, 2),
    (8, 8, 4, 8, 1, 1, 4, 8, 1),   # 1x1 conv == GEMM
])
def test_conv2d_block(h, w, c, k, fh, fw, bc, bk, stride):
    x = rand((h, w, c))
    wgt = rand((fh, fw, c, k), scale=0.5)
    out = conv2d_block(x, wgt, bc=bc, bk=bk, stride=stride, interpret=True)
    expect = ref.conv2d_ref(x[None], wgt, stride)[0]
    np.testing.assert_allclose(out, expect, rtol=2e-3, atol=2e-4)


def test_conv2d_spatial_tiling_with_halo():
    """ops.conv2d tiles space outside the kernel — halo slicing must agree
    with the oracle at tile boundaries."""
    x = rand((2, 20, 20, 4))
    w = rand((3, 3, 4, 8), scale=0.5)
    out = ops.conv2d(x, w, tiles=(6, 6, 4, 8), interpret=True)
    np.testing.assert_allclose(out, ref.conv2d_ref(x, w),
                               rtol=2e-3, atol=2e-4)


def test_im2col_equals_direct():
    """The Caffe-style lowering oracle must agree with direct conv (the
    paper's premise: same math, different memory behaviour)."""
    x = rand((2, 10, 10, 3))
    w = rand((4, 4, 3, 5))
    np.testing.assert_allclose(ref.conv2d_im2col(x, w),
                               ref.conv2d_ref(x, w), rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("sq,skv,d,bq,bkv", [
    (32, 32, 16, 8, 8),
    (64, 64, 32, 16, 32),
    (16, 64, 16, 16, 16),   # decode-ish: fewer queries than keys
    (1, 32, 16, 1, 8),      # single-token decode
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(sq, skv, d, bq, bkv, causal):
    q, k, v = rand((sq, d)), rand((skv, d)), rand((skv, d))
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_kv=bkv,
                          interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, expect, rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("window", [8, 16])
def test_flash_attention_window(window):
    q, k, v = rand((32, 16)), rand((32, 16)), rand((32, 16))
    out = flash_attention(q, k, v, window=window, block_q=8, block_kv=8,
                          interpret=True)
    expect = ref.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(out, expect, rtol=2e-3, atol=2e-4)


def test_flash_attention_softcap():
    q, k, v = rand((32, 16)), rand((32, 16)), rand((32, 16))
    out = flash_attention(q, k, v, logit_cap=30.0, block_q=8, block_kv=8,
                          interpret=True)
    expect = ref.attention_ref(q, k, v, logit_cap=30.0)
    np.testing.assert_allclose(out, expect, rtol=2e-3, atol=2e-4)


def test_flash_attention_grad_matches_ref():
    q, k, v = rand((16, 8)), rand((16, 8)), rand((16, 8))

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, block_q=8, block_kv=8,
                                       interpret=True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v) ** 2)

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-4)


def test_blocked_ref_long_context():
    """The O(S) streaming oracle agrees on an uneven tail-block case."""
    q, k, v = rand((8, 16)), rand((128, 16)), rand((128, 16))
    out = _blocked_ref(q, k, v, causal=True, window=None, logit_cap=None,
                       block_kv=32)
    expect = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(out, expect, rtol=2e-3, atol=2e-4)


def test_ops_attention_gqa():
    q = rand((2, 32, 8, 16))
    k = rand((2, 32, 2, 16))
    v = rand((2, 32, 2, 16))
    out = ops.attention(q, k, v, tiles=(8, 8), interpret=True)
    for bi in range(2):
        for h in range(8):
            expect = ref.attention_ref(q[bi, :, h], k[bi, :, h // 4],
                                       v[bi, :, h // 4])
            np.testing.assert_allclose(out[bi, :, h], expect,
                                       rtol=2e-3, atol=3e-4)


def test_matmul_tiles_derived_from_model():
    from repro.core import matmul_tiles
    bm, bk, bn = matmul_tiles(4096, 4096, 4096, 2)
    assert bm % 8 == 0 and bk % 128 == 0 and bn % 128 == 0
    # VMEM fit (the default budget is vmem/8 = 16 MiB)
    assert (bm * bk + bk * bn) * 2 + bm * bn * 4 <= 16 * 1024 * 1024


def test_conv_tiles_fit_vmem():
    from repro.core import conv_tiles
    bx, by, bc, bk = conv_tiles(56, 56, 128, 256, 3, 3, 2)
    inp = (bx + 2) * (by + 2) * bc * 2
    wgt = 9 * bc * bk * 2
    out = bx * by * bk * 4
    assert inp + wgt + out <= 16 * 1024 * 1024
