"""Cross-op fusion (ISSUE 5): the FusedProblem capacity model, the
epilogue-fused / weight-stationary / oproj-fused Pallas kernels vs
their unfused op chains, and the tune plumbing for the new op keys."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.fusion import (Epilogue, FusedProblem, fused_energy_pj,
                               fused_multicore_dram_bytes, optimize_fused)
from repro.core.loopnest import Problem
from repro.kernels import ops

BUDGET = 2 * 1024 * 1024


# ========================= FusedProblem model ==============================


def test_fused_problem_validates_chain():
    p1 = Problem.gemm(M=64, N_cols=128, K_reduce=32)
    ok = Problem.gemm(M=64, N_cols=32, K_reduce=128)
    FusedProblem.pair(p1, ok)
    with pytest.raises(ValueError, match="consumes"):
        FusedProblem.pair(p1, Problem.gemm(M=64, N_cols=32, K_reduce=64))
    with pytest.raises(ValueError, match="row dim"):
        FusedProblem.pair(p1, Problem.gemm(M=32, N_cols=32, K_reduce=128))
    with pytest.raises(ValueError, match="at least two"):
        FusedProblem((p1,), (Epilogue(),))
    with pytest.raises(ValueError, match="GEMM-family"):
        FusedProblem.pair(Problem(X=8, Y=2, C=4, K=8), ok)


def test_tiles_must_share_fusion_dim_and_divide():
    fp = FusedProblem.mlp(M=64, d_model=32, d_ff=128)
    fp.validate_tiles([(16, 32, 64), (16, 128, 32)])
    with pytest.raises(ValueError, match="shared fusion tile"):
        fp.validate_tiles([(16, 32, 64), (32, 128, 32)])
    with pytest.raises(ValueError, match="divide"):
        fp.validate_tiles([(16, 32, 48), (16, 128, 32)])


def test_fused_never_exceeds_unfused_sweep():
    """Deterministic sweep of the core invariant: for any valid fusion
    tile the fused chain's predicted DRAM bytes never exceed the
    unfused pair's (a fused kernel can always spill the tile)."""
    fp = FusedProblem.mlp(M=256, d_model=128, d_ff=512)
    for bm in (8, 32, 64, 256):
        for bk in (32, 128):
            for bn in (64, 128):
                tiles = [(bm, bk, min(bn, 512)), (bm, min(bk, 512), bn)]
                tr = fp.traffic(tiles, BUDGET)
                assert tr.total_bytes <= tr.unfused_total_bytes, \
                    (tiles, tr)


def test_intermediate_zero_when_tile_fits():
    fp = FusedProblem.mlp(M=256, d_model=128, d_ff=512)
    tiles = [(64, 128, 128), (64, 512, 128)]
    assert fp.intermediate_fits(0, tiles, BUDGET)
    tr = fp.traffic(tiles, BUDGET, always_resident=True)
    assert tr.intermediate_resident == (True,)
    assert tr.intermediate_bytes == (0,)


def test_intermediate_counts_when_tile_does_not_fit():
    """A tiny level-0 budget spills the fusion tile: the intermediate
    crosses DRAM on both sides and the model says so."""
    fp = FusedProblem.mlp(M=256, d_model=128, d_ff=512)
    tiles = [(256, 128, 512), (256, 512, 128)]
    tiny = 4 * 1024
    assert not fp.intermediate_fits(0, tiles, tiny)
    tr = fp.traffic(tiles, tiny)
    assert tr.intermediate_resident == (False,)
    assert tr.intermediate_bytes[0] > 0
    # both sides: at least one write + one read of the full tensor
    assert tr.intermediate_bytes[0] >= \
        2 * fp.intermediate_elems(0) * fp.intermediate_bpe(0)


def test_epilogues_always_fuse():
    """Epilogue round-trips (activation, residual) are eliminated even
    when the inter-GEMM tile spills: fused < unfused at any budget."""
    fp = FusedProblem.mlp(M=256, d_model=128, d_ff=512)
    tiles = [(64, 128, 128), (64, 512, 128)]
    tiny = 4 * 1024
    tr = fp.traffic(tiles, tiny)
    assert tr.total_bytes < tr.unfused_total_bytes


def test_optimize_fused_reports_positive_savings():
    fp = FusedProblem.mlp(M=512, d_model=256, d_ff=1024)
    results = optimize_fused(fp, BUDGET)
    assert results, "search returned no feasible joint schedule"
    best = results[0]
    assert best.savings_bytes > 0
    assert best.fused_bytes == fp.fused_dram_bytes(best.tiles, BUDGET)
    # ranked: fused bytes non-decreasing
    fb = [r.fused_bytes for r in results]
    assert fb == sorted(fb)
    assert "saves" in best.summary()


def test_swiglu_and_w8_variants_model():
    """The SwiGLU gating multiply adds a streamed operand; the w8
    weight stream narrows — both flow through the model's per-operand
    byte accounting."""
    wide = FusedProblem.mlp(M=256, d_model=128, d_ff=512, swiglu=True)
    w8 = FusedProblem.mlp(M=256, d_model=128, d_ff=512, swiglu=True,
                          weight_bytes=1)
    tiles = [(64, 128, 128), (64, 512, 128)]
    assert w8.fused_dram_bytes(tiles, BUDGET) < \
        wide.fused_dram_bytes(tiles, BUDGET)


def test_fused_energy_below_unfused_stage_sum():
    from repro.core.hierarchy import MemLevel, energy_fixed
    from repro.core.fusion import _gemm_string
    fp = FusedProblem.mlp(M=256, d_model=128, d_ff=512)
    tiles = [(64, 128, 128), (64, 512, 128)]
    levels = [MemLevel.sram("VMEM", BUDGET), MemLevel.dram("HBM")]
    unfused = sum(energy_fixed(_gemm_string(p, t), levels).mem_pj
                  for p, t in zip(fp.stages, tiles))
    assert fused_energy_pj(fp, tiles, BUDGET) < unfused


def test_multicore_fusion_only_survives_xy_partitioning():
    """K partitioning scatters the intermediate's channels across cores
    while the consumer reduces over all of them — fusion buys nothing
    there; XY keeps the per-core fusion intact."""
    fp = FusedProblem.mlp(M=256, d_model=128, d_ff=512)
    tiles = [(64, 128, 128), (64, 512, 128)]
    single = fp.fused_dram_bytes(tiles, BUDGET)
    # XY at 1 core degenerates to the single-core fused chain
    assert fused_multicore_dram_bytes(fp, tiles, BUDGET, "XY", 1) == single
    # K scatters the intermediate's channels across cores: it is NEVER
    # eliminated, so the K-scheme chain carries strictly more traffic
    # than the single-core fused chain that kept it resident
    kk = fused_multicore_dram_bytes(fp, tiles, BUDGET, "K", 4)
    assert fp.traffic(tiles, BUDGET).intermediate_resident == (True,)
    assert kk > single
    with pytest.raises(ValueError):
        fused_multicore_dram_bytes(fp, tiles, BUDGET, "Z", 4)


def test_fusion_capacity_property_hypothesis():
    """ISSUE 5 satellite: for ANY valid fusion tile, predicted fused
    DRAM bytes <= the unfused pair's, and the intermediate contributes
    zero DRAM traffic when its tile fits level 0.  Stated on the
    capacity layer (FusedProblem), not on search winners."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    dims = st.sampled_from([16, 32, 64, 128, 256])
    tile_of = st.sampled_from([8, 16, 32, 64, 128, 256])

    @settings(max_examples=60, deadline=None)
    @given(data=st.data())
    def run(data):
        M = data.draw(dims)
        d_model = data.draw(dims)
        d_ff = data.draw(dims)
        swiglu = data.draw(st.booleans())
        wb = data.draw(st.sampled_from([None, 1]))
        fp = FusedProblem.mlp(M, d_model, d_ff, swiglu=swiglu,
                              weight_bytes=wb)

        def tile(full):
            t = data.draw(tile_of)
            while full % t:
                t //= 2
            return max(t, 1)

        bm = tile(M)
        tiles = [(bm, tile(d_model), tile(d_ff)),
                 (bm, tile(d_ff), tile(d_model))]
        budget = data.draw(st.sampled_from(
            [8 * 1024, 64 * 1024, 1024 * 1024]))
        tr = fp.traffic(tiles, budget)
        assert tr.total_bytes <= tr.unfused_total_bytes
        if fp.intermediate_fits(0, tiles, budget):
            forced = fp.traffic(tiles, budget, always_resident=True)
            assert forced.intermediate_bytes == (0,)
            assert forced.total_bytes <= tr.unfused_total_bytes or \
                not forced.intermediate_resident[0]

    run()


# ===================== fused kernels vs unfused chains ======================


@pytest.mark.parametrize("kw", [
    {},
    {"act": "gelu", "bias": True},
    {"act": "silu", "mul": True},
    {"residual": True},
    {"act": "relu", "bias": True, "mul": True, "residual": True},
])
def test_matmul_fused_kernel_matches_unfused_chain(kw):
    """The epilogue-fused GEMM == the per-op chain (matmul, then bias,
    act, mul, residual as separate jnp ops) within fp tolerance."""
    rng = np.random.default_rng(0)
    M, K, N = 32, 64, 48
    a = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(N,)), jnp.float32) \
        if kw.get("bias") else None
    mul = jnp.asarray(rng.normal(size=(M, N)), jnp.float32) \
        if kw.get("mul") else None
    res = jnp.asarray(rng.normal(size=(M, N)), jnp.float32) \
        if kw.get("residual") else None
    act = kw.get("act", "none")

    chain = jnp.dot(a, w)
    if bias is not None:
        chain = chain + bias
    chain = {"none": lambda x: x, "relu": jax.nn.relu,
             "gelu": jax.nn.gelu, "silu": jax.nn.silu}[act](chain)
    if mul is not None:
        chain = chain * mul
    if res is not None:
        chain = chain + res

    out = ops.matmul_fused(a, w, bias=bias, act=act, mul=mul,
                           residual=res, tiles=(16, 32, 16),
                           use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(chain),
                               rtol=1e-5, atol=1e-5)


def test_matmul_fused_w8_matches_quantized_chain():
    """int8-weight epilogue fusion == dequant GEMM + the pointwise tail
    (the PR 4 path composes with fusion)."""
    from repro.kernels.matmul_q import matmul_w8_ref
    from repro.quant import quantize
    rng = np.random.default_rng(1)
    M, K, N = 32, 64, 48
    a = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    res = jnp.asarray(rng.normal(size=(M, N)), jnp.float32)
    qt = quantize(w, "int8")
    chain = jax.nn.gelu(matmul_w8_ref(a, qt.q, qt.scale.reshape(-1))) \
        + res
    out = ops.matmul_fused(a, qt, act="gelu", residual=res,
                           tiles=(16, 32, 16), use_kernel=True,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(chain),
                               rtol=1e-4, atol=1e-4)


def test_matmul_fused_ragged_falls_back_to_oracle():
    """Non-dividing shapes take the jnp oracle: identical to the
    unfused chain bit-for-bit in fp32."""
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(30, 52)), jnp.float32)  # ragged
    w = jnp.asarray(rng.normal(size=(52, 37)), jnp.float32)
    res = jnp.asarray(rng.normal(size=(30, 37)), jnp.float32)
    out = ops.matmul_fused(a, w, act="gelu", residual=res,
                           use_kernel=True, interpret=True)
    chain = jax.nn.gelu(jnp.dot(a, w)) + res
    np.testing.assert_array_equal(np.asarray(out), np.asarray(chain))


def test_matmul_fused_strided_operands():
    """Transposed (strided) operand views hit the same kernel path and
    match the unfused chain — the layout is materialized by XLA, not
    assumed by the BlockSpecs."""
    rng = np.random.default_rng(7)
    at = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    wt = jnp.asarray(rng.normal(size=(48, 64)), jnp.float32)
    a, w = at.T, wt.T                      # (32, 64) @ (64, 48)
    res = jnp.asarray(rng.normal(size=(32, 48)), jnp.float32)
    out = ops.matmul_fused(a, w, act="gelu", residual=res,
                           tiles=(16, 32, 16), use_kernel=True,
                           interpret=True)
    chain = jax.nn.gelu(jnp.dot(a, w)) + res
    np.testing.assert_allclose(np.asarray(out), np.asarray(chain),
                               rtol=1e-5, atol=1e-5)


def test_matmul_fused_leading_dims():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 16, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    res = jnp.asarray(rng.normal(size=(2, 16, 32)), jnp.float32)
    out = ops.matmul_fused(x, w, residual=res, tiles=(8, 32, 16),
                           use_kernel=True, interpret=True)
    assert out.shape == (2, 16, 32)
    ref = jnp.einsum("bsk,kn->bsn", x, w) + res
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_qkv_fused_matches_three_gemms():
    rng = np.random.default_rng(4)
    M, K, nkv, g = 24, 64, 32, 3
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    wq = jnp.asarray(rng.normal(size=(K, g * nkv)), jnp.float32)
    wk = jnp.asarray(rng.normal(size=(K, nkv)), jnp.float32)
    wv = jnp.asarray(rng.normal(size=(K, nkv)), jnp.float32)
    q, k, v = ops.qkv_fused(x, wq, wk, wv, tiles=(8, 32, 16),
                            use_kernel=True, interpret=True)
    for got, w in ((q, wq), (k, wk), (v, wv)):
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(x @ w), rtol=1e-5,
                                   atol=1e-5)


def test_qkv_fused_ragged_oracle_is_exact():
    """Ragged / non-GQA-multiple shapes fall back to three dots that
    are bit-identical to the unfused projections in fp32."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(2, 7, 48)), jnp.float32)
    wq = jnp.asarray(rng.normal(size=(48, 36)), jnp.float32)
    wk = jnp.asarray(rng.normal(size=(48, 12)), jnp.float32)
    wv = jnp.asarray(rng.normal(size=(48, 12)), jnp.float32)
    q, k, v = ops.qkv_fused(x, wq, wk, wv, use_kernel=True,
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(x @ wq))
    np.testing.assert_array_equal(np.asarray(k), np.asarray(x @ wk))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(x @ wv))


@pytest.mark.parametrize("window,logit_cap", [(None, None), (7, None),
                                              (None, 30.0), (5, 20.0)])
def test_flash_decode_oproj_matches_unfused_pair(window, logit_cap):
    """The oproj-fused decode kernel == paged attention followed by the
    dense projection, over ragged lengths and shuffled block tables."""
    rng = np.random.default_rng(6)
    B, hkv, G, D, page, nb, E = 3, 2, 3, 16, 8, 4, 40
    n_pages = B * nb + 1
    q = jnp.asarray(rng.normal(size=(B, hkv * G, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, page, hkv, D)),
                     jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, page, hkv, D)),
                     jnp.float32)
    bt = jnp.asarray(1 + rng.permutation(B * nb).reshape(B, nb),
                     jnp.int32)
    lengths = jnp.asarray([1, 13, 32], jnp.int32)
    wo = jnp.asarray(rng.normal(size=(hkv * G * D, E)), jnp.float32)

    unfused = ops.paged_attention(q, kp, vp, bt, lengths, window=window,
                                  logit_cap=logit_cap)
    unfused = unfused.reshape(B, hkv * G * D) @ wo

    fused = ops.paged_attention_oproj(q, kp, vp, bt, lengths, wo,
                                      window=window,
                                      logit_cap=logit_cap,
                                      use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-4, atol=1e-4)
    # the off-kernel oracle is the exact unfused pair
    oracle = ops.paged_attention_oproj(q, kp, vp, bt, lengths, wo,
                                       window=window,
                                       logit_cap=logit_cap,
                                       use_kernel=False)
    np.testing.assert_allclose(np.asarray(oracle), np.asarray(unfused),
                               rtol=1e-6, atol=1e-6)


# ====================== model-layer fusion routing ==========================


@pytest.mark.parametrize("arch", ["granite-3-8b", "gemma2-9b"])
def test_mlp_and_attention_fused_context_is_exact(arch):
    """With fused ops enabled (oracle path, as the engines run on CPU)
    the MLP block and attention are bit-identical to the unfused
    layers in fp32 — the invariant the token-exact serving tests
    lean on."""
    import dataclasses
    from repro.configs import get_reduced
    from repro.models import layers as L
    cfg = dataclasses.replace(get_reduced(arch), dtype=jnp.float32)
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    h = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    mdefs = L.mlp_defs(cfg, 1)
    from repro.models.base import build
    mp = build(mdefs, "init", key)
    ref_out = L.mlp_apply(mp, x, residual=h)
    with ops.fused_ops(True):
        fused_out = L.mlp_apply(mp, x, residual=h)
    np.testing.assert_array_equal(np.asarray(ref_out),
                                  np.asarray(fused_out))

    adefs = L.attention_defs(cfg, 1)
    ap = build(adefs, "init", key)
    positions = jnp.broadcast_to(jnp.arange(8), (2, 8))
    ref_attn = L.attention_apply(cfg, ap, x, positions)
    with ops.fused_ops(True):
        fused_attn = L.attention_apply(cfg, ap, x, positions)
    np.testing.assert_array_equal(np.asarray(ref_attn),
                                  np.asarray(fused_attn))


def test_fused_ops_flag_default_off():
    assert not ops.fused_ops_enabled()
    with ops.fused_ops(True):
        assert ops.fused_ops_enabled()
        with ops.fused_ops(False):
            assert not ops.fused_ops_enabled()
    assert not ops.fused_ops_enabled()


# ========================= tune plumbing (new keys) =========================


@pytest.mark.parametrize("op,dims", [
    ("matmul_fused", (256, 512, 256)),
    ("qkv_fused", (64, 64, 256, 4)),
    ("flash_decode_oproj", (4, 512, 64, 256)),
])
def test_fused_op_schedules_divide_fit_and_round_trip(op, dims):
    from repro.tune import (OpSpec, Schedule, candidates, divides,
                            fits_vmem, predicted_dram_bytes, vmem_budget)
    spec = OpSpec(op, dims, "float32")
    ranked = candidates(spec)
    assert ranked
    budget = vmem_budget()
    for s in ranked:
        assert divides(spec, s.tiles), s
        assert fits_vmem(spec, s.tiles, budget), s
        assert predicted_dram_bytes(spec, s.tiles) > 0
    # JSON round trip through the schedule cache format
    rt = Schedule.from_json(ranked[0].to_json())
    assert rt.spec == spec and rt.tiles == ranked[0].tiles


def test_fused_op_schedule_cache_round_trip(tmp_path):
    from repro.tune import OpSpec, Schedule, ScheduleCache
    cache = ScheduleCache(str(tmp_path / "schedules.json"))
    spec = OpSpec("flash_decode_oproj", (2, 128, 32, 64), "float32")
    cache.store(Schedule(spec, (64,), source="measured",
                         measured_us=3.0), device="cpu")
    hit = ScheduleCache(str(tmp_path / "schedules.json")).lookup(
        spec, device="cpu")
    assert hit is not None and hit.tiles == (64,)


def test_choose_page_size_fused_key(tmp_path):
    """A fusion-enabled engine sizes its pages under the
    flash_decode_oproj key — a tuned entry there wins."""
    import dataclasses
    from repro.configs import get_reduced
    from repro.serve.kv_cache import choose_page_size
    from repro.tune import OpSpec, Schedule, ScheduleCache
    cfg = dataclasses.replace(get_reduced("granite-3-8b"),
                              dtype=jnp.float32)
    g = cfg.n_heads // cfg.n_kv_heads
    cache = ScheduleCache(str(tmp_path / "s.json"))
    spec = OpSpec("flash_decode_oproj",
                  (g, 64, cfg.head_dim, cfg.d_model), "float32")
    cache.store(Schedule(spec, (16,)), device="cpu")
    assert choose_page_size(cfg, 64, cache=cache, fused=True) == 16


def test_measure_runs_fused_ops():
    """The measurement harness executes all three fused op kinds end to
    end (interpret mode) without falling over."""
    from repro.tune import OpSpec, Schedule
    from repro.tune.measure import make_inputs, run_once
    for op, dims, tiles in [
        ("matmul_fused", (32, 32, 64), (16, 32, 16)),
        ("qkv_fused", (16, 16, 64, 2), (8, 32, 16)),
        ("flash_decode_oproj", (2, 64, 32, 64), (16,)),
    ]:
        sched = Schedule(OpSpec(op, dims, "float32"), tiles)
        out = run_once(sched, make_inputs(sched), interpret=True)
        assert np.all(np.isfinite(np.asarray(out, np.float32)))