"""Autotuner subsystem: cache round-trip, lowering constraints,
best_schedule fallback, and an interpret-mode end-to-end conv tune."""

import json

import numpy as np
import pytest

from repro.tune import (OpSpec, Schedule, ScheduleCache, best_schedule,
                        candidates, predicted_dram_accesses,
                        schedule_to_string, tune_op)
from repro.tune.lowering import divides, fits_vmem, vmem_budget


# -- cache -----------------------------------------------------------------


def test_cache_round_trip(tmp_path):
    path = str(tmp_path / "schedules.json")
    spec = OpSpec("matmul", (256, 256, 512), "bfloat16")
    sched = Schedule(spec, (64, 128, 128), source="measured",
                     predicted_dram_accesses=12345, measured_us=6.5)
    cache = ScheduleCache(path)
    assert cache.lookup(spec, device="cpu") is None
    key = cache.store(sched, device="cpu")
    assert key == "matmul/m256n256k512/bfloat16/cpu"

    fresh = ScheduleCache(path)  # new process's view
    got = fresh.lookup(spec, device="cpu")
    assert got is not None
    assert got.spec == spec
    assert got.tiles == (64, 128, 128)
    assert got.source == "cache"  # disk hits are tagged as such
    assert got.predicted_dram_accesses == 12345
    assert got.measured_us == 6.5


def test_cache_is_device_keyed_and_merges(tmp_path):
    path = str(tmp_path / "schedules.json")
    spec = OpSpec("matmul", (64, 64, 64))
    ScheduleCache(path).store(Schedule(spec, (64, 64, 64),
                                       source="measured"), device="cpu")
    ScheduleCache(path).store(Schedule(spec, (8, 64, 64)), device="tpu")
    cache = ScheduleCache(path)
    assert cache.lookup(spec, device="cpu").tiles == (64, 64, 64)
    assert cache.lookup(spec, device="tpu").tiles == (8, 64, 64)
    assert len(cache.keys()) == 2
    # merging a second entry must not rewrite the first one's provenance
    entries = json.loads((tmp_path / "schedules.json").read_text())
    assert entries["schedules"]["matmul/m64n64k64/float32/cpu"]["source"] \
        == "measured"


def test_cache_survives_corrupt_file(tmp_path):
    path = tmp_path / "schedules.json"
    path.write_text("{not json")
    cache = ScheduleCache(str(path))
    spec = OpSpec("conv2d", (8, 8, 4, 8, 3, 3))
    with pytest.warns(UserWarning, match="quarantin"):
        assert cache.lookup(spec, device="cpu") is None
    cache.store(Schedule(spec, (8, 8, 4, 8)), device="cpu")
    assert json.loads(path.read_text())["version"] == 1


def test_cache_quarantines_corrupt_file(tmp_path):
    """A corrupt cache file must not abort startup: it is moved aside
    to ``<path>.corrupt`` (evidence preserved for the operator), a
    warning names it, and the cache rebuilds cleanly in its place
    (docs/robustness.md)."""
    path = tmp_path / "schedules.json"
    spec = OpSpec("conv2d", (8, 8, 4, 8, 3, 3))
    path.write_text("{truncated by a crashed writ")
    with pytest.warns(UserWarning, match="quarantin"):
        assert ScheduleCache(str(path)).lookup(spec, device="cpu") is None
    corrupt = tmp_path / "schedules.json.corrupt"
    assert corrupt.read_text() == "{truncated by a crashed writ"
    assert not path.exists()
    # the rebuilt cache round-trips where the corrupt file stood
    cache = ScheduleCache(str(path))
    cache.store(Schedule(spec, (8, 8, 4, 8)), device="cpu")
    assert ScheduleCache(str(path)).lookup(spec, device="cpu") is not None
    # a well-formed but non-dict document quarantines the same way
    # (overwriting the previous quarantine: latest evidence wins)
    path2 = tmp_path / "other.json"
    path2.write_text("[1, 2, 3]")
    with pytest.warns(UserWarning, match="quarantin"):
        assert ScheduleCache(str(path2)).lookup(spec, device="cpu") is None
    assert (tmp_path / "other.json.corrupt").exists()
    # a missing file is a cold start, not corruption: no warning
    ScheduleCache(str(tmp_path / "absent.json")).lookup(spec, device="cpu")


# -- lowering --------------------------------------------------------------


def test_matmul_candidates_divide_and_fit():
    spec = OpSpec("matmul", (256, 256, 512), "bfloat16")
    budget = 256 * 1024  # small budget forces real tiling
    cands = candidates(spec, vmem_budget_bytes=budget)
    assert cands
    for s in cands:
        assert divides(spec, s.tiles)
        assert fits_vmem(spec, s.tiles, budget)
        assert s.predicted_dram_accesses is not None


def test_conv_candidates_divide_and_fit():
    spec = OpSpec("conv2d", (26, 26, 32, 64, 3, 3))
    budget = vmem_budget()
    cands = candidates(spec)
    assert cands
    for s in cands:
        assert divides(spec, s.tiles)
        assert fits_vmem(spec, s.tiles, budget)


def test_strided_conv_candidates_respect_stride_halo():
    """The snap loop must budget the stride-widened input halo, or the
    candidate filter (which does) rejects everything."""
    spec = OpSpec("conv2d", (56, 56, 64, 128, 7, 7), stride=2)
    budget = 4 * 1024 * 1024  # tight enough that the halo term is decisive
    cands = candidates(spec, vmem_budget_bytes=budget)
    assert cands
    for s in cands:
        assert fits_vmem(spec, s.tiles, budget)
        assert s.predicted_dram_accesses is not None


def test_candidates_ranked_by_predicted_accesses():
    spec = OpSpec("matmul", (512, 512, 512), "bfloat16")
    cands = candidates(spec, vmem_budget_bytes=512 * 1024)
    accesses = [s.predicted_dram_accesses for s in cands]
    assert accesses == sorted(accesses)


def test_schedule_to_string_covers_problem():
    spec = OpSpec("conv2d", (26, 26, 32, 64, 3, 3))
    s = schedule_to_string(spec, (13, 13, 32, 64))
    # BlockingString validates full coverage on construction; check the
    # level-0 block is what we asked for.
    assert "X13" in repr(s) and "Y13" in repr(s)
    assert s.problem.X == 26 and s.problem.K == 64


def test_predicted_accesses_reject_non_dividing_tiles():
    spec = OpSpec("matmul", (256, 256, 512))
    with pytest.raises(ValueError, match="do not divide"):
        predicted_dram_accesses(spec, (96, 128, 128))


def test_ragged_problem_falls_back_without_bogus_measurement(tmp_path):
    """M=257 divides by no MXU-aligned tile: the tuner must not persist
    an oracle timing as if the kernel achieved it."""
    cache = ScheduleCache(str(tmp_path / "schedules.json"))
    winner = tune_op("matmul", (257, 256, 512), "float32", measure=True,
                     interpret=True, cache=cache)
    assert winner.source == "analytic"
    assert winner.measured_us is None


def test_predicted_accesses_prefer_halo_free_tiles():
    """Full-frame spatial tiles refetch no halo: the model must score them
    at or below a halo-paying 2x2 spatial split."""
    spec = OpSpec("conv2d", (26, 26, 32, 64, 3, 3))
    full = predicted_dram_accesses(spec, (26, 26, 32, 64))
    split = predicted_dram_accesses(spec, (13, 13, 32, 64))
    assert full <= split


# -- best_schedule ---------------------------------------------------------


def test_best_schedule_fallback_is_analytic(tmp_path):
    cache = ScheduleCache(str(tmp_path / "empty.json"))
    s = best_schedule("matmul", (128, 128, 128), "float32", cache=cache)
    assert s.source == "analytic"
    assert divides(s.spec, s.tiles)


def test_best_schedule_prefers_cache(tmp_path):
    cache = ScheduleCache(str(tmp_path / "schedules.json"))
    spec = OpSpec("matmul", (128, 128, 128), "float32")
    cache.store(Schedule(spec, (8, 128, 128), source="measured"))
    s = best_schedule("matmul", (128, 128, 128), "float32", cache=cache)
    assert s.tiles == (8, 128, 128)
    assert s.source in ("cache", "measured")


def test_best_schedule_rederives_when_cached_tiles_blow_budget(tmp_path):
    """An explicit VMEM budget must override an oversized cache hit."""
    cache = ScheduleCache(str(tmp_path / "schedules.json"))
    spec = OpSpec("matmul", (512, 512, 512), "bfloat16")
    cache.store(Schedule(spec, (512, 512, 512), source="measured"))
    small = 256 * 1024
    s = best_schedule("matmul", (512, 512, 512), "bfloat16", cache=cache,
                      vmem_budget_bytes=small)
    assert s.source == "analytic"
    assert fits_vmem(spec, s.tiles, small)


def test_best_schedule_ignores_other_dtypes(tmp_path):
    cache = ScheduleCache(str(tmp_path / "schedules.json"))
    cache.store(Schedule(OpSpec("matmul", (128, 128, 128), "bfloat16"),
                         (8, 128, 128), source="measured"))
    s = best_schedule("matmul", (128, 128, 128), "float32", cache=cache)
    assert s.source == "analytic"


def test_opspec_validation():
    with pytest.raises(ValueError):
        OpSpec("matmul", (1, 2))
    with pytest.raises(ValueError):
        OpSpec("relu", (1, 2, 3))
    with pytest.raises(ValueError):
        Schedule(OpSpec("conv2d", (8, 8, 4, 8, 3, 3)), (8, 8, 4))


# -- end-to-end ------------------------------------------------------------


def test_tune_op_end_to_end_interpret(tmp_path):
    """Tiny conv: tune (measured, interpret mode), persist, and check the
    winner both round-trips through the cache and computes correctly."""
    from repro.kernels import ops, ref
    import jax.numpy as jnp

    cache = ScheduleCache(str(tmp_path / "schedules.json"))
    dims = (6, 6, 4, 8, 3, 3)
    winner = tune_op("conv2d", dims, "float32", top_n=2, interpret=True,
                     cache=cache, persist=True)
    assert winner.source == "measured"
    assert winner.measured_us > 0

    hit = best_schedule("conv2d", dims, "float32", cache=cache)
    assert hit.tiles == winner.tiles

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 8, 8, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)) * 0.5, jnp.float32)
    out = ops.conv2d(x, w, tiles=winner.tiles, interpret=True)
    np.testing.assert_allclose(out, ref.conv2d_ref(x, w),
                               rtol=2e-3, atol=2e-4)


def test_tune_op_analytic_only(tmp_path):
    cache = ScheduleCache(str(tmp_path / "schedules.json"))
    winner = tune_op("matmul", (64, 64, 64), "float32", measure=False,
                     cache=cache, persist=True)
    assert winner.source == "analytic"
    assert ScheduleCache(cache.path).lookup(winner.spec) is not None
