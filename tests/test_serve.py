"""Serving subsystem: paged KV cache, flash-decode kernel, scheduler,
and end-to-end continuous batching vs the dense static-batch engine."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve import kv_cache as KV
from repro.serve.engine import (DecodeEngine, PagedEngine, PagedServeConfig,
                                ServeConfig, default_buckets)
from repro.serve.scheduler import Request, Scheduler


def _cfg(arch: str):
    return dataclasses.replace(get_reduced(arch), dtype=jnp.float32)


# ===================== flash_decode kernel vs jnp oracle ====================


@pytest.mark.parametrize("window,logit_cap", [(None, None), (7, None),
                                              (None, 30.0), (5, 20.0)])
def test_flash_decode_kernel_matches_oracle(window, logit_cap):
    """Pallas kernel (interpret) == dense oracle over ragged cache
    lengths, shuffled block tables, GQA groups, partial last pages."""
    from repro.kernels.flash_decode import flash_decode, paged_attention_ref
    rng = np.random.default_rng(0)
    B, hkv, G, D, page, nb = 3, 2, 3, 16, 8, 4
    n_pages = B * nb + 1
    q = jnp.asarray(rng.normal(size=(B, hkv, G, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, page, hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, page, hkv, D)), jnp.float32)
    bt = jnp.asarray(1 + rng.permutation(B * nb).reshape(B, nb), jnp.int32)
    lengths = jnp.asarray([1, 13, 32], jnp.int32)   # ragged, incl. edges
    out_k = flash_decode(q, kp, vp, bt, lengths, window=window,
                         logit_cap=logit_cap, interpret=True)
    out_r = paged_attention_ref(q, kp, vp, bt, lengths, window=window,
                                logit_cap=logit_cap)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


def test_paged_attention_matches_dense_attention_decode():
    """ops.paged_attention == layers.attention_decode on the same cache
    content (the paged layout is a pure re-indexing of the dense one)."""
    from repro.kernels import ops
    from repro.models import layers as L
    cfg = _cfg("granite-3-8b")
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rng = np.random.default_rng(1)
    B, page, nb = 2, 4, 4
    max_seq = page * nb
    pos = 9                          # tokens 0..9 cached, 9 = current
    k_dense = jnp.asarray(rng.normal(size=(B, max_seq, hkv, hd)),
                          jnp.float32)
    v_dense = jnp.asarray(rng.normal(size=(B, max_seq, hkv, hd)),
                          jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, hq, hd)), jnp.float32)

    # dense: softmax over slots <= pos
    groups = hq // hkv
    qh = q.reshape(B, hkv, groups, hd)
    logits = jnp.einsum("bhgd,blhd->bhgl", qh, k_dense) * hd ** -0.5
    valid = jnp.arange(max_seq) <= pos
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    ref = jnp.einsum("bhgl,blhd->bhgd", probs, v_dense).reshape(B, hq, hd)

    # paged: same content scattered to (shuffled) pages per request
    n_pages = B * nb + 1
    kp = jnp.zeros((n_pages, page, hkv, hd), jnp.float32)
    vp = jnp.zeros((n_pages, page, hkv, hd), jnp.float32)
    bt = np.zeros((B, nb), np.int32)
    perm = 1 + rng.permutation(B * nb)
    for b in range(B):
        for i in range(nb):
            pg = int(perm[b * nb + i])
            bt[b, i] = pg
            kp = kp.at[pg].set(k_dense[b, i * page:(i + 1) * page])
            vp = vp.at[pg].set(v_dense[b, i * page:(i + 1) * page])
    lengths = jnp.full((B,), pos + 1, jnp.int32)
    out = ops.paged_attention(q, kp, vp, jnp.asarray(bt), lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ==================== paged vs dense logit equivalence ======================


@pytest.mark.parametrize("arch", ["granite-3-8b", "gemma2-9b",
                                  "recurrentgemma-9b"])
def test_paged_decode_logits_match_dense(arch):
    """prefill -> N decode steps: the paged cache + flash-decode path
    must reproduce the dense ring-buffer decode logits."""
    cfg = _cfg(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    L, steps, page, max_seq = 6, 5, 4, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, L + steps)),
                       jnp.int32)

    log_d, cache_d = T.prefill(cfg, params, toks[:, :L], max_seq)

    nb = KV.num_blocks(max_seq, page)
    paged = KV.init_paged_cache(cfg, batch=1, n_pages=nb + 1,
                                page_size=page)
    pages = jnp.arange(1, nb + 1, dtype=jnp.int32)
    log_p, dense_full = T.prefill(cfg, params, toks[:, :L], max_seq,
                                  full_kv=True, logits_at=L - 1)
    paged = KV.write_prefill(cfg, paged, dense_full, jnp.int32(0), pages,
                             page)
    block_tables = pages[None, :]
    np.testing.assert_allclose(np.asarray(log_p), np.asarray(log_d),
                               rtol=1e-5, atol=1e-4)

    lengths = jnp.asarray([L], jnp.int32)
    for t in range(L, L + steps):
        log_d, cache_d = T.decode_step(cfg, params, toks[:, t], cache_d,
                                       jnp.int32(t))
        attn = KV.make_paged_attn_step(cfg, block_tables, page)
        log_p, paged = T.decode_step(cfg, params, toks[:, t], paged,
                                     lengths, attn_step=attn)
        lengths = lengths + 1
        np.testing.assert_allclose(np.asarray(log_p), np.asarray(log_d),
                                   rtol=1e-5, atol=1e-4, err_msg=str(t))


# ========================= scheduler invariants =============================


def test_allocator_basics():
    a = KV.PageAllocator(5)
    assert a.capacity == 4 and a.available() == 4
    p = a.alloc()
    assert p != KV.SCRATCH_PAGE
    a.share(p)
    a.free(p)
    assert a.available() == 3        # still one reference held
    a.free(p)
    assert a.available() == 4
    with pytest.raises(ValueError):
        a.free(p)                    # double free
    pages = a.alloc_many(4)
    with pytest.raises(MemoryError):
        a.alloc()
    a.free_many(pages)
    assert a.available() == 4


def test_scheduler_rejects_oversized_request():
    sched = Scheduler(2, 4, KV.PageAllocator(9), max_seq=16)
    with pytest.raises(ValueError):
        sched.submit(Request(0, np.zeros(10, np.int32), 10))


def test_scheduler_rejects_request_exceeding_pool_capacity():
    """A request needing more pages than the whole pool would never be
    admitted — submit must fail loudly instead of spinning forever."""
    sched = Scheduler(2, 8, KV.PageAllocator(3), max_seq=64)
    with pytest.raises(ValueError, match="pool"):
        sched.submit(Request(0, np.zeros(20, np.int32), 8))


def test_scheduler_invariants_hypothesis():
    """Random submit/step/evict traces: no page leaked or double-owned,
    capacity never exceeded, FIFO admission under the page budget."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(data=st.data())
    def run(data):
        n_pages = data.draw(st.integers(3, 12))
        page_size = data.draw(st.sampled_from([2, 4, 8]))
        max_batch = data.draw(st.integers(1, 4))
        max_seq = page_size * (n_pages - 1)
        alloc = KV.PageAllocator(n_pages)
        sched = Scheduler(max_batch, page_size, alloc, max_seq)
        rid = 0
        for _ in range(data.draw(st.integers(1, 12))):
            op = data.draw(st.sampled_from(["submit", "admit", "finish"]))
            if op == "submit":
                L = data.draw(st.integers(1, max(1, max_seq // 2)))
                n = data.draw(st.integers(1, max(1, max_seq - L)))
                sched.submit(Request(rid, np.zeros(L, np.int32), n))
                rid += 1
            elif op == "admit":
                for req in sched.admit():
                    assert req.slot >= 0
                    assert len(req.pages) == sched.pages_needed(req)
            elif sched.running:
                slot = data.draw(st.sampled_from(
                    sorted(sched.running)))
                sched.evict(slot)
            # -- invariants ----------------------------------------------
            owned = [p for r in sched.running.values() for p in r.pages]
            assert len(owned) == len(set(owned)), "page double-owned"
            assert KV.SCRATCH_PAGE not in owned, "scratch page owned"
            assert alloc.in_use() == len(owned), "page leak"
            assert alloc.available() >= 0
            assert len(sched.running) <= max_batch
        # drain: every page returns
        for slot in sorted(sched.running):
            sched.evict(slot)
        assert alloc.available() == alloc.capacity

    run()


# =========================== end-to-end engines =============================


@pytest.mark.parametrize("arch", ["granite-3-8b", "gemma2-9b",
                                  "recurrentgemma-9b", "mamba2-780m"])
def test_paged_generate_matches_dense_engine(arch):
    """Greedy continuous batching == token-for-token the dense engine,
    with ragged prompts, more requests than slots (forced eviction +
    re-admission), and a mid-stream slot reuse."""
    cfg = _cfg(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
               for L in (5, 9, 12)]
    dense = DecodeEngine(cfg, params, ServeConfig(max_seq=64))
    ref = [dense.generate(p[None, :], 10)[0] for p in prompts]
    paged = PagedEngine(cfg, params, PagedServeConfig(
        max_seq=64, max_batch=2, page_size=8, decode_chunk=4))
    out = paged.generate(prompts, 10)
    for i, (a, b) in enumerate(zip(ref, out)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")


def test_paged_engine_flash_decode_kernel_path():
    """Same equivalence with the Pallas flash-decode kernel forced on
    (interpret mode) — the acceptance path of the subsystem."""
    cfg = _cfg("granite-3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
               for L in (5, 9)]
    dense = DecodeEngine(cfg, params, ServeConfig(max_seq=32))
    ref = [dense.generate(p[None, :], 6)[0] for p in prompts]
    paged = PagedEngine(cfg, params, PagedServeConfig(
        max_seq=32, max_batch=2, page_size=8, decode_chunk=3,
        use_kernel=True, interpret=True))
    out = paged.generate(prompts, 6)
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(a, b)


def test_dense_engine_scan_generate_single_transfer():
    """The static engine's token loop is one device program: generate
    must produce identical tokens across calls and batch sizes."""
    cfg = _cfg("granite-3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (3, 8)).astype(np.int32)
    eng = DecodeEngine(cfg, params, ServeConfig(max_seq=32))
    out = eng.generate(prompts, 7)
    assert out.shape == (3, 7)
    # batch-invariance: each row alone reproduces its batched tokens
    for b in range(3):
        np.testing.assert_array_equal(
            eng.generate(prompts[b:b + 1], 7)[0], out[b])


def test_temperature_sampling_stays_in_vocab():
    cfg = _cfg("granite-3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (6,)).astype(np.int32)]
    paged = PagedEngine(cfg, params, PagedServeConfig(
        max_seq=32, max_batch=1, page_size=8, temperature=0.8))
    out = paged.generate(prompts, 8)
    assert out.shape == (1, 8)
    assert (out >= 0).all() and (out < cfg.vocab).all()


# =========================== paged-cache pieces =============================


def test_choose_page_size_uses_schedule_cache(tmp_path):
    """A tuned flash_decode entry must dictate the paged layout."""
    from repro.tune import OpSpec, Schedule, ScheduleCache
    cfg = _cfg("granite-3-8b")
    g = cfg.n_heads // cfg.n_kv_heads
    cache = ScheduleCache(str(tmp_path / "schedules.json"))
    spec = OpSpec("flash_decode", (g, 64, cfg.head_dim), "float32")
    cache.store(Schedule(spec, (16,), source="measured"))
    assert KV.choose_page_size(cfg, 64, cache=cache) == 16


def test_default_buckets_policy():
    """Pure-attention stacks bucket to powers of two; recurrent/SSD
    stacks prefill at exact lengths (right-padding would corrupt their
    O(1) states)."""
    attn = _cfg("granite-3-8b")
    assert default_buckets(attn, 64) is not None
    assert all(b2 % b1 == 0 for b1, b2 in
               zip(default_buckets(attn, 64), default_buckets(attn, 64)[1:]))
    hybrid = _cfg("recurrentgemma-9b")
    assert default_buckets(hybrid, 64) is None


def test_paged_cache_defs_reject_encdec():
    cfg = _cfg("seamless-m4t-medium")
    with pytest.raises(NotImplementedError):
        KV.paged_cache_defs(cfg, 1, 4, 4)


def test_shared_prefix_pages_are_read_only_safe():
    """Two requests sharing full prefix pages decode independently:
    refcounted pages stay intact until the last owner frees them."""
    a = KV.PageAllocator(6)
    prefix = a.alloc_many(2)
    shared = [a.share(p) for p in prefix]
    assert shared == prefix
    a.free_many(prefix)              # first owner done
    assert a.in_use() == 2           # second owner still holds them
    a.free_many(prefix)
    assert a.available() == a.capacity


# ====================== cross-op fusion e2e (ISSUE 5) =======================


@pytest.mark.parametrize("arch", ["granite-3-8b", "gemma2-9b",
                                  "recurrentgemma-9b", "mamba2-780m"])
def test_paged_generate_fused_matches_dense_engine(arch):
    """Token-exact paged-decode e2e with fusion enabled: the fused
    paged engine (epilogue-fused MLP, one-pass QKV, oproj-fused decode
    attention) reproduces the UNFUSED dense engine token for token
    across the arch families — fusion changes where tensors live, not
    what they are."""
    cfg = _cfg(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
               for L in (5, 9, 12)]
    dense = DecodeEngine(cfg, params, ServeConfig(max_seq=64))
    ref = [dense.generate(p[None, :], 10)[0] for p in prompts]
    fused = PagedEngine(cfg, params, PagedServeConfig(
        max_seq=64, max_batch=2, page_size=8, decode_chunk=4, fuse=True))
    out = fused.generate(prompts, 10)
    for i, (a, b) in enumerate(zip(ref, out)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")


def test_dense_engine_fused_matches_unfused():
    cfg = _cfg("granite-3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    ref = DecodeEngine(cfg, params,
                       ServeConfig(max_seq=32)).generate(prompts, 7)
    out = DecodeEngine(cfg, params,
                       ServeConfig(max_seq=32,
                                   fuse=True)).generate(prompts, 7)
    np.testing.assert_array_equal(ref, out)


def test_paged_engine_fused_kernel_path():
    """Fusion with the Pallas kernels forced on (interpret mode): the
    oproj-fused flash-decode runs inside the jitted decode chunk."""
    cfg = _cfg("granite-3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
               for L in (5, 9)]
    dense = DecodeEngine(cfg, params, ServeConfig(max_seq=32))
    ref = [dense.generate(p[None, :], 6)[0] for p in prompts]
    fused = PagedEngine(cfg, params, PagedServeConfig(
        max_seq=32, max_batch=2, page_size=8, decode_chunk=3,
        use_kernel=True, interpret=True, fuse=True))
    out = fused.generate(prompts, 6)
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(a, b)


def test_fused_serving_composes_with_w8_quantization():
    """ISSUE 5 acceptance: serve --fuse composes with --quantize w8.

    Token-exact: the fused paged engine over int8 projection weights
    reproduces the fused DENSE engine over the same weights (both run
    the w8 epilogue-fused semantics).  Drift-bounded: fused-vs-unfused
    quantized logits differ only in scale-application order — (a@q)*s
    vs a@(q*s) — which must stay far inside the fake-quant harness
    tolerance."""
    from repro.quant import quantize_params
    cfg = _cfg("granite-3-8b")
    raw = T.init_params(cfg, jax.random.PRNGKey(2))
    params = quantize_params(raw)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
               for L in (5, 9)]
    dense_fused = DecodeEngine(cfg, params, ServeConfig(max_seq=32,
                                                        fuse=True))
    ref = [dense_fused.generate(p[None, :], 6)[0] for p in prompts]
    fused = PagedEngine(cfg, params, PagedServeConfig(
        max_seq=32, max_batch=2, page_size=8, decode_chunk=3,
        fuse=True))
    out = fused.generate(prompts, 6)
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(a, b)

    toks = jnp.asarray(prompts[1][None, :])
    from repro.kernels import ops as K_ops
    log_unfused, _ = T.prefill(cfg, params, toks, 32)
    with K_ops.fused_ops(True):
        log_fused, _ = T.prefill(cfg, params, toks, 32)
    np.testing.assert_allclose(np.asarray(log_fused),
                               np.asarray(log_unfused),
                               rtol=1e-4, atol=1e-4)


def test_fused_serving_composes_with_fp8_kv():
    """--fuse + an fp8 page pool: the oproj fusion falls back to the
    unfused fp8 decode pair inside ops.paged_attention_oproj, so the
    composition stays token-exact against the fp8 dense path."""
    import dataclasses as dc
    cfg = dc.replace(_cfg("granite-3-8b"),
                     kv_cache_dtype=jnp.float8_e4m3fn)
    params = T.init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
               for L in (5, 8)]
    dense = DecodeEngine(cfg, params, ServeConfig(max_seq=32))
    ref = [dense.generate(p[None, :], 5)[0] for p in prompts]
    fused = PagedEngine(cfg, params, PagedServeConfig(
        max_seq=32, max_batch=2, page_size=8, decode_chunk=2,
        fuse=True))
    out = fused.generate(prompts, 5)
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(a, b)
