import os
import sys
import tempfile

# tests see ONE device (the dry-run sets its own flags in-process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# hermetic schedule cache: never read/write the user's ~/.cache/repro
os.environ.setdefault(
    "REPRO_TUNE_CACHE",
    os.path.join(tempfile.mkdtemp(prefix="repro-tune-test-"),
                 "schedules.json"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    # registered here (no pytest.ini/pyproject): `-m "not slow"` is the
    # fast CI lane; the subprocess sharded-compile tests carry the marker
    config.addinivalue_line(
        "markers",
        "slow: subprocess-spawning sharded-compile tests; excluded from "
        "the fast lane (-m 'not slow'), run by the full CI lane")
