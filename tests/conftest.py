import os
import sys
import tempfile

# tests see ONE device (the dry-run sets its own flags in-process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# hermetic schedule cache: never read/write the user's ~/.cache/repro
os.environ.setdefault(
    "REPRO_TUNE_CACHE",
    os.path.join(tempfile.mkdtemp(prefix="repro-tune-test-"),
                 "schedules.json"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
