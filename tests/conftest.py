import os
import sys

# tests see ONE device (the dry-run sets its own flags in-process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
