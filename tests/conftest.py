import os
import sys
import tempfile

# tests see ONE device (the dry-run sets its own flags in-process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# hermetic schedule cache: never read/write the user's ~/.cache/repro
os.environ.setdefault(
    "REPRO_TUNE_CACHE",
    os.path.join(tempfile.mkdtemp(prefix="repro-tune-test-"),
                 "schedules.json"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    # registered here (no pytest.ini/pyproject): `-m "not slow"` is the
    # fast CI lane; the subprocess sharded-compile tests carry the marker
    config.addinivalue_line(
        "markers",
        "slow: subprocess-spawning sharded-compile tests; excluded from "
        "the fast lane (-m 'not slow'), run by the full CI lane")
    _configure_hypothesis(config)


def _configure_hypothesis(config):
    """Pin down the property suites' randomness.

    CI runs the derandomized profile (examples derived from the test
    body, not the clock) so the fast lane is reproducible and a red
    build always replays.  Local runs keep hypothesis's randomized
    search — more bug-finding power per run — and the plugin's own
    ``--hypothesis-seed N`` flag is the escape hatch to replay a
    specific local failure; passing it forces the randomized profile so
    the seed actually takes effect.  No-op when hypothesis isn't
    installed (the property tests importorskip themselves away)."""
    try:
        from hypothesis import settings
    except ImportError:
        return
    settings.register_profile("repro-ci", derandomize=True,
                              max_examples=50, deadline=None,
                              print_blob=True)
    settings.register_profile("repro-dev", deadline=None,
                              print_blob=True)
    try:
        seeded = config.getoption("--hypothesis-seed") is not None
    except ValueError:          # plugin not active for this run
        seeded = False
    if not seeded and os.environ.get("CI"):
        settings.load_profile("repro-ci")
    else:
        settings.load_profile("repro-dev")
