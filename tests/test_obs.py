"""Observability subsystem: metrics registry, Chrome-trace step spans,
modeled-vs-measured DRAM accounting, and the tracing-off zero-cost
guarantees (docs/observability.md)."""

import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.obs import (DramLedger, MetricsRegistry, Obs, StepTracer,
                       format_metrics, hist_quantile, read_miss_log)
from repro.obs.metrics import Histogram
from repro.serve.engine import PagedEngine, PagedServeConfig


def _cfg(arch: str):
    return dataclasses.replace(get_reduced(arch), dtype=jnp.float32)


@functools.lru_cache(maxsize=None)
def _model(arch: str):
    cfg = _cfg(arch)
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def _run_paged(arch: str, obs=None):
    """The shared tiny workload: two ragged prompts, 6 generated tokens."""
    cfg, params = _model(arch)
    engine = PagedEngine(cfg, params,
                         PagedServeConfig(max_seq=64, max_batch=2),
                         obs=obs)
    prompts = [np.arange(1, 9, dtype=np.int32),
               np.arange(3, 15, dtype=np.int32)]
    out = engine.generate(prompts, 6)
    return engine, out


# ========================== metrics registry ================================


def test_registry_get_or_create_and_conflicts():
    reg = MetricsRegistry()
    c = reg.counter("engine.steps")
    assert reg.counter("engine.steps") is c
    c.inc()
    c.inc(3)
    assert c.value == 4
    g = reg.gauge("pages.in_use")
    g.set(7)
    assert g.value == 7
    # a registered name cannot change type...
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("engine.steps")
    # ...and cannot be both a leaf and a group
    with pytest.raises(ValueError, match="leaf and group"):
        reg.counter("engine.steps.retries")
    with pytest.raises(ValueError, match="leaf and group"):
        reg.counter("engine")


def test_registry_snapshot_nests_by_dots_and_is_json():
    reg = MetricsRegistry()
    reg.counter("a.b.c").inc(2)
    reg.gauge("a.g").set(1)
    reg.counter("top").inc()
    snap = reg.snapshot()
    assert snap == {"a": {"b": {"c": 2}, "g": 1}, "top": 1}
    assert json.loads(reg.to_json()) == snap


def test_histogram_buckets_and_quantiles():
    h = Histogram(bounds=(10.0, 20.0, 40.0))
    for v in (5, 15, 15, 35, 1000):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 5
    assert snap["buckets"] == {"10": 1, "20": 2, "40": 1, "+inf": 1}
    assert snap["sum"] == pytest.approx(1070.0)
    # p50 interpolates inside the (10, 20] bucket
    assert 10.0 <= h.quantile(0.5) <= 20.0
    # the open +inf tail reports its lower bound, not infinity
    assert h.quantile(0.99) == pytest.approx(40.0)
    assert hist_quantile({"count": 0, "sum": 0, "buckets": {}}, 0.5) == 0.0
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError, match="strictly increasing"):
        Histogram(bounds=(10.0, 10.0))


def test_format_metrics_one_formatter():
    tree = {
        "spec": {"verify_calls": 4, "mean_accepted": 2.5},
        "prefix_cache": {"hit_rate": 0.25, "hits": 1},
        "engine": {"step_us": {"count": 2, "sum": 30.0,
                               "buckets": {"10": 1, "20": 1, "+inf": 0}}},
    }
    text = format_metrics(tree)
    assert "spec.verify_calls" in text
    assert "25.0%" in text                     # *rate floats as percents
    assert "p50=" in text and "p99=" in text   # histograms as quantiles
    # sections filter + order
    only = format_metrics(tree, sections=("prefix_cache",))
    assert "spec." not in only and "prefix_cache.hits" in only


# ======================== Chrome-trace tracer ===============================


def test_tracer_emits_valid_nested_chrome_trace(tmp_path):
    path = tmp_path / "trace.json"
    with StepTracer(path) as tr:
        with tr.span("outer", cat="engine", args={"step": 0}):
            with tr.span("inner_a"):
                pass
            with tr.span("inner_b"):
                pass
        tr.instant("marker")
        tr.counter("queue", {"depth": 3})
    events = json.loads(path.read_text())     # the file is one JSON doc
    by_name = {e["name"]: e for e in events}
    assert set(by_name) == {"outer", "inner_a", "inner_b", "marker",
                            "queue"}
    for e in events:
        assert e["ph"] in ("X", "i", "C")
        assert e["ts"] >= 0.0
    # complete-span nesting is by interval containment
    outer, a, b = by_name["outer"], by_name["inner_a"], by_name["inner_b"]
    for inner in (a, b):
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= \
            outer["ts"] + outer["dur"] + 1e-6
    assert a["ts"] + a["dur"] <= b["ts"] + 1e-6   # siblings in order
    assert outer["args"] == {"step": 0}
    tr.close()                                 # idempotent


def test_engine_trace_covers_plan_prefill_decode_spans(tmp_path):
    path = tmp_path / "engine_trace.json"
    obs = Obs(trace=str(path))
    _run_paged("granite-3-8b", obs=obs)
    obs.close()
    events = json.loads(path.read_text())
    names = {e["name"] for e in events}
    assert {"step", "plan_step", "host_prep", "dispatch.decode",
            "readback"} <= names
    assert names & {"dispatch.join", "dispatch.prefill"}  # prompt ingest
    steps = sorted((e for e in events if e["name"] == "step"),
                   key=lambda e: e["ts"])
    assert steps and all(e["ph"] == "X" for e in steps)
    # engine steps are serial: monotonic and non-overlapping
    for prev, cur in zip(steps, steps[1:]):
        assert prev["ts"] + prev["dur"] <= cur["ts"] + 1e-6
    # every other span nests inside some engine step
    for e in events:
        if e["name"] == "step" or e["ph"] != "X":
            continue
        assert any(s["ts"] - 1e-6 <= e["ts"] and
                   e["ts"] + e["dur"] <= s["ts"] + s["dur"] + 1e-6
                   for s in steps), f"{e['name']} outside all steps"


# ================== tracing is observation, not perturbation ================


@pytest.mark.parametrize("arch", ["granite-3-8b", "recurrentgemma-9b"])
def test_tokens_identical_with_tracing_on(arch, tmp_path):
    _, out_off = _run_paged(arch)
    obs = Obs(trace=str(tmp_path / "t.json"))
    _, out_on = _run_paged(arch, obs=obs)
    obs.close()
    assert np.array_equal(out_off, out_on)


def test_no_host_syncs_when_tracing_off(monkeypatch):
    cfg, params = _model("granite-3-8b")
    calls = []
    real = jax.block_until_ready
    monkeypatch.setattr(jax, "block_until_ready",
                        lambda x: calls.append(1) or real(x))
    engine, _ = _run_paged("granite-3-8b")          # tracer is None
    assert engine.obs.tracer is None
    assert not calls, "engine fenced the device without a tracer attached"
    obs = Obs(trace=StepTracer(os.devnull))
    _run_paged("granite-3-8b", obs=obs)             # tracer attached
    assert calls, "traced run never fenced — spans time dispatch only"
    obs.close()


# ==================== modeled-vs-measured DRAM ledger =======================


def test_dram_ledger_records_resolutions_and_misses(tmp_path):
    from repro import tune
    miss_log = tmp_path / "miss.jsonl"
    reg = MetricsRegistry()
    led = DramLedger(registry=reg, miss_log=str(miss_log))
    with led.scope("gemm[64]"):
        tune.best_schedule("matmul", (64, 64, 64))
    with led.scope("gemm[64]"):                     # memoized: no new miss
        tune.best_schedule("matmul", (64, 64, 64))
    led.end_step([0, 1])
    rep = led.report()
    (key,) = rep["per_op"]
    assert key.startswith("matmul/")
    ent = rep["per_op"][key]
    # analytic fallback: the used tiles ARE the model's top candidate
    assert ent["source"] == "analytic"
    assert ent["modeled_bytes"] == ent["used_bytes"] > 0
    assert ent["ratio"] == pytest.approx(1.0)
    tag = rep["per_tag"]["gemm[64]"]
    assert tag["executions"] == 2 and tag["ops"] == [key]
    assert rep["total_bytes"] == 2 * tag["bytes_per_execution"]
    assert rep["per_step"]["steps"] == 1
    assert rep["per_request"]["requests"] == 2
    assert reg.snapshot()["schedule_cache"]["misses"] >= 1
    led.close()
    # miss log round-trips into deduplicated tuning targets
    targets = read_miss_log(str(miss_log))
    assert targets == [{"op": "matmul", "dims": [64, 64, 64],
                        "dtype": "float32", "stride": 1}]


def test_read_miss_log_tolerates_corrupt_lines(tmp_path):
    p = tmp_path / "miss.jsonl"
    p.write_text('{"op": "matmul", "dims": [8, 8, 8]}\n'
                 "not json\n"
                 "\n"
                 '{"op": "matmul", "dims": [8, 8, 8]}\n'     # duplicate
                 '{"dims": [1]}\n')                          # no op key
    assert read_miss_log(str(p)) == [
        {"op": "matmul", "dims": [8, 8, 8], "dtype": "float32",
         "stride": 1}]


def test_tune_cli_replays_telemetry_dry_run(tmp_path, capsys):
    from repro.tune.__main__ import main as tune_main
    p = tmp_path / "miss.jsonl"
    p.write_text('{"op": "matmul", "dims": [64, 64, 64], '
                 '"dtype": "float32", "stride": 1}\n')
    tune_main(["--from-telemetry", str(p), "--dry-run"])
    out = capsys.readouterr().out
    assert "1 distinct miss target(s)" in out
    assert "would tune matmul/" in out
    # an empty log is a clean no-op (CI runs this unconditionally)
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    tune_main(["--from-telemetry", str(empty), "--dry-run"])
    assert "0 distinct miss target(s)" in capsys.readouterr().out
    # without --from-telemetry, op and dims stay required
    with pytest.raises(SystemExit):
        tune_main([])


# ===================== engine integration snapshot ==========================


def test_engine_snapshot_sections_and_stat_views():
    engine, _ = _run_paged("granite-3-8b")
    snap = engine.obs.snapshot()
    assert snap["engine"]["decode_tokens"] > 0
    assert snap["engine"]["steps"] > 0
    assert snap["engine"]["step_us"]["count"] == snap["engine"]["steps"]
    assert snap["sched"]["admitted"] == 2
    assert snap["pages"]["capacity"] > 0
    # the tuner was consulted: schedule-cache section is non-empty...
    sc = snap["schedule_cache"]
    assert sc["hits"] + sc["misses"] > 0
    # ...and every resolved op key carries the modeled-vs-measured triple
    assert snap["dram"]["per_op"]
    for ent in snap["dram"]["per_op"].values():
        assert {"modeled_bytes", "used_bytes", "ratio"} <= set(ent)
    assert snap["dram"]["per_tag"]
    # stats views are thin reads over the same registry (one source of
    # truth — the dict shapes are the pre-registry contract)
    assert set(engine.spec_stats()) == {"verify_calls", "tokens",
                                        "mean_accepted"}
    assert set(engine.prefix_stats()) == {"lookups", "hits", "hit_rate",
                                          "tokens_saved", "cached_pages"}
    # snapshot is JSON-safe end to end
    json.dumps(snap)
