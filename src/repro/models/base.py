"""Declarative parameter trees.

Every module declares its parameters once as a tree of :class:`ParamDef`
(shape + PartitionSpec + init scale).  The same declaration is *built* in
three modes:

* ``init``  — materialize arrays (reduced configs, smoke tests, examples)
* ``shape`` — ``jax.ShapeDtypeStruct`` stand-ins (dry-run, no allocation)
* ``spec``  — the PartitionSpec tree fed to ``jax.jit`` in_shardings

keeping shapes and shardings impossible to de-synchronize.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    spec: P
    scale: float = 1.0          # stddev multiplier for trunc-normal init
    dtype: Any = jnp.bfloat16
    init: str = "normal"        # "normal" | "zeros" | "ones"


def fan_in_scale(fan_in: int) -> float:
    return fan_in ** -0.5


def _init_leaf(d: ParamDef, key: jax.Array) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    return (jax.random.truncated_normal(key, -3, 3, d.shape, jnp.float32)
            * d.scale).astype(d.dtype)


def build(tree: Any, mode: str, rng: jax.Array | None = None) -> Any:
    """Materialize a ParamDef tree in one of the three modes."""
    leaves, treedef = jax.tree.flatten(
        tree, is_leaf=lambda x: isinstance(x, ParamDef))
    if mode == "spec":
        out = [d.spec for d in leaves]
    elif mode == "shape":
        out = [jax.ShapeDtypeStruct(d.shape, d.dtype) for d in leaves]
    elif mode == "init":
        assert rng is not None
        keys = jax.random.split(rng, max(len(leaves), 1))
        out = [_init_leaf(d, k) for d, k in zip(leaves, keys)]
    else:
        raise ValueError(mode)
    return jax.tree.unflatten(treedef, out)


def retype_defs(tree: Any, dtype: Any) -> Any:
    """Replace the default bf16 weight dtype with ``dtype`` (test configs
    run f32).  Leaves that explicitly request another dtype (fp32 SSM
    decay params etc.) are left alone."""
    def _retype(d: ParamDef) -> ParamDef:
        if d.dtype == jnp.bfloat16:
            return dataclasses.replace(d, dtype=dtype)
        return d
    return jax.tree.map(_retype, tree,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def stack_defs(tree: Any, n: int, stack_spec_axis: Any = None) -> Any:
    """Stack a ParamDef tree ``n`` times along a new leading axis (for
    ``lax.scan`` over homogeneous layer groups)."""
    def _stack(d: ParamDef) -> ParamDef:
        spec = P(stack_spec_axis, *d.spec)
        return ParamDef((n,) + d.shape, spec, d.scale, d.dtype, d.init)
    return jax.tree.map(_stack, tree,
                        is_leaf=lambda x: isinstance(x, ParamDef))
