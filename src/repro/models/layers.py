"""Model layers: each module declares ParamDefs and provides apply fns.

Sharding philosophy (paper §3.3 mapped to a TPU mesh, DESIGN.md §3):
weights are the "large buffer" for LM layers, so they are sharded over the
``model`` axis (K-partitioning: heads / ffn / experts / vocab) while
activations are sharded over ``data`` (XY-partitioning: batch/sequence).
``model_ax`` (the model-axis size) is threaded through the def builders so
dims that don't divide are replicated instead of mis-sharded.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops, ref
from repro.models.base import ParamDef, fan_in_scale
from repro.models.config import ModelConfig
from repro.models.sharding import maybe_shard


def _shard_if(dim: int, model_ax: int, axis: str = "model"):
    return axis if model_ax > 1 and dim % model_ax == 0 else None


# =========================== norms & embeddings ===========================


def rmsnorm_defs(d: int) -> dict:
    return {"scale": ParamDef((d,), P(None), init="ones")}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) *
            params["scale"].astype(jnp.float32)).astype(x.dtype)


def embedding_defs(cfg: ModelConfig, model_ax: int) -> dict:
    v = padded_vocab(cfg, model_ax)
    return {"embedding": ParamDef((v, cfg.d_model),
                                  P(_shard_if(v, model_ax), "data"),
                                  scale=cfg.d_model ** -0.5)}


def padded_vocab(cfg: ModelConfig, model_ax: int = 16) -> int:
    mult = max(model_ax, 1) * 16  # lane-align shards
    return ((cfg.vocab + mult - 1) // mult) * mult


# ================================ RoPE =====================================


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq   # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin],
        axis=-1).astype(x.dtype)


# ============================ attention (GQA) ==============================


def attention_defs(cfg: ModelConfig, model_ax: int) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    sq = _shard_if(hq * hd, model_ax) if hq % model_ax == 0 or \
        model_ax <= 1 else None
    skv = "model" if model_ax > 1 and hkv % model_ax == 0 else None
    s = fan_in_scale(d)
    # FSDP: the non-"model" dim of every weight is sharded over "data"
    # (ZeRO-3 storage; GSPMD all-gathers per layer and reduce-scatters
    # gradients automatically).
    return {
        "wq": ParamDef((d, hq * hd), P("data", sq), scale=s),
        "wk": ParamDef((d, hkv * hd), P("data", skv), scale=s),
        "wv": ParamDef((d, hkv * hd), P("data", skv), scale=s),
        "wo": ParamDef((hq * hd, d), P(sq, "data"),
                       scale=fan_in_scale(hq * hd)),
    }


def attention_apply(cfg: ModelConfig, params: dict, x: jax.Array,
                    positions: jax.Array, *, causal: bool = True,
                    window: int | None = None,
                    return_cache: bool = False,
                    full_cache: bool = False):
    """Full-sequence attention.  x: (B, S, D).

    ``full_cache=True`` forces the returned K/V cache into the full
    position-indexed layout even for windowed (local) layers — the paged
    serving path stores every layer's KV in pages and applies the window
    as a mask at decode time, so it cannot use the ring-buffer layout.
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if ops.fused_ops_enabled():
        # one weight-stationary pass: x streams from HBM once for all
        # three projections (docs/fusion.md)
        q, k, v = ops.qkv_fused(x, params["wq"], params["wk"],
                                params["wv"])
        q = q.reshape(b, s, hq, hd)
        k = k.reshape(b, s, hkv, hd)
        v = v.reshape(b, s, hkv, hd)
    else:
        q = ops.linear(x, params["wq"]).reshape(b, s, hq, hd)
        k = ops.linear(x, params["wk"]).reshape(b, s, hkv, hd)
        v = ops.linear(x, params["wv"]).reshape(b, s, hkv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    out = ops.attention(q, k, v, causal=causal, window=window,
                        logit_cap=cfg.attn_logit_cap)
    out = ops.linear(out.reshape(b, s, hq * hd), params["wo"])
    if not return_cache:
        return out
    cache_len = return_cache if isinstance(return_cache, int) and \
        return_cache is not True else s
    cache_dtype = cfg.kv_cache_dtype or cfg.dtype
    if window is not None and not full_cache:
        # ring buffer: slot p % L holds position p; keep the last L
        length = min(window, cache_len)
        keep = min(length, s)
        last = jnp.arange(s - keep, s)
        ck = jnp.zeros((b, length, hkv, hd), cache_dtype)
        cv = jnp.zeros((b, length, hkv, hd), cache_dtype)
        ck = ck.at[:, last % length].set(k[:, last].astype(cache_dtype))
        cv = cv.at[:, last % length].set(v[:, last].astype(cache_dtype))
        return out, {"k": ck, "v": cv}
    pad = cache_len - s
    ck = jnp.pad(k.astype(cache_dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
    cv = jnp.pad(v.astype(cache_dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out, {"k": ck, "v": cv}


def qkv_span_proj(cfg: ModelConfig, params: dict, x: jax.Array,
                  positions: jax.Array):
    """Q/K/V projection + rope for a span of S consecutive tokens — the
    single definition shared by the dense decode path
    (:func:`attention_decode`, S=1), the paged decode path
    (``serve.kv_cache.make_paged_attn_step``) and the multi-token
    verify/chunked-prefill path (``make_paged_span_step``), so they can
    never drift apart.  x: (B, S, D); positions: (B, S).
    Returns q (B, S, Hq, D), k/v (B, S, Hkv, D)."""
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if ops.fused_ops_enabled():
        # fused path falls back to the three ops.linear calls itself
        # when the weights are QuantizedTensors (w8 semantics intact)
        q, k, v = ops.qkv_fused(x.reshape(b * s, -1), params["wq"],
                                params["wk"], params["wv"])
        q, k, v = (q.reshape(b, s, hq, hd), k.reshape(b, s, hkv, hd),
                   v.reshape(b, s, hkv, hd))
    else:
        # ops.linear (not a bare @): quantized params carry
        # QuantizedTensor projection weights, which linear dispatches to
        # the w8 kernel / dequant oracle (docs/quantization.md)
        q = ops.linear(x, params["wq"]).reshape(b, s, hq, hd)
        k = ops.linear(x, params["wk"]).reshape(b, s, hkv, hd)
        v = ops.linear(x, params["wv"]).reshape(b, s, hkv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def qkv_decode_proj(cfg: ModelConfig, params: dict, x: jax.Array,
                    positions: jax.Array):
    """One-token wrapper over :func:`qkv_span_proj`.  x: (B, D);
    positions: (B, 1).  Returns q (B, Hq, D), k/v (B, Hkv, D)."""
    q, k, v = qkv_span_proj(cfg, params, x[:, None, :], positions)
    return q[:, 0], k[:, 0], v[:, 0]


def attention_decode(cfg: ModelConfig, params: dict, x: jax.Array,
                     cache: dict, pos: jax.Array, *,
                     window: int | None = None) -> tuple[jax.Array, dict]:
    """One-token step.  x: (B, 1, D); cache {k,v}: (B, L, hkv, hd) where
    L = window (ring buffer) for local layers else max seq."""
    b, _, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    posv = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = qkv_decode_proj(cfg, params, x[:, 0], posv)
    q, k, v = q[:, None], k[:, None], v[:, None]

    length = cache["k"].shape[1]
    slot = pos % length if window is not None else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))

    slots = jnp.arange(length)
    if window is not None:
        kpos = pos - (pos - slots) % length          # ring-buffer positions
        valid = (kpos >= 0) & (kpos <= pos) & (kpos > pos - window)
    else:
        kpos = slots
        valid = kpos <= pos

    groups = hq // hkv
    qh = q.reshape(b, hkv, groups, hd)               # (B, hkv, G, D)
    logits = jnp.einsum("bhgd,blhd->bhgl", qh.astype(jnp.float32),
                        ck.astype(jnp.float32)) * hd ** -0.5
    if cfg.attn_logit_cap is not None:
        logits = cfg.attn_logit_cap * jnp.tanh(logits / cfg.attn_logit_cap)
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgl,blhd->bhgd", probs, cv.astype(jnp.float32))
    out = out.reshape(b, 1, hq * hd).astype(x.dtype)
    return ops.linear(out, params["wo"]), {"k": ck, "v": cv}


def attention_cache_defs(cfg: ModelConfig, batch: int, max_seq: int,
                         model_ax: int, window: int | None) -> dict:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    cache_dtype = cfg.kv_cache_dtype or cfg.dtype
    length = min(window, max_seq) if window is not None else max_seq
    if model_ax > 1 and hkv % model_ax == 0:
        spec = P("data", None, "model", None)       # head-sharded KV
    elif model_ax > 1 and length % model_ax == 0:
        # GQA/MQA: too few kv heads to split -> shard the SEQUENCE dim
        # (flash-decode style); XLA inserts the partial-softmax reductions.
        spec = P("data", "model", None, None)
    else:
        spec = P("data", None, None, None)
    return {"k": ParamDef((batch, length, hkv, hd), spec, init="zeros",
                          dtype=cache_dtype),
            "v": ParamDef((batch, length, hkv, hd), spec, init="zeros",
                          dtype=cache_dtype)}


# ========================== dense MLP (SwiGLU) =============================


def mlp_defs(cfg: ModelConfig, model_ax: int) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    sh = _shard_if(f, model_ax)
    defs = {
        "w_up": ParamDef((d, f), P("data", sh), scale=fan_in_scale(d)),
        "w_down": ParamDef((f, d), P(sh, "data"), scale=fan_in_scale(f)),
    }
    if cfg.mlp_kind == "swiglu":
        defs["w_gate"] = ParamDef((d, f), P("data", sh),
                                  scale=fan_in_scale(d))
    return defs


def mlp_apply(params: dict, x: jax.Array,
              residual: jax.Array | None = None) -> jax.Array:
    """The MLP block.  ``residual`` (when given) is added to the output
    — callers pass the skip connection so the fused path can absorb the
    add into the down-projection's epilogue.

    With fused ops enabled (``ops.fused_ops`` — the serving engines'
    ``fuse`` flag), the whole chain runs as epilogue-fused GEMMs under
    the ``"matmul_fused"`` schedule key (``"matmul_w8"`` for quantized
    weights): activation, SwiGLU gating multiply and residual add all
    happen on the VMEM-resident output tile, eliminating their HBM
    round-trips (docs/fusion.md).  Otherwise the per-op chain below
    runs — ops.linear is a plain matmul unless blocked linears are
    enabled (training with tc.blocked_linear / REPRO_BLOCKED_LINEAR),
    in which case fwd AND bwd run the tuned Pallas GEMM kernels.
    """
    if ops.fused_ops_enabled():
        if "w_gate" in params:  # SwiGLU
            g = ops.matmul_fused(x, params["w_gate"], act="silu")
            u = ops.matmul_fused(x, params["w_up"], mul=g)
        else:  # plain GELU MLP
            u = ops.matmul_fused(x, params["w_up"], act="gelu")
        return ops.matmul_fused(u, params["w_down"], residual=residual)
    u = ops.linear(x, params["w_up"]).astype(jnp.float32)
    if "w_gate" in params:  # SwiGLU
        g = jax.nn.silu(ops.linear(x, params["w_gate"]).astype(jnp.float32))
        u = g * u
    else:  # plain GELU MLP (granite-34b, seamless encoder/decoder)
        u = jax.nn.gelu(u)
    out = ops.linear(u.astype(x.dtype), params["w_down"])
    return out if residual is None else residual + out


# ============================ MoE (top-k) ==================================


def moe_defs(cfg: ModelConfig, model_ax: int) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    se = _shard_if(e, model_ax)   # expert parallelism over the model axis
    return {
        "router": ParamDef((d, e), P("data", None), scale=fan_in_scale(d)),
        "w_gate": ParamDef((e, d, f), P(se, "data", None),
                           scale=fan_in_scale(d)),
        "w_up": ParamDef((e, d, f), P(se, "data", None),
                         scale=fan_in_scale(d)),
        "w_down": ParamDef((e, f, d), P(se, None, "data"),
                           scale=fan_in_scale(f)),
    }


def moe_apply(cfg: ModelConfig, params: dict, x: jax.Array,
              ) -> tuple[jax.Array, jax.Array]:
    """Capacity-based top-k MoE.

    On a mesh with a model axis, dispatch runs under ``shard_map``: each
    shard routes ITS tokens locally (sort/scatter with no collectives) and
    exchanges expert slices with one explicit all-to-all over the model
    axis (+ inverse for combine) — the §Perf iteration that replaced the
    global-argsort dispatch whose GSPMD lowering moved ~170 TB/step
    (EXPERIMENTS.md §Perf it. 3).  Off-mesh (or when token counts don't
    split) the reference dense dispatch below runs instead; it is also the
    correctness oracle for the shard_map path.

    Paper §3.3 view: experts are the large KB -> partition them, route the
    small token blocks.  Returns (output, aux_load_balance_loss).
    """
    from repro.models.sharding import get_axis_mapping, on_mesh
    if on_mesh():
        mapping = get_axis_mapping()
        if mapping.get("model"):
            try:
                return _moe_apply_shardmap(cfg, params, x, mapping)
            except _ShardMapUnavailable:
                pass
    return _moe_apply_ref(cfg, params, x)


class _ShardMapUnavailable(Exception):
    pass


def _moe_apply_shardmap(cfg: ModelConfig, params: dict, x: jax.Array,
                        mapping: dict) -> tuple[jax.Array, jax.Array]:
    from jax.experimental.shard_map import shard_map
    from repro.models.sharding import translate_spec

    env = jax.interpreters.pxla.thread_resources.env
    mesh = env.physical_mesh
    if mesh.empty or mesh.size <= 1:
        raise _ShardMapUnavailable()
    ma = mapping["model"]
    da = mapping.get("data")
    da = da if isinstance(da, tuple) else ((da,) if da else ())
    m_size = mesh.shape[ma]
    b, s, d = x.shape
    d_size = 1
    for a in da:
        d_size *= mesh.shape[a]
    t_loc = (b // d_size if b % d_size == 0 else b) * s
    e, k = cfg.n_experts, cfg.experts_per_token
    if t_loc % m_size or e % m_size or b % max(d_size, 1):
        raise _ShardMapUnavailable()

    x_spec = P(da if da else None, None, None)
    w_specs = {kk: translate_spec(v) for kk, v in {
        "router": P(None, None),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None)}.items()}

    def local(xs, router, w_gate, w_up, w_down):
        bl, sl, _ = xs.shape
        tl = bl * sl
        tm = tl // m_size
        midx = jax.lax.axis_index(ma)
        xf = xs.reshape(tl, d)
        mine = jax.lax.dynamic_slice(xf, (midx * tm, 0), (tm, d))

        logits = (mine @ router).astype(jnp.float32)          # (tm, E)
        probs = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(probs, k)
        topw = topw / jnp.sum(topw, axis=-1, keepdims=True)
        density = jnp.mean(jax.nn.one_hot(topi[:, 0], e), axis=0)
        aux = jnp.sum(density * jnp.mean(probs, axis=0)) * e
        axes = (ma,) + tuple(da)
        aux = jax.lax.pmean(aux, axes)

        cap = int(math.ceil(tm * k / e * cfg.capacity_factor))
        cap = max(8, ((cap + 7) // 8) * 8)
        e_flat = topi.reshape(-1)
        order = jnp.argsort(e_flat)
        e_sort = e_flat[order]
        w_sort = topw.reshape(-1)[order]
        tok_sort = order // k
        pos = jnp.arange(tm * k) - jnp.searchsorted(e_sort, e_sort,
                                                    side="left")
        keep = pos < cap
        slot = jnp.where(keep, e_sort * cap + pos, e * cap)
        buf = jnp.zeros((e * cap + 1, d), xs.dtype).at[slot].set(
            mine[tok_sort] * keep[:, None].astype(xs.dtype))
        buf = buf[:-1].reshape(e, cap, d)

        # expert-parallel exchange: send each model-peer its expert slice
        buf = jax.lax.all_to_all(buf, ma, split_axis=0, concat_axis=1,
                                 tiled=True)      # (e/M, cap*M, d)
        g = jnp.einsum("ecd,edf->ecf", buf, w_gate,
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("ecd,edf->ecf", buf, w_up,
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(xs.dtype)
        out_e = jnp.einsum("ecf,efd->ecd", h, w_down,
                           preferred_element_type=jnp.float32
                           ).astype(xs.dtype)
        out_e = jax.lax.all_to_all(out_e, ma, split_axis=1, concat_axis=0,
                                   tiled=True)    # (e, cap, d)

        flat = jnp.concatenate([out_e.reshape(e * cap, d),
                                jnp.zeros((1, d), xs.dtype)], axis=0)
        gathered = flat[slot] * (w_sort * keep)[:, None].astype(xs.dtype)
        mine_out = jnp.zeros((tm, d), xs.dtype).at[tok_sort].add(gathered)
        # reassemble the model-replicated activation row
        out = jax.lax.all_gather(mine_out, ma, axis=0,
                                 tiled=True)       # (tl, d)
        return out.reshape(bl, sl, d), aux

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, w_specs["router"], w_specs["w_gate"],
                  w_specs["w_up"], w_specs["w_down"]),
        out_specs=(x_spec, P()),
        check_rep=False)
    return fn(x, params["router"], params["w_gate"], params["w_up"],
              params["w_down"])


def _moe_apply_ref(cfg: ModelConfig, params: dict, x: jax.Array,
                   ) -> tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf @ params["router"]).astype(jnp.float32)      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                       # (T, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    # load-balance aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(topi[:, 0], e), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * e

    cap = int(math.ceil(t * k / e * cfg.capacity_factor))
    cap = max(8, ((cap + 7) // 8) * 8)

    e_flat = topi.reshape(-1)                                  # (T*k,)
    w_flat = topw.reshape(-1)
    order = jnp.argsort(e_flat)
    e_sort = e_flat[order]
    w_sort = w_flat[order]
    tok_sort = order // k
    pos = jnp.arange(t * k) - jnp.searchsorted(e_sort, e_sort, side="left")
    keep = pos < cap
    slot = jnp.where(keep, e_sort * cap + pos, e * cap)        # drop slot

    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(
        xf[tok_sort] * keep[:, None].astype(x.dtype))
    buf = buf[:-1].reshape(e, cap, d)
    buf = maybe_shard(buf, P("model", None, None))

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"],
                               preferred_element_type=jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"],
                   preferred_element_type=jnp.float32)
    h = (g * u).astype(x.dtype)
    out_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    out_e = maybe_shard(out_e, P("model", None, None))

    flat = jnp.concatenate([out_e.reshape(e * cap, d),
                            jnp.zeros((1, d), x.dtype)], axis=0)
    gathered = flat[slot] * (w_sort * keep)[:, None].astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[tok_sort].add(gathered)
    return out.reshape(b, s, d), aux


# ============================ SSD (mamba-2) ================================


def ssd_defs(cfg: ModelConfig, model_ax: int) -> dict:
    d, di, ns = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.ssm_heads
    conv_dim = di + 2 * ns
    proj_out = 2 * di + 2 * ns + nh        # z, x, B, C, dt
    sdi = _shard_if(di, model_ax)
    return {
        "in_proj": ParamDef((d, proj_out), P("data", None),
                            scale=fan_in_scale(d)),
        "conv_w": ParamDef((cfg.conv_width, conv_dim), P(None, None),
                           scale=fan_in_scale(cfg.conv_width)),
        "A_log": ParamDef((nh,), P(None), init="zeros", dtype=jnp.float32),
        "D": ParamDef((nh,), P(None), init="ones", dtype=jnp.float32),
        "dt_bias": ParamDef((nh,), P(None), init="zeros",
                            dtype=jnp.float32),
        "norm_scale": ParamDef((di,), P(sdi), init="ones"),
        "out_proj": ParamDef((di, d), P(sdi, "data"),
                             scale=fan_in_scale(di)),
    }


def _ssd_split(cfg: ModelConfig, proj: jax.Array):
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * ns]
    dt = proj[..., di + di + 2 * ns:]
    return z, xbc, dt


def ssd_apply(cfg: ModelConfig, params: dict, x: jax.Array,
              return_cache: bool = False):
    """Chunked state-space duality forward (Mamba-2 §6).  x: (B, S, D)."""
    b, s, d = x.shape
    di, ns, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    q = min(cfg.ssm_chunk, s)
    if s % q:  # snap to the largest divisor of s (ragged prompts)
        q = max(v for v in range(1, q + 1) if s % v == 0)
    nc = s // q

    proj = x @ params["in_proj"]
    z, xbc_raw, dt = _ssd_split(cfg, proj)
    # causal depthwise conv over time
    xbc = _causal_conv1d(xbc_raw, params["conv_w"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xs = xbc[..., :di].reshape(b, s, nh, hp)
    bmat = xbc[..., di:di + ns]                        # (B, S, N), G=1
    cmat = xbc[..., di + ns:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])                      # (H,)
    da = dt * a                                        # (B, S, H) log-decay

    # chunk views
    xc = xs.reshape(b, nc, q, nh, hp)
    bc = bmat.reshape(b, nc, q, ns).astype(jnp.float32)
    cc = cmat.reshape(b, nc, q, ns).astype(jnp.float32)
    dac = da.reshape(b, nc, q, nh)
    dtc = dt.reshape(b, nc, q, nh)
    cum = jnp.cumsum(dac, axis=2)                      # (B,Nc,Q,H)

    # intra-chunk (the "quadratic attention-like" branch)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,Nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcqn,bckn->bcqk", cc, bc)
    xdt = xc.astype(jnp.float32) * dtc[..., None]
    y_intra = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", scores, decay, xdt)

    # chunk-final states, then scan the recurrence across chunks
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # (B,Nc,Q,H)
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", bc, decay_to_end, xdt)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (B,Nc,H)

    def step(carry, inp):
        st, dec = inp                                      # (B,H,P,N),(B,H)
        new = carry * dec[:, :, None, None] + st
        return new, carry                                  # emit PREV state

    init = jnp.zeros((b, nh, hp, ns), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        step, init, (states.transpose(1, 0, 2, 3, 4),
                     chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (B,Nc,H,P,N)

    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cc, prev_states,
                         jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, s, nh, hp)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, di)
    # gated RMSNorm (mamba-2 norm before out-proj)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * \
        params["norm_scale"].astype(jnp.float32)
    out = y.astype(x.dtype) @ params["out_proj"]
    if not return_cache:
        return out
    w_hist = cfg.conv_width - 1
    tail = xbc_raw[:, -w_hist:, :].astype(cfg.dtype)
    if s < w_hist:
        tail = jnp.pad(tail, ((0, 0), (w_hist - s, 0), (0, 0)))
    return out, {"conv": tail, "state": final_state}


def _causal_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv over time.  x: (B,S,C); w: (W,C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):
        out = out + pad[:, i:i + x.shape[1], :].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def ssd_cache_defs(cfg: ModelConfig, batch: int, model_ax: int) -> dict:
    di, ns, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    conv_dim = di + 2 * ns
    return {
        "conv": ParamDef((batch, cfg.conv_width - 1, conv_dim),
                         P("data", None, None), init="zeros",
                         dtype=cfg.dtype),
        "state": ParamDef((batch, nh, hp, ns), P("data", None, None, None),
                          init="zeros", dtype=jnp.float32),
    }


def ssd_decode(cfg: ModelConfig, params: dict, x: jax.Array,
               cache: dict) -> tuple[jax.Array, dict]:
    """Single-token SSD step: O(1) state update.  x: (B, 1, D)."""
    b = x.shape[0]
    di, ns, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    proj = x[:, 0] @ params["in_proj"]                     # (B, P_out)
    z, xbc, dt = _ssd_split(cfg, proj[:, None, :])
    z, xbc, dt = z[:, 0], xbc[:, 0], dt[:, 0]

    # conv cache update
    hist = jnp.concatenate([cache["conv"],
                            xbc[:, None, :].astype(cache["conv"].dtype)],
                           axis=1)                          # (B, W, C)
    w = params["conv_w"]
    conv_out = jnp.sum(hist.astype(jnp.float32) *
                       w.astype(jnp.float32)[None], axis=1)
    xbc = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = hist[:, 1:, :]

    xs = xbc[:, :di].reshape(b, nh, hp)
    bvec = xbc[:, di:di + ns].astype(jnp.float32)
    cvec = xbc[:, di + ns:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * a)                                 # (B, H)
    upd = jnp.einsum("bn,bh,bhp->bhpn", bvec, dt, xs.astype(jnp.float32))
    state = cache["state"] * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", cvec, state)
    y = y + params["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * \
        params["norm_scale"].astype(jnp.float32)
    out = (y.astype(x.dtype) @ params["out_proj"])[:, None, :]
    return out, {"conv": new_conv, "state": state}


# ========================= RG-LRU (recurrentgemma) =========================

_LRU_C = 8.0


def rglru_defs(cfg: ModelConfig, model_ax: int) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    sw = _shard_if(w, model_ax)
    return {
        "in_x": ParamDef((d, w), P("data", sw), scale=fan_in_scale(d)),
        "in_gate": ParamDef((d, w), P("data", sw), scale=fan_in_scale(d)),
        "conv_w": ParamDef((cfg.conv_width, w), P(None, sw),
                           scale=fan_in_scale(cfg.conv_width)),
        "w_r": ParamDef((w, w), P("data", sw), scale=fan_in_scale(w)),
        "w_i": ParamDef((w, w), P("data", sw), scale=fan_in_scale(w)),
        "lam": ParamDef((w,), P(sw), init="ones", dtype=jnp.float32),
        "out": ParamDef((w, d), P(sw, "data"), scale=fan_in_scale(w)),
    }


def _rglru_gates(params: dict, xr: jax.Array):
    r = jax.nn.sigmoid((xr @ params["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((xr @ params["w_i"]).astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * \
        (i * xr.astype(jnp.float32))
    return a, gated


def rglru_apply(cfg: ModelConfig, params: dict, x: jax.Array,
                return_cache: bool = False):
    """Griffin recurrent block: conv1d -> RG-LRU -> GeLU-gate.  x:(B,S,D)."""
    gate = jax.nn.gelu((x @ params["in_gate"]).astype(jnp.float32))
    xr_raw = x @ params["in_x"]
    xr = _causal_conv1d(xr_raw, params["conv_w"])
    a, gated = _rglru_gates(params, xr)

    # linear recurrence h_t = a_t h_{t-1} + b_t via associative scan
    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    y = (h * gate).astype(x.dtype)
    out = y @ params["out"]
    if not return_cache:
        return out
    w_hist = cfg.conv_width - 1
    s = x.shape[1]
    tail = xr_raw[:, -w_hist:, :].astype(cfg.dtype)
    if s < w_hist:
        tail = jnp.pad(tail, ((0, 0), (w_hist - s, 0), (0, 0)))
    return out, {"conv": tail, "h": h[:, -1, :]}


def rglru_cache_defs(cfg: ModelConfig, batch: int, model_ax: int) -> dict:
    w = cfg.lru_width
    sw = _shard_if(w, model_ax)
    return {
        "conv": ParamDef((batch, cfg.conv_width - 1, w),
                         P("data", None, sw), init="zeros", dtype=cfg.dtype),
        "h": ParamDef((batch, w), P("data", sw), init="zeros",
                      dtype=jnp.float32),
    }


def rglru_decode(cfg: ModelConfig, params: dict, x: jax.Array,
                 cache: dict) -> tuple[jax.Array, dict]:
    gate = jax.nn.gelu((x[:, 0] @ params["in_gate"]).astype(jnp.float32))
    xr = x[:, 0] @ params["in_x"]
    hist = jnp.concatenate([cache["conv"],
                            xr[:, None, :].astype(cache["conv"].dtype)],
                           axis=1)
    conv = jnp.sum(hist.astype(jnp.float32) *
                   params["conv_w"].astype(jnp.float32)[None], axis=1)
    xr = conv.astype(x.dtype)
    a, gated = _rglru_gates(params, xr)
    h = a * cache["h"] + gated
    y = (h * gate).astype(x.dtype) @ params["out"]
    return y[:, None, :], {"conv": hist[:, 1:, :], "h": h}
