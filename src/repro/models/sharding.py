"""Mesh-aware sharding helpers.

Model code writes PartitionSpecs against *canonical* axis names
("data", "model").  The launcher installs an axis mapping per mesh
(multi-pod: "data" -> ("pod", "data"); unshardable batch: "data" -> None)
and every in-model ``maybe_shard`` constraint is translated through it, so
the same model definition runs on any mesh layout.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

_AXIS_MAPPING: dict[str, Any] = {}


def set_axis_mapping(mapping: dict[str, Any]) -> None:
    global _AXIS_MAPPING
    _AXIS_MAPPING = dict(mapping)


def get_axis_mapping() -> dict[str, Any]:
    return dict(_AXIS_MAPPING)


def translate_spec(spec: P, mapping: dict[str, Any] | None = None) -> P:
    mapping = _AXIS_MAPPING if mapping is None else mapping

    def tr(axis):
        if isinstance(axis, (tuple, list)):
            out = []
            for a in axis:
                m = mapping.get(a, a)
                if m is None:
                    continue
                out.extend(m if isinstance(m, tuple) else (m,))
            return tuple(out) if out else None
        return mapping.get(axis, axis)

    return P(*(tr(a) for a in spec))


def translate_tree(tree: Any, mapping: dict[str, Any] | None = None) -> Any:
    return jax.tree.map(lambda s: translate_spec(s, mapping), tree,
                        is_leaf=lambda x: isinstance(x, P))


def on_mesh() -> bool:
    """True when running under a ``with mesh:`` context with >1 device."""
    try:
        env = jax.interpreters.pxla.thread_resources.env
        return env.physical_mesh.size > 1
    except Exception:
        return False


def maybe_shard(x: jax.Array, spec: P) -> jax.Array:
    if on_mesh():
        return jax.lax.with_sharding_constraint(x, translate_spec(spec))
    return x
