"""Unified model assembly for every assigned architecture family.

A model is a stack of pre-norm blocks; each block has a *mixer* chosen by
``cfg.layer_pattern`` ("global" / "local" attention, "recurrent" RG-LRU,
"ssd" Mamba-2) and an FFN (dense SwiGLU or MoE).  Layers are stacked in
*pattern cycles* and iterated with ``lax.scan`` over stacked parameters so
deep configs (94 layers) lower quickly; the remainder layers (when
``n_layers % len(pattern) != 0``) run unrolled.

Encoder-decoder (seamless-m4t) adds a bidirectional encoder over
precomputed frontend embeddings and cross-attention in every decoder block.
VLM/audio prefix embeddings are concatenated ahead of token embeddings
(the modality frontend is a stub per the assignment).
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


from repro.util import scan_or_unroll as _scan
from repro.models import layers as L
from repro.models.base import (ParamDef, build, fan_in_scale, retype_defs,
                               stack_defs)
from repro.models.config import ModelConfig
from repro.models.sharding import maybe_shard


# ------------------------------ block defs ---------------------------------


def _mixer_defs(cfg: ModelConfig, mixer: str, model_ax: int) -> dict:
    if mixer in ("global", "local"):
        return L.attention_defs(cfg, model_ax)
    if mixer == "recurrent":
        return L.rglru_defs(cfg, model_ax)
    if mixer == "ssd":
        return L.ssd_defs(cfg, model_ax)
    raise ValueError(mixer)


def _ffn_defs(cfg: ModelConfig, model_ax: int) -> dict | None:
    if cfg.n_experts:
        return L.moe_defs(cfg, model_ax)
    if cfg.d_ff:
        return L.mlp_defs(cfg, model_ax)
    return None  # pure-SSM archs have no separate FFN


def block_defs(cfg: ModelConfig, mixer: str, model_ax: int,
               cross: bool = False) -> dict:
    d = {"norm1": L.rmsnorm_defs(cfg.d_model),
         "mixer": _mixer_defs(cfg, mixer, model_ax)}
    ffn = _ffn_defs(cfg, model_ax)
    if ffn is not None:
        d["norm2"] = L.rmsnorm_defs(cfg.d_model)
        d["ffn"] = ffn
    if cross:
        d["norm_x"] = L.rmsnorm_defs(cfg.d_model)
        d["cross"] = L.attention_defs(cfg, model_ax)
    return d


def model_defs(cfg: ModelConfig, model_ax: int = 1) -> dict:
    pattern = cfg.layer_pattern
    n_groups = cfg.n_layers // len(pattern)
    rem = cfg.n_layers % len(pattern)
    defs: dict[str, Any] = {
        "embed": L.embedding_defs(cfg, model_ax),
        "final_norm": L.rmsnorm_defs(cfg.d_model),
        "layers": [stack_defs(block_defs(cfg, m, model_ax,
                                         cross=cfg.is_encdec), n_groups)
                   for m in pattern],
        "tail": [block_defs(cfg, pattern[j], model_ax,
                            cross=cfg.is_encdec) for j in range(rem)],
    }
    if not cfg.tie_embeddings:
        v = L.padded_vocab(cfg, model_ax)
        defs["lm_head"] = ParamDef(
            (cfg.d_model, v), P("data", L._shard_if(v, model_ax)),
            scale=fan_in_scale(cfg.d_model))
    if cfg.is_encdec:
        defs["encoder"] = {
            "layers": stack_defs(block_defs(cfg, "global", model_ax),
                                 cfg.encoder_layers),
            "final_norm": L.rmsnorm_defs(cfg.d_model),
        }
    return retype_defs(defs, cfg.dtype)


def init_params(cfg: ModelConfig, rng: jax.Array, model_ax: int = 1):
    return build(model_defs(cfg, model_ax), "init", rng)


def param_shapes(cfg: ModelConfig, model_ax: int = 1):
    return build(model_defs(cfg, model_ax), "shape")


def param_specs(cfg: ModelConfig, model_ax: int = 1):
    return build(model_defs(cfg, model_ax), "spec")


# ------------------------------ forward ------------------------------------


def _block_apply(cfg: ModelConfig, p: dict, h: jax.Array, mixer: str,
                 positions: jax.Array, enc_out: jax.Array | None = None,
                 enc_positions: jax.Array | None = None,
                 ) -> tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    window = cfg.window if mixer == "local" else None
    hn = L.rmsnorm(p["norm1"], h)
    if mixer in ("global", "local"):
        h = h + L.attention_apply(cfg, p["mixer"], hn, positions,
                                  causal=True, window=window)
    elif mixer == "recurrent":
        h = h + L.rglru_apply(cfg, p["mixer"], hn)
    elif mixer == "ssd":
        h = h + L.ssd_apply(cfg, p["mixer"], hn)
    if enc_out is not None and "cross" in p:
        hx = L.rmsnorm(p["norm_x"], h)
        h = h + _cross_attention(cfg, p["cross"], hx, enc_out,
                                 positions, enc_positions)
    if "ffn" in p:
        hf = L.rmsnorm(p["norm2"], h)
        if cfg.n_experts:
            out, a = L.moe_apply(cfg, p["ffn"], hf)
            h = h + out
            aux = aux + a
        else:
            h = L.mlp_apply(p["ffn"], hf, residual=h)
    return h, aux


def _cross_attention(cfg, p, x, enc_out, positions, enc_positions):
    from repro.kernels import ops
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    se = enc_out.shape[1]
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k = (enc_out @ p["wk"]).reshape(b, se, hkv, hd)
    v = (enc_out @ p["wv"]).reshape(b, se, hkv, hd)
    out = ops.attention(q, k, v, causal=False)
    return out.reshape(b, s, hq * hd) @ p["wo"]


def _encoder_apply(cfg: ModelConfig, params: dict, embeds: jax.Array):
    enc = params["encoder"]
    b, se, _ = embeds.shape
    positions = jnp.broadcast_to(jnp.arange(se), (b, se))
    h = embeds

    def step(carry, p):
        h = carry
        hn = L.rmsnorm(p["norm1"], h)
        h = h + L.attention_apply(cfg, p["mixer"], hn, positions,
                                  causal=False)
        hf = L.rmsnorm(p["norm2"], h)
        h = L.mlp_apply(p["ffn"], hf, residual=h)
        return h, None

    h, _ = _scan(step, h, enc["layers"])
    return L.rmsnorm(enc["final_norm"], h), positions


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
            prefix_embeds: jax.Array | None = None,
            enc_embeds: jax.Array | None = None) -> tuple[jax.Array,
                                                          jax.Array]:
    """Full-sequence forward.  Returns (hidden (B,S,D), aux_loss)."""
    emb = params["embed"]["embedding"]
    h = jnp.take(emb, tokens, axis=0) * (cfg.d_model ** 0.5)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    b, s, _ = h.shape
    h = maybe_shard(h, P("data", None, None))
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    enc_out = enc_positions = None
    if cfg.is_encdec:
        assert enc_embeds is not None
        enc_out, enc_positions = _encoder_apply(cfg, params, enc_embeds)

    pattern = cfg.layer_pattern
    aux_total = jnp.zeros((), jnp.float32)

    def cycle(h, cycle_params):
        aux = jnp.zeros((), jnp.float32)
        for j, mixer in enumerate(pattern):
            h, a = _block_apply(cfg, cycle_params[j], h, mixer, positions,
                                enc_out, enc_positions)
            aux = aux + a
        return h, aux

    if cfg.remat in ("block", "full"):
        cycle = jax.checkpoint(cycle)
    elif cfg.remat == "dots":
        # §Perf lever: save matmul outputs, recompute elementwise only —
        # removes most of the remat FLOP waste at modest activation memory
        cycle = jax.checkpoint(
            cycle,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    def scan_step(carry, cycle_params):
        h, aux = carry
        h, a = cycle(h, cycle_params)
        return (h, aux + a), None

    n_groups = cfg.n_layers // len(pattern)
    if n_groups:
        (h, aux_total), _ = _scan(scan_step, (h, aux_total),
                                         params["layers"])
    for j, p in enumerate(params["tail"]):
        h, a = _block_apply(cfg, p, h, pattern[j], positions, enc_out,
                            enc_positions)
        aux_total = aux_total + a
    h = L.rmsnorm(params["final_norm"], h)
    return h, aux_total


def logits_fn(cfg: ModelConfig, params: dict, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = h @ params["embed"]["embedding"].T
    else:
        logits = h @ params["lm_head"]
    logits = maybe_shard(logits, P("data", None, "model"))
    if cfg.final_logit_cap is not None:
        logits = cfg.final_logit_cap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.final_logit_cap)
    return logits


def loss_fn(cfg: ModelConfig, params: dict, batch: dict,
            model_ax: int = 1) -> tuple[jax.Array, dict]:
    """Cross-entropy LM loss.  batch: tokens, labels (+ modality extras)."""
    h, aux = forward(cfg, params, batch["tokens"],
                     prefix_embeds=batch.get("prefix_embeds"),
                     enc_embeds=batch.get("enc_embeds"))
    if batch.get("prefix_embeds") is not None:
        h = h[:, batch["prefix_embeds"].shape[1]:, :]  # loss on text only
    logits = logits_fn(cfg, params, h).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    nll = (logz - gold) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux": aux,
                   "tokens": jnp.sum(mask)}


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            max_seq: int, prefix_embeds: jax.Array | None = None,
            enc_embeds: jax.Array | None = None,
            full_kv: bool = False,
            logits_at: jax.Array | int | None = None):
    """Full-sequence forward that also writes the decode caches.

    Returns (last_logits (B, V), cache).  Caches are sized to ``max_seq``
    (global attention) / ``window`` (local) / O(1) (ssd, recurrent).

    Serving plumbing: ``full_kv=True`` keeps windowed layers' K/V in the
    full position-indexed layout (the paged cache scatters it into pages
    and masks the window at decode time); ``logits_at`` returns the
    logits of that sequence position instead of the last one — bucketed
    prefill right-pads a prompt to its bucket, so the "last real token"
    sits at ``true_len - 1``, not at ``bucket - 1``.
    """
    emb = params["embed"]["embedding"]
    h = jnp.take(emb, tokens, axis=0) * (cfg.d_model ** 0.5)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    b, s, _ = h.shape
    h = maybe_shard(h, P("data", None, None))
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    enc_out = enc_positions = None
    if cfg.is_encdec:
        assert enc_embeds is not None
        enc_out, enc_positions = _encoder_apply(cfg, params, enc_embeds)

    pattern = cfg.layer_pattern

    def block_prefill(p, h, mixer):
        hn = L.rmsnorm(p["norm1"], h)
        window = cfg.window if mixer == "local" else None
        if mixer in ("global", "local"):
            out, cache = L.attention_apply(
                cfg, p["mixer"], hn, positions, causal=True, window=window,
                return_cache=max_seq, full_cache=full_kv)
        elif mixer == "recurrent":
            out, cache = L.rglru_apply(cfg, p["mixer"], hn,
                                       return_cache=True)
        elif mixer == "ssd":
            out, cache = L.ssd_apply(cfg, p["mixer"], hn, return_cache=True)
        h = h + out
        if enc_out is not None and "cross" in p:
            hx = L.rmsnorm(p["norm_x"], h)
            h = h + _cross_attention(cfg, p["cross"], hx, enc_out,
                                     positions, enc_positions)
            se = enc_out.shape[1]
            hkv, hd = cfg.n_kv_heads, cfg.head_dim
            cache = dict(cache)
            cache["cross_k"] = (enc_out @ p["cross"]["wk"]).reshape(
                b, se, hkv, hd).astype(cfg.dtype)
            cache["cross_v"] = (enc_out @ p["cross"]["wv"]).reshape(
                b, se, hkv, hd).astype(cfg.dtype)
        if "ffn" in p:
            hf = L.rmsnorm(p["norm2"], h)
            if cfg.n_experts:
                out, _ = L.moe_apply(cfg, p["ffn"], hf)
                h = h + out
            else:
                h = L.mlp_apply(p["ffn"], hf, residual=h)
        return h, cache

    def scan_step(h, cycle_params):
        caches = []
        for j, mixer in enumerate(pattern):
            h, c = block_prefill(cycle_params[j], h, mixer)
            caches.append(c)
        return h, caches

    n_groups = cfg.n_layers // len(pattern)
    if n_groups:
        h, layer_caches = _scan(scan_step, h, params["layers"])
    else:
        layer_caches = [jax.tree.map(lambda d: None, {})] * 0
    tail_caches = []
    for j, p in enumerate(params["tail"]):
        h, c = block_prefill(p, h, pattern[j])
        tail_caches.append(c)
    h = L.rmsnorm(params["final_norm"], h)
    if logits_at is None:
        h_last = h[:, -1:, :]
    else:
        h_last = jax.lax.dynamic_slice_in_dim(h, logits_at, 1, axis=1)
    logits = logits_fn(cfg, params, h_last)[:, 0, :]
    return logits, {"layers": layer_caches if n_groups else [],
                    "tail": tail_caches}


# ------------------------------ decoding -----------------------------------


def cache_defs(cfg: ModelConfig, batch: int, max_seq: int,
               model_ax: int = 1, enc_seq: int = 0) -> dict:
    """Decode-state tree matching the layer structure."""
    pattern = cfg.layer_pattern
    n_groups = cfg.n_layers // len(pattern)
    rem = cfg.n_layers % len(pattern)

    def one(mixer: str) -> dict:
        if mixer == "global":
            return L.attention_cache_defs(cfg, batch, max_seq, model_ax,
                                          None)
        if mixer == "local":
            return L.attention_cache_defs(cfg, batch, max_seq, model_ax,
                                          cfg.window)
        if mixer == "recurrent":
            return L.rglru_cache_defs(cfg, batch, model_ax)
        if mixer == "ssd":
            return L.ssd_cache_defs(cfg, batch, model_ax)
        raise ValueError(mixer)

    def with_cross(d: dict) -> dict:
        if cfg.is_encdec:
            hkv, hd = cfg.n_kv_heads, cfg.head_dim
            d = dict(d)
            d["cross_k"] = ParamDef((batch, enc_seq, hkv, hd),
                                    P("data", None, None, None),
                                    init="zeros", dtype=cfg.dtype)
            d["cross_v"] = ParamDef((batch, enc_seq, hkv, hd),
                                    P("data", None, None, None),
                                    init="zeros", dtype=cfg.dtype)
        return d

    return {
        "layers": [stack_defs(with_cross(one(m)), n_groups)
                   for m in pattern],
        "tail": [with_cross(one(pattern[j])) for j in range(rem)],
    }


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               model_ax: int = 1, enc_seq: int = 0):
    return build(cache_defs(cfg, batch, max_seq, model_ax, enc_seq),
                 "init", jax.random.PRNGKey(0))


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int,
                model_ax: int = 1, enc_seq: int = 0):
    return build(cache_defs(cfg, batch, max_seq, model_ax, enc_seq), "spec")


def cache_shapes(cfg: ModelConfig, batch: int, max_seq: int,
                 model_ax: int = 1, enc_seq: int = 0):
    return build(cache_defs(cfg, batch, max_seq, model_ax, enc_seq),
                 "shape")


def _block_decode(cfg: ModelConfig, p: dict, h: jax.Array, mixer: str,
                  cache: dict, pos: jax.Array,
                  attn_step=None) -> tuple[jax.Array, dict]:
    """One block's decode step.

    ``attn_step`` swaps the attention-layer implementation: it receives
    ``(params, hn, cache, pos, window)`` and returns ``(out, new cache
    entries)``.  The default is the dense per-request cache
    (``L.attention_decode``); the serving subsystem passes the paged
    flash-decode step (``serve.kv_cache.make_paged_attn_step``).  The
    recurrent / SSD / FFN structure is shared by both paths.
    """
    hn = L.rmsnorm(p["norm1"], h)
    new_cache = dict(cache)
    if mixer in ("global", "local"):
        window = cfg.window if mixer == "local" else None
        if attn_step is None:
            attn_cache = {"k": cache["k"], "v": cache["v"]}
            out, attn_new = L.attention_decode(cfg, p["mixer"], hn,
                                               attn_cache, pos,
                                               window=window)
        else:
            out, attn_new = attn_step(p["mixer"], hn, cache, pos, window)
        h = h + out
        new_cache.update(attn_new)
    elif mixer == "recurrent":
        out, rc = L.rglru_decode(cfg, p["mixer"], hn,
                                 {"conv": cache["conv"], "h": cache["h"]})
        h = h + out
        new_cache.update(rc)
    elif mixer == "ssd":
        out, sc = L.ssd_decode(cfg, p["mixer"], hn,
                               {"conv": cache["conv"],
                                "state": cache["state"]})
        h = h + out
        new_cache.update(sc)
    if "cross" in p and "cross_k" in cache:
        hx = L.rmsnorm(p["norm_x"], h)
        h = h + _cross_decode(cfg, p["cross"], hx, cache["cross_k"],
                              cache["cross_v"])
    if "ffn" in p:
        hf = L.rmsnorm(p["norm2"], h)
        if cfg.n_experts:
            out, _ = L.moe_apply(cfg, p["ffn"], hf)
            h = h + out
        else:
            h = L.mlp_apply(p["ffn"], hf, residual=h)
    return h, new_cache


def _cross_decode(cfg, p, x, ck, cv):
    b = x.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, 1, hq, hd)
    groups = hq // hkv
    qh = q.reshape(b, hkv, groups, hd)
    logits = jnp.einsum("bhgd,blhd->bhgl", qh.astype(jnp.float32),
                        ck.astype(jnp.float32)) * hd ** -0.5
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgl,blhd->bhgd", probs, cv.astype(jnp.float32))
    return out.reshape(b, 1, hq * hd).astype(x.dtype) @ p["wo"]


def decode_step(cfg: ModelConfig, params: dict, token: jax.Array,
                cache: dict, pos: jax.Array,
                attn_step=None) -> tuple[jax.Array, dict]:
    """One decode step.  token: (B,) int32; returns (logits (B, V), cache).

    ``attn_step`` (see :func:`_block_decode`) substitutes the attention
    cache implementation — the paged serving engine threads its
    flash-decode step through here so every non-attention layer reuses
    this exact code path.

    A 2-D ``token`` of shape (B, S) is the multi-token span form
    (speculative verify / chunked prefill): the S tokens occupy
    consecutive positions starting at ``pos``, and logits come back for
    EVERY position, (B, S, V).  Only the attention mixers support spans
    (the rglru/ssd state updates are strictly one-token), so this form
    requires a span-capable ``attn_step``
    (``serve.kv_cache.make_paged_span_step``) and an attention-only
    ``layer_pattern``; the norm/FFN/MoE structure is shape-polymorphic
    and shared verbatim.
    """
    single = token.ndim == 1
    if not single:
        if attn_step is None:
            raise ValueError("multi-token decode_step needs a span-capable "
                             "attn_step (the dense cache is one-token)")
        bad = [m for m in cfg.layer_pattern if m not in ("global", "local")]
        if bad:
            raise ValueError(f"multi-token decode_step is attention-only; "
                             f"layer_pattern has {bad}")
    emb = params["embed"]["embedding"]
    h = jnp.take(emb, token[:, None] if single else token,
                 axis=0) * (cfg.d_model ** 0.5)
    pattern = cfg.layer_pattern

    def scan_step(h, xs):
        cycle_params, cycle_cache = xs
        new_caches = []
        for j, mixer in enumerate(pattern):
            h, nc = _block_decode(cfg, cycle_params[j], h, mixer,
                                  cycle_cache[j], pos, attn_step)
            new_caches.append(nc)
        return h, new_caches

    n_groups = cfg.n_layers // len(pattern)
    if n_groups:
        h, new_layer_caches = _scan(
            scan_step, h, (params["layers"], cache["layers"]))
    else:
        new_layer_caches = cache["layers"]
    new_tail = []
    for j, p in enumerate(params["tail"]):
        h, nc = _block_decode(cfg, p, h, pattern[j], cache["tail"][j], pos,
                              attn_step)
        new_tail.append(nc)
    h = L.rmsnorm(params["final_norm"], h)
    logits = logits_fn(cfg, params, h)
    if single:
        logits = logits[:, 0, :]
    return logits, {"layers": new_layer_caches, "tail": new_tail}


def prefill_cross_cache(cfg: ModelConfig, params: dict, cache: dict,
                        enc_embeds: jax.Array) -> dict:
    """Encoder-decoder: run the encoder once, fill cross K/V caches."""
    enc_out, _ = _encoder_apply(cfg, params, enc_embeds)
    b, se, _ = enc_out.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim

    def fill(group_params, group_cache):
        k = (enc_out @ group_params["cross"]["wk"]).reshape(b, se, hkv, hd)
        v = (enc_out @ group_params["cross"]["wv"]).reshape(b, se, hkv, hd)
        gc = dict(group_cache)
        gc["cross_k"] = k.astype(cfg.dtype)
        gc["cross_v"] = v.astype(cfg.dtype)
        return gc

    new = {"layers": [], "tail": []}
    for gp, gc in zip(params["layers"], cache["layers"]):
        new["layers"].append(_fill_stacked(cfg, gp, gc, enc_out))
    for p, c in zip(params["tail"], cache["tail"]):
        new["tail"].append(fill(p, c))
    return new


def _fill_stacked(cfg, gp, gc, enc_out):
    b, se, _ = enc_out.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim

    def one(wk, wv):
        k = (enc_out @ wk).reshape(b, se, hkv, hd).astype(cfg.dtype)
        v = (enc_out @ wv).reshape(b, se, hkv, hd).astype(cfg.dtype)
        return k, v

    ks, vs = jax.vmap(one)(gp["cross"]["wk"], gp["cross"]["wv"])
    out = dict(gc)
    out["cross_k"] = ks
    out["cross_v"] = vs
    return out
