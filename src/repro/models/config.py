"""Unified model configuration covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | encdec | ssm | hybrid | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # attention flavour
    rope_theta: float = 10_000.0
    window: int | None = None            # sliding-window size (local attn)
    layer_pattern: tuple[str, ...] = ("global",)
    #   entries: "global" | "local" | "recurrent" | "ssd"
    attn_logit_cap: float | None = None  # gemma-2 soft-capping
    final_logit_cap: float | None = None
    tie_embeddings: bool = True

    mlp_kind: str = "swiglu"   # "swiglu" (3 mats) | "gelu" (2 mats)

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba-2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4

    # RG-LRU (recurrentgemma)
    lru_width: int = 0          # 0 -> d_model

    # encoder-decoder
    encoder_layers: int = 0
    encoder_seq: int = 0        # frontend-stub sequence length

    # multimodal frontend stub (vlm / audio): number of prefix embeddings
    # supplied pre-computed by input_specs()
    prefix_tokens: int = 0

    dtype: Any = jnp.bfloat16
    kv_cache_dtype: Any = None  # None -> dtype; fp8 halves the decode
                                # memory term (EXPERIMENTS.md §Perf it. 4)
                                # and routes paged decode through the
                                # fp8 flash-decode kernel + page sizing
                                # (docs/quantization.md)

    # training
    remat: str = "block"        # "none" | "block" | "full" | "dots"

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)
        if self.kv_cache_dtype is not None:
            # validate at construction: every downstream consumer
            # (models/layers.py cache defs, serve/kv_cache.py pools,
            # launch/dryrun.py --kv8) casts K/V into this dtype silently,
            # so an unsupported width must fail HERE, loudly.
            try:
                dt = jnp.dtype(self.kv_cache_dtype)
            except TypeError as exc:
                raise ValueError(
                    f"kv_cache_dtype is not a dtype: "
                    f"{self.kv_cache_dtype!r} ({exc})") from None
            if not (jnp.issubdtype(dt, jnp.floating)
                    and dt.itemsize in (1, 2, 4)):
                raise ValueError(
                    "kv_cache_dtype must be a floating dtype of width "
                    "1/2/4 bytes (float8_e4m3fn / float8_e5m2, "
                    f"bfloat16 / float16, float32); got {dt.name}")

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return all(p == "ssd" for p in self.layer_pattern)

    @property
    def supports_long_context(self) -> bool:
        """True when decode state does not grow linearly with full-attn KV
        (SSM state / RG-LRU state / local-window only)."""
        return all(p in ("ssd", "recurrent", "local")
                   for p in self.layer_pattern)

    def mixer_for_layer(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    @property
    def d_inner(self) -> int:   # SSD inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # -- parameter / FLOP accounting (roofline §Roofline) --------------------

    def param_count(self) -> int:
        d, v = self.d_model, self.vocab
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        for i in range(self.n_layers):
            n += self._layer_params(self.mixer_for_layer(i))
        for _ in range(self.encoder_layers):
            n += self._layer_params("global") + \
                2 * (2 * d * self.n_heads * self.head_dim)  # cross-attn q,o
        return n

    def _layer_params(self, mixer: str) -> int:
        d = self.d_model
        hd, hq, hkv = self.head_dim, self.n_heads, self.n_kv_heads
        n = 2 * d  # norms
        if mixer in ("global", "local"):
            n += d * hd * (hq + 2 * hkv) + hq * hd * d
        elif mixer == "recurrent":
            w = self.lru_width
            n += 2 * d * w + w * d + 3 * w + self.conv_width * w
        elif mixer == "ssd":
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            n += d * (2 * di + 2 * ns + nh) + di * d + \
                self.conv_width * (di + 2 * ns) + 2 * nh
        mats = 3 if self.mlp_kind == "swiglu" else 2
        if self.n_experts:
            n += d * self.n_experts  # router
            n += self.n_experts * 3 * d * self.moe_d_ff
        elif self.d_ff:
            n += mats * d * self.d_ff
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        total -= self.n_layers * self.n_experts * 3 * d * self.moe_d_ff
        total += self.n_layers * self.experts_per_token * 3 * d * \
            self.moe_d_ff
        return total

    def model_flops_per_token(self) -> float:
        """6 * N_active (the standard training-FLOPs estimate)."""
        return 6.0 * self.active_param_count()
