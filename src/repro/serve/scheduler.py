"""Continuous-batching scheduler: admission by free-page budget.

Policy layer of the serving subsystem (layout lives in ``kv_cache``,
model math in ``engine``).  Requests wait in FIFO order; one is admitted
when (a) a batch slot is free and (b) the page pool can cover its whole
lifetime — ``ceil((prompt_len + max_new_tokens) / page_size)`` pages are
reserved up front, so a running request can never stall mid-decode
waiting for a page (no admission deadlock, at the cost of tail-page
slack).  Finished requests are evicted at the step boundary, their pages
return to the pool, and the freed slot joins the next admission round —
the "per-step join of new prefills into the running decode batch".
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.serve.kv_cache import PageAllocator, num_blocks


@dataclasses.dataclass
class Request:
    """One generation request (host-side bookkeeping)."""

    rid: int
    prompt: np.ndarray              # (L,) int32
    max_new_tokens: int
    pages: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    generated: int = 0              # tokens sampled so far
    output: np.ndarray | None = None   # set at eviction

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.max_new_tokens

    @property
    def done(self) -> bool:
        return self.generated >= self.max_new_tokens


class Scheduler:
    """FIFO continuous batching over ``max_batch`` slots and a page pool."""

    def __init__(self, max_batch: int, page_size: int,
                 allocator: PageAllocator, max_seq: int):
        self.max_batch = max_batch
        self.page_size = page_size
        self.allocator = allocator
        self.max_seq = max_seq
        self.waiting: deque[Request] = deque()
        self.running: dict[int, Request] = {}          # slot -> Request
        self._free_slots = list(range(max_batch - 1, -1, -1))

    # -- queue ----------------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.total_len > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + "
                f"{req.max_new_tokens} new > max_seq {self.max_seq}")
        if self.pages_needed(req) > self.allocator.capacity:
            # would wait forever: even an empty pool can't cover it
            raise ValueError(
                f"request {req.rid}: needs {self.pages_needed(req)} pages "
                f"but the pool holds {self.allocator.capacity}")
        self.waiting.append(req)

    def pages_needed(self, req: Request) -> int:
        return num_blocks(req.total_len, self.page_size)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # -- admission / eviction -------------------------------------------------

    def admit(self) -> list[Request]:
        """Admit FIFO head requests while a slot and the page budget
        allow; each admitted request leaves with its slot and its whole
        page reservation (block table order = logical block order)."""
        admitted = []
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            if self.allocator.available() < self.pages_needed(req):
                break                    # strict FIFO: no head-of-line skip
            self.waiting.popleft()
            req.slot = self._free_slots.pop()
            req.pages = self.allocator.alloc_many(self.pages_needed(req))
            self.running[req.slot] = req
            admitted.append(req)
        return admitted

    def evict(self, slot: int) -> Request:
        """Release a finished (or cancelled) request's slot and pages."""
        req = self.running.pop(slot)
        self.allocator.free_many(req.pages)
        req.pages = []
        req.slot = -1
        self._free_slots.append(slot)
        return req
