"""Decode-priority continuous-batching scheduler.

Policy layer of the serving subsystem (layout lives in ``kv_cache``,
model math in ``engine``).  Two decisions live here, both pure host-side
bookkeeping so the hypothesis suite (``tests/test_serve_invariants.py``)
can drive them with random traces:

**Admission** (:meth:`Scheduler.admit`) is backfill-with-aging.  A
request is admitted when (a) a batch slot is free and (b) the page pool
can cover its whole lifetime — ``ceil((prompt_len + max_new_tokens) /
page_size)`` pages are reserved up front, so a running request can never
stall mid-decode waiting for a page (no admission deadlock, at the cost
of tail-page slack).  Unlike the original strict-FIFO rule, a younger
request that fits may be admitted past a head that doesn't
(head-of-line backfill keeps slots busy) — bounded by an anti-starvation
aging rule: every admission round a waiting request stays queued
increments its ``age``, and once the head's age reaches ``age_limit``
admission becomes head-only until the head gets in.  Because running
requests have bounded token budgets and whole-lifetime reservations,
their pages always return, so a starving head is eventually admitted —
the property the invariant suite checks.

With a :class:`~repro.serve.kv_cache.PrefixCache` attached, admission
first matches the prompt's longest cached full-page prefix: matched
pages are *shared* (refcount bump) instead of allocated, the page
budget counts only the unshared tail, and the request's prefill starts
at the matched boundary.  An exact full-page match CoW-forks its last
page (the final prompt token must re-run for the first-sample logits,
and its K/V write would otherwise land in the shared page).  When the
free list alone cannot cover the unshared tail, admission reclaims LRU
leaves from the tree — pages only the tree references, never one a
live request owns — so a full cache degrades to a smaller cache, not
to an admission stall (the aging liveness guarantee survives sharing).

**Step planning** (:meth:`Scheduler.plan_step`) is decode-priority:
every decode-ready slot decodes every step (a decode-ready slot is never
skipped in favor of prefill — the no-starvation invariant), and prefill
chunks backfill the remaining per-step token budget
(``max_batch * decode_chunk`` tokens), round-robin across prefilling
slots so one long prompt cannot monopolize the backfill.  At least one
chunk runs whenever any slot is prefilling, so prefill always makes
progress even at full decode load.

Finished requests are evicted at the step boundary, their pages return
to the pool, and the freed slot joins the next admission round.

**Lifecycle hardening** (docs/robustness.md) rides on the same
bookkeeping: every admission probe failure counts against an optional
retry budget with aging-aware backoff (a backed-off request probes
less often, but never so rarely it can't reach the head-only aging
guarantee), waiting requests expire against a wall deadline or a TTL
in scheduler steps (:meth:`Scheduler.expire`), and under sustained
pressure the engine may :meth:`Scheduler.preempt` the lowest-priority
running request: its *complete* pages are registered into the prefix
tree before eviction, so the replacement — requeued directly behind
the starving head — re-admits via prefix match and replays only the
unshared tail (``lifecycle.replay_cost_tokens`` ranks victims by
exactly that tail).
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.serve.kv_cache import PageAllocator, num_blocks
from repro.serve.lifecycle import replay_cost_tokens


@dataclasses.dataclass
class Request:
    """One generation request (host-side bookkeeping)."""

    rid: int
    prompt: np.ndarray              # (L,) int32
    max_new_tokens: int
    pages: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    prefilled: int = 0              # prompt tokens already in the KV cache
    generated: int = 0              # tokens sampled so far
    age: int = 0                    # admission rounds spent waiting
    output: np.ndarray | None = None   # set at eviction
    cached_tokens: int = 0          # prompt tokens matched in the prefix tree
    cow_fork: tuple[int, int] | None = None   # (src, dst) page fork to apply
    # -- lifecycle (docs/robustness.md) --------------------------------------
    priority: int = 0               # higher survives preemption longer
    deadline_ns: int | None = None  # absolute engine-clock ns, None = none
    expire_step: int | None = None  # absolute scheduler step, None = none
    retries: int = 0                # admission probe failures so far
    preempt_count: int = 0          # times preempted-and-restored
    prior_tokens: np.ndarray | None = None   # emitted before preemption(s)
    orig_prompt_len: int = -1       # prompt length at first submission
    orig_max_new: int = -1          # token budget at first submission
    cancelled: bool = False         # cooperative cancel -> TRUNCATED
    failed: bool = False            # NaN guard / retry exhaustion -> FAILED
    status: object = None           # lifecycle.RequestStatus, terminal
    backoff: int = 0                # admission rounds until the next probe

    def __post_init__(self):
        if self.orig_prompt_len < 0:
            self.orig_prompt_len = self.prompt_len
        if self.orig_max_new < 0:
            self.orig_max_new = self.max_new_tokens

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.max_new_tokens

    @property
    def prefill_done(self) -> bool:
        return self.prefilled >= self.prompt_len

    @property
    def done(self) -> bool:
        return self.generated >= self.max_new_tokens

    @property
    def decode_ready(self) -> bool:
        """Admitted, fully prefilled, budget left — decodes this step."""
        return self.slot >= 0 and self.prefill_done and not self.done

    @property
    def emitted_total(self) -> int:
        """Tokens emitted across every admission of this request."""
        prior = 0 if self.prior_tokens is None else len(self.prior_tokens)
        return prior + self.generated

    def expired(self, now_ns: int, step: int) -> bool:
        return ((self.deadline_ns is not None
                 and now_ns >= self.deadline_ns)
                or (self.expire_step is not None
                    and step >= self.expire_step))


@dataclasses.dataclass
class StepPlan:
    """One step's work, in execution order: decode first, then chunks.

    ``prefill_slots`` may name a slot more than once (several chunks of
    the same prompt in one otherwise-idle step); the engine executes
    them in order.
    """

    decode_slots: list[int]
    prefill_slots: list[int]


class Scheduler:
    """Decode-priority continuous batching over ``max_batch`` slots and
    a refcounted page pool."""

    def __init__(self, max_batch: int, page_size: int,
                 allocator: PageAllocator, max_seq: int,
                 age_limit: int = 8, prefix_cache=None, metrics=None,
                 max_retries: int | None = None):
        self.max_batch = max_batch
        self.page_size = page_size
        self.allocator = allocator
        self.max_seq = max_seq
        self.age_limit = age_limit
        self.max_retries = max_retries   # probe failures before FAILED
        self.prefix_cache = prefix_cache       # kv_cache.PrefixCache | None
        self.waiting: deque[Request] = deque()
        self.running: dict[int, Request] = {}          # slot -> Request
        self._free_slots = list(range(max_batch - 1, -1, -1))
        self._rr = 0                                   # backfill round-robin
        self._rejected: list[Request] = []     # retry budget exhausted
        # a private registry when none is shared keeps the report paths
        # branch-free (same cost either way: one int op per event)
        m = metrics if metrics is not None else MetricsRegistry()
        self._m_admitted = m.counter("sched.admitted")
        self._m_evicted = m.counter("sched.evicted")
        self._m_queue_depth = m.gauge("sched.queue_depth")
        self._m_head_age = m.gauge("sched.head_age")
        self._m_preemptions = m.counter("sched.preemptions")
        self._m_rejected = m.counter("sched.rejected")
        self._m_expired = m.counter("sched.expired")
        self._m_rollbacks = m.counter("sched.admit_rollbacks")

    # -- queue ----------------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.total_len > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + "
                f"{req.max_new_tokens} new > max_seq {self.max_seq}")
        if self.pages_needed(req) > self.allocator.capacity:
            # would wait forever: even an empty pool can't cover it
            raise ValueError(
                f"request {req.rid}: needs {self.pages_needed(req)} pages "
                f"but the pool holds {self.allocator.capacity}")
        self.waiting.append(req)

    def pages_needed(self, req: Request) -> int:
        return num_blocks(req.total_len, self.page_size)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # -- admission / eviction -------------------------------------------------

    def _fresh_needed(self, req: Request, matched: int) -> int:
        """Unshared pages a request must allocate given ``matched``
        prefix tokens from the tree — shared pages don't count against
        the budget, but an exact full-prompt match costs one extra page
        for the CoW fork of its last block."""
        shared = matched // self.page_size
        fork = 1 if (matched and matched == req.prompt_len) else 0
        return self.pages_needed(req) - shared + fork

    def _prepare(self, req: Request) -> list[int] | None:
        """Try to make ``req`` admittable right now.

        Probes the prefix tree for the longest cached full-page prefix,
        reclaims LRU tree leaves if the free list can't cover the
        unshared tail (never a page a live request owns), and — if even
        that falls short — gives the match up entirely and retries as a
        full re-prefill.  Returns the matched pages in block order
        (``[]`` for no match) when the request fits, else ``None``.
        No references are taken here; :meth:`_admit_one` attaches them.
        """
        matched_pages: list[int] = []
        if self.prefix_cache is not None:
            matched_pages = self.prefix_cache.match(req.prompt)
        need = self._fresh_needed(req,
                                  len(matched_pages) * self.page_size)
        if self.allocator.available() < need \
                and self.prefix_cache is not None:
            self.prefix_cache.evict(need - self.allocator.available(),
                                    protect=frozenset(matched_pages))
        if self.allocator.available() < need and matched_pages:
            # sharing can't fit (the matched path pins pages eviction
            # must not touch): drop the match and admit as a plain
            # full re-prefill if the pool allows it
            matched_pages = []
            need = self._fresh_needed(req, 0)
            if self.allocator.available() < need:
                self.prefix_cache.evict(need - self.allocator.available())
        if self.allocator.available() < need:
            return None
        return matched_pages

    def _admit_one(self, req: Request,
                   matched_pages: list[int]) -> Request | None:
        """Attach references and admit, or roll back *completely* and
        return None when the allocator reneges mid-admission (fault
        injection, or any future source of ``available()``/``alloc()``
        disagreement): no page may leak and the request must keep its
        queue position — chaos-harness invariants."""
        shared: list[int] = []
        fresh: list[int] = []
        fork = None
        try:
            for p in matched_pages:
                shared.append(self.allocator.share(p))
            matched = len(shared) * self.page_size
            start = matched
            if matched and matched == req.prompt_len:
                # exact full-page hit: the last prompt token must re-run
                # for the first-sample logits, and its K/V write lands in
                # the final matched page — CoW-fork it (the engine copies
                # the page contents device-side before the re-run)
                dst = self.allocator.alloc()
                src = shared[-1]
                fork = (src, dst)
                self.allocator.free(src)    # drop our ref on the original
                shared[-1] = dst
                start = matched - 1
            for _ in range(self.pages_needed(req) - len(shared)):
                fresh.append(self.allocator.alloc())
        except MemoryError:
            self.allocator.free_many(shared + fresh)
            self._m_rollbacks.inc()
            return None
        self.waiting.remove(req)
        req.slot = self._free_slots.pop()
        req.cow_fork = fork
        req.pages = shared + fresh
        req.cached_tokens = matched
        req.prefilled = start               # prefill resumes at the boundary
        self.running[req.slot] = req
        return req

    def _probe_failed(self, req: Request) -> bool:
        """Bookkeeping for one failed admission probe: bump the retry
        count, set the aging-aware backoff (doubles per failure, but
        shrinks to nothing as ``age`` approaches ``age_limit`` so a
        backed-off request still reaches the head-only aging guarantee),
        and — when a retry budget is set — reject the request outright
        once it is exhausted.  Returns True when the request was
        rejected (caller must not probe it again)."""
        req.retries += 1
        if self.max_retries is not None and req.retries > self.max_retries:
            self.waiting.remove(req)
            req.failed = True
            self._rejected.append(req)
            self._m_rejected.inc()
            return True
        req.backoff = max(0, min(1 << min(req.retries, 3),
                                 self.age_limit - req.age) - 1)
        return False

    def take_rejected(self) -> list[Request]:
        """Drain requests whose admission retry budget ran out (the
        engine fails them out with a terminal status)."""
        out, self._rejected = self._rejected, []
        return out

    def admit(self) -> list[Request]:
        """One admission round: backfill past a head that doesn't fit,
        unless the head is starving (``age >= age_limit``), in which
        case admission is head-only until it gets in.  Each admitted
        request leaves with its slot and its whole page reservation
        (block table order = logical block order), the leading entries
        shared from the prefix tree on a hit.  Backfill candidates in
        backoff are skipped without a probe; the head is always probed
        (head-of-line liveness is what the aging rule protects)."""
        admitted = []
        while self.waiting and self._free_slots:
            head = self.waiting[0]
            plan = self._prepare(head)
            got = self._admit_one(head, plan) if plan is not None else None
            if got is not None:
                admitted.append(got)
                continue
            if self._probe_failed(head):
                continue        # rejected: the next head gets its turn
            if head.age >= self.age_limit:
                break           # starving head blocks younger admissions
            for req in list(self.waiting)[1:]:
                if req.backoff > 0:
                    continue
                plan = self._prepare(req)
                got = self._admit_one(req, plan) if plan is not None \
                    else None
                if got is not None:
                    admitted.append(got)
                    break
                self._probe_failed(req)
            else:
                break           # nobody fits
        for req in self.waiting:
            req.age += 1
            if req.backoff > 0:
                req.backoff -= 1
        self._m_admitted.inc(len(admitted))
        self._m_queue_depth.set(len(self.waiting))
        self._m_head_age.set(self.waiting[0].age if self.waiting else 0)
        return admitted

    # -- lifecycle: expiry, cancellation, preemption --------------------------

    def cancel(self, rid: int) -> bool:
        """Cooperative cancel: the request finishes TRUNCATED at the
        next step boundary (queued requests drain via :meth:`expire`)."""
        for req in self.waiting:
            if req.rid == rid:
                req.cancelled = True
                return True
        for req in self.running.values():
            if req.rid == rid:
                req.cancelled = True
                return True
        return False

    def expire(self, now_ns: int, step: int) -> list[Request]:
        """Remove waiting requests whose deadline/TTL passed or that
        were cancelled while queued; the engine assigns their terminal
        status.  Running requests are handled at the engine's step
        boundary (their partial output needs the device readback)."""
        out = [r for r in self.waiting
               if r.expired(now_ns, step) or r.cancelled]
        for r in out:
            self.waiting.remove(r)
            if not r.cancelled:
                self._m_expired.inc()
        return out

    def preempt_candidate(self, force: bool = False) -> int | None:
        """Slot worth preempting so the waiting head can make progress,
        or None.

        Fires only when the head is starving (``age >= age_limit``,
        bypassed by ``force`` — the degradation ladder's top rung) and
        genuinely cannot be admitted right now.  The victim is the
        lowest-priority running request with budget left (never one
        above the head's priority), ties broken by the cheapest restore
        (fewest replayed tokens, per ``replay_cost_tokens``), then by
        youth (largest rid keeps long-running work).
        """
        if not self.waiting or not self.running:
            return None
        head = self.waiting[0]
        if not force and head.age < self.age_limit:
            return None
        if self._free_slots and self._prepare(head) is not None:
            return None         # head fits as-is: no victim needed
        shared = self.prefix_cache is not None
        cands = [r for r in self.running.values()
                 if r.priority <= head.priority
                 and r.max_new_tokens - r.generated > 0]
        if not cands:
            return None
        victim = min(cands, key=lambda r: (
            r.priority,
            replay_cost_tokens(r.prefilled + max(r.generated - 1, 0),
                               self.page_size, shared),
            -r.rid))
        return victim.slot

    def preempt(self, slot: int, emitted: np.ndarray) -> Request:
        """Preempt the running request in ``slot`` and requeue a
        replacement that restores it exactly.

        ``emitted`` is the slot's sampled-token readback (length
        ``generated``).  Every *complete* page of written K/V — the
        device length is ``prefilled + generated - 1``: the latest
        sampled token's K/V is only written when it is fed back — goes
        into the prefix tree before eviction, so the tree keeps those
        pages alive (refcount = tree ref) while the victim's owner refs
        are dropped.  The replacement carries prompt + emitted tokens as
        its new prompt and the remaining budget, so on re-admission it
        prefix-matches the registered pages and replays only the
        unshared tail; greedy decoding makes the continuation
        byte-exact.  It is queued directly *behind* the current head:
        preemption exists to unblock the starving head, so the victim
        must not race it for the freed pages.
        """
        req = self.running[slot]
        emitted = np.asarray(emitted, np.int32).reshape(-1)
        full_seq = np.concatenate([req.prompt, emitted])
        cached = req.prefilled + max(req.generated - 1, 0)
        if self.prefix_cache is not None:
            nc = cached // self.page_size
            if nc:
                self.prefix_cache.insert(full_seq[:nc * self.page_size],
                                         req.pages[:nc])
        self.evict(slot)
        prior = (emitted if req.prior_tokens is None
                 else np.concatenate([req.prior_tokens, emitted]))
        new = Request(
            req.rid, full_seq, req.orig_max_new - len(prior),
            priority=req.priority, deadline_ns=req.deadline_ns,
            expire_step=req.expire_step, age=req.age,
            preempt_count=req.preempt_count + 1, prior_tokens=prior,
            orig_prompt_len=req.orig_prompt_len,
            orig_max_new=req.orig_max_new, cancelled=req.cancelled)
        self.waiting.insert(min(1, len(self.waiting)), new)
        self._m_preemptions.inc()
        return new

    def register_prefix(self, req: Request) -> None:
        """Cache a fully-prefilled request's full prompt pages in the
        tree (the engine calls this once prefill completes, when the
        pages are frozen — decode writes strictly past them)."""
        if self.prefix_cache is None:
            return
        nb = req.prompt_len // self.page_size
        if nb:
            self.prefix_cache.insert(req.prompt[:nb * self.page_size],
                                     req.pages[:nb])

    def evict(self, slot: int) -> Request:
        """Release a finished (or cancelled) request's slot and pages."""
        req = self.running.pop(slot)
        self.allocator.free_many(req.pages)
        req.pages = []
        req.slot = -1
        self._free_slots.append(slot)
        self._m_evicted.inc()
        return req

    # -- step planning --------------------------------------------------------

    def plan_step(self, decode_chunk: int, prefill_chunk: int) -> StepPlan:
        """Decode-priority plan for one engine step.

        Every decode-ready slot is in ``decode_slots`` — unconditionally,
        which is the whole no-starvation guarantee.  Prefill chunks then
        backfill the leftover of a ``max_batch * decode_chunk`` token
        budget (minimum one chunk whenever anything is prefilling, so
        prefill progresses even at full decode load), assigned
        round-robin over the prefilling slots.
        """
        decode_slots = sorted(
            s for s, r in self.running.items() if r.decode_ready)
        prefilling = sorted(
            s for s, r in self.running.items() if not r.prefill_done)
        if not prefilling:
            return StepPlan(decode_slots, [])
        budget = self.max_batch * decode_chunk
        budget -= len(decode_slots) * decode_chunk
        n_chunks = max(1, budget // max(prefill_chunk, 1))
        remaining = {
            s: num_blocks(self.running[s].prompt_len
                          - self.running[s].prefilled, prefill_chunk)
            for s in prefilling}
        chosen: list[int] = []
        i = self._rr
        while len(chosen) < n_chunks and any(remaining.values()):
            s = prefilling[i % len(prefilling)]
            i += 1
            if remaining[s] > 0:
                chosen.append(s)
                remaining[s] -= 1
        self._rr = i % len(prefilling)
        return StepPlan(decode_slots, chosen)
