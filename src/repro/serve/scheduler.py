"""Decode-priority continuous-batching scheduler.

Policy layer of the serving subsystem (layout lives in ``kv_cache``,
model math in ``engine``).  Two decisions live here, both pure host-side
bookkeeping so the hypothesis suite (``tests/test_serve_invariants.py``)
can drive them with random traces:

**Admission** (:meth:`Scheduler.admit`) is backfill-with-aging.  A
request is admitted when (a) a batch slot is free and (b) the page pool
can cover its whole lifetime — ``ceil((prompt_len + max_new_tokens) /
page_size)`` pages are reserved up front, so a running request can never
stall mid-decode waiting for a page (no admission deadlock, at the cost
of tail-page slack).  Unlike the original strict-FIFO rule, a younger
request that fits may be admitted past a head that doesn't
(head-of-line backfill keeps slots busy) — bounded by an anti-starvation
aging rule: every admission round a waiting request stays queued
increments its ``age``, and once the head's age reaches ``age_limit``
admission becomes head-only until the head gets in.  Because running
requests have bounded token budgets and whole-lifetime reservations,
their pages always return, so a starving head is eventually admitted —
the property the invariant suite checks.

**Step planning** (:meth:`Scheduler.plan_step`) is decode-priority:
every decode-ready slot decodes every step (a decode-ready slot is never
skipped in favor of prefill — the no-starvation invariant), and prefill
chunks backfill the remaining per-step token budget
(``max_batch * decode_chunk`` tokens), round-robin across prefilling
slots so one long prompt cannot monopolize the backfill.  At least one
chunk runs whenever any slot is prefilling, so prefill always makes
progress even at full decode load.

Finished requests are evicted at the step boundary, their pages return
to the pool, and the freed slot joins the next admission round.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.serve.kv_cache import PageAllocator, num_blocks


@dataclasses.dataclass
class Request:
    """One generation request (host-side bookkeeping)."""

    rid: int
    prompt: np.ndarray              # (L,) int32
    max_new_tokens: int
    pages: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    prefilled: int = 0              # prompt tokens already in the KV cache
    generated: int = 0              # tokens sampled so far
    age: int = 0                    # admission rounds spent waiting
    output: np.ndarray | None = None   # set at eviction

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.max_new_tokens

    @property
    def prefill_done(self) -> bool:
        return self.prefilled >= self.prompt_len

    @property
    def done(self) -> bool:
        return self.generated >= self.max_new_tokens

    @property
    def decode_ready(self) -> bool:
        """Admitted, fully prefilled, budget left — decodes this step."""
        return self.slot >= 0 and self.prefill_done and not self.done


@dataclasses.dataclass
class StepPlan:
    """One step's work, in execution order: decode first, then chunks.

    ``prefill_slots`` may name a slot more than once (several chunks of
    the same prompt in one otherwise-idle step); the engine executes
    them in order.
    """

    decode_slots: list[int]
    prefill_slots: list[int]


class Scheduler:
    """Decode-priority continuous batching over ``max_batch`` slots and
    a refcounted page pool."""

    def __init__(self, max_batch: int, page_size: int,
                 allocator: PageAllocator, max_seq: int,
                 age_limit: int = 8):
        self.max_batch = max_batch
        self.page_size = page_size
        self.allocator = allocator
        self.max_seq = max_seq
        self.age_limit = age_limit
        self.waiting: deque[Request] = deque()
        self.running: dict[int, Request] = {}          # slot -> Request
        self._free_slots = list(range(max_batch - 1, -1, -1))
        self._rr = 0                                   # backfill round-robin

    # -- queue ----------------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.total_len > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + "
                f"{req.max_new_tokens} new > max_seq {self.max_seq}")
        if self.pages_needed(req) > self.allocator.capacity:
            # would wait forever: even an empty pool can't cover it
            raise ValueError(
                f"request {req.rid}: needs {self.pages_needed(req)} pages "
                f"but the pool holds {self.allocator.capacity}")
        self.waiting.append(req)

    def pages_needed(self, req: Request) -> int:
        return num_blocks(req.total_len, self.page_size)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # -- admission / eviction -------------------------------------------------

    def _admit_one(self, req: Request) -> Request:
        self.waiting.remove(req)
        req.slot = self._free_slots.pop()
        req.pages = self.allocator.alloc_many(self.pages_needed(req))
        self.running[req.slot] = req
        return req

    def admit(self) -> list[Request]:
        """One admission round: backfill past a head that doesn't fit,
        unless the head is starving (``age >= age_limit``), in which
        case admission is head-only until it gets in.  Each admitted
        request leaves with its slot and its whole page reservation
        (block table order = logical block order)."""
        admitted = []
        while self.waiting and self._free_slots:
            head = self.waiting[0]
            if self.allocator.available() >= self.pages_needed(head):
                admitted.append(self._admit_one(head))
                continue
            if head.age >= self.age_limit:
                break           # starving head blocks younger admissions
            for req in list(self.waiting)[1:]:
                if self.allocator.available() >= self.pages_needed(req):
                    admitted.append(self._admit_one(req))
                    break
            else:
                break           # nobody fits
        for req in self.waiting:
            req.age += 1
        return admitted

    def evict(self, slot: int) -> Request:
        """Release a finished (or cancelled) request's slot and pages."""
        req = self.running.pop(slot)
        self.allocator.free_many(req.pages)
        req.pages = []
        req.slot = -1
        self._free_slots.append(slot)
        return req

    # -- step planning --------------------------------------------------------

    def plan_step(self, decode_chunk: int, prefill_chunk: int) -> StepPlan:
        """Decode-priority plan for one engine step.

        Every decode-ready slot is in ``decode_slots`` — unconditionally,
        which is the whole no-starvation guarantee.  Prefill chunks then
        backfill the leftover of a ``max_batch * decode_chunk`` token
        budget (minimum one chunk whenever anything is prefilling, so
        prefill progresses even at full decode load), assigned
        round-robin over the prefilling slots.
        """
        decode_slots = sorted(
            s for s, r in self.running.items() if r.decode_ready)
        prefilling = sorted(
            s for s, r in self.running.items() if not r.prefill_done)
        if not prefilling:
            return StepPlan(decode_slots, [])
        budget = self.max_batch * decode_chunk
        budget -= len(decode_slots) * decode_chunk
        n_chunks = max(1, budget // max(prefill_chunk, 1))
        remaining = {
            s: num_blocks(self.running[s].prompt_len
                          - self.running[s].prefilled, prefill_chunk)
            for s in prefilling}
        chosen: list[int] = []
        i = self._rr
        while len(chosen) < n_chunks and any(remaining.values()):
            s = prefilling[i % len(prefilling)]
            i += 1
            if remaining[s] > 0:
                chosen.append(s)
                remaining[s] -= 1
        self._rr = i % len(prefilling)
        return StepPlan(decode_slots, chosen)
