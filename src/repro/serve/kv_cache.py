"""Paged KV cache: fixed-size KV blocks + per-request block tables.

The serving analogue of the paper's buffer-sizing rule: instead of one
dense ``(B, max_seq, Hkv, D)`` ring buffer per request slot, every
attention layer owns a global *page pool* ``(n_pages, page, Hkv, D)`` and
each request holds a block table mapping its logical KV blocks to
physical pages.  The page size is not a heuristic — it is the KV block
of the flash-decode kernel, chosen by the analytical blocking optimizer
through ``repro.tune`` under the ``"flash_decode"`` op key
(:func:`choose_page_size`), so cache layout and kernel schedule are one
decision.

Layout properties:

* allocation granularity is one page — admission control is a free-page
  budget (``PageAllocator``), not a max-batch-times-max-seq reservation;
* pages are position-agnostic, so the layout admits prefix sharing: two
  block tables may point at the same physical page, and the allocator
  refcounts owners (:meth:`PageAllocator.share`).  :class:`PrefixCache`
  is the sharing layer — a radix tree over *full-page token spans*
  mapping each span to its physical page, so a new request's admission
  matches its longest cached prefix and only prefills the tail.  Only
  full, frozen blocks are ever shared, because decode writes into the
  page holding position ``lengths[b]``; when a shared page *would* be
  written (an exact full-page prefix hit must re-run its last token for
  the first-sample logits), the page is copy-on-write forked first;
* page 0 is a reserved scratch page: retired or inactive request slots
  keep all-zero block tables, so their (masked, ignored) decode writes
  land harmlessly in the scratch page instead of needing a branch.

Non-attention mixers (SSD, RG-LRU) keep their O(1) dense states, indexed
by batch slot — paging only ever applies to the linearly-growing KV.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.base import ParamDef, build, stack_defs
from repro.models.config import ModelConfig
from repro.obs.metrics import MetricsRegistry

SCRATCH_PAGE = 0


def choose_page_size(cfg: ModelConfig, max_seq: int,
                     cache=None, fused: bool = False,
                     reuse_rate: float | None = None) -> int:
    """KV page size from the analytical model (op key ``"flash_decode"``).

    The spec's dims are (G, S, D): G query heads per KV head stream over
    an S-long cache of head dim D.  A tuned entry in the schedule cache
    (``python -m repro.tune flash_decode ...``) wins; otherwise the
    analytic top candidate is used.

    An fp8 cache (``kv_cache_dtype`` of width 1) sizes its pages under
    the ``"flash_decode_fp8"`` key instead: the dtype-aware search sees
    the 1-byte page stream, so the fp8 pool's page size — and the fp8
    kernel's KV block — both come from the fp8 model, not the bf16 one.

    ``fused=True`` (the engine's ``fuse`` flag, wide caches only) sizes
    pages under ``"flash_decode_oproj"``: the fused kernel's resident
    wo slab + output accumulator squeeze the VMEM budget the KV block
    competes for, so the fusion-aware search may pick smaller pages.

    ``reuse_rate`` (prefix caching on) extends the tradeoff the page
    size arbitrates to hit-rate-vs-streaming: the prefix tree shares
    only *full* pages, so a cached hit re-prefills on average
    ``(page - 1) / 2`` boundary-slack tokens — small pages share
    better — while the decode kernel pays a fixed per-page cost
    (block-table fetch + DMA issue) for every page it streams — large
    pages stream better.  :func:`reuse_priced_page` re-prices the tuned
    block under that model; ``reuse_rate`` is the expected fraction of
    admissions that hit the cache.
    """
    from repro.tune import best_schedule
    g = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
    kv_dtype = jnp.dtype(cfg.kv_cache_dtype or cfg.dtype)
    if kv_dtype.itemsize == 1:
        op, dtype_name = "flash_decode_fp8", jnp.dtype(cfg.dtype).name
        dims: tuple[int, ...] = (g, max_seq, cfg.head_dim)
    elif fused:
        op, dtype_name = "flash_decode_oproj", kv_dtype.name
        dims = (g, max_seq, cfg.head_dim, cfg.d_model)
    else:
        op, dtype_name = "flash_decode", kv_dtype.name
        dims = (g, max_seq, cfg.head_dim)
    sched = best_schedule(op, dims, dtype_name, cache=cache)
    page = max(1, min(sched.tiles[0], max_seq))
    if reuse_rate:
        return reuse_priced_page(page, max_seq, float(reuse_rate))
    return page


# per-page fixed streaming overhead, in token-equivalents: what one
# extra page boundary costs the decode kernel (block-table fetch + DMA
# issue) relative to streaming one more KV token.  Small by design —
# the analytical access counts tie across page sizes (every KV element
# streams exactly once), so this models the *constant* per-page work
# the access model cannot see.
PAGE_OVERHEAD_TOKENS = 0.25


def reuse_priced_page(tuned: int, max_seq: int, reuse_rate: float) -> int:
    """Share-vs-stream page pricing for the prefix cache.

    Candidates are the whole-page divisors of ``max_seq`` (the grid
    needs whole blocks) plus the tuned block.  Each candidate ``p``
    scores, in expected re-streamed tokens per request:

    * **sharing loss** ``reuse_rate * (p - 1) / 2`` — the tree shares
      full pages only, so a hit loses the matched prefix's boundary
      slack (uniform residue: ``(p - 1) / 2`` tokens re-prefilled);
    * **streaming loss** ``PAGE_OVERHEAD_TOKENS * max_seq / p`` — a
      full-length decode stream touches ``max_seq / p`` pages, each
      paying the fixed per-page cost.

    ``reuse_rate -> 0`` recovers the tuned kernel block (the streaming
    term dominates); higher reuse rates monotonically shrink the page.
    Ties break toward the larger page (closer to the tuned block).
    """
    tuned = max(1, min(tuned, max_seq))
    floor = min(8, max_seq)
    cands = {d for d in range(floor, max_seq + 1) if max_seq % d == 0}
    cands.add(tuned)

    def score(p: int) -> float:
        return (reuse_rate * (p - 1) / 2.0
                + PAGE_OVERHEAD_TOKENS * max_seq / p)

    return min(sorted(cands), key=lambda p: (score(p), -p))


def num_blocks(length: int, page_size: int) -> int:
    return -(-length // page_size)


def choose_prefill_chunk(cfg: ModelConfig, max_seq: int,
                         page_size: int) -> int:
    """Prefill chunk size from the same blocking model as the page size.

    A prefill chunk is processed as one multi-position q block of the
    flash-decode kernel (``q_span = chunk``), so its VMEM cost is priced
    by the kernel's own footprint model: the chunk is the largest
    power-of-two multiple of the page size (a whole number of pages, so
    chunk boundaries and page boundaries never disagree) whose q/score/
    accumulator rows still fit the VMEM budget the page size was tuned
    under, capped at ``max_seq``.  Growing the chunk amortizes the
    per-chunk KV stream over more query rows — the same
    arithmetic-intensity argument the paper makes for output blocking —
    until the row-proportional buffers hit the budget.
    """
    from repro.core.tpu_adapter import default_vmem_budget
    from repro.kernels.flash_decode import vmem_bytes_required
    g = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
    kv_bytes = jnp.dtype(cfg.kv_cache_dtype or cfg.dtype).itemsize
    act_bytes = jnp.dtype(cfg.dtype).itemsize
    budget = default_vmem_budget()
    chunk = min(page_size, max_seq)
    while chunk * 2 <= max_seq and vmem_bytes_required(
            page_size, g, cfg.head_dim, act_bytes, kv_bytes=kv_bytes,
            q_span=chunk * 2) <= budget:
        chunk *= 2
    return chunk


# ------------------------------ device side --------------------------------


def paged_attention_cache_defs(cfg: ModelConfig, n_pages: int,
                               page_size: int, model_ax: int) -> dict:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    cache_dtype = cfg.kv_cache_dtype or cfg.dtype
    skv = "model" if model_ax > 1 and hkv % model_ax == 0 else None
    spec = P(None, None, skv, None)
    return {"k_pages": ParamDef((n_pages, page_size, hkv, hd), spec,
                                init="zeros", dtype=cache_dtype),
            "v_pages": ParamDef((n_pages, page_size, hkv, hd), spec,
                                init="zeros", dtype=cache_dtype)}


def paged_cache_defs(cfg: ModelConfig, batch: int, n_pages: int,
                     page_size: int, model_ax: int = 1) -> dict:
    """Decode-state tree with paged KV for every attention layer.

    Mirrors ``transformer.cache_defs`` so the scan structure is
    identical; only the attention entries change layout (pools are
    shared across the batch — no leading batch dim).
    """
    if cfg.is_encdec or cfg.prefix_tokens:
        raise NotImplementedError(
            "paged serving covers decoder-only token models")
    pattern = cfg.layer_pattern
    n_groups = cfg.n_layers // len(pattern)
    rem = cfg.n_layers % len(pattern)

    def one(mixer: str) -> dict:
        if mixer in ("global", "local"):
            return paged_attention_cache_defs(cfg, n_pages, page_size,
                                              model_ax)
        if mixer == "recurrent":
            return L.rglru_cache_defs(cfg, batch, model_ax)
        if mixer == "ssd":
            return L.ssd_cache_defs(cfg, batch, model_ax)
        raise ValueError(mixer)

    return {"layers": [stack_defs(one(m), n_groups) for m in pattern],
            "tail": [one(pattern[j]) for j in range(rem)]}


def init_paged_cache(cfg: ModelConfig, batch: int, n_pages: int,
                     page_size: int, model_ax: int = 1):
    return build(paged_cache_defs(cfg, batch, n_pages, page_size, model_ax),
                 "init", jax.random.PRNGKey(0))


def write_prefill(cfg: ModelConfig, paged: dict, dense: dict,
                  slot: jax.Array, pages: jax.Array,
                  page_size: int) -> dict:
    """Scatter one request's dense prefill cache into the paged tree.

    ``dense`` is a batch-1 ``transformer.prefill(..., full_kv=True)``
    cache; ``pages`` is the request's physical page per logical block
    (length >= ceil(bucket / page_size); spill entries may point at the
    scratch page).  Attention K/V land in the pools; O(1) states land at
    batch ``slot``.  Traceable — the engine jits this together with the
    prefill itself, once per bucket length.
    """
    pattern = cfg.layer_pattern

    def attn_group(pc: dict, dc: dict, stacked: bool) -> dict:
        k, v = dc["k"], dc["v"]         # (..., 1, bucket, hkv, hd)
        bucket = k.shape[-3]
        nb = num_blocks(bucket, page_size)
        pad = nb * page_size - bucket

        def scatter(pool, kv):          # (n_pages, p, hkv, hd), (bucket,...)
            blocks = jnp.pad(kv, ((0, pad), (0, 0), (0, 0))).reshape(
                nb, page_size, *kv.shape[1:]).astype(pool.dtype)
            return pool.at[pages[:nb]].set(blocks)

        if stacked:
            return {"k_pages": jax.vmap(scatter)(pc["k_pages"], k[:, 0]),
                    "v_pages": jax.vmap(scatter)(pc["v_pages"], v[:, 0])}
        return {"k_pages": scatter(pc["k_pages"], k[0]),
                "v_pages": scatter(pc["v_pages"], v[0])}

    def state_group(pc: dict, dc: dict, stacked: bool) -> dict:
        if stacked:   # (n_groups, B, ...) <- (n_groups, 1, ...)
            return {kk: pc[kk].at[:, slot].set(
                        dc[kk][:, 0].astype(pc[kk].dtype))
                    for kk in pc}
        return {kk: pc[kk].at[slot].set(dc[kk][0].astype(pc[kk].dtype))
                for kk in pc}

    def one(mixer: str, pc: dict, dc: dict, stacked: bool) -> dict:
        if mixer in ("global", "local"):
            return attn_group(pc, dc, stacked)
        return state_group(pc, dc, stacked)

    new = {"layers": [], "tail": []}
    for m, pc, dc in zip(pattern, paged["layers"], dense["layers"]):
        new["layers"].append(one(m, pc, dc, stacked=True))
    for j, (pc, dc) in enumerate(zip(paged["tail"], dense["tail"])):
        new["tail"].append(one(pattern[j], pc, dc, stacked=False))
    return new


def make_paged_attn_step(cfg: ModelConfig, block_tables: jax.Array,
                         page_size: int, use_kernel: bool | None = None,
                         interpret: bool | None = None,
                         fused: bool = False):
    """The ``attn_step`` the paged engine threads through
    ``transformer.decode_step``.

    ``pos`` arrives as the per-request cached-token count (B,): the new
    token sits at position ``pos[b]``, its K/V are scattered into page
    ``block_tables[b, pos // page]`` slot ``pos % page``, and attention
    runs over ``pos + 1`` positions through ``ops.paged_attention``
    (the flash-decode kernel / its oracle).

    ``fused=True`` (the engine's ``fuse`` flag) routes attention +
    output projection through ``ops.paged_attention_oproj`` — the
    per-head attention outputs never round-trip through HBM
    (docs/fusion.md); quantized wo / fp8 pools fall back inside the op.
    """
    from repro.kernels import ops

    def attn_step(p: dict, hn: jax.Array, cache: dict, pos: jax.Array,
                  window: int | None):
        b, _, _ = hn.shape
        hq, hd = cfg.n_heads, cfg.head_dim
        q, k, v = L.qkv_decode_proj(cfg, p, hn[:, 0], pos[:, None])

        rows = jnp.arange(b)
        page_idx = block_tables[rows, pos // page_size]
        slot_idx = pos % page_size
        kp = cache["k_pages"].at[page_idx, slot_idx].set(
            k.astype(cache["k_pages"].dtype))
        vp = cache["v_pages"].at[page_idx, slot_idx].set(
            v.astype(cache["v_pages"].dtype))

        if fused:
            out = ops.paged_attention_oproj(
                q, kp, vp, block_tables, pos + 1, p["wo"],
                window=window, logit_cap=cfg.attn_logit_cap,
                use_kernel=use_kernel, interpret=interpret)
            out = out[:, None, :].astype(hn.dtype)
            return out, {"k_pages": kp, "v_pages": vp}
        out = ops.paged_attention(q, kp, vp, block_tables, pos + 1,
                                  window=window,
                                  logit_cap=cfg.attn_logit_cap,
                                  use_kernel=use_kernel,
                                  interpret=interpret)
        out = out.reshape(b, 1, hq * hd).astype(hn.dtype)
        # ops.linear: wo may be a QuantizedTensor (quantized serving)
        return ops.linear(out, p["wo"]), {"k_pages": kp, "v_pages": vp}

    return attn_step


def make_paged_span_step(cfg: ModelConfig, block_tables: jax.Array,
                         page_size: int, max_seq: int,
                         use_kernel: bool | None = None,
                         interpret: bool | None = None):
    """The span-capable ``attn_step`` for multi-token
    ``transformer.decode_step`` — one definition behind both chunked
    prefill and speculative verify.

    ``hn`` is (B, S, D): S consecutive tokens starting at position
    ``pos[b]`` (= the cached length).  All S positions' K/V are
    scattered into the request's pages first, then ONE
    ``ops.paged_attention`` call with a (B, S, Hq, D) q block scores
    every position under its own causal mask — the kernel streams each
    KV page once for all S rows.  Positions at or past ``max_seq`` (the
    padded tail of a final prefill chunk, or draft rows past the token
    budget) scatter harmlessly into the scratch page; positions inside
    ``max_seq`` but past the span's accepted prefix are overwritten by
    the next span before the length mask ever exposes them.

    The fused oproj kernel is single-token (its output block is one
    (1, E) row), so spans always use the unfused attention + ``linear``
    pair; under ``fuse`` the QKV projection and the FFN still fuse.
    """
    from repro.kernels import ops

    def attn_step(p: dict, hn: jax.Array, cache: dict, pos: jax.Array,
                  window: int | None):
        b, s, _ = hn.shape
        hq, hd = cfg.n_heads, cfg.head_dim
        positions = pos[:, None] + jnp.arange(s, dtype=pos.dtype)[None, :]
        q, k, v = L.qkv_span_proj(cfg, p, hn, positions)

        rows = jnp.arange(b)[:, None]
        nb = block_tables.shape[1]
        safe = positions < max_seq
        blk = jnp.minimum(positions // page_size, nb - 1)
        page_idx = jnp.where(safe, block_tables[rows, blk], SCRATCH_PAGE)
        slot_idx = jnp.where(safe, positions % page_size, 0)
        kp = cache["k_pages"].at[page_idx, slot_idx].set(
            k.astype(cache["k_pages"].dtype))
        vp = cache["v_pages"].at[page_idx, slot_idx].set(
            v.astype(cache["v_pages"].dtype))

        out = ops.paged_attention(q, kp, vp, block_tables, pos + 1,
                                  window=window,
                                  logit_cap=cfg.attn_logit_cap,
                                  use_kernel=use_kernel,
                                  interpret=interpret)   # (B, S, Hq, hd)
        out = out.reshape(b, s, hq * hd).astype(hn.dtype)
        # ops.linear: wo may be a QuantizedTensor (quantized serving)
        return ops.linear(out, p["wo"]), {"k_pages": kp, "v_pages": vp}

    return attn_step


# ------------------------------- host side ---------------------------------


class PageAllocator:
    """Host-side refcounted free list over the page pool.

    Page 0 (``SCRATCH_PAGE``) is reserved and never handed out: it can
    never be allocated, shared, or owned, which is what lets the engine
    mask inactive block-table rows to it — and why :class:`PrefixCache`
    rejects it outright (a scratch page in the tree would hand decode
    garbage to every matching request).  :meth:`share` takes an extra
    reference for prefix sharing (one per owning request, plus one held
    by the prefix tree itself — see the module docstring for the
    full-frozen-blocks rule a sharer must follow); a page returns to
    the free list when its last owner releases it.  Every transition is
    checked, so a leak or double-free fails loudly — the serving
    hypothesis suite leans on that.
    """

    def __init__(self, n_pages: int, metrics=None):
        if n_pages < 2:
            raise ValueError("need at least one scratch + one real page")
        self.n_pages = n_pages
        self._refs = np.zeros(n_pages, np.int32)
        self._free = list(range(n_pages - 1, 0, -1))   # page 0 reserved
        m = metrics if metrics is not None else MetricsRegistry()
        m.gauge("pages.capacity").set(self.capacity)
        self._m_in_use = m.gauge("pages.in_use")

    @property
    def capacity(self) -> int:
        return self.n_pages - 1

    def available(self) -> int:
        return len(self._free)

    def in_use(self) -> int:
        return self.capacity - len(self._free)

    def refcount(self, page: int) -> int:
        """Current reference count (0 = free; scratch is always 0)."""
        return int(self._refs[page])

    def alloc(self) -> int:
        if not self._free:
            raise MemoryError("page pool exhausted")
        page = self._free.pop()
        assert self._refs[page] == 0, page
        self._refs[page] = 1
        self._m_in_use.set(self.in_use())
        return page

    def alloc_many(self, n: int) -> list[int]:
        if n > len(self._free):
            raise MemoryError(
                f"page pool exhausted: need {n}, have {len(self._free)}")
        return [self.alloc() for _ in range(n)]

    def share(self, page: int) -> int:
        """Take an extra reference (shared prompt prefix)."""
        if page == SCRATCH_PAGE or self._refs[page] <= 0:
            raise ValueError(f"cannot share unowned page {page}")
        self._refs[page] += 1
        return page

    def free(self, page: int) -> None:
        if page == SCRATCH_PAGE:
            return                       # scratch is never owned
        if self._refs[page] <= 0:
            raise ValueError(f"double free of page {page}")
        self._refs[page] -= 1
        if self._refs[page] == 0:
            self._free.append(page)
            self._m_in_use.set(self.in_use())

    def free_many(self, pages) -> None:
        for p in pages:
            self.free(int(p))


class _PrefixNode:
    """One full-page token span cached in the prefix tree."""

    __slots__ = ("key", "page", "parent", "children", "last_used")

    def __init__(self, key, page, parent):
        self.key = key                  # tuple of page_size token ids
        self.page = page                # physical page holding the span's KV
        self.parent = parent
        self.children: dict = {}        # key -> _PrefixNode
        self.last_used = 0


class PrefixCache:
    """Radix tree over full-page token spans -> physical KV pages.

    Each node caches one *page-aligned* span of prompt tokens and the
    physical page holding that span's K/V; a root-to-node path spells a
    cached prompt prefix.  The tree holds its own allocator reference on
    every cached page (``refcount == owning requests + 1``), so a page
    outlives the request that prefilled it and later requests can
    :meth:`match` it — admission bumps refcounts instead of
    re-prefilling.

    Invariants (enforced here, exercised by the serving hypothesis
    suite in ``tests/test_serve_invariants.py``):

    * spans are always exactly ``page_size`` tokens (page-aligned);
    * the scratch page can never enter the tree;
    * eviction (:meth:`evict`) only ever frees **LRU leaves whose sole
      reference is the tree's** — a page a live request owns has
      ``refcount >= 2`` and is skipped, so sharing can never free a
      page out from under a reader.
    """

    def __init__(self, allocator: PageAllocator, page_size: int,
                 metrics=None):
        self.allocator = allocator
        self.page_size = page_size
        self._root = _PrefixNode((), -1, None)
        self._pages: dict[int, _PrefixNode] = {}   # page -> node
        self._clock = 0
        m = metrics if metrics is not None else MetricsRegistry()
        self._m_cached = m.gauge("prefix_cache.cached_pages")
        self._m_evicted = m.counter("prefix_cache.evicted_pages")

    def __len__(self) -> int:
        return len(self._pages)

    def pages(self) -> set[int]:
        """The set of physical pages the tree currently references."""
        return set(self._pages)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # -- lookup / registration ----------------------------------------------

    def match(self, prompt) -> list[int]:
        """Pages of the longest cached full-page prefix of ``prompt``,
        in block order (possibly the whole prompt when its length is an
        exact page multiple — the caller must then CoW-fork the last
        page before re-running the final token).  Bumps LRU on the
        matched path; takes no references — the caller shares each page
        it actually attaches."""
        p = self.page_size
        toks = [int(t) for t in np.asarray(prompt).reshape(-1)]
        node, out, t = self._root, [], self._tick()
        for i in range(0, len(toks) - len(toks) % p, p):
            child = node.children.get(tuple(toks[i:i + p]))
            if child is None:
                break
            child.last_used = t
            out.append(child.page)
            node = child
        return out

    def insert(self, tokens, pages) -> int:
        """Register full, frozen prompt pages; returns new nodes added.

        ``tokens`` must be page-aligned and ``pages`` its physical page
        per block.  Spans already cached keep their incumbent page (the
        duplicate prefill is the caller's loss, not a correctness
        issue); new nodes take the tree's own reference via
        :meth:`PageAllocator.share`."""
        p = self.page_size
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        if len(toks) % p:
            raise ValueError(
                f"prefix spans must be page-aligned: {len(toks)} tokens "
                f"with page {p}")
        if len(toks) != len(pages) * p:
            raise ValueError(f"{len(toks)} tokens != {len(pages)} pages")
        node, added, t = self._root, 0, self._tick()
        for i, page in enumerate(pages):
            key = tuple(toks[i * p:(i + 1) * p])
            child = node.children.get(key)
            if child is None:
                page = int(page)
                if page == SCRATCH_PAGE:
                    raise ValueError(
                        "scratch page can never enter the prefix tree")
                if page in self._pages:
                    raise ValueError(
                        f"page {page} already cached under another span")
                self.allocator.share(page)     # the tree's own reference
                child = _PrefixNode(key, page, node)
                node.children[key] = child
                self._pages[page] = child
                added += 1
            child.last_used = t
            node = child
        self._m_cached.set(len(self._pages))
        return added

    # -- eviction ------------------------------------------------------------

    def evict(self, n_pages: int, protect=frozenset()) -> int:
        """Free up to ``n_pages`` pages from LRU leaves the tree is the
        sole owner of (``refcount == 1``); returns how many were freed.

        Pages in ``protect`` (a just-matched path the caller is about
        to attach) and pages any live request owns are never touched;
        an internal node only becomes evictable once its subtree is
        gone, so a cached span never loses the prefix context that
        gives it meaning."""
        freed = 0
        while freed < n_pages:
            victim = None
            for node in self._pages.values():
                if (node.children or node.page in protect
                        or self.allocator.refcount(node.page) != 1):
                    continue
                if victim is None or node.last_used < victim.last_used:
                    victim = node
            if victim is None:
                break
            del victim.parent.children[victim.key]
            del self._pages[victim.page]
            self.allocator.free(victim.page)
            freed += 1
        self._m_evicted.inc(freed)
        self._m_cached.set(len(self._pages))
        return freed
