"""Request lifecycle + graceful degradation (docs/robustness.md).

Everything the serving stack needs to give a request a *definite
terminal outcome* lives here, shared by the scheduler, the engine, the
chaos harness and the launch CLI:

* :class:`RequestStatus` — the five terminal states every request ends
  in.  ``generate(..., return_status=True)`` surfaces them, and the
  engine counts each under ``lifecycle.<status>`` in the obs registry.
* :func:`replay_cost_tokens` — the preempt-and-recompute price.  With
  the prefix cache on, a preempted request's *complete* pages go into
  the radix tree, so restoring it replays only the unshared tail past
  the last page boundary — the same store-vs-recompute tradeoff
  :func:`repro.serve.kv_cache.reuse_priced_page` prices when choosing
  the page size (its boundary-slack term ``(p-1)/2`` is exactly the
  expected tail here).  The scheduler uses this to pick the cheapest
  victim among equal priorities.
* :class:`DegradationController` — the pressure ladder.  Reads the
  PR 8 metrics registry (p99 step latency, free-page watermark, queue
  depth) and steps through ``no_spec`` (disable speculative decode) →
  ``small_chunk`` (halve the decode chunk) → ``preempt`` (allow
  preemption even when the config flag is off), with hysteresis in
  both directions.  Each transition is a trace instant event and a
  counter tick; the current rung is the ``degrade.level`` gauge.
"""

from __future__ import annotations

import dataclasses
import enum


class RequestStatus(enum.Enum):
    """Terminal outcome of one serving request.

    ``OK``                 — full token budget emitted, never disturbed.
    ``TRUNCATED``          — cancelled mid-flight; ``output`` holds the
                             tokens emitted so far (a byte-exact prefix
                             of the undisturbed run).
    ``DEADLINE_EXCEEDED``  — wall deadline or TTL expired (queued or
                             running); partial output like TRUNCATED.
    ``PREEMPTED_RETRIED``  — full budget emitted, but the request was
                             preempted and restored at least once on
                             the way (tokens still byte-exact).
    ``FAILED``             — admission retries exhausted, or the NaN/Inf
                             guard caught poisoned logits for this slot;
                             output holds only tokens emitted before the
                             fault.
    """

    OK = "ok"
    TRUNCATED = "truncated"
    DEADLINE_EXCEEDED = "deadline_exceeded"
    PREEMPTED_RETRIED = "preempted_retried"
    FAILED = "failed"


#: statuses whose output must be a byte-exact prefix of (or equal to)
#: the fault-free run's tokens — the chaos runner's correctness bar
EXACT_STATUSES = (RequestStatus.OK, RequestStatus.PREEMPTED_RETRIED)
PREFIX_STATUSES = (RequestStatus.TRUNCATED,
                   RequestStatus.DEADLINE_EXCEEDED,
                   RequestStatus.FAILED)


def replay_cost_tokens(cached_positions: int, page_size: int,
                       shared: bool) -> int:
    """Model-call tokens a preempted request re-runs when restored.

    ``cached_positions`` is the number of K/V positions written for the
    victim (its device length).  With the prefix cache (``shared``),
    complete pages survive in the radix tree and only the tail past the
    last page boundary replays, plus the one position whose sampled
    token never had its K/V written.  Without a tree every position
    replays.  This is the recompute side of the store-vs-recompute
    tradeoff ``reuse_priced_page`` prices analytically (expected tail
    = ``(page_size - 1) / 2``); here the *actual* tail ranks victims.
    """
    if shared:
        return cached_positions - (cached_positions // page_size) \
            * page_size + 1
    return cached_positions + 1


@dataclasses.dataclass
class DegradeThresholds:
    """Pressure signals that push the ladder up a rung.

    Any one signal firing counts as pressure for that update; pressure
    must persist ``sustain`` consecutive updates to escalate, and
    ``recover`` consecutive clear updates to de-escalate (hysteresis —
    one noisy step never flips the ladder).
    """

    p99_step_us: float = 0.0        # 0 -> ignore the latency signal
    free_page_frac: float = 0.125   # free/capacity watermark
    queue_depth: int = 8            # waiting requests
    sustain: int = 2
    recover: int = 8


class DegradationController:
    """Steps the serving engine down a ladder of cheaper modes.

    Rungs (``LEVELS`` index = severity): ``normal`` → ``no_spec``
    (speculative decode off: verify calls waste full-span model work
    exactly when the batch is saturated) → ``small_chunk`` (halve the
    decode chunk: finished requests leave, and admission re-checks,
    twice as often) → ``preempt`` (reclaim pages from the lowest-
    priority running request via preempt-with-restore).

    Reads only the shared metrics registry — the same numbers the
    operator sees — so the ladder is reproducible from a metrics
    snapshot.  The engine calls :meth:`update` once per step *before*
    planning and applies the rung's overrides for that step.
    """

    LEVELS = ("normal", "no_spec", "small_chunk", "preempt")

    def __init__(self, registry, thresholds: DegradeThresholds | None = None,
                 tracer=None):
        self.thresholds = thresholds or DegradeThresholds()
        self.tracer = tracer
        self.level = 0
        self._hot = 0
        self._cool = 0
        # share the engine/scheduler/allocator metric objects: _register
        # returns the existing instance for a known name
        self._step_us = registry.histogram("engine.step_us")
        self._queue_depth = registry.gauge("sched.queue_depth")
        self._pages_in_use = registry.gauge("pages.in_use")
        self._pages_capacity = registry.gauge("pages.capacity")
        self._m_level = registry.gauge("degrade.level")
        self._m_escalations = registry.counter("degrade.escalations")
        self._m_recoveries = registry.counter("degrade.recoveries")

    def _pressure(self) -> str | None:
        """Name of the first firing signal, or None when clear."""
        thr = self.thresholds
        cap = self._pages_capacity.value
        if cap and self._queue_depth.value > 0:
            free_frac = 1.0 - self._pages_in_use.value / cap
            if free_frac < thr.free_page_frac:
                return "free_pages"
        if self._queue_depth.value >= thr.queue_depth:
            return "queue_depth"
        if thr.p99_step_us and self._step_us.count:
            if self._step_us.quantile(0.99) > thr.p99_step_us:
                return "p99_step_us"
        return None

    def update(self) -> int:
        """One control tick; returns the (possibly new) ladder level."""
        signal = self._pressure()
        if signal is not None:
            self._hot += 1
            self._cool = 0
        else:
            self._cool += 1
            self._hot = 0
        thr = self.thresholds
        if self._hot >= thr.sustain and self.level < len(self.LEVELS) - 1:
            self._transition(self.level + 1, signal)
            self._hot = 0
        elif self._cool >= thr.recover and self.level > 0:
            self._transition(self.level - 1, "recovered")
            self._cool = 0
        self._m_level.set(self.level)
        return self.level

    def _transition(self, new_level: int, signal: str | None) -> None:
        up = new_level > self.level
        old = self.LEVELS[self.level]
        self.level = new_level
        (self._m_escalations if up else self._m_recoveries).inc()
        if self.tracer is not None:
            self.tracer.instant(
                f"degrade.{'up' if up else 'down'}", cat="lifecycle",
                args={"from": old, "to": self.LEVELS[new_level],
                      "signal": signal})

    # rung -> engine overrides -------------------------------------------------

    @property
    def spec_disabled(self) -> bool:
        return self.level >= 1

    @property
    def shrink_chunk(self) -> bool:
        return self.level >= 2

    @property
    def allow_preempt(self) -> bool:
        return self.level >= 3
