"""Serving engines: static-batch baseline and paged continuous batching.

``DecodeEngine`` is the static-batch baseline: left-padded prefill, dense
per-slot KV caches, one jitted token loop.  Its decode loop is a
``lax.scan`` with device-side sampling — tokens accumulate on device and
transfer to the host once per call, not once per token.

``PagedEngine`` is the production path (docs/serving.md): a paged KV
cache whose page size comes from the analytical blocking model
(``tune`` op key ``"flash_decode"``), a decode-priority continuous-
batching scheduler, and three mechanisms that keep steady-state decode
from ever stalling:

* **chunked prefill** — prompts are cached ``prefill_chunk`` tokens at a
  time (a whole number of KV pages, sized by
  ``kv_cache.choose_prefill_chunk`` under the same VMEM budget as the
  page size) through the multi-position form of the flash-decode kernel,
  interleaved with decode steps instead of monopolizing one;
* **speculative decode** — an n-gram self-drafted draft-verify step
  scores ``spec_decode`` draft tokens plus the current token in ONE
  flash-decode call (the kernel's GQA grouping carries the multi-row q
  block) and accepts the longest greedy-matching prefix, so accepted
  tokens amortize the per-step host overhead;
* **persistent device state** — block tables and lengths live on device
  and are updated incrementally at admission/eviction instead of being
  rebuilt and re-uploaded every step.

With ``prefix_cache=True`` a radix tree over full-page token spans
(``kv_cache.PrefixCache``) is threaded through admission: a request
whose prompt prefix is cached shares the matched pages (refcount bump,
no allocation, no model call) and chunk-prefills only the O(new tokens)
tail from the matched boundary; an exact full-page match CoW-forks its
final page before re-running the last prompt token for the first-sample
logits.  Completed prefills register their full prompt pages back into
the tree, and admission under page pressure reclaims LRU tree leaves —
never a page a live request owns.

The decode step remains fully jitted — paged flash-decode attention,
device-side sampling, and an on-device output buffer read back only when
a request finishes.

Every request leaves with a terminal :class:`~repro.serve.lifecycle.
RequestStatus` (docs/robustness.md): deadlines/TTLs expire it,
``cancel()`` truncates it, exhausted admission retries or the NaN/Inf
logit guard (``nan_guard=True`` — per-slot isfinite tracking inside the
jitted decode, failing only the poisoned slot) fail it, and under page
exhaustion the scheduler can preempt it and restore it later through
the prefix cache with byte-exact tokens.  A
:class:`~repro.serve.lifecycle.DegradationController` (``degrade=True``)
steps spec-decode off, shrinks the decode chunk, and finally enables
preemption as pressure mounts.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.obs import Obs
from repro.obs.trace import null_span
from repro.serve import kv_cache as KV
from repro.serve.lifecycle import DegradationController, RequestStatus
from repro.serve.scheduler import Request, Scheduler


def sample_tokens(cfg: ModelConfig, logits: jax.Array, temperature: float,
                  key: jax.Array) -> jax.Array:
    """Greedy (temperature <= 0) or categorical sampling; masks the
    padded-vocab tail.  logits: (B, V_padded) -> (B,) int32."""
    logits = logits[:, :cfg.vocab]
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1).astype(jnp.int32)


# ========================= static-batch baseline ===========================


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    temperature: float = 0.0   # 0 -> greedy
    seed: int = 0
    fuse: bool = False         # cross-op fused kernels (docs/fusion.md)


class DecodeEngine:
    """Static batch: every request prefills together (left-padded to a
    common length) and decodes in lock-step for a fixed token budget."""

    def __init__(self, cfg: ModelConfig, params: Any, sc: ServeConfig,
                 obs: Obs | None = None):
        self.cfg, self.params, self.sc = cfg, params, sc
        self.obs = obs if obs is not None else Obs()
        reg = self.obs.registry
        self._m_prefill_tokens = reg.counter("engine.prefill_tokens")
        self._m_decode_tokens = reg.counter("engine.decode_tokens")

        def prefill(*a, **kw):
            # the fusion flag is read at TRACE time; each engine owns its
            # jit wrappers, so the flag is pinned per instance
            with ops.fused_ops(sc.fuse):
                return T.prefill(cfg, *a, **kw)

        self._prefill = jax.jit(prefill, static_argnames=("max_seq",))
        self._gen = jax.jit(self._gen_fn, static_argnames=("n_tokens",))

    def generate(self, prompts: np.ndarray, n_tokens: int,
                 enc_embeds=None, prefix_embeds=None) -> np.ndarray:
        """prompts: (B, S0) int32 (right-aligned).  Returns (B, n_tokens)."""
        cfg, sc = self.cfg, self.sc
        b, s0 = prompts.shape
        extras = {}
        if enc_embeds is not None:
            extras["enc_embeds"] = enc_embeds
        if prefix_embeds is not None:
            extras["prefix_embeds"] = prefix_embeds
        tr = self.obs.tracer
        sp = tr.span if tr is not None else null_span
        with sp("prefill", cat="static"), \
                self.obs.dram.scope(f"static_prefill[{s0}]"):
            logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                          max_seq=sc.max_seq, **extras)
            if tr is not None:
                jax.block_until_ready(logits)
        pos = s0 + (cfg.prefix_tokens if prefix_embeds is not None else 0)
        rng = jax.random.PRNGKey(sc.seed)
        # the whole token loop runs on device (lax.scan, sampling
        # included) and transfers once — no per-token host sync
        with sp("decode", cat="static"), \
                self.obs.dram.scope(f"static_generate[{n_tokens}]"):
            out = self._gen(self.params, logits, cache, jnp.int32(pos), rng,
                            n_tokens=n_tokens)
            if tr is not None:
                jax.block_until_ready(out)
        with sp("readback", cat="static"):
            host = np.asarray(out)
        self._m_prefill_tokens.inc(b * s0)
        self._m_decode_tokens.inc(b * n_tokens)
        self.obs.dram.end_step(range(b))
        return host

    def _gen_fn(self, params, logits, cache, pos, rng, *, n_tokens: int):
        cfg, sc = self.cfg, self.sc
        tok0 = sample_tokens(cfg, logits, sc.temperature,
                             jax.random.fold_in(rng, 0))

        def body(carry, i):
            tok, cache, pos = carry
            logits, cache = T.decode_step(cfg, params, tok, cache, pos)
            t = sample_tokens(cfg, logits, sc.temperature,
                              jax.random.fold_in(rng, i))
            return (t, cache, pos + 1), t

        with ops.fused_ops(sc.fuse):
            (_, _, _), rest = jax.lax.scan(
                body, (tok0, cache, pos), jnp.arange(1, n_tokens))
        return jnp.concatenate([tok0[:, None], rest.T], axis=1)


# ======================== paged continuous batching ========================


@dataclasses.dataclass
class PagedServeConfig:
    max_seq: int = 1024            # per-request prompt + generation cap
    max_batch: int = 8             # decode batch slots
    page_size: int | None = None   # None -> tuned ("flash_decode" key)
    n_pages: int | None = None     # None -> max_batch full sequences + 1
    temperature: float = 0.0
    seed: int = 0
    fuse: bool = False             # cross-op fused kernels (docs/fusion.md)
    buckets: tuple[int, ...] | None = None   # prefill padding lengths
    decode_chunk: int = 8          # decode steps per scheduler visit
    prefill_chunk: int | None = None   # None -> auto-sized; 0 -> whole-
    #                                    prompt joins (legacy behavior)
    spec_decode: int = 0           # draft tokens per verify step (0 = off;
    #                                greedy only, attention-only stacks)
    prefix_cache: bool = False     # radix-tree prefix sharing across
    #                                requests (attention-only stacks with
    #                                chunked prefill; docs/serving.md)
    reuse_hint: float = 0.5        # expected prompt-reuse rate, used by
    #                                choose_page_size to price the
    #                                share-vs-stream page tradeoff when
    #                                the prefix cache is on
    age_limit: int = 8             # admission rounds before a waiting head
    #                                suspends backfill (anti-starvation)
    use_kernel: bool | None = None  # paged attention: None -> TPU only
    interpret: bool | None = None
    # -- lifecycle / robustness (docs/robustness.md) -------------------------
    nan_guard: bool = False        # per-slot non-finite logit detection:
    #                                fails only the poisoned request, at the
    #                                cost of one readback per decode chunk
    preempt: bool = False          # preempt-with-restore when the waiting
    #                                head starves (greedy only; rung 3 of
    #                                the degradation ladder enables it too)
    degrade: bool = False          # graceful-degradation ladder controller
    max_retries: int | None = None  # admission probe failures before a
    #                                 queued request is FAILED (None = never)


def default_buckets(cfg: ModelConfig, max_seq: int) -> tuple[int, ...] | None:
    """Prefill length buckets: powers of two for pure-attention stacks
    (bounded recompilation; right-padding is safe because causal
    attention ignores the tail, and although the pad positions' K/V are
    scattered into the request's reserved pages, they stay masked by the
    length until decode overwrites each slot in order).  Recurrent/SSD
    mixers fold *every* position into their O(1) state, so right-padding
    would corrupt it — those prefill at exact lengths (None), one
    compile per distinct prompt length."""
    if all(p in ("global", "local") for p in cfg.layer_pattern):
        out, b = [], 8
        while b < max_seq:
            out.append(b)
            b *= 2
        out.append(max_seq)
        return tuple(sorted(set(out)))
    return None


class PagedEngine:
    """Request/response serving over the paged cache.

    ``submit()`` enqueues a prompt; ``step()`` runs one scheduler
    iteration and returns the requests that finished; ``generate()`` is
    the batch-convenience wrapper used by the examples and benchmarks.

    A step executes the scheduler's :class:`~repro.serve.scheduler.
    StepPlan` in decode-priority order: admission first (chunk-prefilled
    requests only reserve state; legacy joins prefill whole prompts),
    then ONE jitted decode chunk covering every decode-ready slot, then
    prefill chunks backfilling the leftover token budget, then eviction.
    A decode chunk is up to ``decode_chunk`` steps fused into one
    ``lax.scan`` — per-slot activity is masked inside the scan, so
    chunking changes scheduling granularity, never results.  With
    ``spec_decode=k`` each scan step is a draft-verify call that can
    emit up to ``k+1`` tokens (greedy semantics preserved exactly:
    tokens are accepted only while they match the argmax chain).

    Page reservations are made in full at admission, which is what makes
    block tables stable across a chunk; the tables themselves live on
    device and are updated incrementally at admission/eviction — steady-
    state decode re-uploads nothing.

    Chunked prefill and speculative decode need every mixer to be
    attention (the rglru/ssd state updates are strictly one-token);
    hybrid stacks silently fall back to whole-prompt joins and plain
    decode, keeping one engine API across all architectures.
    """

    def __init__(self, cfg: ModelConfig, params: Any, sc: PagedServeConfig,
                 obs: Obs | None = None):
        if cfg.is_encdec or cfg.prefix_tokens:
            raise NotImplementedError(
                "paged serving covers decoder-only token models")
        self.cfg, self.params, self.sc = cfg, params, sc
        self.obs = obs if obs is not None else Obs()
        has_attn = any(p in ("global", "local") for p in cfg.layer_pattern)
        attn_only = has_attn and all(
            p in ("global", "local") for p in cfg.layer_pattern)
        reuse = (sc.reuse_hint or None) if (sc.prefix_cache
                                            and attn_only) else None
        with self.obs.dram.scope("setup"):
            # page-size / chunk selection resolves the flash-decode
            # schedule once, here — attributed to "setup", not a step
            self.page_size = sc.page_size or (
                KV.choose_page_size(cfg, sc.max_seq, fused=sc.fuse,
                                    reuse_rate=reuse) if has_attn
                else min(sc.max_seq, 128))   # attention-free: pages unused
        self.max_blocks = KV.num_blocks(sc.max_seq, self.page_size)
        n_pages = sc.n_pages or sc.max_batch * self.max_blocks + 1
        self.cache = KV.init_paged_cache(cfg, sc.max_batch, n_pages,
                                         self.page_size)
        self.buckets = (sc.buckets if sc.buckets is not None
                        else default_buckets(cfg, sc.max_seq))

        # resolve the span-based features against the stack's capability
        if sc.prefill_chunk is None:
            self.prefill_chunk = (KV.choose_prefill_chunk(
                cfg, sc.max_seq, self.page_size) if attn_only else 0)
        elif sc.prefill_chunk and attn_only:
            # snap an explicit chunk to a whole number of pages
            self.prefill_chunk = min(
                sc.max_seq,
                KV.num_blocks(sc.prefill_chunk, self.page_size)
                * self.page_size)
        else:
            self.prefill_chunk = 0
        self.spec = int(sc.spec_decode or 0) if attn_only else 0
        if self.spec and sc.temperature > 0:
            raise ValueError(
                "spec_decode is greedy-only: draft acceptance compares "
                "against the argmax chain, which sampling would break")
        if sc.preempt and sc.temperature > 0:
            raise ValueError(
                "preempt is greedy-only: restoring a preempted request "
                "replays its tail deterministically, which sampling "
                "would break (byte-exactness is the correctness bar)")

        # prefix caching needs the span machinery to resume prefill at
        # the matched boundary, so it gates exactly like chunked prefill
        # (attention-only stacks; explicit prefill_chunk=0 turns it off)
        self.prefix_caching = bool(sc.prefix_cache) and attn_only \
            and self.prefill_chunk > 0
        reg = self.obs.registry
        allocator = KV.PageAllocator(n_pages, metrics=reg)
        self.prefix_cache = (KV.PrefixCache(allocator, self.page_size,
                                            metrics=reg)
                             if self.prefix_caching else None)
        self.scheduler = Scheduler(sc.max_batch, self.page_size,
                                   allocator, sc.max_seq,
                                   age_limit=sc.age_limit,
                                   prefix_cache=self.prefix_cache,
                                   metrics=reg,
                                   max_retries=sc.max_retries)
        self.degrade = (DegradationController(reg, tracer=self.obs.tracer)
                        if sc.degrade else None)

        b = sc.max_batch
        self._block_tables = jnp.zeros((b, self.max_blocks), jnp.int32)
        self._lengths = jnp.zeros(b, jnp.int32)    # cached tokens per slot
        self._cur_tok = jnp.zeros(b, jnp.int32)
        self._out_buf = jnp.zeros((b, sc.max_seq), jnp.int32)
        self._hist = jnp.zeros((b, sc.max_seq), jnp.int32)  # prompt+tokens
        self._rng = jax.random.PRNGKey(sc.seed)
        self._step_count = 0
        self._next_rid = 0
        # chaos seam: added to every logit a slot produces (nan_guard
        # reads it; the host mirror skips no-op device updates)
        self._poison = jnp.zeros(b, jnp.float32)
        self._poison_host = np.zeros(b, np.float64)
        self._clock = time.monotonic_ns    # injectable for deterministic tests
        self._sched_steps = 0              # TTL / expiry step counter
        self._joins: dict[int, Any] = {}           # bucket -> jitted join
        self._chunk_fns: dict[int, Any] = {}       # span width -> chunk fn
        self._fork_fn: Any = None                  # jitted CoW page copy
        self._decode = jax.jit(self._decode_fn,
                               static_argnames=("chunk",))
        self._decode_spec = jax.jit(self._decode_spec_fn,
                                    static_argnames=("chunk",))
        self.last_step_tokens = 0                  # benchmark counter
        # registry-backed counters (spec_stats/prefix_stats are views)
        self._m_steps = reg.counter("engine.steps")
        self._m_step_us = reg.histogram("engine.step_us")
        self._m_decode_tokens = reg.counter("engine.decode_tokens")
        self._m_prefill_tokens = reg.counter("engine.prefill_tokens")
        self._m_spec_calls = reg.counter("spec.verify_calls")
        self._m_spec_tokens = reg.counter("spec.tokens")
        self._m_prefix_lookups = reg.counter("prefix_cache.lookups")
        self._m_prefix_hits = reg.counter("prefix_cache.hits")
        self._m_prefix_saved = reg.counter("prefix_cache.tokens_saved")
        self._m_status = {s: reg.counter(f"lifecycle.{s.value}")
                          for s in RequestStatus}
        self._m_nan_trips = reg.counter("lifecycle.nan_guard_trips")

    # -- request API ----------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int, *,
               priority: int = 0, deadline_s: float | None = None,
               ttl_steps: int | None = None) -> int:
        """Enqueue one prompt; returns the request id.

        ``deadline_s`` is a wall budget from now (engine clock);
        ``ttl_steps`` a deterministic budget in scheduler steps —
        whichever passes first expires the request to
        DEADLINE_EXCEEDED with whatever tokens it has.  ``priority``
        orders preemption victims (lower goes first)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        rid = self._next_rid
        self._next_rid += 1
        deadline_ns = (None if deadline_s is None
                       else self._clock() + int(deadline_s * 1e9))
        expire_step = (None if ttl_steps is None
                       else self._sched_steps + int(ttl_steps))
        self.scheduler.submit(Request(rid, prompt, int(max_new_tokens),
                                      priority=int(priority),
                                      deadline_ns=deadline_ns,
                                      expire_step=expire_step))
        return rid

    def cancel(self, rid: int) -> bool:
        """Cooperative cancel: the request finishes TRUNCATED (partial
        output) at the next step boundary.  False if rid is unknown."""
        return self.scheduler.cancel(rid)

    def preempt(self, rid: int) -> bool:
        """Force-preempt a running request (the pressure path calls
        this automatically; exposed for tests and the chaos harness).
        Its tokens so far are preserved and it will be re-admitted —
        through the prefix cache when one is attached — to finish with
        byte-exact output and status PREEMPTED_RETRIED."""
        for slot, r in self.scheduler.running.items():
            if r.rid == rid:
                self._preempt_slot(slot, self.obs.tracer)
                return True
        return False

    def inject_logit_fault(self, rid: int,
                           value: float = float("nan")) -> None:
        """Chaos seam: add ``value`` to every logit ``rid``'s slot
        produces from now on.  With ``nan_guard`` on, a non-finite
        ``value`` fails exactly this request and no other."""
        if not self.sc.nan_guard:
            raise RuntimeError(
                "inject_logit_fault needs PagedServeConfig(nan_guard="
                "True): without the guard a poisoned slot would decode "
                "garbage forever instead of failing fast")
        for slot, r in self.scheduler.running.items():
            if r.rid == rid:
                self._poison = self._poison.at[slot].set(value)
                self._poison_host[slot] = value
                return
        raise KeyError(f"rid {rid} is not running")

    def shutdown(self) -> list[Request]:
        """Cancel all in-flight work and drain to terminal statuses.

        Frees every request-owned page (prefix-tree references are
        dropped too, so the pool returns to empty) — the Ctrl-C path in
        ``launch/serve``.  Returns the requests finished by the drain.
        """
        for r in list(self.scheduler.waiting):
            r.cancelled = True
        for r in self.scheduler.running.values():
            r.cancelled = True
        out = []
        while self.has_work:
            out.extend(self.step())
        if self.prefix_cache is not None:
            while len(self.prefix_cache):
                if not self.prefix_cache.evict(len(self.prefix_cache)):
                    break
        return out

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    def spec_stats(self) -> dict:
        """Draft-verify counters: total verify calls, tokens they
        emitted, and the mean accepted span (1.0 = plain decode).
        A thin view over the metrics registry (``spec.*``)."""
        calls, toks = self._m_spec_calls.value, self._m_spec_tokens.value
        return {"verify_calls": calls, "tokens": toks,
                "mean_accepted": toks / calls if calls else 0.0}

    def prefix_stats(self) -> dict:
        """Prefix-cache counters: admissions probed, admissions that
        matched, prompt tokens served from shared pages instead of
        being re-prefilled, and the tree's current page count.
        A thin view over the metrics registry (``prefix_cache.*``)."""
        lookups = self._m_prefix_lookups.value
        hits = self._m_prefix_hits.value
        return {"lookups": lookups, "hits": hits,
                "hit_rate": hits / lookups if lookups else 0.0,
                "tokens_saved": self._m_prefix_saved.value,
                "cached_pages": (len(self.prefix_cache)
                                 if self.prefix_cache is not None else 0)}

    def lifecycle_stats(self) -> dict:
        """Terminal-status counts plus the pressure/fault counters — a
        thin view over the ``lifecycle.*`` / ``sched.*`` registry
        entries (docs/robustness.md)."""
        reg = self.obs.registry
        out = {s.value: self._m_status[s].value for s in RequestStatus}
        out["preemptions"] = reg.counter("sched.preemptions").value
        out["admit_rollbacks"] = reg.counter("sched.admit_rollbacks").value
        out["nan_guard_trips"] = self._m_nan_trips.value
        if self.degrade is not None:
            out["degrade_level"] = self.degrade.level
            out["degrade_escalations"] = \
                reg.counter("degrade.escalations").value
        return out

    def step(self) -> list[Request]:
        """One continuous-batching iteration; returns finished requests
        (with ``.output`` filled).

        With a tracer attached, the step and its phases (host prep,
        ``plan_step``, device dispatches, readback) emit Chrome-trace
        spans, and each device dispatch is fenced with
        ``block_until_ready`` so span durations mean device time.  With
        no tracer, ``sp`` is the shared no-op span and NO fence runs —
        the hot path stays async (guarded by ``tests/test_obs.py``).
        """
        t0 = time.perf_counter_ns()
        tr = self.obs.tracer
        sp = tr.span if tr is not None else null_span
        self.last_step_tokens = 0
        step_rids: set[int] = set()
        with sp("step", cat="engine", args={"step": self._step_count}):
            finished = self._step_inner(sp, tr, step_rids)
        self._m_steps.inc()
        self._m_step_us.observe((time.perf_counter_ns() - t0) / 1000.0)
        self.obs.dram.end_step(sorted(step_rids))
        return finished

    def _step_inner(self, sp, tr, step_rids: set[int]) -> list[Request]:
        self._sched_steps += 1
        now = self._clock()
        finished: list[Request] = []
        # lifecycle sweep: queued deadline/TTL expiry and cancellation
        # drain before admission so a dead request never takes pages
        for req in self.scheduler.expire(now, self._sched_steps):
            self._finish(req, finished)
        # degradation ladder: one control tick per step, applied to THIS
        # step's spec/chunk/preemption decisions
        decode_chunk = self.sc.decode_chunk
        use_spec = self.spec
        allow_preempt = self.sc.preempt
        force_preempt = False
        if self.degrade is not None:
            self.degrade.update()
            if self.degrade.spec_disabled:
                use_spec = 0
            if self.degrade.shrink_chunk:
                decode_chunk = max(1, decode_chunk // 2)
            if self.degrade.allow_preempt and self.sc.temperature <= 0:
                allow_preempt = force_preempt = True
        if allow_preempt:
            victim = self.scheduler.preempt_candidate(force=force_preempt)
            if victim is not None:
                with sp("preempt", cat="sched"):
                    self._preempt_slot(victim, tr)
        with sp("host_prep", cat="engine"):
            for req in self.scheduler.admit():
                step_rids.add(req.rid)
                row = np.full(self.max_blocks, KV.SCRATCH_PAGE, np.int32)
                row[:len(req.pages)] = req.pages
                self._block_tables = self._block_tables.at[req.slot].set(
                    jnp.asarray(row))
                if self.prefix_caching:
                    self._m_prefix_lookups.inc()
                if req.cached_tokens:
                    # prefix hit: shared pages already hold the matched
                    # K/V; prefill resumes at the boundary through the
                    # chunk path, so only O(new tokens) run the model
                    self._m_prefix_hits.inc()
                    self._m_prefix_saved.inc(req.prefilled)
                    if req.cow_fork is not None:
                        src, dst = req.cow_fork
                        with sp("dispatch.fork", cat="device"), \
                                self.obs.dram.scope("cow_fork"):
                            self.cache = self._get_fork_fn()(
                                self.cache, jnp.int32(src), jnp.int32(dst))
                    # the spec-decode draft history must cover the cached
                    # prefix the chunk path will never feed
                    hist_row = np.zeros(self.sc.max_seq, np.int32)
                    L = min(req.prompt_len, self.sc.max_seq)
                    hist_row[:L] = req.prompt[:L]
                    self._hist = self._hist.at[req.slot].set(
                        jnp.asarray(hist_row))
                    # a tail that fits one chunk prefills inline, exactly
                    # where a miss would run its join — the hit request is
                    # decode-ready this very step instead of waiting a
                    # scheduling round (longer tails go through plan_step)
                    if req.prompt_len - req.prefilled <= self.prefill_chunk:
                        with sp("dispatch.prefill", cat="device"):
                            self._prefill_one_chunk(req)
                            if tr is not None:
                                jax.block_until_ready(self._cur_tok)
                    continue
                if (not self.prefill_chunk
                        or req.prompt_len <= self.prefill_chunk):
                    # whole-prompt join: chunking a prompt that fits in ONE
                    # chunk would pay the fixed-span chunk call (span =
                    # prefill_chunk, padded) where the bucketed join prices
                    # the prefill at the prompt's own pow2 bucket — chunked
                    # prefill only earns its keep on multi-chunk prompts
                    with sp("dispatch.join", cat="device"):
                        self._join(req)
                        if tr is not None:
                            jax.block_until_ready(self._cur_tok)
                    req.prefilled = req.prompt_len
                    if not req.failed:      # poisoned pages never cached
                        self.scheduler.register_prefix(req)
                        self.last_step_tokens += 1     # the prefill token
        for req in self.scheduler.take_rejected():
            self._finish(req, finished)
        with sp("plan_step", cat="sched"):
            plan = self.scheduler.plan_step(decode_chunk,
                                            self.prefill_chunk or 1)
        # plan entries are validated and deduped before dispatch: a
        # duplicated decode slot would double-count ``generated`` and a
        # stale/dropped entry is simply skipped (the next plan recomputes
        # from scheduler state, so nothing is lost) — chaos-harness seam
        running = self.scheduler.running
        decode_rs: list[Request] = []
        seen: set[int] = set()
        for s in plan.decode_slots:
            r = running.get(s)
            if r is None or s in seen or not r.decode_ready \
                    or r.cancelled or r.expired(now, self._sched_steps):
                continue            # dead slots stop decoding immediately
            seen.add(s)
            decode_rs.append(r)
        step_rids.update(r.rid for r in decode_rs)
        # decode first: decode-ready slots are never stalled by prefill
        if decode_rs:
            with sp("dispatch.decode", cat="device"):
                self._decode_once(decode_rs, decode_chunk, use_spec)
                if tr is not None:
                    jax.block_until_ready(self._out_buf)
        for slot in plan.prefill_slots:
            r = running.get(slot)
            if r is None or r.prefill_done or r.cancelled \
                    or r.expired(now, self._sched_steps):
                continue
            step_rids.add(r.rid)
            with sp("dispatch.prefill", cat="device"):
                self._prefill_one_chunk(r)
                if tr is not None:
                    jax.block_until_ready(self._cur_tok)
        done_slots = [s for s, r in self.scheduler.running.items()
                      if r.done or r.failed or r.cancelled
                      or r.expired(now, self._sched_steps)]
        if done_slots:
            # one host transfer covers every request finishing this step;
            # device state is NOT reset — the decode fns mask unoccupied
            # slots to scratch, and admission rewrites the row anyway
            with sp("readback", cat="engine"):
                host_out = np.asarray(self._out_buf)
            for slot in done_slots:
                req = self.scheduler.running[slot]
                tail = host_out[slot, :req.generated].copy()
                req.output = (tail if req.prior_tokens is None else
                              np.concatenate([req.prior_tokens, tail]))
                self._clear_poison(slot)
                self._finish(self.scheduler.evict(slot), finished)
        return finished

    def _finish(self, req: Request, out: list[Request]) -> None:
        """Assign the terminal status (docs/robustness.md), count it,
        and hand the request back.  Precedence: a tripped fault always
        FAILs; a request that finished its budget is OK (or
        PREEMPTED_RETRIED) even if a cancel/deadline raced the last
        step; otherwise cancel beats deadline."""
        if req.output is None:     # never ran: expired/rejected in queue
            req.output = (req.prior_tokens if req.prior_tokens is not None
                          else np.zeros(0, np.int32))
        if req.failed:
            status = RequestStatus.FAILED
        elif req.done:
            status = (RequestStatus.PREEMPTED_RETRIED if req.preempt_count
                      else RequestStatus.OK)
        elif req.cancelled:
            status = RequestStatus.TRUNCATED
        else:
            status = RequestStatus.DEADLINE_EXCEEDED
        req.status = status
        self._m_status[status].inc()
        out.append(req)

    def _preempt_slot(self, slot: int, tr=None) -> None:
        """Preempt one running slot: read back its sampled tokens (the
        rare sync preemption pays), hand them to the scheduler — which
        registers complete pages in the prefix tree and requeues the
        replacement — and clear any injected poison with the slot."""
        req = self.scheduler.running[slot]
        host_out = np.asarray(self._out_buf)
        emitted = host_out[slot, :req.generated].copy()
        new = self.scheduler.preempt(slot, emitted)
        self._clear_poison(slot)
        if tr is not None:
            tr.instant("preempt", cat="lifecycle",
                       args={"rid": req.rid, "slot": slot,
                             "kept_tokens": int(len(new.prior_tokens))})

    def _clear_poison(self, slot: int) -> None:
        if self._poison_host[slot]:
            self._poison = self._poison.at[slot].set(0.0)
            self._poison_host[slot] = 0.0

    def generate(self, prompts, n_tokens: int, *, priorities=None,
                 deadline_s: float | None = None,
                 ttl_steps: int | None = None,
                 return_requests: bool = False):
        """Batch convenience: submit all, run to completion, return
        (B, n_tokens) in submission order.  ``prompts`` may be a 2-D
        array or a list of 1-D arrays (ragged lengths welcome).

        With ``return_requests=True`` the finished
        :class:`~repro.serve.scheduler.Request` objects come back
        instead (``.output`` + terminal ``.status``, submission order)
        — the only safe form when deadlines/TTLs/faults can truncate
        outputs to ragged lengths."""
        pr = (list(priorities) if priorities is not None
              else [0] * len(prompts))
        rids = [self.submit(p, n_tokens, priority=q, deadline_s=deadline_s,
                            ttl_steps=ttl_steps)
                for p, q in zip(prompts, pr)]
        done: dict[int, Request] = {}
        while self.has_work:
            for req in self.step():
                done[req.rid] = req
        if return_requests:
            return [done[r] for r in rids]
        return np.stack([done[r].output for r in rids])

    # -- internals ------------------------------------------------------------

    def _bucket(self, length: int) -> int:
        if self.buckets is None:
            return length
        for b in self.buckets:
            if b >= length:
                return b
        return length

    def _next_key(self) -> jax.Array:
        self._step_count += 1
        return jax.random.fold_in(self._rng, self._step_count)

    def _join(self, req: Request) -> None:
        """Prefill an admitted request at its bucketed true length,
        scatter its KV into the reserved pages, sample its first token —
        all in one jitted call per bucket length."""
        slot, L = req.slot, req.prompt_len
        bucket = self._bucket(L)
        prompt = np.zeros((1, bucket), np.int32)
        prompt[0, :L] = req.prompt
        nb = KV.num_blocks(bucket, self.page_size)
        pages = np.full(nb, KV.SCRATCH_PAGE, np.int32)
        pages[:min(nb, len(req.pages))] = req.pages[:nb]
        # the scope tag carries the jit variant (one trace per bucket),
        # so resolution bytes x execution count attributes correctly
        with self.obs.dram.scope(f"join[{bucket}]"):
            res = self._get_join(bucket)(
                self.params, self.cache, jnp.asarray(prompt),
                jnp.int32(L), jnp.int32(slot), jnp.asarray(pages),
                self._lengths, self._cur_tok, self._out_buf, self._hist,
                self._next_key(), self._poison)
        if self.sc.nan_guard:
            (self.cache, self._lengths, self._cur_tok, self._out_buf,
             self._hist, bad) = res
            self._m_prefill_tokens.inc(L)
            if bool(np.asarray(bad)):
                req.failed = True
                self._m_nan_trips.inc()
                return
        else:
            (self.cache, self._lengths, self._cur_tok, self._out_buf,
             self._hist) = res
            self._m_prefill_tokens.inc(L)
        req.generated = 1

    def _get_join(self, bucket: int):
        if bucket not in self._joins:
            cfg, sc = self.cfg, self.sc

            def join(params, cache, prompt, true_len, slot, pages,
                     lengths, cur_tok, out_buf, hist, key, poison):
                with ops.fused_ops(sc.fuse):
                    logits, dense = T.prefill(cfg, params, prompt,
                                              max_seq=bucket, full_kv=True,
                                              logits_at=true_len - 1)
                cache = KV.write_prefill(cfg, cache, dense, slot, pages,
                                         self.page_size)
                if sc.nan_guard:
                    logits = logits + poison[slot]
                tok = sample_tokens(cfg, logits, sc.temperature, key)[0]
                hist = jax.lax.dynamic_update_slice(
                    hist, prompt, (slot, jnp.int32(0)))
                hist = hist.at[slot, true_len].set(tok, mode="drop")
                out = (cache, lengths.at[slot].set(true_len),
                       cur_tok.at[slot].set(tok),
                       out_buf.at[slot, 0].set(tok), hist)
                if sc.nan_guard:
                    bad = ~jnp.all(jnp.isfinite(logits[..., :cfg.vocab]))
                    return out + (bad,)
                return out

            self._joins[bucket] = jax.jit(join)
        return self._joins[bucket]

    # -- prefix cache ---------------------------------------------------------

    def _get_fork_fn(self):
        """Jitted copy-on-write page copy: duplicate page ``src`` into
        ``dst`` across every attention layer's pools (prefix caching is
        gated to attention-only stacks, so every group pages)."""
        if self._fork_fn is None:
            def fork(cache, src, dst):
                def cp(pc, stacked):
                    if stacked:     # (n_groups, n_pages, page, hkv, hd)
                        return {k: pc[k].at[:, dst].set(pc[k][:, src])
                                for k in ("k_pages", "v_pages")}
                    return {k: pc[k].at[dst].set(pc[k][src])
                            for k in ("k_pages", "v_pages")}
                return {"layers": [cp(pc, True) for pc in cache["layers"]],
                        "tail": [cp(pc, False) for pc in cache["tail"]]}

            # donate the pools: the fork updates one page slice in
            # place instead of copying the whole cache
            self._fork_fn = jax.jit(fork, donate_argnums=(0,))
        return self._fork_fn

    # -- chunked prefill ------------------------------------------------------

    def _prefill_one_chunk(self, req: Request) -> None:
        """Advance one request's prefill by one chunk.

        The chunk runs as a batch-1 multi-token ``decode_step`` over the
        paged cache (``make_paged_span_step``): K/V for all chunk
        positions scatter into the reserved pages and one q-span
        flash-decode call attends each position to everything before it
        — identical math to whole-prompt prefill, paid ``prefill_chunk``
        tokens at a time.  The final chunk samples the first token
        exactly as a join would.
        """
        start, L = req.prefilled, req.prompt_len
        c_real = min(self.prefill_chunk, L - start)
        # span width = pow2 bucket of the real remainder, not the full
        # prefill_chunk: the final partial chunk of any prompt — and the
        # short unshared tail after a prefix-cache hit — pays for the
        # tokens it actually carries
        C = 1
        while C < c_real:
            C *= 2
        final = start + c_real >= L
        tokens = np.zeros((1, C), np.int32)
        tokens[0, :c_real] = req.prompt[start:start + c_real]
        take_at = (L - 1 - start) if final else -1
        with self.obs.dram.scope(f"prefill[{C}]"):
            res = self._get_chunk_fn(C)(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.int32(start), self._block_tables,
                self._lengths, jnp.int32(req.slot),
                jnp.int32(start + c_real), jnp.int32(take_at),
                self._cur_tok, self._out_buf, self._hist, self._next_key(),
                self._poison)
        if self.sc.nan_guard:
            (self.cache, self._lengths, self._cur_tok, self._out_buf,
             self._hist, bad) = res
            self._m_prefill_tokens.inc(c_real)
            req.prefilled = start + c_real
            if bool(np.asarray(bad)):   # guard sync: one scalar per chunk
                req.failed = True
                self._m_nan_trips.inc()
                return
        else:
            (self.cache, self._lengths, self._cur_tok, self._out_buf,
             self._hist) = res
            self._m_prefill_tokens.inc(c_real)
            req.prefilled = start + c_real
        if final:
            req.generated = 1
            self.scheduler.register_prefix(req)
            self.last_step_tokens += 1             # the prefill token

    def _get_chunk_fn(self, C: int):
        if C not in self._chunk_fns:
            cfg, sc = self.cfg, self.sc

            def chunk(params, cache, tokens, start, block_tables, lengths,
                      slot, new_len, take_at, cur_tok, out_buf, hist, key,
                      poison):
                bt_row = jax.lax.dynamic_slice_in_dim(block_tables,
                                                      slot, 1)
                with ops.fused_ops(sc.fuse):
                    attn = KV.make_paged_span_step(
                        cfg, bt_row, self.page_size, sc.max_seq,
                        sc.use_kernel, sc.interpret)
                    logits, cache = T.decode_step(
                        cfg, params, tokens, cache,
                        jnp.full((1,), start, jnp.int32), attn_step=attn)
                if sc.nan_guard:
                    logits = logits + poison[slot]
                lengths = lengths.at[slot].set(new_len)
                idx = start + jnp.arange(C)
                hist = hist.at[slot, jnp.where(idx < sc.max_seq, idx,
                                               sc.max_seq)].set(
                    tokens[0], mode="drop")
                # final chunk: the prompt's last logits seed generation
                tok = sample_tokens(cfg,
                                    logits[:, jnp.clip(take_at, 0, C - 1)],
                                    sc.temperature, key)[0]
                is_final = take_at >= 0
                cur_tok = cur_tok.at[slot].set(
                    jnp.where(is_final, tok, cur_tok[slot]))
                out_buf = out_buf.at[slot, 0].set(
                    jnp.where(is_final, tok, out_buf[slot, 0]))
                hist = hist.at[slot, new_len].set(
                    jnp.where(is_final, tok, hist[slot, new_len]),
                    mode="drop")
                if sc.nan_guard:
                    bad = ~jnp.all(jnp.isfinite(logits[..., :cfg.vocab]))
                    return cache, lengths, cur_tok, out_buf, hist, bad
                return cache, lengths, cur_tok, out_buf, hist

            self._chunk_fns[C] = jax.jit(chunk)
        return self._chunk_fns[C]

    # -- decode ---------------------------------------------------------------

    def _decode_fn(self, params, cache, cur_tok, block_tables, lengths,
                   occupied, remaining, out_idx, out_buf, key, poison, *,
                   chunk: int):
        """``chunk`` fused decode steps (one device dispatch).

        ``remaining[b]`` is the slot's token budget at chunk start; a
        step is active for slot b while ``occupied[b]`` and its emitted
        count is under budget.  Inactive slots freeze their length,
        token and output row, and their block-table rows / lengths are
        masked to scratch/0 *here, inside the jit* — so eviction never
        has to reset device state (a stale row is harmless) and freeing
        a request costs zero device dispatches.

        With ``nan_guard`` on, ``poison`` (the chaos seam) is added to
        the logits and any slot producing a non-finite logit is frozen
        for the rest of the chunk — its sampled-so-far output stays
        intact — and reported in a per-slot ``(emitted, bad)`` stats
        array the host reads back once per chunk.  Guard off: no stats
        output, no readback, the hot path stays async."""
        cfg = self.cfg
        guard = self.sc.nan_guard
        lengths_in = lengths
        block_tables = jnp.where(occupied[:, None], block_tables,
                                 KV.SCRATCH_PAGE)
        lengths = jnp.where(occupied, lengths, 0)
        attn = KV.make_paged_attn_step(cfg, block_tables, self.page_size,
                                       self.sc.use_kernel,
                                       self.sc.interpret,
                                       fused=self.sc.fuse)
        rows = jnp.arange(cur_tok.shape[0])

        def body(carry, i):
            cur_tok, cache, lengths, out_idx, out_buf, emitted, bad = carry
            active = occupied & (emitted < remaining) & ~bad
            logits, cache = T.decode_step(cfg, params, cur_tok, cache,
                                          lengths, attn_step=attn)
            if guard:
                logits = logits + poison[:, None]
                finite = jnp.all(jnp.isfinite(logits[:, :cfg.vocab]),
                                 axis=-1)
                bad = bad | (active & ~finite)
                active = active & finite
            tok = sample_tokens(cfg, logits, self.sc.temperature,
                                jax.random.fold_in(key, i))
            tok = jnp.where(active, tok, cur_tok)
            keep = out_buf[rows, out_idx]
            out_buf = out_buf.at[rows, out_idx].set(
                jnp.where(active, tok, keep))
            out_idx = jnp.where(active, out_idx + 1, out_idx)
            lengths = jnp.where(active, lengths + 1, lengths)
            emitted = emitted + active.astype(jnp.int32)
            return (tok, cache, lengths, out_idx, out_buf, emitted,
                    bad), None

        with ops.fused_ops(self.sc.fuse):
            carry = (cur_tok, cache, lengths, out_idx, out_buf,
                     jnp.zeros_like(remaining),
                     jnp.zeros(cur_tok.shape[0], bool))
            (cur_tok, cache, lengths, _, out_buf, emitted,
             bad), _ = jax.lax.scan(body, carry, jnp.arange(chunk))
        # restore masked-out lengths (a still-prefilling slot keeps its)
        out = (cur_tok, cache,
               jnp.where(occupied, lengths, lengths_in), out_buf)
        if guard:
            return out + (jnp.stack([emitted, bad.astype(jnp.int32)]),)
        return out

    def _decode_spec_fn(self, params, cache, cur_tok, block_tables,
                        lengths, occupied, remaining, out_idx, out_buf,
                        hist, poison, *, chunk: int):
        """``chunk`` draft-verify steps (one device dispatch).

        Each step drafts ``k = spec_decode`` tokens by n-gram lookup
        over the slot's own history (prompt-lookup decoding: the latest
        earlier occurrence of the trailing 2-gram proposes its
        continuation; no match drafts -1, which can never be accepted),
        scores current + drafts in ONE span decode_step, and accepts the
        longest prefix matching the greedy argmax chain — so emitted
        tokens are bit-identical to plain greedy decode, just cheaper
        per token.  Draft rows past the accepted prefix leave garbage
        K/V above the new length; the next span overwrites every such
        position before the length mask can expose it.

        ``remaining`` bounds *emitted tokens*, not steps; a step that
        would overshoot the budget truncates its accepted span.  Returns
        per-slot emitted counts and the active-call total for the
        acceptance stats.
        """
        cfg = self.cfg
        k = self.spec
        span = k + 1
        max_seq = self.sc.max_seq
        b = cur_tok.shape[0]
        rows = jnp.arange(b)
        # inactive slots (free, evicted-stale, or still prefilling) are
        # masked to scratch here so eviction never resets device state
        lengths_in = lengths
        block_tables = jnp.where(occupied[:, None], block_tables,
                                 KV.SCRATCH_PAGE)
        lengths = jnp.where(occupied, lengths, 0)
        attn = KV.make_paged_span_step(cfg, block_tables, self.page_size,
                                       max_seq, self.sc.use_kernel,
                                       self.sc.interpret)

        def drafts_for(hist, lengths):
            hl = lengths + 1                     # tokens in hist per slot
            last = hist[rows, jnp.clip(hl - 1, 0, max_seq - 1)]
            prev = hist[rows, jnp.clip(hl - 2, 0, max_seq - 1)]
            m2 = ((hist[:, 1:] == last[:, None])
                  & (hist[:, :-1] == prev[:, None]))
            p = jnp.arange(1, max_seq)
            m2 &= p[None, :] < (hl - 1)[:, None]     # strictly earlier
            j = jnp.max(jnp.where(m2, p[None, :], -1), axis=1)
            gidx = j[:, None] + 1 + jnp.arange(k)[None, :]
            valid = (j >= 0)[:, None] & (gidx < hl[:, None])
            d = hist[rows[:, None], jnp.clip(gidx, 0, max_seq - 1)]
            return jnp.where(valid, d, -1)

        guard = self.sc.nan_guard

        def body(carry, i):
            (cur_tok, cache, lengths, out_idx, out_buf, hist, emitted,
             calls, bad) = carry
            active = occupied & (emitted < remaining) & ~bad
            d = drafts_for(hist, lengths)
            feed = jnp.concatenate(
                [cur_tok[:, None], jnp.maximum(d, 0)], axis=1)
            logits, cache = T.decode_step(cfg, params, feed, cache,
                                          lengths, attn_step=attn)
            if guard:
                # any non-finite logit in the slot's span freezes the
                # whole verify step for that slot (emits nothing): a
                # poisoned draft chain must never be accepted
                logits = logits + poison[:, None, None]
                finite = jnp.all(jnp.isfinite(logits[..., :cfg.vocab]),
                                 axis=(1, 2))
                bad = bad | (active & ~finite)
                active = active & finite
            a = jnp.argmax(logits[..., :cfg.vocab],
                           axis=-1).astype(jnp.int32)         # (B, span)
            prefix = jnp.cumprod((d == a[:, :k]).astype(jnp.int32), axis=1)
            m = jnp.sum(prefix, axis=1)          # accepted drafts in [0, k]
            n_emit = jnp.where(active,
                               jnp.minimum(m + 1, remaining - emitted), 0)
            t = jnp.arange(span)
            take = t[None, :] < n_emit[:, None]
            oidx = jnp.where(take, out_idx[:, None] + t[None, :], max_seq)
            out_buf = out_buf.at[rows[:, None], oidx].set(a, mode="drop")
            hidx = jnp.where(take, (lengths + 1)[:, None] + t[None, :],
                             max_seq)
            hist = hist.at[rows[:, None], hidx].set(a, mode="drop")
            new_cur = a[rows, jnp.clip(n_emit - 1, 0, k)]
            cur_tok = jnp.where(active, new_cur, cur_tok)
            return (cur_tok, cache, lengths + n_emit, out_idx + n_emit,
                    out_buf, hist, emitted + n_emit,
                    calls + jnp.sum(active.astype(jnp.int32)), bad), None

        with ops.fused_ops(self.sc.fuse):
            carry = (cur_tok, cache, lengths, out_idx, out_buf, hist,
                     jnp.zeros(b, jnp.int32), jnp.int32(0),
                     jnp.zeros(b, bool))
            (cur_tok, cache, lengths, _, out_buf, hist, emitted,
             calls, bad), _ = jax.lax.scan(body, carry, jnp.arange(chunk))
        out = (cur_tok, cache, jnp.where(occupied, lengths, lengths_in),
               out_buf, hist, emitted, calls)
        if guard:
            return out + (bad.astype(jnp.int32),)
        return out

    def _decode_once(self, running: list[Request],
                     decode_chunk: int | None = None,
                     use_spec: int | None = None) -> None:
        decode_chunk = (self.sc.decode_chunk if decode_chunk is None
                        else decode_chunk)
        use_spec = self.spec if use_spec is None else use_spec
        guard = self.sc.nan_guard
        occupied = np.zeros(self.sc.max_batch, bool)
        remaining = np.zeros(self.sc.max_batch, np.int32)
        out_idx = np.zeros(self.sc.max_batch, np.int32)
        for r in running:
            occupied[r.slot] = True
            remaining[r.slot] = r.max_new_tokens - r.generated
            out_idx[r.slot] = r.generated
        # chunk is a static jit arg: snap the tail to the next power of
        # two so the decode scan compiles O(log decode_chunk) times, not
        # once per distinct remaining-budget value (masking keeps any
        # over-length steps result-invariant)
        chunk = 1 << (int(remaining.max()) - 1).bit_length()
        chunk = int(min(decode_chunk, chunk))
        if use_spec:
            # each verify call emits 1..spec+1 tokens; size the scan for
            # the token budget at full acceptance — zero acceptance just
            # spreads a slot's budget over more scheduler visits instead
            # of burning idle full-span model calls here
            iters = -(-chunk // (self.spec + 1))
            with self.obs.dram.scope(f"spec_decode[{iters}]"):
                (self._cur_tok, self.cache, self._lengths, self._out_buf,
                 self._hist, emitted, calls, *badv) = self._decode_spec(
                    self.params, self.cache, self._cur_tok,
                    self._block_tables, self._lengths,
                    jnp.asarray(occupied), jnp.asarray(remaining),
                    jnp.asarray(out_idx), self._out_buf, self._hist,
                    self._poison, chunk=iters)
            # the one per-step readback: how far each slot actually got
            emitted = np.asarray(emitted)
            bad = np.asarray(badv[0]).astype(bool) if guard else None
            for r in running:
                n = int(emitted[r.slot])
                r.generated += n
                self.last_step_tokens += n
                if guard and bad[r.slot]:
                    r.failed = True
                    self._m_nan_trips.inc()
            self._m_spec_calls.inc(int(calls))
            self._m_spec_tokens.inc(int(emitted.sum()))
            self._m_decode_tokens.inc(int(emitted.sum()))
            return
        with self.obs.dram.scope(f"decode[{chunk}]"):
            res = self._decode(
                self.params, self.cache, self._cur_tok, self._block_tables,
                self._lengths, jnp.asarray(occupied),
                jnp.asarray(remaining), jnp.asarray(out_idx),
                self._out_buf, self._next_key(), self._poison, chunk=chunk)
        if guard:
            (self._cur_tok, self.cache, self._lengths, self._out_buf,
             stats) = res
            stats = np.asarray(stats)   # the guard's per-chunk readback
            emitted, bad = stats[0], stats[1].astype(bool)
            for r in running:
                n = int(emitted[r.slot])
                r.generated += n
                self.last_step_tokens += n
                self._m_decode_tokens.inc(n)
                if bad[r.slot]:
                    r.failed = True
                    self._m_nan_trips.inc()
            return
        (self._cur_tok, self.cache, self._lengths, self._out_buf) = res
        for r in running:
            steps = min(chunk, r.max_new_tokens - r.generated)
            r.generated += steps
            self.last_step_tokens += steps
            self._m_decode_tokens.inc(steps)
