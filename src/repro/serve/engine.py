"""Batched serving engine: prefill + jitted decode loop with KV caches.

``DecodeEngine`` serves a batch of requests of (possibly) different prompt
lengths by left-padding to a common prefill length, then stepping the
jitted ``decode_step`` with greedy or temperature sampling.  Cache layout
(ring buffers for local attention, O(1) states for SSM/RG-LRU) comes from
``transformer.cache_defs`` — the decode working set is exactly the paper's
"buffer sized to the reuse window" idea applied to serving.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    temperature: float = 0.0   # 0 -> greedy
    seed: int = 0


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, sc: ServeConfig):
        self.cfg, self.params, self.sc = cfg, params, sc
        self._step = jax.jit(
            functools.partial(T.decode_step, cfg))
        self._prefill = jax.jit(
            functools.partial(T.prefill, cfg),
            static_argnames=("max_seq",))

    def generate(self, prompts: np.ndarray, n_tokens: int,
                 enc_embeds=None, prefix_embeds=None) -> np.ndarray:
        """prompts: (B, S0) int32 (right-aligned).  Returns (B, n_tokens)."""
        cfg, sc = self.cfg, self.sc
        b, s0 = prompts.shape
        extras = {}
        if enc_embeds is not None:
            extras["enc_embeds"] = enc_embeds
        if prefix_embeds is not None:
            extras["prefix_embeds"] = prefix_embeds
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      max_seq=sc.max_seq, **extras)
        pos = s0 + (cfg.prefix_tokens if prefix_embeds is not None else 0)
        rng = jax.random.PRNGKey(sc.seed)
        out = np.zeros((b, n_tokens), np.int32)
        tok = self._sample(logits, rng, 0)
        out[:, 0] = np.asarray(tok)
        for i in range(1, n_tokens):
            logits, cache = self._step(self.params, tok, cache,
                                       jnp.int32(pos))
            pos += 1
            tok = self._sample(logits, rng, i)
            out[:, i] = np.asarray(tok)
        return out

    def _sample(self, logits: jax.Array, rng: jax.Array,
                i: int) -> jax.Array:
        # mask padded-vocab tail
        logits = logits[:, :self.cfg.vocab]
        if self.sc.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        key = jax.random.fold_in(rng, i)
        return jax.random.categorical(
            key, logits / self.sc.temperature, axis=-1).astype(jnp.int32)
