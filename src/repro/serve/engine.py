"""Serving engines: static-batch baseline and paged continuous batching.

``DecodeEngine`` is the static-batch baseline: left-padded prefill, dense
per-slot KV caches, one jitted token loop.  Its decode loop is a
``lax.scan`` with device-side sampling — tokens accumulate on device and
transfer to the host once per call, not once per token.

``PagedEngine`` is the production path (docs/serving.md): a paged KV
cache whose page size comes from the analytical blocking model
(``tune`` op key ``"flash_decode"``), a decode-priority continuous-
batching scheduler, and three mechanisms that keep steady-state decode
from ever stalling:

* **chunked prefill** — prompts are cached ``prefill_chunk`` tokens at a
  time (a whole number of KV pages, sized by
  ``kv_cache.choose_prefill_chunk`` under the same VMEM budget as the
  page size) through the multi-position form of the flash-decode kernel,
  interleaved with decode steps instead of monopolizing one;
* **speculative decode** — an n-gram self-drafted draft-verify step
  scores ``spec_decode`` draft tokens plus the current token in ONE
  flash-decode call (the kernel's GQA grouping carries the multi-row q
  block) and accepts the longest greedy-matching prefix, so accepted
  tokens amortize the per-step host overhead;
* **persistent device state** — block tables and lengths live on device
  and are updated incrementally at admission/eviction instead of being
  rebuilt and re-uploaded every step.

With ``prefix_cache=True`` a radix tree over full-page token spans
(``kv_cache.PrefixCache``) is threaded through admission: a request
whose prompt prefix is cached shares the matched pages (refcount bump,
no allocation, no model call) and chunk-prefills only the O(new tokens)
tail from the matched boundary; an exact full-page match CoW-forks its
final page before re-running the last prompt token for the first-sample
logits.  Completed prefills register their full prompt pages back into
the tree, and admission under page pressure reclaims LRU tree leaves —
never a page a live request owns.

The decode step remains fully jitted — paged flash-decode attention,
device-side sampling, and an on-device output buffer read back only when
a request finishes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.obs import Obs
from repro.obs.trace import null_span
from repro.serve import kv_cache as KV
from repro.serve.scheduler import Request, Scheduler


def sample_tokens(cfg: ModelConfig, logits: jax.Array, temperature: float,
                  key: jax.Array) -> jax.Array:
    """Greedy (temperature <= 0) or categorical sampling; masks the
    padded-vocab tail.  logits: (B, V_padded) -> (B,) int32."""
    logits = logits[:, :cfg.vocab]
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1).astype(jnp.int32)


# ========================= static-batch baseline ===========================


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    temperature: float = 0.0   # 0 -> greedy
    seed: int = 0
    fuse: bool = False         # cross-op fused kernels (docs/fusion.md)


class DecodeEngine:
    """Static batch: every request prefills together (left-padded to a
    common length) and decodes in lock-step for a fixed token budget."""

    def __init__(self, cfg: ModelConfig, params: Any, sc: ServeConfig,
                 obs: Obs | None = None):
        self.cfg, self.params, self.sc = cfg, params, sc
        self.obs = obs if obs is not None else Obs()
        reg = self.obs.registry
        self._m_prefill_tokens = reg.counter("engine.prefill_tokens")
        self._m_decode_tokens = reg.counter("engine.decode_tokens")

        def prefill(*a, **kw):
            # the fusion flag is read at TRACE time; each engine owns its
            # jit wrappers, so the flag is pinned per instance
            with ops.fused_ops(sc.fuse):
                return T.prefill(cfg, *a, **kw)

        self._prefill = jax.jit(prefill, static_argnames=("max_seq",))
        self._gen = jax.jit(self._gen_fn, static_argnames=("n_tokens",))

    def generate(self, prompts: np.ndarray, n_tokens: int,
                 enc_embeds=None, prefix_embeds=None) -> np.ndarray:
        """prompts: (B, S0) int32 (right-aligned).  Returns (B, n_tokens)."""
        cfg, sc = self.cfg, self.sc
        b, s0 = prompts.shape
        extras = {}
        if enc_embeds is not None:
            extras["enc_embeds"] = enc_embeds
        if prefix_embeds is not None:
            extras["prefix_embeds"] = prefix_embeds
        tr = self.obs.tracer
        sp = tr.span if tr is not None else null_span
        with sp("prefill", cat="static"), \
                self.obs.dram.scope(f"static_prefill[{s0}]"):
            logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                          max_seq=sc.max_seq, **extras)
            if tr is not None:
                jax.block_until_ready(logits)
        pos = s0 + (cfg.prefix_tokens if prefix_embeds is not None else 0)
        rng = jax.random.PRNGKey(sc.seed)
        # the whole token loop runs on device (lax.scan, sampling
        # included) and transfers once — no per-token host sync
        with sp("decode", cat="static"), \
                self.obs.dram.scope(f"static_generate[{n_tokens}]"):
            out = self._gen(self.params, logits, cache, jnp.int32(pos), rng,
                            n_tokens=n_tokens)
            if tr is not None:
                jax.block_until_ready(out)
        with sp("readback", cat="static"):
            host = np.asarray(out)
        self._m_prefill_tokens.inc(b * s0)
        self._m_decode_tokens.inc(b * n_tokens)
        self.obs.dram.end_step(range(b))
        return host

    def _gen_fn(self, params, logits, cache, pos, rng, *, n_tokens: int):
        cfg, sc = self.cfg, self.sc
        tok0 = sample_tokens(cfg, logits, sc.temperature,
                             jax.random.fold_in(rng, 0))

        def body(carry, i):
            tok, cache, pos = carry
            logits, cache = T.decode_step(cfg, params, tok, cache, pos)
            t = sample_tokens(cfg, logits, sc.temperature,
                              jax.random.fold_in(rng, i))
            return (t, cache, pos + 1), t

        with ops.fused_ops(sc.fuse):
            (_, _, _), rest = jax.lax.scan(
                body, (tok0, cache, pos), jnp.arange(1, n_tokens))
        return jnp.concatenate([tok0[:, None], rest.T], axis=1)


# ======================== paged continuous batching ========================


@dataclasses.dataclass
class PagedServeConfig:
    max_seq: int = 1024            # per-request prompt + generation cap
    max_batch: int = 8             # decode batch slots
    page_size: int | None = None   # None -> tuned ("flash_decode" key)
    n_pages: int | None = None     # None -> max_batch full sequences + 1
    temperature: float = 0.0
    seed: int = 0
    fuse: bool = False             # cross-op fused kernels (docs/fusion.md)
    buckets: tuple[int, ...] | None = None   # prefill padding lengths
    decode_chunk: int = 8          # decode steps per scheduler visit
    prefill_chunk: int | None = None   # None -> auto-sized; 0 -> whole-
    #                                    prompt joins (legacy behavior)
    spec_decode: int = 0           # draft tokens per verify step (0 = off;
    #                                greedy only, attention-only stacks)
    prefix_cache: bool = False     # radix-tree prefix sharing across
    #                                requests (attention-only stacks with
    #                                chunked prefill; docs/serving.md)
    reuse_hint: float = 0.5        # expected prompt-reuse rate, used by
    #                                choose_page_size to price the
    #                                share-vs-stream page tradeoff when
    #                                the prefix cache is on
    age_limit: int = 8             # admission rounds before a waiting head
    #                                suspends backfill (anti-starvation)
    use_kernel: bool | None = None  # paged attention: None -> TPU only
    interpret: bool | None = None


def default_buckets(cfg: ModelConfig, max_seq: int) -> tuple[int, ...] | None:
    """Prefill length buckets: powers of two for pure-attention stacks
    (bounded recompilation; right-padding is safe because causal
    attention ignores the tail, and although the pad positions' K/V are
    scattered into the request's reserved pages, they stay masked by the
    length until decode overwrites each slot in order).  Recurrent/SSD
    mixers fold *every* position into their O(1) state, so right-padding
    would corrupt it — those prefill at exact lengths (None), one
    compile per distinct prompt length."""
    if all(p in ("global", "local") for p in cfg.layer_pattern):
        out, b = [], 8
        while b < max_seq:
            out.append(b)
            b *= 2
        out.append(max_seq)
        return tuple(sorted(set(out)))
    return None


class PagedEngine:
    """Request/response serving over the paged cache.

    ``submit()`` enqueues a prompt; ``step()`` runs one scheduler
    iteration and returns the requests that finished; ``generate()`` is
    the batch-convenience wrapper used by the examples and benchmarks.

    A step executes the scheduler's :class:`~repro.serve.scheduler.
    StepPlan` in decode-priority order: admission first (chunk-prefilled
    requests only reserve state; legacy joins prefill whole prompts),
    then ONE jitted decode chunk covering every decode-ready slot, then
    prefill chunks backfilling the leftover token budget, then eviction.
    A decode chunk is up to ``decode_chunk`` steps fused into one
    ``lax.scan`` — per-slot activity is masked inside the scan, so
    chunking changes scheduling granularity, never results.  With
    ``spec_decode=k`` each scan step is a draft-verify call that can
    emit up to ``k+1`` tokens (greedy semantics preserved exactly:
    tokens are accepted only while they match the argmax chain).

    Page reservations are made in full at admission, which is what makes
    block tables stable across a chunk; the tables themselves live on
    device and are updated incrementally at admission/eviction — steady-
    state decode re-uploads nothing.

    Chunked prefill and speculative decode need every mixer to be
    attention (the rglru/ssd state updates are strictly one-token);
    hybrid stacks silently fall back to whole-prompt joins and plain
    decode, keeping one engine API across all architectures.
    """

    def __init__(self, cfg: ModelConfig, params: Any, sc: PagedServeConfig,
                 obs: Obs | None = None):
        if cfg.is_encdec or cfg.prefix_tokens:
            raise NotImplementedError(
                "paged serving covers decoder-only token models")
        self.cfg, self.params, self.sc = cfg, params, sc
        self.obs = obs if obs is not None else Obs()
        has_attn = any(p in ("global", "local") for p in cfg.layer_pattern)
        attn_only = has_attn and all(
            p in ("global", "local") for p in cfg.layer_pattern)
        reuse = (sc.reuse_hint or None) if (sc.prefix_cache
                                            and attn_only) else None
        with self.obs.dram.scope("setup"):
            # page-size / chunk selection resolves the flash-decode
            # schedule once, here — attributed to "setup", not a step
            self.page_size = sc.page_size or (
                KV.choose_page_size(cfg, sc.max_seq, fused=sc.fuse,
                                    reuse_rate=reuse) if has_attn
                else min(sc.max_seq, 128))   # attention-free: pages unused
        self.max_blocks = KV.num_blocks(sc.max_seq, self.page_size)
        n_pages = sc.n_pages or sc.max_batch * self.max_blocks + 1
        self.cache = KV.init_paged_cache(cfg, sc.max_batch, n_pages,
                                         self.page_size)
        self.buckets = (sc.buckets if sc.buckets is not None
                        else default_buckets(cfg, sc.max_seq))

        # resolve the span-based features against the stack's capability
        if sc.prefill_chunk is None:
            self.prefill_chunk = (KV.choose_prefill_chunk(
                cfg, sc.max_seq, self.page_size) if attn_only else 0)
        elif sc.prefill_chunk and attn_only:
            # snap an explicit chunk to a whole number of pages
            self.prefill_chunk = min(
                sc.max_seq,
                KV.num_blocks(sc.prefill_chunk, self.page_size)
                * self.page_size)
        else:
            self.prefill_chunk = 0
        self.spec = int(sc.spec_decode or 0) if attn_only else 0
        if self.spec and sc.temperature > 0:
            raise ValueError(
                "spec_decode is greedy-only: draft acceptance compares "
                "against the argmax chain, which sampling would break")

        # prefix caching needs the span machinery to resume prefill at
        # the matched boundary, so it gates exactly like chunked prefill
        # (attention-only stacks; explicit prefill_chunk=0 turns it off)
        self.prefix_caching = bool(sc.prefix_cache) and attn_only \
            and self.prefill_chunk > 0
        reg = self.obs.registry
        allocator = KV.PageAllocator(n_pages, metrics=reg)
        self.prefix_cache = (KV.PrefixCache(allocator, self.page_size,
                                            metrics=reg)
                             if self.prefix_caching else None)
        self.scheduler = Scheduler(sc.max_batch, self.page_size,
                                   allocator, sc.max_seq,
                                   age_limit=sc.age_limit,
                                   prefix_cache=self.prefix_cache,
                                   metrics=reg)

        b = sc.max_batch
        self._block_tables = jnp.zeros((b, self.max_blocks), jnp.int32)
        self._lengths = jnp.zeros(b, jnp.int32)    # cached tokens per slot
        self._cur_tok = jnp.zeros(b, jnp.int32)
        self._out_buf = jnp.zeros((b, sc.max_seq), jnp.int32)
        self._hist = jnp.zeros((b, sc.max_seq), jnp.int32)  # prompt+tokens
        self._rng = jax.random.PRNGKey(sc.seed)
        self._step_count = 0
        self._next_rid = 0
        self._joins: dict[int, Any] = {}           # bucket -> jitted join
        self._chunk_fns: dict[int, Any] = {}       # span width -> chunk fn
        self._fork_fn: Any = None                  # jitted CoW page copy
        self._decode = jax.jit(self._decode_fn,
                               static_argnames=("chunk",))
        self._decode_spec = jax.jit(self._decode_spec_fn,
                                    static_argnames=("chunk",))
        self.last_step_tokens = 0                  # benchmark counter
        # registry-backed counters (spec_stats/prefix_stats are views)
        self._m_steps = reg.counter("engine.steps")
        self._m_step_us = reg.histogram("engine.step_us")
        self._m_decode_tokens = reg.counter("engine.decode_tokens")
        self._m_prefill_tokens = reg.counter("engine.prefill_tokens")
        self._m_spec_calls = reg.counter("spec.verify_calls")
        self._m_spec_tokens = reg.counter("spec.tokens")
        self._m_prefix_lookups = reg.counter("prefix_cache.lookups")
        self._m_prefix_hits = reg.counter("prefix_cache.hits")
        self._m_prefix_saved = reg.counter("prefix_cache.tokens_saved")

    # -- request API ----------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        """Enqueue one prompt; returns the request id."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        rid = self._next_rid
        self._next_rid += 1
        self.scheduler.submit(Request(rid, prompt, int(max_new_tokens)))
        return rid

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    def spec_stats(self) -> dict:
        """Draft-verify counters: total verify calls, tokens they
        emitted, and the mean accepted span (1.0 = plain decode).
        A thin view over the metrics registry (``spec.*``)."""
        calls, toks = self._m_spec_calls.value, self._m_spec_tokens.value
        return {"verify_calls": calls, "tokens": toks,
                "mean_accepted": toks / calls if calls else 0.0}

    def prefix_stats(self) -> dict:
        """Prefix-cache counters: admissions probed, admissions that
        matched, prompt tokens served from shared pages instead of
        being re-prefilled, and the tree's current page count.
        A thin view over the metrics registry (``prefix_cache.*``)."""
        lookups = self._m_prefix_lookups.value
        hits = self._m_prefix_hits.value
        return {"lookups": lookups, "hits": hits,
                "hit_rate": hits / lookups if lookups else 0.0,
                "tokens_saved": self._m_prefix_saved.value,
                "cached_pages": (len(self.prefix_cache)
                                 if self.prefix_cache is not None else 0)}

    def step(self) -> list[Request]:
        """One continuous-batching iteration; returns finished requests
        (with ``.output`` filled).

        With a tracer attached, the step and its phases (host prep,
        ``plan_step``, device dispatches, readback) emit Chrome-trace
        spans, and each device dispatch is fenced with
        ``block_until_ready`` so span durations mean device time.  With
        no tracer, ``sp`` is the shared no-op span and NO fence runs —
        the hot path stays async (guarded by ``tests/test_obs.py``).
        """
        t0 = time.perf_counter_ns()
        tr = self.obs.tracer
        sp = tr.span if tr is not None else null_span
        self.last_step_tokens = 0
        step_rids: set[int] = set()
        with sp("step", cat="engine", args={"step": self._step_count}):
            finished = self._step_inner(sp, tr, step_rids)
        self._m_steps.inc()
        self._m_step_us.observe((time.perf_counter_ns() - t0) / 1000.0)
        self.obs.dram.end_step(sorted(step_rids))
        return finished

    def _step_inner(self, sp, tr, step_rids: set[int]) -> list[Request]:
        with sp("host_prep", cat="engine"):
            for req in self.scheduler.admit():
                step_rids.add(req.rid)
                row = np.full(self.max_blocks, KV.SCRATCH_PAGE, np.int32)
                row[:len(req.pages)] = req.pages
                self._block_tables = self._block_tables.at[req.slot].set(
                    jnp.asarray(row))
                if self.prefix_caching:
                    self._m_prefix_lookups.inc()
                if req.cached_tokens:
                    # prefix hit: shared pages already hold the matched
                    # K/V; prefill resumes at the boundary through the
                    # chunk path, so only O(new tokens) run the model
                    self._m_prefix_hits.inc()
                    self._m_prefix_saved.inc(req.prefilled)
                    if req.cow_fork is not None:
                        src, dst = req.cow_fork
                        with sp("dispatch.fork", cat="device"), \
                                self.obs.dram.scope("cow_fork"):
                            self.cache = self._get_fork_fn()(
                                self.cache, jnp.int32(src), jnp.int32(dst))
                    # the spec-decode draft history must cover the cached
                    # prefix the chunk path will never feed
                    hist_row = np.zeros(self.sc.max_seq, np.int32)
                    L = min(req.prompt_len, self.sc.max_seq)
                    hist_row[:L] = req.prompt[:L]
                    self._hist = self._hist.at[req.slot].set(
                        jnp.asarray(hist_row))
                    # a tail that fits one chunk prefills inline, exactly
                    # where a miss would run its join — the hit request is
                    # decode-ready this very step instead of waiting a
                    # scheduling round (longer tails go through plan_step)
                    if req.prompt_len - req.prefilled <= self.prefill_chunk:
                        with sp("dispatch.prefill", cat="device"):
                            self._prefill_one_chunk(req)
                            if tr is not None:
                                jax.block_until_ready(self._cur_tok)
                    continue
                if (not self.prefill_chunk
                        or req.prompt_len <= self.prefill_chunk):
                    # whole-prompt join: chunking a prompt that fits in ONE
                    # chunk would pay the fixed-span chunk call (span =
                    # prefill_chunk, padded) where the bucketed join prices
                    # the prefill at the prompt's own pow2 bucket — chunked
                    # prefill only earns its keep on multi-chunk prompts
                    with sp("dispatch.join", cat="device"):
                        self._join(req)
                        if tr is not None:
                            jax.block_until_ready(self._cur_tok)
                    req.prefilled = req.prompt_len
                    self.scheduler.register_prefix(req)
                    self.last_step_tokens += 1     # the prefill token
        with sp("plan_step", cat="sched"):
            plan = self.scheduler.plan_step(self.sc.decode_chunk,
                                            self.prefill_chunk or 1)
        step_rids.update(self.scheduler.running[s].rid
                         for s in plan.decode_slots + plan.prefill_slots)
        # decode first: decode-ready slots are never stalled by prefill
        if plan.decode_slots:
            with sp("dispatch.decode", cat="device"):
                self._decode_once(
                    [self.scheduler.running[s] for s in plan.decode_slots])
                if tr is not None:
                    jax.block_until_ready(self._out_buf)
        for slot in plan.prefill_slots:
            with sp("dispatch.prefill", cat="device"):
                self._prefill_one_chunk(self.scheduler.running[slot])
                if tr is not None:
                    jax.block_until_ready(self._cur_tok)
        finished = []
        done_slots = [s for s, r in self.scheduler.running.items()
                      if r.done]
        if done_slots:
            # one host transfer covers every request finishing this step;
            # device state is NOT reset — the decode fns mask unoccupied
            # slots to scratch, and admission rewrites the row anyway
            with sp("readback", cat="engine"):
                host_out = np.asarray(self._out_buf)
            for slot in done_slots:
                req = self.scheduler.running[slot]
                req.output = host_out[slot, :req.generated].copy()
                finished.append(self.scheduler.evict(slot))
        return finished

    def generate(self, prompts, n_tokens: int) -> np.ndarray:
        """Batch convenience: submit all, run to completion, return
        (B, n_tokens) in submission order.  ``prompts`` may be a 2-D
        array or a list of 1-D arrays (ragged lengths welcome)."""
        rids = [self.submit(p, n_tokens) for p in prompts]
        done: dict[int, np.ndarray] = {}
        while self.has_work:
            for req in self.step():
                done[req.rid] = req.output
        return np.stack([done[r] for r in rids])

    # -- internals ------------------------------------------------------------

    def _bucket(self, length: int) -> int:
        if self.buckets is None:
            return length
        for b in self.buckets:
            if b >= length:
                return b
        return length

    def _next_key(self) -> jax.Array:
        self._step_count += 1
        return jax.random.fold_in(self._rng, self._step_count)

    def _join(self, req: Request) -> None:
        """Prefill an admitted request at its bucketed true length,
        scatter its KV into the reserved pages, sample its first token —
        all in one jitted call per bucket length."""
        slot, L = req.slot, req.prompt_len
        bucket = self._bucket(L)
        prompt = np.zeros((1, bucket), np.int32)
        prompt[0, :L] = req.prompt
        nb = KV.num_blocks(bucket, self.page_size)
        pages = np.full(nb, KV.SCRATCH_PAGE, np.int32)
        pages[:min(nb, len(req.pages))] = req.pages[:nb]
        # the scope tag carries the jit variant (one trace per bucket),
        # so resolution bytes x execution count attributes correctly
        with self.obs.dram.scope(f"join[{bucket}]"):
            (self.cache, self._lengths, self._cur_tok, self._out_buf,
             self._hist) = self._get_join(bucket)(
                self.params, self.cache, jnp.asarray(prompt),
                jnp.int32(L), jnp.int32(slot), jnp.asarray(pages),
                self._lengths, self._cur_tok, self._out_buf, self._hist,
                self._next_key())
        self._m_prefill_tokens.inc(L)
        req.generated = 1

    def _get_join(self, bucket: int):
        if bucket not in self._joins:
            cfg, sc = self.cfg, self.sc

            def join(params, cache, prompt, true_len, slot, pages,
                     lengths, cur_tok, out_buf, hist, key):
                with ops.fused_ops(sc.fuse):
                    logits, dense = T.prefill(cfg, params, prompt,
                                              max_seq=bucket, full_kv=True,
                                              logits_at=true_len - 1)
                cache = KV.write_prefill(cfg, cache, dense, slot, pages,
                                         self.page_size)
                tok = sample_tokens(cfg, logits, sc.temperature, key)[0]
                hist = jax.lax.dynamic_update_slice(
                    hist, prompt, (slot, jnp.int32(0)))
                hist = hist.at[slot, true_len].set(tok, mode="drop")
                return (cache, lengths.at[slot].set(true_len),
                        cur_tok.at[slot].set(tok),
                        out_buf.at[slot, 0].set(tok), hist)

            self._joins[bucket] = jax.jit(join)
        return self._joins[bucket]

    # -- prefix cache ---------------------------------------------------------

    def _get_fork_fn(self):
        """Jitted copy-on-write page copy: duplicate page ``src`` into
        ``dst`` across every attention layer's pools (prefix caching is
        gated to attention-only stacks, so every group pages)."""
        if self._fork_fn is None:
            def fork(cache, src, dst):
                def cp(pc, stacked):
                    if stacked:     # (n_groups, n_pages, page, hkv, hd)
                        return {k: pc[k].at[:, dst].set(pc[k][:, src])
                                for k in ("k_pages", "v_pages")}
                    return {k: pc[k].at[dst].set(pc[k][src])
                            for k in ("k_pages", "v_pages")}
                return {"layers": [cp(pc, True) for pc in cache["layers"]],
                        "tail": [cp(pc, False) for pc in cache["tail"]]}

            # donate the pools: the fork updates one page slice in
            # place instead of copying the whole cache
            self._fork_fn = jax.jit(fork, donate_argnums=(0,))
        return self._fork_fn

    # -- chunked prefill ------------------------------------------------------

    def _prefill_one_chunk(self, req: Request) -> None:
        """Advance one request's prefill by one chunk.

        The chunk runs as a batch-1 multi-token ``decode_step`` over the
        paged cache (``make_paged_span_step``): K/V for all chunk
        positions scatter into the reserved pages and one q-span
        flash-decode call attends each position to everything before it
        — identical math to whole-prompt prefill, paid ``prefill_chunk``
        tokens at a time.  The final chunk samples the first token
        exactly as a join would.
        """
        start, L = req.prefilled, req.prompt_len
        c_real = min(self.prefill_chunk, L - start)
        # span width = pow2 bucket of the real remainder, not the full
        # prefill_chunk: the final partial chunk of any prompt — and the
        # short unshared tail after a prefix-cache hit — pays for the
        # tokens it actually carries
        C = 1
        while C < c_real:
            C *= 2
        final = start + c_real >= L
        tokens = np.zeros((1, C), np.int32)
        tokens[0, :c_real] = req.prompt[start:start + c_real]
        take_at = (L - 1 - start) if final else -1
        with self.obs.dram.scope(f"prefill[{C}]"):
            (self.cache, self._lengths, self._cur_tok, self._out_buf,
             self._hist) = self._get_chunk_fn(C)(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.int32(start), self._block_tables,
                self._lengths, jnp.int32(req.slot),
                jnp.int32(start + c_real), jnp.int32(take_at),
                self._cur_tok, self._out_buf, self._hist, self._next_key())
        self._m_prefill_tokens.inc(c_real)
        req.prefilled = start + c_real
        if final:
            req.generated = 1
            self.scheduler.register_prefix(req)
            self.last_step_tokens += 1             # the prefill token

    def _get_chunk_fn(self, C: int):
        if C not in self._chunk_fns:
            cfg, sc = self.cfg, self.sc

            def chunk(params, cache, tokens, start, block_tables, lengths,
                      slot, new_len, take_at, cur_tok, out_buf, hist, key):
                bt_row = jax.lax.dynamic_slice_in_dim(block_tables,
                                                      slot, 1)
                with ops.fused_ops(sc.fuse):
                    attn = KV.make_paged_span_step(
                        cfg, bt_row, self.page_size, sc.max_seq,
                        sc.use_kernel, sc.interpret)
                    logits, cache = T.decode_step(
                        cfg, params, tokens, cache,
                        jnp.full((1,), start, jnp.int32), attn_step=attn)
                lengths = lengths.at[slot].set(new_len)
                idx = start + jnp.arange(C)
                hist = hist.at[slot, jnp.where(idx < sc.max_seq, idx,
                                               sc.max_seq)].set(
                    tokens[0], mode="drop")
                # final chunk: the prompt's last logits seed generation
                tok = sample_tokens(cfg,
                                    logits[:, jnp.clip(take_at, 0, C - 1)],
                                    sc.temperature, key)[0]
                is_final = take_at >= 0
                cur_tok = cur_tok.at[slot].set(
                    jnp.where(is_final, tok, cur_tok[slot]))
                out_buf = out_buf.at[slot, 0].set(
                    jnp.where(is_final, tok, out_buf[slot, 0]))
                hist = hist.at[slot, new_len].set(
                    jnp.where(is_final, tok, hist[slot, new_len]),
                    mode="drop")
                return cache, lengths, cur_tok, out_buf, hist

            self._chunk_fns[C] = jax.jit(chunk)
        return self._chunk_fns[C]

    # -- decode ---------------------------------------------------------------

    def _decode_fn(self, params, cache, cur_tok, block_tables, lengths,
                   occupied, remaining, out_idx, out_buf, key, *,
                   chunk: int):
        """``chunk`` fused decode steps (one device dispatch).

        ``remaining[b]`` is the slot's token budget at chunk start; step
        ``i`` is active for slot b iff ``occupied[b] and i <
        remaining[b]``.  Inactive slots freeze their length, token and
        output row, and their block-table rows / lengths are masked to
        scratch/0 *here, inside the jit* — so eviction never has to
        reset device state (a stale row is harmless) and freeing a
        request costs zero device dispatches."""
        cfg = self.cfg
        lengths_in = lengths
        block_tables = jnp.where(occupied[:, None], block_tables,
                                 KV.SCRATCH_PAGE)
        lengths = jnp.where(occupied, lengths, 0)
        attn = KV.make_paged_attn_step(cfg, block_tables, self.page_size,
                                       self.sc.use_kernel,
                                       self.sc.interpret,
                                       fused=self.sc.fuse)
        rows = jnp.arange(cur_tok.shape[0])

        def body(carry, i):
            cur_tok, cache, lengths, out_idx, out_buf = carry
            active = occupied & (i < remaining)
            logits, cache = T.decode_step(cfg, params, cur_tok, cache,
                                          lengths, attn_step=attn)
            tok = sample_tokens(cfg, logits, self.sc.temperature,
                                jax.random.fold_in(key, i))
            tok = jnp.where(active, tok, cur_tok)
            keep = out_buf[rows, out_idx]
            out_buf = out_buf.at[rows, out_idx].set(
                jnp.where(active, tok, keep))
            out_idx = jnp.where(active, out_idx + 1, out_idx)
            lengths = jnp.where(active, lengths + 1, lengths)
            return (tok, cache, lengths, out_idx, out_buf), None

        with ops.fused_ops(self.sc.fuse):
            (cur_tok, cache, lengths, _, out_buf), _ = jax.lax.scan(
                body, (cur_tok, cache, lengths, out_idx, out_buf),
                jnp.arange(chunk))
        # restore masked-out lengths (a still-prefilling slot keeps its)
        return (cur_tok, cache,
                jnp.where(occupied, lengths, lengths_in), out_buf)

    def _decode_spec_fn(self, params, cache, cur_tok, block_tables,
                        lengths, occupied, remaining, out_idx, out_buf,
                        hist, *, chunk: int):
        """``chunk`` draft-verify steps (one device dispatch).

        Each step drafts ``k = spec_decode`` tokens by n-gram lookup
        over the slot's own history (prompt-lookup decoding: the latest
        earlier occurrence of the trailing 2-gram proposes its
        continuation; no match drafts -1, which can never be accepted),
        scores current + drafts in ONE span decode_step, and accepts the
        longest prefix matching the greedy argmax chain — so emitted
        tokens are bit-identical to plain greedy decode, just cheaper
        per token.  Draft rows past the accepted prefix leave garbage
        K/V above the new length; the next span overwrites every such
        position before the length mask can expose it.

        ``remaining`` bounds *emitted tokens*, not steps; a step that
        would overshoot the budget truncates its accepted span.  Returns
        per-slot emitted counts and the active-call total for the
        acceptance stats.
        """
        cfg = self.cfg
        k = self.spec
        span = k + 1
        max_seq = self.sc.max_seq
        b = cur_tok.shape[0]
        rows = jnp.arange(b)
        # inactive slots (free, evicted-stale, or still prefilling) are
        # masked to scratch here so eviction never resets device state
        lengths_in = lengths
        block_tables = jnp.where(occupied[:, None], block_tables,
                                 KV.SCRATCH_PAGE)
        lengths = jnp.where(occupied, lengths, 0)
        attn = KV.make_paged_span_step(cfg, block_tables, self.page_size,
                                       max_seq, self.sc.use_kernel,
                                       self.sc.interpret)

        def drafts_for(hist, lengths):
            hl = lengths + 1                     # tokens in hist per slot
            last = hist[rows, jnp.clip(hl - 1, 0, max_seq - 1)]
            prev = hist[rows, jnp.clip(hl - 2, 0, max_seq - 1)]
            m2 = ((hist[:, 1:] == last[:, None])
                  & (hist[:, :-1] == prev[:, None]))
            p = jnp.arange(1, max_seq)
            m2 &= p[None, :] < (hl - 1)[:, None]     # strictly earlier
            j = jnp.max(jnp.where(m2, p[None, :], -1), axis=1)
            gidx = j[:, None] + 1 + jnp.arange(k)[None, :]
            valid = (j >= 0)[:, None] & (gidx < hl[:, None])
            d = hist[rows[:, None], jnp.clip(gidx, 0, max_seq - 1)]
            return jnp.where(valid, d, -1)

        def body(carry, i):
            (cur_tok, cache, lengths, out_idx, out_buf, hist, emitted,
             calls) = carry
            active = occupied & (emitted < remaining)
            d = drafts_for(hist, lengths)
            feed = jnp.concatenate(
                [cur_tok[:, None], jnp.maximum(d, 0)], axis=1)
            logits, cache = T.decode_step(cfg, params, feed, cache,
                                          lengths, attn_step=attn)
            a = jnp.argmax(logits[..., :cfg.vocab],
                           axis=-1).astype(jnp.int32)         # (B, span)
            prefix = jnp.cumprod((d == a[:, :k]).astype(jnp.int32), axis=1)
            m = jnp.sum(prefix, axis=1)          # accepted drafts in [0, k]
            n_emit = jnp.where(active,
                               jnp.minimum(m + 1, remaining - emitted), 0)
            t = jnp.arange(span)
            take = t[None, :] < n_emit[:, None]
            oidx = jnp.where(take, out_idx[:, None] + t[None, :], max_seq)
            out_buf = out_buf.at[rows[:, None], oidx].set(a, mode="drop")
            hidx = jnp.where(take, (lengths + 1)[:, None] + t[None, :],
                             max_seq)
            hist = hist.at[rows[:, None], hidx].set(a, mode="drop")
            new_cur = a[rows, jnp.clip(n_emit - 1, 0, k)]
            cur_tok = jnp.where(active, new_cur, cur_tok)
            return (cur_tok, cache, lengths + n_emit, out_idx + n_emit,
                    out_buf, hist, emitted + n_emit,
                    calls + jnp.sum(active.astype(jnp.int32))), None

        with ops.fused_ops(self.sc.fuse):
            carry = (cur_tok, cache, lengths, out_idx, out_buf, hist,
                     jnp.zeros(b, jnp.int32), jnp.int32(0))
            (cur_tok, cache, lengths, _, out_buf, hist, emitted,
             calls), _ = jax.lax.scan(body, carry, jnp.arange(chunk))
        return (cur_tok, cache, jnp.where(occupied, lengths, lengths_in),
                out_buf, hist, emitted, calls)

    def _decode_once(self, running: list[Request]) -> None:
        occupied = np.zeros(self.sc.max_batch, bool)
        remaining = np.zeros(self.sc.max_batch, np.int32)
        out_idx = np.zeros(self.sc.max_batch, np.int32)
        for r in running:
            occupied[r.slot] = True
            remaining[r.slot] = r.max_new_tokens - r.generated
            out_idx[r.slot] = r.generated
        # chunk is a static jit arg: snap the tail to the next power of
        # two so the decode scan compiles O(log decode_chunk) times, not
        # once per distinct remaining-budget value (masking keeps any
        # over-length steps result-invariant)
        chunk = 1 << (int(remaining.max()) - 1).bit_length()
        chunk = int(min(self.sc.decode_chunk, chunk))
        if self.spec:
            # each verify call emits 1..spec+1 tokens; size the scan for
            # the token budget at full acceptance — zero acceptance just
            # spreads a slot's budget over more scheduler visits instead
            # of burning idle full-span model calls here
            iters = -(-chunk // (self.spec + 1))
            with self.obs.dram.scope(f"spec_decode[{iters}]"):
                (self._cur_tok, self.cache, self._lengths, self._out_buf,
                 self._hist, emitted, calls) = self._decode_spec(
                    self.params, self.cache, self._cur_tok,
                    self._block_tables, self._lengths,
                    jnp.asarray(occupied), jnp.asarray(remaining),
                    jnp.asarray(out_idx), self._out_buf, self._hist,
                    chunk=iters)
            # the one per-step readback: how far each slot actually got
            emitted = np.asarray(emitted)
            for r in running:
                n = int(emitted[r.slot])
                r.generated += n
                self.last_step_tokens += n
            self._m_spec_calls.inc(int(calls))
            self._m_spec_tokens.inc(int(emitted.sum()))
            self._m_decode_tokens.inc(int(emitted.sum()))
            return
        with self.obs.dram.scope(f"decode[{chunk}]"):
            (self._cur_tok, self.cache, self._lengths,
             self._out_buf) = self._decode(
                self.params, self.cache, self._cur_tok, self._block_tables,
                self._lengths, jnp.asarray(occupied),
                jnp.asarray(remaining), jnp.asarray(out_idx),
                self._out_buf, self._next_key(), chunk=chunk)
        for r in running:
            steps = min(chunk, r.max_new_tokens - r.generated)
            r.generated += steps
            self.last_step_tokens += steps
            self._m_decode_tokens.inc(steps)
