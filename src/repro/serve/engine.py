"""Serving engines: static-batch baseline and paged continuous batching.

``DecodeEngine`` is the static-batch baseline: left-padded prefill, dense
per-slot KV caches, one jitted token loop.  Its decode loop is a
``lax.scan`` with device-side sampling — tokens accumulate on device and
transfer to the host once per call, not once per token.

``PagedEngine`` is the production path (docs/serving.md): a paged KV
cache whose page size comes from the analytical blocking model
(``tune`` op key ``"flash_decode"``), bucketed true-length prefill, and
a continuous-batching scheduler that joins new prefills into the running
decode batch each step and evicts finished requests.  The decode step is
fully jitted — paged flash-decode attention, device-side sampling, and
an on-device output buffer read back only when a request finishes.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serve import kv_cache as KV
from repro.serve.scheduler import Request, Scheduler


def sample_tokens(cfg: ModelConfig, logits: jax.Array, temperature: float,
                  key: jax.Array) -> jax.Array:
    """Greedy (temperature <= 0) or categorical sampling; masks the
    padded-vocab tail.  logits: (B, V_padded) -> (B,) int32."""
    logits = logits[:, :cfg.vocab]
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1).astype(jnp.int32)


# ========================= static-batch baseline ===========================


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    temperature: float = 0.0   # 0 -> greedy
    seed: int = 0
    fuse: bool = False         # cross-op fused kernels (docs/fusion.md)


class DecodeEngine:
    """Static batch: every request prefills together (left-padded to a
    common length) and decodes in lock-step for a fixed token budget."""

    def __init__(self, cfg: ModelConfig, params: Any, sc: ServeConfig):
        self.cfg, self.params, self.sc = cfg, params, sc

        def prefill(*a, **kw):
            # the fusion flag is read at TRACE time; each engine owns its
            # jit wrappers, so the flag is pinned per instance
            with ops.fused_ops(sc.fuse):
                return T.prefill(cfg, *a, **kw)

        self._prefill = jax.jit(prefill, static_argnames=("max_seq",))
        self._gen = jax.jit(self._gen_fn, static_argnames=("n_tokens",))

    def generate(self, prompts: np.ndarray, n_tokens: int,
                 enc_embeds=None, prefix_embeds=None) -> np.ndarray:
        """prompts: (B, S0) int32 (right-aligned).  Returns (B, n_tokens)."""
        cfg, sc = self.cfg, self.sc
        _, s0 = prompts.shape
        extras = {}
        if enc_embeds is not None:
            extras["enc_embeds"] = enc_embeds
        if prefix_embeds is not None:
            extras["prefix_embeds"] = prefix_embeds
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      max_seq=sc.max_seq, **extras)
        pos = s0 + (cfg.prefix_tokens if prefix_embeds is not None else 0)
        rng = jax.random.PRNGKey(sc.seed)
        # the whole token loop runs on device (lax.scan, sampling
        # included) and transfers once — no per-token host sync
        out = self._gen(self.params, logits, cache, jnp.int32(pos), rng,
                        n_tokens=n_tokens)
        return np.asarray(out)

    def _gen_fn(self, params, logits, cache, pos, rng, *, n_tokens: int):
        cfg, sc = self.cfg, self.sc
        tok0 = sample_tokens(cfg, logits, sc.temperature,
                             jax.random.fold_in(rng, 0))

        def body(carry, i):
            tok, cache, pos = carry
            logits, cache = T.decode_step(cfg, params, tok, cache, pos)
            t = sample_tokens(cfg, logits, sc.temperature,
                              jax.random.fold_in(rng, i))
            return (t, cache, pos + 1), t

        with ops.fused_ops(sc.fuse):
            (_, _, _), rest = jax.lax.scan(
                body, (tok0, cache, pos), jnp.arange(1, n_tokens))
        return jnp.concatenate([tok0[:, None], rest.T], axis=1)


# ======================== paged continuous batching ========================


@dataclasses.dataclass
class PagedServeConfig:
    max_seq: int = 1024            # per-request prompt + generation cap
    max_batch: int = 8             # decode batch slots
    page_size: int | None = None   # None -> tuned ("flash_decode" key)
    n_pages: int | None = None     # None -> max_batch full sequences + 1
    temperature: float = 0.0
    seed: int = 0
    fuse: bool = False             # cross-op fused kernels (docs/fusion.md)
    buckets: tuple[int, ...] | None = None   # prefill padding lengths
    decode_chunk: int = 8          # decode steps per scheduler visit
    use_kernel: bool | None = None  # paged attention: None -> TPU only
    interpret: bool | None = None


def default_buckets(cfg: ModelConfig, max_seq: int) -> tuple[int, ...] | None:
    """Prefill length buckets: powers of two for pure-attention stacks
    (bounded recompilation; right-padding is safe because causal
    attention ignores the tail, and although the pad positions' K/V are
    scattered into the request's reserved pages, they stay masked by the
    length until decode overwrites each slot in order).  Recurrent/SSD
    mixers fold *every* position into their O(1) state, so right-padding
    would corrupt it — those prefill at exact lengths (None), one
    compile per distinct prompt length."""
    if all(p in ("global", "local") for p in cfg.layer_pattern):
        out, b = [], 8
        while b < max_seq:
            out.append(b)
            b *= 2
        out.append(max_seq)
        return tuple(sorted(set(out)))
    return None


class PagedEngine:
    """Request/response serving over the paged cache.

    ``submit()`` enqueues a prompt; ``step()`` runs one scheduler
    iteration (admit + prefill joins, one jitted *decode chunk*,
    evictions) and returns the requests that finished; ``generate()`` is
    the batch-convenience wrapper used by the examples and benchmarks.

    A decode chunk is up to ``decode_chunk`` token steps fused into one
    ``lax.scan`` — the scheduler's quantum.  Per-slot activity is masked
    inside the scan (a slot that exhausts its budget mid-chunk keeps its
    length frozen and its output buffer untouched), so chunking changes
    scheduling granularity, never results.  Page reservations are made
    in full at admission, which is what makes block tables stable across
    a chunk.
    """

    def __init__(self, cfg: ModelConfig, params: Any, sc: PagedServeConfig):
        if cfg.is_encdec or cfg.prefix_tokens:
            raise NotImplementedError(
                "paged serving covers decoder-only token models")
        self.cfg, self.params, self.sc = cfg, params, sc
        has_attn = any(p in ("global", "local") for p in cfg.layer_pattern)
        self.page_size = sc.page_size or (
            KV.choose_page_size(cfg, sc.max_seq, fused=sc.fuse) if has_attn
            else min(sc.max_seq, 128))   # attention-free: pages unused
        self.max_blocks = KV.num_blocks(sc.max_seq, self.page_size)
        n_pages = sc.n_pages or sc.max_batch * self.max_blocks + 1
        self.cache = KV.init_paged_cache(cfg, sc.max_batch, n_pages,
                                         self.page_size)
        self.scheduler = Scheduler(sc.max_batch, self.page_size,
                                   KV.PageAllocator(n_pages), sc.max_seq)
        self.buckets = (sc.buckets if sc.buckets is not None
                        else default_buckets(cfg, sc.max_seq))

        b = sc.max_batch
        self._block_tables = np.zeros((b, self.max_blocks), np.int32)
        self._lengths = np.zeros(b, np.int32)      # cached tokens per slot
        self._cur_tok = jnp.zeros(b, jnp.int32)
        self._out_buf = jnp.zeros((b, sc.max_seq), jnp.int32)
        self._rng = jax.random.PRNGKey(sc.seed)
        self._step_count = 0
        self._next_rid = 0
        self._joins: dict[int, Any] = {}           # bucket -> jitted join
        self._decode = jax.jit(self._decode_fn,
                               static_argnames=("chunk",))
        self.last_step_tokens = 0                  # benchmark counter

    # -- request API ----------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        """Enqueue one prompt; returns the request id."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        rid = self._next_rid
        self._next_rid += 1
        self.scheduler.submit(Request(rid, prompt, int(max_new_tokens)))
        return rid

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    def step(self) -> list[Request]:
        """One continuous-batching iteration; returns finished requests
        (with ``.output`` filled)."""
        self.last_step_tokens = 0
        for req in self.scheduler.admit():
            self._join(req)
            self.last_step_tokens += 1             # the prefill token
        running = [r for r in self.scheduler.running.values()
                   if not r.done]
        if running:
            self._decode_once(running)
        finished = []
        done_slots = [s for s, r in self.scheduler.running.items()
                      if r.done]
        if done_slots:
            # copy-on-write (see _join): one fresh buffer per step
            self._block_tables = self._block_tables.copy()
            self._lengths = self._lengths.copy()
        for slot in done_slots:
            req = self.scheduler.running[slot]
            # the single host transfer for this request's tokens
            req.output = np.asarray(
                self._out_buf[slot, :req.generated])
            self._block_tables[slot] = KV.SCRATCH_PAGE
            self._lengths[slot] = 0
            finished.append(self.scheduler.evict(slot))
        return finished

    def generate(self, prompts, n_tokens: int) -> np.ndarray:
        """Batch convenience: submit all, run to completion, return
        (B, n_tokens) in submission order.  ``prompts`` may be a 2-D
        array or a list of 1-D arrays (ragged lengths welcome)."""
        rids = [self.submit(p, n_tokens) for p in prompts]
        done: dict[int, np.ndarray] = {}
        while self.has_work:
            for req in self.step():
                done[req.rid] = req.output
        return np.stack([done[r] for r in rids])

    # -- internals ------------------------------------------------------------

    def _bucket(self, length: int) -> int:
        if self.buckets is None:
            return length
        for b in self.buckets:
            if b >= length:
                return b
        return length

    def _next_key(self) -> jax.Array:
        self._step_count += 1
        return jax.random.fold_in(self._rng, self._step_count)

    def _join(self, req: Request) -> None:
        """Prefill an admitted request at its bucketed true length,
        scatter its KV into the reserved pages, sample its first token —
        all in one jitted call per bucket length."""
        slot, L = req.slot, req.prompt_len
        bucket = self._bucket(L)
        row = np.full(self.max_blocks, KV.SCRATCH_PAGE, np.int32)
        row[:len(req.pages)] = req.pages
        # copy-on-write: asynchronously dispatched device computations may
        # hold zero-copy views of the old host arrays (CPU jax aliases
        # numpy buffers) — never mutate them in place
        self._block_tables = self._block_tables.copy()
        self._block_tables[slot] = row
        self._lengths = self._lengths.copy()
        self._lengths[slot] = L

        prompt = np.zeros((1, bucket), np.int32)
        prompt[0, :L] = req.prompt
        nb = KV.num_blocks(bucket, self.page_size)
        pages = np.full(nb, KV.SCRATCH_PAGE, np.int32)
        pages[:min(nb, len(req.pages))] = req.pages[:nb]
        self.cache, self._cur_tok, self._out_buf = self._get_join(bucket)(
            self.params, self.cache, jnp.asarray(prompt),
            jnp.int32(L), jnp.int32(slot), jnp.asarray(pages),
            self._cur_tok, self._out_buf, self._next_key())
        req.generated = 1

    def _get_join(self, bucket: int):
        if bucket not in self._joins:
            cfg, sc = self.cfg, self.sc

            def join(params, cache, prompt, true_len, slot, pages,
                     cur_tok, out_buf, key):
                with ops.fused_ops(sc.fuse):
                    logits, dense = T.prefill(cfg, params, prompt,
                                              max_seq=bucket, full_kv=True,
                                              logits_at=true_len - 1)
                cache = KV.write_prefill(cfg, cache, dense, slot, pages,
                                         self.page_size)
                tok = sample_tokens(cfg, logits, sc.temperature, key)[0]
                return (cache, cur_tok.at[slot].set(tok),
                        out_buf.at[slot, 0].set(tok))

            self._joins[bucket] = jax.jit(join)
        return self._joins[bucket]

    def _decode_fn(self, params, cache, cur_tok, block_tables, lengths,
                   occupied, remaining, out_idx, out_buf, key, *,
                   chunk: int):
        """``chunk`` fused decode steps (one device dispatch).

        ``remaining[b]`` is the slot's token budget at chunk start; step
        ``i`` is active for slot b iff ``occupied[b] and i <
        remaining[b]``.  Inactive slots freeze their length, token and
        output row (their masked pool writes land in their own reserved
        pages or the scratch page — never in another request's)."""
        cfg = self.cfg
        attn = KV.make_paged_attn_step(cfg, block_tables, self.page_size,
                                       self.sc.use_kernel,
                                       self.sc.interpret,
                                       fused=self.sc.fuse)
        rows = jnp.arange(cur_tok.shape[0])

        def body(carry, i):
            cur_tok, cache, lengths, out_idx, out_buf = carry
            active = occupied & (i < remaining)
            logits, cache = T.decode_step(cfg, params, cur_tok, cache,
                                          lengths, attn_step=attn)
            tok = sample_tokens(cfg, logits, self.sc.temperature,
                                jax.random.fold_in(key, i))
            tok = jnp.where(active, tok, cur_tok)
            keep = out_buf[rows, out_idx]
            out_buf = out_buf.at[rows, out_idx].set(
                jnp.where(active, tok, keep))
            out_idx = jnp.where(active, out_idx + 1, out_idx)
            lengths = jnp.where(active, lengths + 1, lengths)
            return (tok, cache, lengths, out_idx, out_buf), None

        with ops.fused_ops(self.sc.fuse):
            (cur_tok, cache, _, _, out_buf), _ = jax.lax.scan(
                body, (cur_tok, cache, lengths, out_idx, out_buf),
                jnp.arange(chunk))
        return cur_tok, cache, out_buf

    def _decode_once(self, running: list[Request]) -> None:
        occupied = np.zeros(self.sc.max_batch, bool)
        remaining = np.zeros(self.sc.max_batch, np.int32)
        out_idx = np.zeros(self.sc.max_batch, np.int32)
        for r in running:
            occupied[r.slot] = True
            remaining[r.slot] = r.max_new_tokens - r.generated
            out_idx[r.slot] = r.generated
        # chunk is a static jit arg: snap the tail to the next power of
        # two so the decode scan compiles O(log decode_chunk) times, not
        # once per distinct remaining-budget value (masking keeps any
        # over-length steps result-invariant)
        chunk = 1 << (int(remaining.max()) - 1).bit_length()
        chunk = int(min(self.sc.decode_chunk, chunk))
        self._cur_tok, self.cache, self._out_buf = self._decode(
            self.params, self.cache, self._cur_tok,
            jnp.asarray(self._block_tables), jnp.asarray(self._lengths),
            jnp.asarray(occupied), jnp.asarray(remaining),
            jnp.asarray(out_idx), self._out_buf, self._next_key(),
            chunk=chunk)
        # copy-on-write (see _join): the chunk just dispatched may hold a
        # zero-copy view of the old _lengths buffer; replace, don't mutate
        self._lengths = self._lengths.copy()
        for r in running:
            steps = min(chunk, r.max_new_tokens - r.generated)
            r.generated += steps
            self._lengths[r.slot] += steps
            self.last_step_tokens += steps
