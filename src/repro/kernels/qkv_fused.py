"""Fused QKV projection Pallas kernel: one activation pass, three heads.

The unfused attention front-end runs three GEMMs — ``x @ wq``,
``x @ wk``, ``x @ wv`` — each streaming the SAME activation matrix from
HBM.  This kernel shares one A tile per grid step across all three
weight streams, so the activation crosses the HBM boundary once instead
of three times (the ``core.fusion`` input-sharing edge: the three nests
share their input operand, and blocking them jointly makes two of the
three fetches free).

GQA layout: ``wq`` is (K, G*Nkv) and ``wk``/``wv`` are (K, Nkv) with
G = Hq/Hkv; the grid blocks the per-projection width Nkv, and each
grid step produces a (bm, G*bn) q block next to (bm, bn) k/v blocks —
so one (bm, bk) A tile feeds (G+2)*bn output columns.  Tiles come from
the ``"qkv_fused"`` tune key (dims ``(M, Nkv, K, G)``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def vmem_bytes_required(bm: int, bk: int, bn: int, groups: int,
                        bytes_per_elem: int = 2) -> int:
    """VMEM footprint of one grid step of :func:`qkv_fused`: one
    streamed A tile, (G+2)*bn streamed weight columns, and (G+2)*bn
    resident output columns with fp32 accumulators.  Single source of
    truth for the ``"qkv_fused"`` schedule-candidate filter."""
    cols = (groups + 2) * bn
    streamed = 2 * (bm * bk + bk * cols) * bytes_per_elem
    resident = bm * cols * (bytes_per_elem + 4)
    return streamed + resident


def hbm_bytes(M: int, Nkv: int, K: int, groups: int,
              bm: int, bk: int, bn: int,
              bytes_per_elem: int = 2) -> int:
    """Exact HBM traffic of one :func:`qkv_fused` call (the grid's
    actual block transfers under DMA elision; see
    ``matmul_blocked.hbm_bytes``).  The unfused baseline is three GEMM
    calls, each re-streaming A."""
    gm, gn, gk = M // bm, Nkv // bn, K // bk
    cols = (groups + 2) * Nkv
    # A: once per j sweep, elided to once total when gk == 1
    total = M * K * bytes_per_elem * (gn if gk > 1 else 1)
    # all three weight streams: per i-row unless a single (j, k) block
    total += K * cols * bytes_per_elem * (gm if (gk > 1 or gn > 1) else 1)
    total += M * cols * bytes_per_elem           # q, k, v written once
    return total


def _qkv_kernel(x_ref, wq_ref, wk_ref, wv_ref, q_ref, k_ref, v_ref,
                accq_ref, acck_ref, accv_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        accq_ref[...] = jnp.zeros_like(accq_ref)
        acck_ref[...] = jnp.zeros_like(acck_ref)
        accv_ref[...] = jnp.zeros_like(accv_ref)

    x = x_ref[...]                               # ONE tile, three uses
    accq_ref[...] += jnp.dot(x, wq_ref[...],
                             preferred_element_type=jnp.float32)
    acck_ref[...] += jnp.dot(x, wk_ref[...],
                             preferred_element_type=jnp.float32)
    accv_ref[...] += jnp.dot(x, wv_ref[...],
                             preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        q_ref[...] = accq_ref[...].astype(q_ref.dtype)
        k_ref[...] = acck_ref[...].astype(k_ref.dtype)
        v_ref[...] = accv_ref[...].astype(v_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn",
                                             "interpret"))
def qkv_fused(x: jax.Array, wq: jax.Array, wk: jax.Array, wv: jax.Array,
              *, bm: int, bk: int, bn: int,
              interpret: bool = False) -> tuple[jax.Array, jax.Array,
                                                jax.Array]:
    """(x@wq, x@wk, x@wv) in one weight-stationary pass.

    x: (M, K); wq: (K, G*Nkv); wk, wv: (K, Nkv).  ``bn`` blocks the
    per-projection width Nkv (the q block is G*bn wide).  Dims must
    divide; ragged shapes take the three-GEMM fallback in
    ``kernels.ops``.
    """
    m, k = x.shape
    _, nq = wq.shape
    _, nkv = wk.shape
    assert wv.shape == wk.shape, (wv.shape, wk.shape)
    assert wq.shape[0] == k and wk.shape[0] == k, (wq.shape, wk.shape)
    assert nq % nkv == 0, (nq, nkv)
    g = nq // nkv
    assert m % bm == 0 and k % bk == 0 and nkv % bn == 0, \
        f"tiles ({bm},{bk},{bn}) must divide ({m},{k},{nkv})"
    grid = (m // bm, nkv // bn, k // bk)
    q, kk, v = pl.pallas_call(
        functools.partial(_qkv_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, r: (i, r)),
            pl.BlockSpec((bk, g * bn), lambda i, j, r: (r, j)),
            pl.BlockSpec((bk, bn), lambda i, j, r: (r, j)),
            pl.BlockSpec((bk, bn), lambda i, j, r: (r, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, g * bn), lambda i, j, r: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, r: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, r: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, nq), x.dtype),
            jax.ShapeDtypeStruct((m, nkv), x.dtype),
            jax.ShapeDtypeStruct((m, nkv), x.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, g * bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        interpret=interpret,
    )(x, wq, wk, wv)
    return q, kk, v


def qkv_fused_ref(x: jax.Array, wq: jax.Array, wk: jax.Array,
                  wv: jax.Array) -> tuple[jax.Array, jax.Array,
                                          jax.Array]:
    """jnp oracle (and the unfused chain it replaces): three dots with
    fp32 accumulation, bit-comparable to the kernel."""
    def one(w):
        return jnp.dot(x, w,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    return one(wq), one(wk), one(wv)
