"""Streaming-softmax (flash) attention Pallas kernel.

In the paper's vocabulary (DESIGN.md §4): the K/V tiles are the kernel
buffer KB (reused by every query block), the running (m, l, acc) statistics
are the output buffer OB held VMEM-resident across the KV reduction loop,
and block_q/block_kv come from the blocking model (``flash_tiles``).

Supports causal masking, sliding-window (local) attention and Gemma-2
logit soft-capping.  q: (Sq, D), k/v: (Skv, D); heads/batch are vmapped in
ops.py.  ``kv_offset = Skv - Sq`` aligns decode queries to cache tail.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
BIG = 1e30  # lse sentinel for fully-masked rows: exp(s - BIG) == 0


def hbm_bytes(seq_q: int, seq_kv: int, head_dim: int,
              block_q: int, block_kv: int, bytes_per_elem: int = 2,
              with_lse: bool = False) -> int:
    """Exact HBM traffic of one head through :func:`_flash_forward`.

    Grid (Sq/bq, Skv/bkv), KV minor-most: the q and output blocks are
    (qi, 0)-indexed (once per q-row); the K/V blocks stream per q-row —
    elided to a single pass when the KV extent is one block.  The score
    matrix never exists in HBM (that is the point of the kernel);
    ``with_lse`` adds the per-row fp32 residual the backward saves.
    """
    gq, gkv = seq_q // block_q, seq_kv // block_kv
    q = seq_q * head_dim * bytes_per_elem
    kv = 2 * seq_kv * head_dim * bytes_per_elem * (gq if gkv > 1 else 1)
    out = seq_q * head_dim * bytes_per_elem
    lse = seq_q * 4 if with_lse else 0
    return q + kv + out + lse


def attention_mask(qi, ki, *, block_q: int, block_kv: int, causal: bool,
                   window: int | None, kv_offset: int):
    """Valid-position mask for one (q-block, kv-block) tile.

    The single definition shared by the forward kernel and the backward
    recompute kernels (``flash_attention_bwd``) — they must stay
    bit-identical or the VJP differentiates a different attention
    pattern than the forward computes.
    """
    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0) + kv_offset
    kpos = ki * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = jnp.ones((block_q, block_kv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *rest,
                  scale: float, causal: bool, window: int | None,
                  logit_cap: float | None, block_q: int, block_kv: int,
                  n_kv: int, kv_offset: int, with_lse: bool = False):
    if with_lse:
        lse_ref, m_ref, l_ref, acc_ref = rest
    else:
        lse_ref = None
        m_ref, l_ref, acc_ref = rest
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)              # (bq, d)
    k = k_ref[...].astype(jnp.float32)              # (bkv, d)
    v = v_ref[...].astype(jnp.float32)              # (bkv, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)

    mask = attention_mask(qi, ki, block_q=block_q, block_kv=block_kv,
                          causal=causal, window=window, kv_offset=kv_offset)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (m_new == NEG_INF) against NaN
    p = jnp.exp(s - jnp.where(m_new <= NEG_INF / 2, 0.0, m_new))
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(jnp.where(m_prev <= NEG_INF / 2, NEG_INF,
                              m_prev - m_new))
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / safe_l).astype(o_ref.dtype)
        if with_lse:
            lse_ref[...] = jnp.where(l == 0.0, BIG,
                                     m_ref[...] + jnp.log(safe_l))


def _blocked_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 causal: bool, window: int | None,
                 logit_cap: float | None, block_kv: int) -> jax.Array:
    """Streaming-softmax attention in pure jnp (lax.scan over KV chunks,
    per-chunk checkpointing) — differentiable with O(Sq * block_kv) live
    memory.  The ``REPRO_REF_ATTENTION=blocked`` roofline path and the
    long-sequence oracle for the Pallas kernels (fwd and bwd)."""
    sq, d = q.shape
    skv = k.shape[0]
    block_kv = min(block_kv, skv)
    if skv % block_kv:
        block_kv = skv
    nb = skv // block_kv
    scale = d ** -0.5
    qf = q.astype(jnp.float32)
    qpos = jnp.arange(sq) + (skv - sq)

    def chunk(carry, i):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice(k, (i * block_kv, 0), (block_kv, d))
        vs = jax.lax.dynamic_slice(v, (i * block_kv, 0), (block_kv, d))
        s = (qf @ ks.astype(jnp.float32).T) * scale
        if logit_cap is not None:
            s = logit_cap * jnp.tanh(s / logit_cap)
        kpos = i * block_kv + jnp.arange(block_kv)
        mask = jnp.ones((sq, block_kv), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - jnp.where(m_new <= NEG_INF / 2, 0.0, m_new))
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m <= NEG_INF / 2, 0.0,
                          jnp.exp(jnp.minimum(m - m_new, 0.0)))
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + p @ vs.astype(jnp.float32)
        return (m_new, l, acc), None

    init = (jnp.full((sq, 1), NEG_INF, jnp.float32),
            jnp.zeros((sq, 1), jnp.float32),
            jnp.zeros((sq, d), jnp.float32))
    from repro.util import scan_or_unroll
    (m, l, acc), _ = scan_or_unroll(jax.checkpoint(chunk), init,
                                    jnp.arange(nb))
    return (acc / jnp.where(l == 0.0, 1.0, l)).astype(q.dtype)


@functools.lru_cache(maxsize=64)
def _make_differentiable(causal, window, logit_cap, block_q, block_kv,
                         interpret):
    """Pallas forward + Pallas recompute backward (flash-style).

    The forward saves (o, lse) as residuals; the backward runs the two
    Pallas kernels in ``flash_attention_bwd`` (dq over the KV grid,
    dk/dv over the Q grid) — see docs/training.md.
    """
    kw = dict(causal=causal, window=window, logit_cap=logit_cap,
              block_q=block_q, block_kv=block_kv, interpret=interpret)

    @jax.custom_vjp
    def fn(q, k, v):
        return _flash_forward(q, k, v, **kw)

    def fwd(q, k, v):
        o, lse = _flash_forward(q, k, v, return_lse=True, **kw)
        return o, (q, k, v, o, lse)

    def bwd(res, g):
        from repro.kernels.flash_attention_bwd import flash_attention_bwd
        q, k, v, o, lse = res
        return flash_attention_bwd(q, k, v, o, lse, g, **kw)

    fn.defvjp(fwd, bwd)
    return fn


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    logit_cap: float | None = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """Differentiable flash attention (Pallas fwd AND Pallas bwd)."""
    fn = _make_differentiable(causal, window, logit_cap,
                              min(block_q, q.shape[0]),
                              min(block_kv, k.shape[0]), interpret)
    return fn(q, k, v)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "logit_cap", "block_q", "block_kv", "interpret",
    "return_lse"))
def _flash_forward(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: int | None = None,
                   logit_cap: float | None = None,
                   block_q: int = 128, block_kv: int = 128,
                   interpret: bool = False, return_lse: bool = False):
    sq, d = q.shape
    skv = k.shape[0]
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0, \
        (sq, block_q, skv, block_kv)
    grid = (sq // block_q, skv // block_kv)
    scale = d ** -0.5
    o_spec = pl.BlockSpec((block_q, d), lambda qi, ki: (qi, 0))
    o_shape = jax.ShapeDtypeStruct((sq, d), q.dtype)
    out_specs, out_shape = o_spec, o_shape
    if return_lse:  # the backward's residual: lse = m + log(l), per row
        out_specs = [o_spec,
                     pl.BlockSpec((block_q, 1), lambda qi, ki: (qi, 0))]
        out_shape = [o_shape,
                     jax.ShapeDtypeStruct((sq, 1), jnp.float32)]
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            logit_cap=logit_cap, block_q=block_q, block_kv=block_kv,
            n_kv=grid[1], kv_offset=skv - sq, with_lse=return_lse),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda qi, ki: (qi, 0)),
            pl.BlockSpec((block_kv, d), lambda qi, ki: (ki, 0)),
            pl.BlockSpec((block_kv, d), lambda qi, ki: (ki, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),   # accumulator (OB)
        ],
        interpret=interpret,
    )(q, k, v)
