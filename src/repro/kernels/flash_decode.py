"""Paged flash-decode attention Pallas kernel (the serving nest).

One query row per request streams over a block-table-indexed paged KV
cache: the KV *pages* are the paper's kernel buffer (each page is fetched
from HBM exactly once per step), and the fp32 running (m, l, acc)
statistics are the output buffer held VMEM-resident across the whole KV
reduction.  The page size — which is simultaneously the kernel's KV block
— is tuned through ``repro.tune`` under the ``"flash_decode"`` op key, so
the paged cache layout (``serve/kv_cache.py``) and the kernel schedule
come from the same analytical blocking model.

Layouts (GQA-native: all G query heads of one KV head share its pages):

* ``q``:            (B, Hkv, G, D) — the current token's query rows;
* ``k/v_pages``:    (n_pages, page, Hkv, D) — the global page pool;
* ``block_tables``: (B, n_blocks) int32 — physical page of each logical
  KV block; entries past a request's length must still be *valid* page
  indices (use 0) because the DMA runs before the mask is applied;
* ``lengths``:      (B,) int32 — tokens in the cache *including* the one
  being decoded (its K/V must already be scattered into the pages).

Grid is (B, Hkv, n_blocks) with the KV-block dim minor-most so the
accumulators persist across the reduction; block tables and lengths ride
in scalar-prefetch SMEM so the page DMA for block ``i`` of request ``b``
is issued straight from ``block_tables[b, i]``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import NEG_INF


def vmem_bytes_required(block_kv: int, groups: int, head_dim: int,
                        bytes_per_elem: int = 2,
                        kv_bytes: int | None = None,
                        q_span: int = 1) -> int:
    """VMEM footprint of one grid step of :func:`flash_decode`.

    The K and V pages are streamed (Pallas double-buffers them across
    grid steps, hence the factor 2); the query tile, the output tile and
    the fp32 (m, l, acc) running statistics stay resident; the score
    block is fp32 intermediate.  Single source of truth for the
    ``"flash_decode"`` schedule-candidate filter in ``tune.lowering``.

    ``kv_bytes`` is the page element width when the cache is quantized
    (fp8: 1) — only the streamed pages narrow; q/out keep their dtype
    and the running statistics stay fp32.

    ``q_span`` is the number of query *positions* folded into the q
    block (speculative verify / chunked prefill): everything that scales
    with the query rows — q/o tiles, scores, running stats — multiplies
    by it, while the streamed pages do not.  That asymmetry is what lets
    ``serve.kv_cache.choose_prefill_chunk`` price a multi-page chunk
    against the same VMEM budget the page size was tuned under.
    """
    kvb = kv_bytes or bytes_per_elem
    rows = groups * q_span
    streamed = 2 * 2 * block_kv * head_dim * kvb                # K + V pages
    q_tile = rows * head_dim * bytes_per_elem
    o_tile = rows * head_dim * bytes_per_elem
    scores = rows * block_kv * 4
    acc = rows * head_dim * 4 + 2 * rows * 4                    # acc, m, l
    return streamed + q_tile + o_tile + scores + acc


def _block_mask(len_ref, b, i, block_kv: int, window: int | None,
                q_span: int = 1, groups: int = 1):
    """Validity mask for KV block ``i`` of request ``b``.

    With ``q_span == 1`` (plain decode) the mask is ``(1, block_kv)`` and
    broadcasts over the G query rows.  With ``q_span > 1`` the q block
    holds ``q_span`` consecutive *positions* of ``groups`` rows each
    (position-major: row r is position offset ``r // groups``), and the
    mask is per-row causal: position offset t sees ``kpos < length + t``
    — ``lengths`` counts the cache *including the first* spanned token,
    exactly the single-token convention extended row-wise.
    """
    length = len_ref[b]                                  # tokens incl. current
    kpos = i * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_kv), 1)                     # logical positions
    if q_span == 1:
        mask = kpos < length
        if window is not None:
            # same rule as the dense decode path: query position is
            # length-1, and it sees kpos > qpos - window
            mask &= kpos > (length - 1) - window
        return mask
    offs = jax.lax.broadcasted_iota(
        jnp.int32, (q_span * groups, 1), 0) // groups    # row -> position off
    mask = kpos < length + offs
    if window is not None:
        mask &= kpos > (length - 1 + offs) - window
    return mask


def _softmax_update(s, v, mask, m_ref, l_ref, acc_ref):
    """One streaming-softmax step over a masked score block — the shared
    core of the bf16 and fp8 decode kernels."""
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_ref[...]                                  # (G, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked blocks/rows (m == NEG_INF) against NaN
    p = jnp.exp(s - jnp.where(m_new <= NEG_INF / 2, 0.0, m_new))
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                      jnp.exp(jnp.minimum(m_prev - m_new, 0.0)))
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _decode_init(i, m_ref, l_ref, acc_ref):
    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)


def _decode_finish(i, n_blocks, o_ref, m_ref, l_ref, acc_ref):
    @pl.when(i == n_blocks - 1)
    def _done():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / safe_l)[None, None].astype(o_ref.dtype)


def _decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale: float,
                   window: int | None, logit_cap: float | None,
                   block_kv: int, n_blocks: int, q_span: int = 1,
                   groups: int = 1):
    b = pl.program_id(0)
    i = pl.program_id(2)
    _decode_init(i, m_ref, l_ref, acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (q_span*G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)

    mask = _block_mask(len_ref, b, i, block_kv, window, q_span, groups)
    _softmax_update(s, v, mask, m_ref, l_ref, acc_ref)
    _decode_finish(i, n_blocks, o_ref, m_ref, l_ref, acc_ref)


def _decode_fp8_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, ks_ref,
                       vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                       scale: float, window: int | None,
                       logit_cap: float | None, block_kv: int,
                       n_blocks: int, q_span: int = 1, groups: int = 1):
    """fp8-page variant: K/V stream in at 1 byte/elem and dequantize
    in-VMEM with the per-kv-head fp32 scales.  The scales are scalars
    within a grid step, so K's folds into the score block and V's into
    the accumulator update — no widened page tile is ever materialized.
    """
    b = pl.program_id(0)
    i = pl.program_id(2)
    _decode_init(i, m_ref, l_ref, acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, D) fp8->f32
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    ks = ks_ref[0, 0]                                    # this head's scales
    vs = vs_ref[0, 0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * (scale * ks)
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)

    mask = _block_mask(len_ref, b, i, block_kv, window, q_span, groups)
    _softmax_update(s, v * vs, mask, m_ref, l_ref, acc_ref)
    _decode_finish(i, n_blocks, o_ref, m_ref, l_ref, acc_ref)


@functools.partial(jax.jit, static_argnames=("window", "logit_cap",
                                             "q_span", "interpret"))
def flash_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                 block_tables: jax.Array, lengths: jax.Array, *,
                 window: int | None = None,
                 logit_cap: float | None = None,
                 q_span: int = 1,
                 interpret: bool = False) -> jax.Array:
    """Paged attention over one q block per (batch, kv-head).

    ``q`` is (B, Hkv, q_span*G, D): with ``q_span == 1`` the classic
    single-token decode; with ``q_span > 1`` the rows hold ``q_span``
    consecutive positions (position-major — row r is position offset
    ``r // G``) whose K/V must already be scattered into the pages, and
    each position's rows get a causal per-row mask (``lengths`` still
    counts the cache including the FIRST spanned token).  This is the
    one kernel capability behind both speculative verify and chunked
    prefill: the GQA grouping already streams a multi-row q block, so
    spanning positions costs no extra KV traffic.  Returns the same
    shape as ``q``.
    """
    b, hkv, gtot, d = q.shape
    if gtot % q_span:
        raise ValueError(f"q rows {gtot} not divisible by q_span {q_span}")
    g = gtot // q_span
    _, page, _, _ = k_pages.shape
    n_blocks = block_tables.shape[1]
    scale = d ** -0.5
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, gtot, d),
                         lambda bi, h, i, bt, ln: (bi, h, 0, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda bi, h, i, bt, ln: (bt[bi, i], 0, h, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda bi, h, i, bt, ln: (bt[bi, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, gtot, d),
                               lambda bi, h, i, bt, ln: (bi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((gtot, 1), jnp.float32),  # running max m
            pltpu.VMEM((gtot, 1), jnp.float32),  # running denom l
            pltpu.VMEM((gtot, d), jnp.float32),  # accumulator (OB)
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, window=window,
                          logit_cap=logit_cap, block_kv=page,
                          n_blocks=n_blocks, q_span=q_span, groups=g),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, gtot, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages)


@functools.partial(jax.jit, static_argnames=("window", "logit_cap",
                                             "q_span", "interpret"))
def flash_decode_fp8(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     k_scale: jax.Array, v_scale: jax.Array,
                     block_tables: jax.Array, lengths: jax.Array, *,
                     window: int | None = None,
                     logit_cap: float | None = None,
                     q_span: int = 1,
                     interpret: bool = False) -> jax.Array:
    """Paged attention over an fp8-quantized page pool.

    Same contract as :func:`flash_decode` (including the multi-position
    ``q_span`` q block) except ``k_pages``/``v_pages`` are fp8
    (``float8_e4m3fn``) and ``k_scale``/``v_scale`` are fp32 per-kv-head
    dequantization scales of shape ``(Hkv,)`` (pass ones for a pure-cast
    cache).  The pages stream from HBM at one byte per element;
    dequantization happens in VMEM inside the kernel, so HBM traffic for
    the dominant decode operand is halved vs bf16 — which is why the
    page size comes from the ``"flash_decode_fp8"`` schedule key.
    Returns the same shape as ``q`` in ``q.dtype``.
    """
    b, hkv, gtot, d = q.shape
    if gtot % q_span:
        raise ValueError(f"q rows {gtot} not divisible by q_span {q_span}")
    g = gtot // q_span
    _, page, _, _ = k_pages.shape
    n_blocks = block_tables.shape[1]
    scale = d ** -0.5
    ks = jnp.asarray(k_scale, jnp.float32).reshape(hkv, 1)
    vs = jnp.asarray(v_scale, jnp.float32).reshape(hkv, 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, gtot, d),
                         lambda bi, h, i, bt, ln: (bi, h, 0, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda bi, h, i, bt, ln: (bt[bi, i], 0, h, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda bi, h, i, bt, ln: (bt[bi, i], 0, h, 0)),
            pl.BlockSpec((1, 1), lambda bi, h, i, bt, ln: (h, 0)),
            pl.BlockSpec((1, 1), lambda bi, h, i, bt, ln: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, gtot, d),
                               lambda bi, h, i, bt, ln: (bi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((gtot, 1), jnp.float32),  # running max m
            pltpu.VMEM((gtot, 1), jnp.float32),  # running denom l
            pltpu.VMEM((gtot, d), jnp.float32),  # accumulator (OB)
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_fp8_kernel, scale=scale, window=window,
                          logit_cap=logit_cap, block_kv=page,
                          n_blocks=n_blocks, q_span=q_span, groups=g),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, gtot, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages, ks, vs)


def hbm_bytes(batch: int, hkv: int, groups: int, head_dim: int,
              seq: int, block_kv: int, bytes_per_elem: int = 2,
              kv_bytes: int | None = None) -> int:
    """Exact HBM traffic of one :func:`flash_decode` call (the grid's
    actual block transfers; scalar-prefetch block tables and lengths are
    excluded, as in :func:`oproj_hbm_bytes`).

    The q and output blocks are (bi, h)-indexed — constant across the
    KV-block grid dim, so each moves once per (batch, kv-head) row; the
    K/V pages stream once per row.  ``kv_bytes`` gives the paged K/V
    streams their own width (fp8 cache: 1); the fp8 variant additionally
    fetches the two per-head fp32 dequant scales once per row change.
    """
    nb = -(-seq // block_kv)
    kvb = bytes_per_elem if kv_bytes is None else kv_bytes
    q_bytes = batch * hkv * groups * head_dim * bytes_per_elem
    kv = 2 * batch * hkv * nb * block_kv * head_dim * kvb
    out = batch * hkv * groups * head_dim * bytes_per_elem
    total = q_bytes + kv + out
    if kv_bytes is not None:
        # (h, 0)-indexed scale scalars: refetched when h changes
        total += 2 * 4 * (batch * hkv if hkv > 1 else 1)
    return total


def oproj_vmem_bytes_required(block_kv: int, groups: int, head_dim: int,
                              d_model: int,
                              bytes_per_elem: int = 2) -> int:
    """VMEM footprint of one grid step of :func:`flash_decode_oproj`:
    the base decode footprint plus the streamed per-head wo slab
    (G*D x E) and the fp32 (1, E) output accumulator that stays
    resident across the head loop.  Single source of truth for the
    ``"flash_decode_oproj"`` schedule-candidate filter."""
    base = vmem_bytes_required(block_kv, groups, head_dim, bytes_per_elem)
    wo_slab = 2 * groups * head_dim * d_model * bytes_per_elem
    out_acc = d_model * 4 + d_model * bytes_per_elem
    return base + wo_slab + out_acc


def oproj_hbm_bytes(batch: int, hkv: int, groups: int, head_dim: int,
                    d_model: int, seq: int, block_kv: int,
                    bytes_per_elem: int = 2) -> int:
    """Exact HBM traffic of one :func:`flash_decode_oproj` call (the
    grid's actual block transfers).  The unfused baseline additionally
    writes the (B, Hq, D) attention output and reads it back for the
    projection GEMM — that intermediate never exists here."""
    nb = -(-seq // block_kv)
    q_bytes = batch * hkv * groups * head_dim * bytes_per_elem
    kv = 2 * batch * hkv * nb * block_kv * head_dim * bytes_per_elem
    wo = batch * hkv * groups * head_dim * d_model * bytes_per_elem
    out = batch * d_model * bytes_per_elem
    return q_bytes + kv + wo + out


def _decode_oproj_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, wo_ref,
                         o_ref, m_ref, l_ref, acc_ref, oacc_ref, *,
                         scale: float, window: int | None,
                         logit_cap: float | None, block_kv: int,
                         n_blocks: int, n_heads: int):
    """Flash-decode with the output projection's row tile fused in.

    Grid is (B, Hkv, n_blocks) with the KV block minor-most, exactly as
    :func:`flash_decode` — but the per-head attention output (G, D) is
    never written to HBM: at the last KV block of each head it is
    multiplied into that head's wo row slab and accumulated into the
    (1, E) output block, which ignores the head grid dim and therefore
    stays VMEM-resident across the whole head loop (the paper's OB rule
    applied to the *consumer* nest's reduction over heads).
    """
    b = pl.program_id(0)
    h = pl.program_id(1)
    i = pl.program_id(2)
    _decode_init(i, m_ref, l_ref, acc_ref)

    @pl.when((h == 0) & (i == 0))
    def _init_out():
        oacc_ref[...] = jnp.zeros_like(oacc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)

    mask = _block_mask(len_ref, b, i, block_kv, window)
    _softmax_update(s, v, mask, m_ref, l_ref, acc_ref)

    @pl.when(i == n_blocks - 1)
    def _project():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        attn = (acc_ref[...] / safe_l)                   # (G, D) fp32
        wo = wo_ref[0].astype(jnp.float32)               # (G*D, E)
        oacc_ref[...] += jnp.dot(attn.reshape(1, -1), wo,
                                 preferred_element_type=jnp.float32)

    @pl.when((h == n_heads - 1) & (i == n_blocks - 1))
    def _done():
        o_ref[...] = oacc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "logit_cap",
                                             "interpret"))
def flash_decode_oproj(q: jax.Array, k_pages: jax.Array,
                       v_pages: jax.Array, block_tables: jax.Array,
                       lengths: jax.Array, wo: jax.Array, *,
                       window: int | None = None,
                       logit_cap: float | None = None,
                       interpret: bool = False) -> jax.Array:
    """Paged single-token attention fused with the output projection.

    Same contract as :func:`flash_decode` plus ``wo``: the attention
    output projection reshaped per kv head, ``(Hkv, G*D, E)`` (rows of
    the dense ``(Hq*D, E)`` weight grouped by the kv head that produces
    them).  Returns ``(B, E)`` — the per-head (G, D) attention outputs
    are reduced into the projection inside VMEM and never round-trip
    through HBM.  Schedule key: ``"flash_decode_oproj"`` (the KV block
    is still the tunable, and still the paged cache's page size).

    Traffic caveat (docs/fusion.md, "when fusion loses"): the output
    block is resident across the head loop of ONE batch row, so the wo
    slabs are refetched per row — ``B * Hq * D * E`` weight bytes vs
    the unfused GEMM's single pass.  Per request (B=1, the paged
    engine's per-slot view) fusion strictly saves the attention
    output's round-trip; at large decode batches the wo refetch can
    outweigh it, which is exactly the arithmetic
    ``oproj_hbm_bytes`` exposes — leave ``fuse`` off there.
    """
    b, hkv, g, d = q.shape
    _, page, _, _ = k_pages.shape
    e = wo.shape[-1]
    assert wo.shape == (hkv, g * d, e), (wo.shape, (hkv, g * d, e))
    n_blocks = block_tables.shape[1]
    scale = d ** -0.5
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, h, i, bt, ln: (bi, h, 0, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda bi, h, i, bt, ln: (bt[bi, i], 0, h, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda bi, h, i, bt, ln: (bt[bi, i], 0, h, 0)),
            pl.BlockSpec((1, g * d, e), lambda bi, h, i, bt, ln: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, e), lambda bi, h, i, bt, ln: (bi, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),     # running max m
            pltpu.VMEM((g, 1), jnp.float32),     # running denom l
            pltpu.VMEM((g, d), jnp.float32),     # attention acc (OB)
            pltpu.VMEM((1, e), jnp.float32),     # projected-output acc
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_oproj_kernel, scale=scale, window=window,
                          logit_cap=logit_cap, block_kv=page,
                          n_blocks=n_blocks, n_heads=hkv),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, e), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages, wo)


def paged_attention_oproj_ref(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array,
                              block_tables: jax.Array,
                              lengths: jax.Array, wo: jax.Array, *,
                              window: int | None = None,
                              logit_cap: float | None = None,
                              ) -> jax.Array:
    """jnp oracle (and the unfused chain): paged attention, then the
    dense projection over the flattened heads.  wo: (Hkv, G*D, E)."""
    b, hkv, g, d = q.shape
    e = wo.shape[-1]
    attn = paged_attention_ref(q, k_pages, v_pages, block_tables,
                               lengths, window=window,
                               logit_cap=logit_cap)    # (B, Hkv, G, D)
    flat = attn.reshape(b, hkv * g * d).astype(jnp.float32)
    w2 = wo.reshape(hkv * g * d, e).astype(jnp.float32)
    return jnp.dot(flat, w2,
                   preferred_element_type=jnp.float32).astype(q.dtype)


def paged_attention_fp8_ref(q: jax.Array, k_pages: jax.Array,
                            v_pages: jax.Array, k_scale: jax.Array,
                            v_scale: jax.Array, block_tables: jax.Array,
                            lengths: jax.Array, *,
                            window: int | None = None,
                            logit_cap: float | None = None,
                            q_span: int = 1) -> jax.Array:
    """jnp oracle for :func:`flash_decode_fp8`: dequantize the page pool
    in fp32, then the dense masked softmax of :func:`paged_attention_ref`.
    """
    hkv = k_pages.shape[2]
    ks = jnp.asarray(k_scale, jnp.float32).reshape(1, 1, hkv, 1)
    vs = jnp.asarray(v_scale, jnp.float32).reshape(1, 1, hkv, 1)
    return paged_attention_ref(q, k_pages.astype(jnp.float32) * ks,
                               v_pages.astype(jnp.float32) * vs,
                               block_tables, lengths, window=window,
                               logit_cap=logit_cap, q_span=q_span)


def paged_attention_ref(q: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, block_tables: jax.Array,
                        lengths: jax.Array, *,
                        window: int | None = None,
                        logit_cap: float | None = None,
                        q_span: int = 1) -> jax.Array:
    """jnp oracle: gather pages by block table, dense masked softmax.

    Bit-comparable semantics to :func:`flash_decode` (same masking rules
    — including the per-position rows of a ``q_span > 1`` block — and
    fp32 math); the correctness oracle in tests and the fast vectorized
    path off-TPU.
    """
    b, hkv, gtot, d = q.shape
    g = gtot // q_span
    _, page, _, _ = k_pages.shape
    nb = block_tables.shape[1]
    k = k_pages[block_tables].reshape(b, nb * page, hkv, d)
    v = v_pages[block_tables].reshape(b, nb * page, hkv, d)
    s = jnp.einsum("bhgd,blhd->bhgl", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * d ** -0.5
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)
    kpos = jnp.arange(nb * page)
    offs = jnp.arange(gtot) // g                         # row -> position off
    lim = lengths[:, None] + offs[None, :]               # (b, gtot)
    valid = kpos[None, None, :] < lim[..., None]
    if window is not None:
        valid &= kpos[None, None, :] > (lim[..., None] - 1) - window
    s = jnp.where(valid[:, None, :, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgl,blhd->bhgd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
