"""Quantized-weight GEMM Pallas kernel (w8a16/w8a32, fp32 accumulation).

``C[M,N] = A[M,K] @ dequant(Wq[K,N])`` where ``Wq`` is int8 and the
per-output-channel fp32 ``scale[N]`` is applied once at the epilogue —
mathematically identical to dequantizing inside the reduction
(``sum_k a*w*s == s * sum_k a*w`` because the scale depends only on the
output channel), but the weight stream crosses the HBM->VMEM boundary at
ONE byte per element.  That halved-or-quartered weight traffic is exactly
what the dtype-aware blocking model (per-operand ``weight_bytes`` on
``core.loopnest.Problem``) optimizes for, so the tiles come from the
``"matmul_w8"`` schedule key (``repro.tune``), not the bf16 search.

Grid order matches :mod:`repro.kernels.matmul_blocked`: (m, n, k) with k
minor-most so the fp32 accumulator block stays VMEM-resident across the
whole reduction (the paper's OB rule).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def vmem_bytes_required(bm: int, bk: int, bn: int,
                        a_bytes: int = 2, w_bytes: int = 1) -> int:
    """VMEM footprint of one grid step of :func:`matmul_w8`.

    The A and Wq tiles are streamed at their own element widths (Pallas
    double-buffers them, hence the factor 2); the output block plus the
    fp32 accumulator scratch stay resident; the per-channel scale row is
    double-buffered fp32.  Single source of truth for the ``"matmul_w8"``
    schedule-candidate filter in ``tune.lowering``.
    """
    streamed = 2 * (bm * bk * a_bytes + bk * bn * w_bytes)
    resident = bm * bn * (a_bytes + 4)
    scale_row = 2 * bn * 4
    return streamed + resident + scale_row


def hbm_bytes(M: int, N: int, K: int, bm: int, bk: int, bn: int,
              a_bytes: int = 2, w_bytes: int = 1) -> int:
    """Exact HBM traffic of one :func:`matmul_w8` call: the elision-aware
    GEMM block transfers with a ``w_bytes``-wide weight stream
    (``matmul_blocked.hbm_bytes``) plus the fp32 dequant-scale row, which
    is (0, j)-indexed like a fused bias and moves once per i-row only
    when the row changes between i-rows."""
    from repro.kernels.matmul_blocked import hbm_bytes as gemm_bytes
    gm, gn = M // bm, N // bn
    total = gemm_bytes(M, N, K, bm, bk, bn, a_bytes, w_bytes)
    return total + N * 4 * (gm if gn > 1 else 1)


def _matmul_w8_kernel(a_ref, w_ref, s_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)       # in-kernel int8 -> fp32
    acc_ref[...] += jnp.dot(a, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        # per-output-channel scale applied once, after the K reduction
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "bn", "interpret"))
def matmul_w8(a: jax.Array, w_q: jax.Array, scale: jax.Array, *,
              bm: int, bk: int, bn: int,
              interpret: bool = False) -> jax.Array:
    """C[M,N] = A[M,K] @ (Wq[K,N] * scale[N]) tiled (bm, bk, bn).

    ``w_q`` is int8; ``scale`` is fp32, either per-channel ``(N,)`` or a
    per-tensor scalar (broadcast).  Dims must divide the tiles.
    """
    m, k = a.shape
    k2, n = w_q.shape
    assert k == k2, (a.shape, w_q.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, \
        f"tiles ({bm},{bk},{bn}) must divide ({m},{k},{n})"
    scale = jnp.broadcast_to(
        jnp.asarray(scale, jnp.float32).reshape(1, -1), (1, n))
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_w8_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, w_q, scale)


def matmul_w8_ref(a: jax.Array, w_q: jax.Array,
                  scale: jax.Array) -> jax.Array:
    """jnp oracle: fp32 dequant-then-matmul.  Bit-comparable math to the
    kernel (fp32 accumulate, scale in the epilogue); the correctness
    oracle in tests and the ragged-shape fallback in ``kernels.ops``."""
    scale = jnp.asarray(scale, jnp.float32).reshape(1, -1)
    acc = jnp.dot(a.astype(jnp.float32), w_q.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return (acc * scale).astype(a.dtype)
