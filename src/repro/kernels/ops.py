"""Public jit'd wrappers around the Pallas kernels.

Each op (a) asks the schedule autotuner (``repro.tune.best_schedule``)
for its VMEM tiles — a tuned, persisted schedule when one is cached for
this (op, shapes, dtype, device), else the analytical blocking model's
winner — (b) runs the Pallas kernel when shapes tile cleanly, and
(c) falls back to the jnp oracle otherwise — so models can use these ops
unconditionally.  ``interpret`` defaults to True off-TPU (kernel body
executed in Python for correctness validation on CPU).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.tpu_adapter import flash_tiles
from repro.kernels import ref
from repro.kernels.conv2d_blocked import conv2d_block
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul_blocked import matmul_blocked
from repro.tune import best_schedule


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def matmul(a: jax.Array, b: jax.Array,
           tiles: tuple[int, int, int] | None = None,
           interpret: bool | None = None) -> jax.Array:
    """Blocked GEMM with tuned/model-derived tiles; oracle fallback."""
    m, k = a.shape
    _, n = b.shape
    bm, bk, bn = tiles or best_schedule("matmul", (m, n, k),
                                        a.dtype.name).tiles
    if m % bm or k % bk or n % bn:
        return ref.matmul_ref(a, b)
    interpret = default_interpret() if interpret is None else interpret
    return matmul_blocked(a, b, bm=bm, bk=bk, bn=bn, interpret=interpret)


def conv2d(x: jax.Array, w: jax.Array, stride: int = 1,
           tiles: tuple[int, int, int, int] | None = None,
           interpret: bool | None = None) -> jax.Array:
    """Direct blocked conv, NHWC x HWIO -> NHWC (VALID padding).

    Level-1 spatial blocking (halo slices from HBM) happens here; level-0
    channel/kernel blocking happens inside the Pallas kernel.
    """
    n, h, wd, c = x.shape
    fh, fw, _, k = w.shape
    oh = (h - fh) // stride + 1
    ow = (wd - fw) // stride + 1
    bx, by, bc, bk = tiles or best_schedule(
        "conv2d", (ow, oh, c, k, fw, fh), x.dtype.name, stride=stride).tiles
    if c % bc or k % bk:
        return ref.conv2d_ref(x, w, stride)
    interpret = default_interpret() if interpret is None else interpret

    per_image = functools.partial(_conv_one, w=w, stride=stride, bx=bx,
                                  by=by, bc=bc, bk=bk, oh=oh, ow=ow,
                                  fh=fh, fw=fw, interpret=interpret)
    return jax.vmap(per_image)(x)


def _conv_one(img, *, w, stride, bx, by, bc, bk, oh, ow, fh, fw, interpret):
    # level-1 spatial tiles with halo (paper's X1/Y1 loops)
    if oh % by or ow % bx:
        by, bx = oh, ow  # ragged spatial: single tile
    rows = []
    for ty in range(0, oh, by):
        cols = []
        for tx in range(0, ow, bx):
            tile = jax.lax.dynamic_slice(
                img, (ty * stride, tx * stride, 0),
                ((by - 1) * stride + fh, (bx - 1) * stride + fw,
                 img.shape[2]))
            cols.append(conv2d_block(tile, w, bc=bc, bk=bk, stride=stride,
                                     interpret=interpret))
        rows.append(jnp.concatenate(cols, axis=1))
    return jnp.concatenate(rows, axis=0)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              logit_cap: float | None = None,
              tiles: tuple[int, int] | None = None,
              interpret: bool | None = None) -> jax.Array:
    """Multi-head attention with GQA.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D); Hq a multiple of Hkv.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0
    bq, bkv = tiles or flash_tiles(sq, skv, d, q.dtype.itemsize)
    interpret = default_interpret() if interpret is None else interpret
    use_kernel = sq % min(bq, sq) == 0 and skv % min(bkv, skv) == 0
    # roofline analysis variant: exact HLO flops without the Pallas
    # interpreter's while-loops.  "blocked" keeps flash-style O(S) memory.
    ref_mode = os.environ.get("REPRO_REF_ATTENTION")
    if ref_mode:
        use_kernel = False

    groups = hq // hkv
    qg = q.reshape(b, sq, hkv, groups, d)

    def one_head(qh, kh, vh):  # (Sq, D), (Skv, D), (Skv, D)
        if use_kernel:
            return flash_attention(qh, kh, vh, causal=causal, window=window,
                                   logit_cap=logit_cap, block_q=bq,
                                   block_kv=bkv, interpret=interpret)
        if ref_mode == "blocked":
            from repro.kernels.flash_attention import _blocked_ref
            return _blocked_ref(qh, kh, vh, causal=causal, window=window,
                                logit_cap=logit_cap, block_kv=bkv)
        return ref.attention_ref(qh, kh, vh, causal=causal,
                                 logit_cap=logit_cap, window=window)

    def per_kvhead(qh, kh, vh):  # qh: (Sq, G, D); kh, vh: (Skv, D)
        return jax.vmap(lambda qx: one_head(qx, kh, vh),
                        in_axes=1, out_axes=1)(qh)       # (Sq, G, D)

    # vmap over kv-heads (inner) and batch (outer)
    fn = jax.vmap(jax.vmap(per_kvhead))
    out = fn(qg.transpose(0, 2, 1, 3, 4),   # (B, Hkv, Sq, G, D)
             k.transpose(0, 2, 1, 3),       # (B, Hkv, Skv, D)
             v.transpose(0, 2, 1, 3))       # -> (B, Hkv, Sq, G, D)
    out = out.transpose(0, 2, 1, 3, 4).reshape(b, sq, hq, d)
    return out
