"""Public jit'd wrappers around the Pallas kernels — differentiable.

Each op (a) asks the schedule autotuner (``repro.tune.best_schedule``)
for its VMEM tiles — a tuned, persisted schedule when one is cached for
this (op, shapes, dtype, device), else the analytical blocking model's
winner — (b) runs the Pallas kernel when shapes tile cleanly, and
(c) falls back to the jnp oracle otherwise — so models can use these ops
unconditionally.  ``interpret`` defaults to True off-TPU (kernel body
executed in Python for correctness validation on CPU).

Every op carries a ``jax.custom_vjp``: the backward nests are Pallas
kernels too (``matmul_bwd`` / ``conv2d_bwd`` / ``flash_attention_bwd``),
each lowered through the same tune pipeline under its own schedule key
(``"matmul_dgrad"``, ``"conv2d_dgrad"``, ``"conv2d_wgrad"``), with jnp
oracle fallbacks for ragged shapes — so ``jax.grad`` through a model
built on these ops takes real training steps through blocked kernels.

``linear`` is the training-path entry: a plain ``x @ w`` unless blocked
linears are enabled (``blocked_linear(True)`` context or the
``REPRO_BLOCKED_LINEAR`` env var), in which case it routes through the
differentiable blocked GEMM.
"""

from __future__ import annotations

import contextlib
import contextvars
import functools
import os

import jax
import jax.numpy as jnp

from repro.core.tpu_adapter import flash_tiles
from repro.kernels import ref
from repro.kernels.conv2d_bwd import conv2d_dgrad, conv2d_wgrad
from repro.kernels.conv2d_blocked import conv2d_tiled
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul_blocked import matmul_blocked
from repro.kernels.matmul_bwd import matmul_dgrad_a, matmul_dgrad_b
from repro.tune import best_schedule


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ------------------------------- matmul ------------------------------------


def _matmul_fwd_impl(a, b, tiles, interpret):
    m, k = a.shape
    _, n = b.shape
    bm, bk, bn = tiles or best_schedule("matmul", (m, n, k),
                                        a.dtype.name).tiles
    if m % bm or k % bk or n % bn:
        return ref.matmul_ref(a, b)
    return matmul_blocked(a, b, bm=bm, bk=bk, bn=bn, interpret=interpret)


def _matmul_da(g, b, interpret):
    """dA[M,K] = g[M,N] @ B^T under the "matmul_dgrad" schedule."""
    m, n = g.shape
    k = b.shape[0]
    # dims in (M_out, N_out, K_reduce) convention of the dA nest
    bm, br, bo = best_schedule("matmul_dgrad", (m, k, n), g.dtype.name).tiles
    if m % bm or n % br or k % bo:
        return jnp.dot(g, b.T, preferred_element_type=jnp.float32)
    return matmul_dgrad_a(g, b, bm=bm, br=br, bo=bo, interpret=interpret)


def _matmul_db(a, g, interpret):
    """dB[K,N] = A^T @ g[M,N] under the "matmul_dgrad" schedule."""
    m, k = a.shape
    n = g.shape[1]
    bk, br, bn = best_schedule("matmul_dgrad", (k, n, m), g.dtype.name).tiles
    if k % bk or m % br or n % bn:
        return jnp.dot(a.T, g, preferred_element_type=jnp.float32)
    return matmul_dgrad_b(a, g, bk=bk, br=br, bn=bn, interpret=interpret)


@functools.lru_cache(maxsize=256)
def _matmul_vjp(tiles, interpret):
    @jax.custom_vjp
    def fn(a, b):
        return _matmul_fwd_impl(a, b, tiles, interpret)

    def fwd(a, b):
        return fn(a, b), (a, b)

    def bwd(res, g):
        a, b = res
        return (_matmul_da(g, b, interpret).astype(a.dtype),
                _matmul_db(a, g, interpret).astype(b.dtype))

    fn.defvjp(fwd, bwd)
    return fn


def matmul(a: jax.Array, b: jax.Array,
           tiles: tuple[int, int, int] | None = None,
           interpret: bool | None = None) -> jax.Array:
    """Blocked GEMM with tuned/model-derived tiles; oracle fallback.

    Differentiable: the VJP runs the NT/TN dgrad Pallas kernels with
    their own tuned schedules (explicit ``tiles`` pin the forward only).
    """
    interpret = default_interpret() if interpret is None else interpret
    return _matmul_vjp(tuple(tiles) if tiles else None, interpret)(a, b)


def matmul_w8(a: jax.Array, w_q: jax.Array, scale: jax.Array,
              tiles: tuple[int, int, int] | None = None,
              interpret: bool | None = None) -> jax.Array:
    """int8-weight GEMM ``A @ (Wq * scale)`` under the ``"matmul_w8"``
    schedule key — the dtype-aware blocking search sizes the weight tile
    at ONE byte per element, so its tiles differ from the bf16 GEMM's.

    ``scale`` is fp32 per-output-channel ``(N,)`` or a per-tensor
    scalar.  Inference-path op (no VJP); ragged shapes take the fp32
    dequant oracle.
    """
    from repro.kernels.matmul_q import matmul_w8 as _kernel, matmul_w8_ref
    m, k = a.shape
    _, n = w_q.shape
    interpret = default_interpret() if interpret is None else interpret
    bm, bk, bn = tiles or best_schedule("matmul_w8", (m, n, k),
                                        a.dtype.name).tiles
    if m % bm or k % bk or n % bn:
        return matmul_w8_ref(a, w_q, scale)
    return _kernel(a, w_q, scale, bm=bm, bk=bk, bn=bn, interpret=interpret)


# ----------------------------- fused ops -----------------------------------

_FUSED_OPS: contextvars.ContextVar[bool | None] = \
    contextvars.ContextVar("repro_fused_ops", default=None)


def fused_ops_enabled() -> bool:
    v = _FUSED_OPS.get()
    if v is None:
        return os.environ.get("REPRO_FUSED_OPS") == "1"
    return v


@contextlib.contextmanager
def fused_ops(enable: bool = True):
    """Route model hot paths through the cross-op fused kernels while
    tracing under this context (docs/fusion.md): the MLP block through
    the epilogue-fused GEMM (:func:`matmul_fused`), the attention
    front-end through the weight-stationary QKV pass
    (:func:`qkv_fused`), and — when the serving engine asks — paged
    decode through the oproj-fused flash decode.  The serving engines
    set this from their ``fuse`` config flag at trace time."""
    tok = _FUSED_OPS.set(bool(enable))
    try:
        yield
    finally:
        _FUSED_OPS.reset(tok)


def _kernels_on(use_kernel: bool | None) -> bool:
    """Fused kernels run on TPU by default; off-TPU the jnp oracle IS
    the fused semantics (XLA fuses the epilogue) without paying the
    Pallas interpreter — same policy as ``paged_attention``.

    ``REPRO_FORCE_KERNELS=1`` forces the kernel paths (interpret mode
    off-TPU) — the profiler sets it so every hot-path op resolves its
    schedule through the tuner and dispatches the grid whose transfers
    ``kernels.*.hbm_bytes`` accounts; forced runs are for attribution,
    not throughput.
    """
    if use_kernel is None:
        if os.environ.get("REPRO_FORCE_KERNELS") == "1":
            return True
        return jax.default_backend() == "tpu"
    return use_kernel


def _attn_kernels_on(use_kernel: bool | None) -> bool:
    """Attention-kernel gating: :func:`_kernels_on` plus the
    ``REPRO_REF_ATTENTION`` roofline override, which forces the
    reference path even when a caller asked for the kernel.  The single
    policy shared by ``paged_attention`` and ``paged_attention_oproj``.
    """
    if os.environ.get("REPRO_REF_ATTENTION"):
        return False
    return _kernels_on(use_kernel)


def matmul_fused(a: jax.Array, w, *, bias: jax.Array | None = None,
                 act: str = "none", mul: jax.Array | None = None,
                 residual: jax.Array | None = None,
                 tiles: tuple[int, int, int] | None = None,
                 use_kernel: bool | None = None,
                 interpret: bool | None = None) -> jax.Array:
    """``act(a @ w + bias) * mul + residual`` with the epilogue fused
    into the GEMM — the output tile never round-trips through HBM
    between the reduction and its pointwise tail.

    ``a`` may have any leading shape; ``mul``/``residual`` must match
    the output shape.  ``w`` may be a
    :class:`repro.quant.QuantizedTensor` (int8): the w8 epilogue-fused
    kernel runs under the PR 4 ``"matmul_w8"`` schedule key, so
    quantization and fusion compose.  Inference-path op (no VJP);
    ragged shapes take the jnp oracle.
    """
    from repro.kernels.matmul_fused import (matmul_fused as _kernel,
                                            matmul_fused_ref)
    from repro.quant.quantize import QuantizedTensor
    scale = None
    if isinstance(w, QuantizedTensor):
        if w.q.ndim != 2 or w.q.dtype != jnp.int8:
            w2 = w.dequant(jnp.float32).astype(a.dtype)
            return matmul_fused(a, w2, bias=bias, act=act, mul=mul,
                                residual=residual, tiles=tiles,
                                use_kernel=use_kernel,
                                interpret=interpret)
        scale = w.scale.reshape(-1)
        w = w.q
    lead = a.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    a2 = a.reshape(m, a.shape[-1])
    n = w.shape[-1]
    mul2 = mul.reshape(m, n) if mul is not None else None
    res2 = residual.reshape(m, n) if residual is not None else None
    k = a2.shape[-1]
    if _kernels_on(use_kernel):
        op = "matmul_w8" if scale is not None else "matmul_fused"
        bm, bk, bn = tiles or best_schedule(op, (m, n, k),
                                            a.dtype.name).tiles
        fits = True
        if tiles is None and scale is not None:
            # a cached "matmul_w8" schedule was validated against the
            # UNFUSED kernel's footprint (tune.fits_vmem); re-check it
            # against the fused footprint — the streamed epilogue tiles
            # it never accounted for — before running it
            from repro.kernels.matmul_fused import vmem_bytes_required
            from repro.tune import vmem_budget
            fits = vmem_bytes_required(bm, bk, bn, a.dtype.itemsize,
                                       w_bytes=1) <= vmem_budget()
        if fits and m % bm == 0 and k % bk == 0 and n % bn == 0:
            interpret = default_interpret() if interpret is None \
                else interpret
            out = _kernel(a2, w, scale=scale, bias=bias, mul=mul2,
                          residual=res2, act=act, bm=bm, bk=bk, bn=bn,
                          interpret=interpret)
            return out.reshape(*lead, n)
        if scale is not None and not fits:
            # keep the 1-byte weight stream: the unfused w8 kernel under
            # its own validated schedule, epilogue composed outside
            from repro.kernels.matmul_fused import ACTIVATIONS
            y = matmul_w8(a2, w, scale,
                          interpret=interpret).astype(jnp.float32)
            if bias is not None:
                y = y + jnp.asarray(bias, jnp.float32).reshape(1, -1)
            y = ACTIVATIONS[act](y)
            if mul2 is not None:
                y = y * mul2.astype(jnp.float32)
            if res2 is not None:
                y = y + res2.astype(jnp.float32)
            return y.astype(a.dtype).reshape(*lead, n)
    out = matmul_fused_ref(a2, w, scale=scale, bias=bias, mul=mul2,
                           residual=res2, act=act)
    return out.reshape(*lead, n)


def qkv_fused(x: jax.Array, wq, wk, wv, *,
              tiles: tuple[int, int, int] | None = None,
              use_kernel: bool | None = None,
              interpret: bool | None = None):
    """The attention front-end's three projections in one
    weight-stationary pass: the activation streams from HBM once
    instead of three times.  Quantized (``QuantizedTensor``) weights
    fall back to three :func:`linear` calls, preserving the w8
    semantics exactly.  Returns ``(q, k, v)`` with the input's leading
    shape."""
    from repro.kernels.qkv_fused import qkv_fused as _kernel
    from repro.quant.quantize import QuantizedTensor
    if any(isinstance(w, QuantizedTensor) for w in (wq, wk, wv)):
        return (linear(x, wq, interpret), linear(x, wk, interpret),
                linear(x, wv, interpret))
    lead = x.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, x.shape[-1])
    k = x2.shape[-1]
    nq, nkv = wq.shape[-1], wk.shape[-1]
    if _kernels_on(use_kernel) and nq % nkv == 0:
        g = nq // nkv
        bm, bk, bn = tiles or best_schedule("qkv_fused", (m, nkv, k, g),
                                            x.dtype.name).tiles
        if m % bm == 0 and k % bk == 0 and nkv % bn == 0:
            interpret = default_interpret() if interpret is None \
                else interpret
            q2, k2, v2 = _kernel(x2, wq, wk, wv, bm=bm, bk=bk, bn=bn,
                                 interpret=interpret)
            return (q2.reshape(*lead, nq), k2.reshape(*lead, nkv),
                    v2.reshape(*lead, nkv))
    from repro.kernels.qkv_fused import qkv_fused_ref
    q2, k2, v2 = qkv_fused_ref(x2, wq, wk, wv)
    return (q2.reshape(*lead, nq), k2.reshape(*lead, nkv),
            v2.reshape(*lead, nkv))


def paged_attention_oproj(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, block_tables: jax.Array,
                          lengths: jax.Array, wo, *,
                          window: int | None = None,
                          logit_cap: float | None = None,
                          use_kernel: bool | None = None,
                          interpret: bool | None = None) -> jax.Array:
    """Paged decode attention with the output projection fused in.

    Same contract as :func:`paged_attention` plus ``wo`` — the dense
    ``(Hq*D, E)`` output-projection weight — and returns ``(B, E)``:
    the per-head attention outputs are reduced into the projection in
    VMEM and never exist in HBM.  An fp8 page pool or a quantized
    ``wo`` falls back to the unfused pair (``paged_attention`` +
    :func:`linear`), so ``--fuse`` composes with every ``--quantize``
    mode.
    """
    from repro.kernels.flash_decode import (flash_decode_oproj,
                                            paged_attention_oproj_ref)
    from repro.quant.quantize import QuantizedTensor
    b, hq, d = q.shape
    hkv = k_pages.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    fp8 = jnp.dtype(k_pages.dtype).itemsize == 1
    if fp8 or isinstance(wo, QuantizedTensor):
        out = paged_attention(q, k_pages, v_pages, block_tables, lengths,
                              window=window, logit_cap=logit_cap,
                              use_kernel=use_kernel, interpret=interpret)
        return linear(out.reshape(b, hq * d), wo, interpret)
    e = wo.shape[-1]
    qg = q.reshape(b, hkv, g, d)
    wo3 = wo.reshape(hkv, g * d, e)
    if _attn_kernels_on(use_kernel):
        interpret = default_interpret() if interpret is None else interpret
        return flash_decode_oproj(qg, k_pages, v_pages, block_tables,
                                  lengths, wo3, window=window,
                                  logit_cap=logit_cap,
                                  interpret=interpret)
    return paged_attention_oproj_ref(qg, k_pages, v_pages, block_tables,
                                     lengths, wo3, window=window,
                                     logit_cap=logit_cap)


# ------------------------------- linear ------------------------------------

_BLOCKED_LINEAR: contextvars.ContextVar[bool | None] = \
    contextvars.ContextVar("repro_blocked_linear", default=None)


def blocked_linear_enabled() -> bool:
    v = _BLOCKED_LINEAR.get()
    if v is None:
        return os.environ.get("REPRO_BLOCKED_LINEAR") == "1"
    return v


@contextlib.contextmanager
def blocked_linear(enable: bool = True):
    """Route model projections (``ops.linear``) through the blocked,
    custom-VJP GEMM while tracing under this context."""
    tok = _BLOCKED_LINEAR.set(bool(enable))
    try:
        yield
    finally:
        _BLOCKED_LINEAR.reset(tok)


def linear(x: jax.Array, w, interpret: bool | None = None) -> jax.Array:
    """Projection ``x @ w`` for any-rank x; blocked + differentiable when
    blocked linears are enabled (see :func:`blocked_linear`).

    ``w`` may be a :class:`repro.quant.QuantizedTensor` (int8/fp8
    payload + fp32 scale): on TPU — or whenever blocked linears are on —
    2-D int8 weights route through the ``matmul_w8`` Pallas kernel
    (in-kernel dequant, 1-byte weight stream); otherwise the fp32
    dequant matmul runs, which is the fake-quant reference semantics.
    """
    from repro.quant.quantize import QuantizedTensor
    if isinstance(w, QuantizedTensor):
        return _quantized_linear(x, w, interpret)
    if not blocked_linear_enabled():
        return x @ w
    lead = x.shape[:-1]
    out = matmul(x.reshape(-1, x.shape[-1]), w, interpret=interpret)
    return out.reshape(*lead, w.shape[-1])


def _quantized_linear(x: jax.Array, w, interpret: bool | None):
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    use_kernel = (blocked_linear_enabled()
                  or jax.default_backend() == "tpu")
    if use_kernel and w.q.ndim == 2 and w.q.dtype == jnp.int8:
        out = matmul_w8(x2, w.q, w.scale.reshape(-1), interpret=interpret)
    else:
        out = (x2 @ w.dequant(jnp.float32)).astype(x.dtype)
    return out.reshape(*lead, w.shape[-1])


# -------------------------------- conv2d -----------------------------------


def _conv2d_fwd_impl(x, w, stride, tiles, interpret):
    n, h, wd, c = x.shape
    fh, fw, _, k = w.shape
    oh = (h - fh) // stride + 1
    ow = (wd - fw) // stride + 1
    bx, by, bc, bk = tiles or best_schedule(
        "conv2d", (ow, oh, c, k, fw, fh), x.dtype.name, stride=stride).tiles
    if c % bc or k % bk:
        return ref.conv2d_ref(x, w, stride)
    per_image = functools.partial(conv2d_tiled, w=w, bx=bx, by=by, bc=bc,
                                  bk=bk, stride=stride, interpret=interpret)
    return jax.vmap(per_image)(x)


@functools.lru_cache(maxsize=256)
def _conv2d_vjp(stride, tiles, interpret):
    @jax.custom_vjp
    def fn(x, w):
        return _conv2d_fwd_impl(x, w, stride, tiles, interpret)

    def fwd(x, w):
        return fn(x, w), (x, w)

    def bwd(res, g):
        x, w = res
        fh, fw = w.shape[0], w.shape[1]
        dx = conv2d_dgrad(g, w, x.shape, stride=stride, interpret=interpret)
        dw = conv2d_wgrad(x, g, fh, fw, stride=stride, interpret=interpret)
        return dx.astype(x.dtype), dw.astype(w.dtype)

    fn.defvjp(fwd, bwd)
    return fn


def conv2d(x: jax.Array, w: jax.Array, stride: int = 1,
           tiles: tuple[int, int, int, int] | None = None,
           interpret: bool | None = None) -> jax.Array:
    """Direct blocked conv, NHWC x HWIO -> NHWC (VALID padding).

    Level-1 spatial blocking (halo slices from HBM) happens outside the
    kernel; level-0 channel/kernel blocking inside.  Differentiable: the
    VJP runs the wgrad Pallas kernel and the transposed-conv dgrad under
    the ``"conv2d_wgrad"`` / ``"conv2d_dgrad"`` schedule keys.
    """
    interpret = default_interpret() if interpret is None else interpret
    return _conv2d_vjp(stride, tuple(tiles) if tiles else None,
                       interpret)(x, w)


# ------------------------------- attention ---------------------------------


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              logit_cap: float | None = None,
              tiles: tuple[int, int] | None = None,
              interpret: bool | None = None) -> jax.Array:
    """Multi-head attention with GQA.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D); Hq a multiple of Hkv.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0
    bq, bkv = tiles or flash_tiles(sq, skv, d, q.dtype.itemsize)
    interpret = default_interpret() if interpret is None else interpret
    use_kernel = sq % min(bq, sq) == 0 and skv % min(bkv, skv) == 0
    # roofline analysis variant: exact HLO flops without the Pallas
    # interpreter's while-loops.  "blocked" keeps flash-style O(S) memory.
    ref_mode = os.environ.get("REPRO_REF_ATTENTION")
    if ref_mode:
        use_kernel = False

    groups = hq // hkv
    qg = q.reshape(b, sq, hkv, groups, d)

    def one_head(qh, kh, vh):  # (Sq, D), (Skv, D), (Skv, D)
        if use_kernel:
            return flash_attention(qh, kh, vh, causal=causal, window=window,
                                   logit_cap=logit_cap, block_q=bq,
                                   block_kv=bkv, interpret=interpret)
        if ref_mode == "blocked":
            from repro.kernels.flash_attention import _blocked_ref
            return _blocked_ref(qh, kh, vh, causal=causal, window=window,
                                logit_cap=logit_cap, block_kv=bkv)
        return ref.attention_ref(qh, kh, vh, causal=causal,
                                 logit_cap=logit_cap, window=window)

    def per_kvhead(qh, kh, vh):  # qh: (Sq, G, D); kh, vh: (Skv, D)
        return jax.vmap(lambda qx: one_head(qx, kh, vh),
                        in_axes=1, out_axes=1)(qh)       # (Sq, G, D)

    # vmap over kv-heads (inner) and batch (outer)
    fn = jax.vmap(jax.vmap(per_kvhead))
    out = fn(qg.transpose(0, 2, 1, 3, 4),   # (B, Hkv, Sq, G, D)
             k.transpose(0, 2, 1, 3),       # (B, Hkv, Skv, D)
             v.transpose(0, 2, 1, 3))       # -> (B, Hkv, Sq, G, D)
    out = out.transpose(0, 2, 1, 3, 4).reshape(b, sq, hq, d)
    return out


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array, *,
                    window: int | None = None,
                    logit_cap: float | None = None,
                    k_scale: jax.Array | None = None,
                    v_scale: jax.Array | None = None,
                    use_kernel: bool | None = None,
                    interpret: bool | None = None) -> jax.Array:
    """Single-token attention over a paged KV cache (decode path).

    q: (B, Hq, D) — the current token's query rows; k/v_pages:
    (n_pages, page, Hkv, D); block_tables: (B, n_blocks) physical page
    per logical KV block; lengths: (B,) cache length per request
    *including* the token being decoded.  Returns (B, Hq, D).

    A 4-D ``q`` of shape (B, S, Hq, D) is the multi-position form
    (speculative verify / chunked prefill): the S positions are
    consecutive, their K/V already scattered into the pages, and
    ``lengths`` counts the cache including the FIRST of them.  Rows fold
    into the kernel's GQA group dim (``q_span = S``) so all S positions
    score in ONE flash-decode call over the same streamed pages; each
    position gets a causal per-row mask.  Returns (B, S, Hq, D).

    The page size doubles as the flash-decode kernel's KV block; it is
    chosen by ``repro.tune`` under the ``"flash_decode"`` op key when the
    paged cache is built (``serve.kv_cache.choose_page_size``).  With
    ``use_kernel=None`` the Pallas kernel runs on TPU and the vectorized
    jnp oracle runs elsewhere (the interpret-mode kernel is a correctness
    harness, not a CPU fast path); pass ``use_kernel=True`` to force the
    kernel (tests run it with ``interpret=True``).

    A 1-byte page pool (fp8 KV cache) routes to the fp8 kernel variant,
    whose schedule — and therefore the pool's page size — comes from the
    fp8-aware ``"flash_decode_fp8"`` op key.  ``k_scale``/``v_scale``
    are optional per-kv-head fp32 dequant scales (default: pure cast,
    which is exactly the dense ``kv_cache_dtype=fp8`` semantics, keeping
    the paged path token-exact against the fp8 dense path).
    """
    from repro.kernels.flash_decode import (flash_decode, flash_decode_fp8,
                                            paged_attention_fp8_ref,
                                            paged_attention_ref)
    multi = q.ndim == 4
    if multi:
        b, span, hq, d = q.shape
    else:
        b, hq, d = q.shape
        span = 1
    hkv = k_pages.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    if multi:
        # (B, S, Hq, D) -> (B, Hkv, S*G, D) with rows position-major
        # inside each kv head: row r of head h is position offset r // G,
        # local group r % G — the layout flash_decode's q_span mask
        # expects.
        qg = (q.transpose(0, 2, 1, 3)
               .reshape(b, hkv, g, span, d)
               .transpose(0, 1, 3, 2, 4)
               .reshape(b, hkv, span * g, d))
    else:
        qg = q.reshape(b, hkv, g, d)
    fp8 = jnp.dtype(k_pages.dtype).itemsize == 1
    scaled = k_scale is not None or v_scale is not None
    if scaled and not fp8:
        raise ValueError("k_scale/v_scale require a 1-byte (fp8) page pool")
    if fp8:
        # unit scales = pure-cast semantics, shared by kernel and oracle
        ks = jnp.ones(hkv, jnp.float32) if k_scale is None else k_scale
        vs = jnp.ones(hkv, jnp.float32) if v_scale is None else v_scale
    if _attn_kernels_on(use_kernel):
        interpret = default_interpret() if interpret is None else interpret
        if fp8:
            out = flash_decode_fp8(qg, k_pages, v_pages, ks, vs,
                                   block_tables, lengths, window=window,
                                   logit_cap=logit_cap, q_span=span,
                                   interpret=interpret)
        else:
            out = flash_decode(qg, k_pages, v_pages, block_tables, lengths,
                               window=window, logit_cap=logit_cap,
                               q_span=span, interpret=interpret)
    elif fp8 and scaled:
        out = paged_attention_fp8_ref(qg, k_pages, v_pages, ks, vs,
                                      block_tables, lengths, window=window,
                                      logit_cap=logit_cap, q_span=span)
    else:
        out = paged_attention_ref(qg, k_pages, v_pages, block_tables,
                                  lengths, window=window,
                                  logit_cap=logit_cap, q_span=span)
    if multi:
        return (out.reshape(b, hkv, span, g, d)
                   .transpose(0, 2, 1, 3, 4)
                   .reshape(b, span, hq, d))
    return out.reshape(b, hq, d)
