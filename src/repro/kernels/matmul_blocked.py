"""Blocked-GEMM Pallas kernel with optimizer-derived VMEM tiles.

The tile shape (bm, bk, bn) comes from the paper's blocking model
instantiated for the TPU hierarchy (``repro.core.tpu_adapter.matmul_tiles``)
— the HBM->VMEM boundary plays the role of DRAM->SRAM in the paper, and
fp32 accumulation in VMEM scratch is the paper's output buffer held across
the C (reduction) loop.

Grid order is (m, n, k) with k minor-most so the accumulator block stays
VMEM-resident across the whole reduction (the OB rule: allocate the output
buffer under the C loop).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def vmem_bytes_required(bm: int, bk: int, bn: int,
                        bytes_per_elem: int = 2) -> int:
    """VMEM footprint of one grid step of :func:`matmul_blocked`.

    The A and B tiles are streamed (Pallas double-buffers them across grid
    steps, hence the factor 2); the output block plus the fp32 accumulator
    scratch stay resident.  This is the single source of truth the
    schedule lowering checks tile candidates against.
    """
    streamed = 2 * (bm * bk + bk * bn) * bytes_per_elem
    resident = bm * bn * (bytes_per_elem + 4)
    return streamed + resident


def hbm_bytes(M: int, N: int, K: int, bm: int, bk: int, bn: int,
              bytes_per_elem: int = 2, w_bytes: int | None = None) -> int:
    """Exact HBM traffic of one :func:`matmul_blocked` call, in bytes.

    Counts the grid's block transfers under Pallas DMA elision: a block
    is refetched only when consecutive grid steps map it to a *different*
    block index.  With grid (M/bm, N/bn, K/bk), k minor-most:

    * the A block ``(i, kk)`` is refetched for every j-column — unless
      the reduction is a single block (``gk == 1``), when its index is
      constant across j and each A block moves once;
    * the B block ``(kk, j)`` changes every step, so the whole of B moves
      per i-row — unless B is a single block in both k and n, when it
      moves exactly once;
    * each output block is written once, at the last reduction step.

    ``w_bytes`` gives the B stream its own element width (int8 weights).
    The dims/tiles convention matches the ``"matmul"``/``"matmul_dgrad"``
    schedule keys, so the dgrad kernels (same streamed-operands layout,
    reduction minor-most) share this accounting verbatim.
    """
    gm, gn, gk = M // bm, N // bn, K // bk
    wb = bytes_per_elem if w_bytes is None else w_bytes
    a = M * K * bytes_per_elem * (gn if gk > 1 else 1)
    b = K * N * wb * (gm if (gk > 1 or gn > 1) else 1)
    out = M * N * bytes_per_elem
    return a + b + out


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "bn", "interpret"))
def matmul_blocked(a: jax.Array, b: jax.Array, *, bm: int, bk: int, bn: int,
                   interpret: bool = False) -> jax.Array:
    """C[M,N] = A[M,K] @ B[K,N] tiled (bm, bk, bn).  Dims must divide."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, \
        f"tiles ({bm},{bk},{bn}) must divide ({m},{k},{n})"
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
