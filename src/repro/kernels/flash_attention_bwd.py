"""Backward Pallas kernels for the streaming-softmax attention.

Flash-style recompute backward: the forward saves only the output ``o``
and the per-row log-sum-exp ``lse = m + log(l)``; the backward replays
each (q-block, kv-block) tile's scores in VMEM and accumulates

* ``dq`` over the KV loop (grid (nq, nkv), KV minor — the dq block is
  the OB resident across the reduction), and
* ``dk``/``dv`` over the Q loop (grid (nkv, nq), Q minor — the dk/dv
  blocks are the OB),

so nothing quadratic in sequence length ever exists in HBM.  In the
paper's vocabulary both passes are the same blocked nest as the forward
with the roles of the operands rotated; the (block_q, block_kv) tiles
are shared with the forward (``core.tpu_adapter.flash_tiles``).

``p = exp(s - lse)`` reconstructs the exact forward probabilities, and
``delta = rowsum(do * o)`` (computed host-side, O(S)) supplies the
softmax-jacobian correction ``ds = p * (dp - delta)``.  Gemma-2 logit
soft-capping backpropagates through ``d/ds cap*tanh(s/cap) = 1 - t^2``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import NEG_INF, attention_mask


def _block_ds(q, k, v, g, lse, delta, qi, ki, *, scale, causal, window,
              logit_cap, block_q, block_kv, kv_offset):
    """Recompute one tile's p and ds (both fp32, masked)."""
    s_pre = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if logit_cap is not None:
        t = jnp.tanh(s_pre / logit_cap)
        s = logit_cap * t
    else:
        s = s_pre
    mask = attention_mask(qi, ki, block_q=block_q, block_kv=block_kv,
                          causal=causal, window=window,
                          kv_offset=kv_offset)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)       # (bq, bkv)
    dp = jnp.dot(g, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - delta)
    if logit_cap is not None:
        ds = ds * (1.0 - t * t)                      # through the softcap
    return p, ds


def _dq_kernel(q_ref, k_ref, v_ref, g_ref, lse_ref, delta_ref, dq_ref,
               acc_ref, *, scale, causal, window, logit_cap, block_q,
               block_kv, n_kv, kv_offset):
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _, ds = _block_ds(
        q_ref[...].astype(jnp.float32), k_ref[...].astype(jnp.float32),
        v_ref[...].astype(jnp.float32), g_ref[...].astype(jnp.float32),
        lse_ref[...], delta_ref[...], qi, ki, scale=scale, causal=causal,
        window=window, logit_cap=logit_cap, block_q=block_q,
        block_kv=block_kv, kv_offset=kv_offset)
    acc_ref[...] += jnp.dot(ds, k_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(ki == n_kv - 1)
    def _done():
        dq_ref[...] = (acc_ref[...] * scale).astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, g_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *, scale, causal, window,
                logit_cap, block_q, block_kv, n_q, kv_offset):
    ki = pl.program_id(0)
    qi = pl.program_id(1)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    p, ds = _block_ds(
        q, k_ref[...].astype(jnp.float32),
        v_ref[...].astype(jnp.float32), g,
        lse_ref[...], delta_ref[...], qi, ki, scale=scale, causal=causal,
        window=window, logit_cap=logit_cap, block_q=block_q,
        block_kv=block_kv, kv_offset=kv_offset)
    dv_acc[...] += jnp.dot(p.T, g, preferred_element_type=jnp.float32)
    dk_acc[...] += jnp.dot(ds.T, q, preferred_element_type=jnp.float32)

    @pl.when(qi == n_q - 1)
    def _done():
        dk_ref[...] = (dk_acc[...] * scale).astype(dk_ref.dtype)
        dv_ref[...] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "logit_cap", "block_q", "block_kv", "interpret"))
def flash_attention_bwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        o: jax.Array, lse: jax.Array, g: jax.Array, *,
                        causal: bool, window: int | None,
                        logit_cap: float | None, block_q: int,
                        block_kv: int, interpret: bool = False):
    """(dq, dk, dv) for one head.  lse: (Sq, 1) fp32 from the forward."""
    sq, d = q.shape
    skv = k.shape[0]
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0, \
        (sq, block_q, skv, block_kv)
    n_q, n_kv = sq // block_q, skv // block_kv
    scale = d ** -0.5
    kv_offset = skv - sq
    delta = jnp.sum(g.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1, keepdims=True)          # (sq, 1)
    common = dict(scale=scale, causal=causal, window=window,
                  logit_cap=logit_cap, block_q=block_q, block_kv=block_kv,
                  kv_offset=kv_offset)
    q_spec = pl.BlockSpec((block_q, d), lambda a, b: (a, 0))
    kv_spec = pl.BlockSpec((block_kv, d), lambda a, b: (b, 0))
    row_spec = pl.BlockSpec((block_q, 1), lambda a, b: (a, 0))

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, n_kv=n_kv, **common),
        grid=(n_q, n_kv),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=pl.BlockSpec((block_q, d), lambda a, b: (a, 0)),
        out_shape=jax.ShapeDtypeStruct((sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, g, lse, delta)

    # second pass: Q minor-most so the dk/dv blocks stay resident
    q_spec2 = pl.BlockSpec((block_q, d), lambda a, b: (b, 0))
    kv_spec2 = pl.BlockSpec((block_kv, d), lambda a, b: (a, 0))
    row_spec2 = pl.BlockSpec((block_q, 1), lambda a, b: (b, 0))
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, n_q=n_q, **common),
        grid=(n_kv, n_q),
        in_specs=[q_spec2, kv_spec2, kv_spec2, q_spec2, row_spec2,
                  row_spec2],
        out_specs=[pl.BlockSpec((block_kv, d), lambda a, b: (a, 0)),
                   pl.BlockSpec((block_kv, d), lambda a, b: (a, 0))],
        out_shape=[jax.ShapeDtypeStruct((skv, d), k.dtype),
                   jax.ShapeDtypeStruct((skv, d), v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_kv, d), jnp.float32),
                        pltpu.VMEM((block_kv, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, g, lse, delta)
    return dq, dk, dv
