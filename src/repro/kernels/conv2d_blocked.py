"""Direct blocked convolution Pallas kernel (the paper's technique on TPU).

Two-level blocking, exactly the structure the paper's optimizer emits for
its Conv benchmarks:

* level 1 (outside the kernel, ops.py): spatial (X, Y) tiles with halo,
  sliced from HBM — the paper's outer ``X1/Y1`` loops with the KB held
  across them;
* level 0 (this kernel): channel/kernel (bc, bk) VMEM tiles — the grid is
  (K-tiles, C-tiles) with C minor-most so the fp32 accumulator (the OB)
  stays resident across the channel reduction, and the weight tile (the KB)
  is streamed per (k, c) step.  The Fw x Fh window loop runs inside the
  block over a VMEM-resident input tile, capturing the sliding-window
  reuse the paper contrasts against GEMM lowering (no data replication).

Layout: x (H, W, C) with halo included; w (Fh, Fw, C, K); out (OH, OW, K).
Batch is vmapped in ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def vmem_bytes_required(bx: int, by: int, bc: int, bk: int,
                        fh: int, fw: int, bytes_per_elem: int = 2,
                        stride: int = 1) -> int:
    """VMEM footprint of one grid step of :func:`conv2d_block`.

    The input tile carries the halo ((bx-1)*stride+fw wide); input and
    weight tiles are streamed across the (k, c) grid (double-buffered by
    the Pallas pipeline), while the output block and its fp32 accumulator
    scratch stay resident across the C reduction.
    """
    ih = (by - 1) * stride + fh
    iw = (bx - 1) * stride + fw
    streamed = 2 * (ih * iw * bc + fh * fw * bc * bk) * bytes_per_elem
    resident = bx * by * bk * (bytes_per_elem + 4)
    return streamed + resident


def hbm_bytes(X: int, Y: int, C: int, K: int, Fw: int, Fh: int,
              bx: int, by: int, bc: int, bk: int,
              bytes_per_elem: int = 2, stride: int = 1) -> int:
    """Exact HBM traffic of one image through :func:`conv2d_tiled`.

    Per (by, bx) level-1 spatial tile, the level-0 grid is (K/bk, C/bc)
    with C minor-most: the halo'd input tile is (0, 0, cc)-indexed, so
    it streams once per K block — elided to once total when C is a
    single block; the (cc, kk)-indexed weight tile changes every step
    (the whole filter bank moves once per spatial tile); each output
    block is written once at the last C step.  Dims are output-space
    (X, Y), matching the ``"conv2d"``/``"conv2d_dgrad"`` schedule keys;
    tiles must divide (the kernels' fallback paths are not counted).
    """
    gx, gy = X // bx, Y // by
    gk, gc = K // bk, C // bc
    ih = (by - 1) * stride + Fh
    iw = (bx - 1) * stride + Fw
    x_tile = ih * iw * C * bytes_per_elem * (gk if gc > 1 else 1)
    w_tile = Fh * Fw * C * K * bytes_per_elem
    out = X * Y * K * bytes_per_elem
    return gx * gy * (x_tile + w_tile) + out


def _conv_kernel(x_ref, w_ref, o_ref, acc_ref, *, fh: int, fw: int,
                 oh: int, ow: int, n_c: int, stride: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]  # (OH*stride + fh - 1, OW*stride + fw - 1, bc)
    bc = x.shape[-1]
    bk = acc_ref.shape[-1]
    acc = acc_ref[...].reshape(oh * ow, bk)
    for i in range(fh):
        for j in range(fw):
            # shifted window: the in-VMEM sliding reuse (shift-register
            # analogue from paper §4.2)
            patch = jax.lax.slice(
                x, (i, j, 0),
                (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, bc),
                (stride, stride, 1))                     # (OH, OW, bc)
            wij = w_ref[i, j, :, :]                      # (bc, bk)
            acc += jnp.dot(patch.reshape(oh * ow, bc), wij,
                           preferred_element_type=jnp.float32)
    acc_ref[...] = acc.reshape(oh, ow, bk)

    @pl.when(pl.program_id(1) == n_c - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def conv2d_tiled(img: jax.Array, w: jax.Array, *, bx: int, by: int,
                 bc: int, bk: int, stride: int = 1,
                 interpret: bool = False) -> jax.Array:
    """Level-1 spatial halo tiling around :func:`conv2d_block`, one image.

    The paper's outer ``X1/Y1`` loops: each (by, bx) output tile slices
    its halo'd input window from HBM and runs the level-0 Pallas kernel.
    Ragged spatial extents collapse to a single tile.  Shared by the
    forward op (``ops.conv2d`` vmaps it over batch) and the dgrad driver
    (``conv2d_bwd``), whose transposed conv is this same nest.
    """
    fh, fw = w.shape[0], w.shape[1]
    oh = (img.shape[0] - fh) // stride + 1
    ow = (img.shape[1] - fw) // stride + 1
    if oh % by or ow % bx:
        by, bx = oh, ow  # ragged spatial: single tile
    rows = []
    for ty in range(0, oh, by):
        cols = []
        for tx in range(0, ow, bx):
            tile = jax.lax.dynamic_slice(
                img, (ty * stride, tx * stride, 0),
                ((by - 1) * stride + fh, (bx - 1) * stride + fw,
                 img.shape[2]))
            cols.append(conv2d_block(tile, w, bc=bc, bk=bk, stride=stride,
                                     interpret=interpret))
        rows.append(jnp.concatenate(cols, axis=1))
    return jnp.concatenate(rows, axis=0)


@functools.partial(jax.jit, static_argnames=("bc", "bk", "stride",
                                             "interpret"))
def conv2d_block(x: jax.Array, w: jax.Array, *, bc: int, bk: int,
                 stride: int = 1, interpret: bool = False) -> jax.Array:
    """One spatial tile: x (IH, IW, C) already includes the halo."""
    ih, iw, c = x.shape
    fh, fw, c2, k = w.shape
    assert c == c2
    assert c % bc == 0 and k % bk == 0, (c, bc, k, bk)
    oh = (ih - fh) // stride + 1
    ow = (iw - fw) // stride + 1
    grid = (k // bk, c // bc)  # C minor-most: OB resident across reduction
    return pl.pallas_call(
        functools.partial(_conv_kernel, fh=fh, fw=fw, oh=oh, ow=ow,
                          n_c=grid[1], stride=stride),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ih, iw, bc), lambda kk, cc: (0, 0, cc)),
            pl.BlockSpec((fh, fw, bc, bk), lambda kk, cc: (0, 0, cc, kk)),
        ],
        out_specs=pl.BlockSpec((oh, ow, bk), lambda kk, cc: (0, 0, kk)),
        out_shape=jax.ShapeDtypeStruct((oh, ow, k), x.dtype),
        scratch_shapes=[pltpu.VMEM((oh, ow, bk), jnp.float32)],
        interpret=interpret,
    )(x, w)
