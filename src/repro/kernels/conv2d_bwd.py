"""Backward Pallas kernels for the direct blocked convolution.

The paper's blocking analysis applies to the backward nests unchanged,
because both are CNN-like loop nests over the same six dims:

* **wgrad** ``dW[i,j,c,k] = sum_{n,y,x} X[n, y*s+i, x*s+j, c] *
  g[n, y, x, k]`` — the same (Fw, Fh, X, Y, C, K) nest with the weights
  as the written operand and the output space (X, Y) as the reduction.
  Lowered here as a dedicated kernel: the dW tile is the OB held
  VMEM-resident while a whole level-1 spatial tile reduces into it, and
  the grid is (K-tiles, C-tiles) writing disjoint dW slabs.
* **dgrad** ``dX = conv(dilate_s(g) pad (Fh-1, Fw-1), rot180(W)^T)`` —
  a *transposed* convolution, i.e. another direct conv with the channel
  dims swapped (K in, C out) and stride folded into host-side input
  dilation.  It reuses the forward level-0 kernel + level-1 tiling
  (``conv2d_blocked.conv2d_tiled``) under its own schedule key.

Schedules come from ``repro.tune.best_schedule`` under the op keys
``"conv2d_wgrad"`` / ``"conv2d_dgrad"``; non-dividing channel tiles fall
back to the jnp oracles in ``repro.kernels.ref`` so ``jax.grad`` through
``ops.conv2d`` works unconditionally.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref
from repro.kernels.conv2d_blocked import conv2d_tiled


def vmem_bytes_required(bx: int, by: int, bc: int, bk: int,
                        fh: int, fw: int, bytes_per_elem: int = 2,
                        stride: int = 1) -> int:
    """VMEM footprint of one grid step of :func:`conv2d_wgrad_block`.

    The halo'd input tile and the cotangent tile are streamed across the
    (k, c) grid (double-buffered); the fp32 dW block being produced is
    resident.  (dgrad reuses the forward kernel, hence the forward
    ``conv2d_blocked.vmem_bytes_required``.)
    """
    ih = (by - 1) * stride + fh
    iw = (bx - 1) * stride + fw
    streamed = 2 * (ih * iw * bc + by * bx * bk) * bytes_per_elem
    resident = fh * fw * bc * bk * 4
    return streamed + resident


def hbm_bytes(X: int, Y: int, C: int, K: int, Fw: int, Fh: int,
              bx: int, by: int, bc: int, bk: int,
              bytes_per_elem: int = 2, stride: int = 1) -> int:
    """Exact HBM traffic of one image through :func:`conv2d_wgrad`.

    Per (by, bx) spatial reduction tile, the (K/bk, C/bc) grid streams
    the halo'd input tile once per K block (elided when C is a single
    block) and the (0, 0, kk)-indexed cotangent tile once per K block
    (its index is constant across the minor C dim), and writes the
    whole fp32 dW once (every (cc, kk) cell writes its disjoint slab).
    Dims follow the ``"conv2d_wgrad"`` key (the forward's, verbatim).
    """
    gx, gy = X // bx, Y // by
    gk, gc = K // bk, C // bc
    ih = (by - 1) * stride + Fh
    iw = (bx - 1) * stride + Fw
    per_tile = (ih * iw * C * bytes_per_elem * (gk if gc > 1 else 1)
                + by * bx * K * bytes_per_elem
                + Fh * Fw * C * K * 4)
    return gx * gy * per_tile


def _wgrad_kernel(x_ref, g_ref, o_ref, *, fh: int, fw: int,
                  oh: int, ow: int, stride: int):
    x = x_ref[...]                                   # (ih, iw, bc)
    bc = x.shape[-1]
    bk = o_ref.shape[-1]
    g = g_ref[...].astype(jnp.float32).reshape(oh * ow, bk)
    for i in range(fh):
        for j in range(fw):
            patch = jax.lax.slice(
                x, (i, j, 0),
                (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, bc),
                (stride, stride, 1))                 # (oh, ow, bc)
            o_ref[i, j, :, :] = jnp.dot(
                patch.reshape(oh * ow, bc).astype(jnp.float32).T, g,
                preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bc", "bk", "stride",
                                             "interpret"))
def conv2d_wgrad_block(x: jax.Array, g: jax.Array, *, bc: int, bk: int,
                       stride: int = 1, interpret: bool = False
                       ) -> jax.Array:
    """dW partial for one spatial tile: x (IH, IW, C) includes the halo,
    g (OH, OW, K) is the matching cotangent tile.  Returns fp32
    (Fh, Fw, C, K); the caller accumulates across tiles and batch."""
    ih, iw, c = x.shape
    oh, ow, k = g.shape
    fh = ih - (oh - 1) * stride
    fw = iw - (ow - 1) * stride
    assert fh >= 1 and fw >= 1, (x.shape, g.shape, stride)
    assert c % bc == 0 and k % bk == 0, (c, bc, k, bk)
    grid = (k // bk, c // bc)
    return pl.pallas_call(
        functools.partial(_wgrad_kernel, fh=fh, fw=fw, oh=oh, ow=ow,
                          stride=stride),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ih, iw, bc), lambda kk, cc: (0, 0, cc)),
            pl.BlockSpec((oh, ow, bk), lambda kk, cc: (0, 0, kk)),
        ],
        out_specs=pl.BlockSpec((fh, fw, bc, bk),
                               lambda kk, cc: (0, 0, cc, kk)),
        out_shape=jax.ShapeDtypeStruct((fh, fw, c, k), jnp.float32),
        interpret=interpret,
    )(x, g)


def conv2d_wgrad(x: jax.Array, g: jax.Array, fh: int, fw: int,
                 stride: int = 1,
                 tiles: tuple[int, int, int, int] | None = None,
                 interpret: bool = False) -> jax.Array:
    """dW[Fh,Fw,C,K] for y = conv2d(x, w, stride), NHWC cotangent g.

    Level-1 spatial tiles reduce into the host fp32 accumulator; level-0
    channel blocking runs inside the Pallas kernel.  Tiles come from the
    ``"conv2d_wgrad"`` schedule; ragged channel tiles take the oracle.
    """
    from repro.tune import best_schedule

    n, h, wd, c = x.shape
    _, oh, ow, k = g.shape
    bx, by, bc, bk = tiles or best_schedule(
        "conv2d_wgrad", (ow, oh, c, k, fw, fh), g.dtype.name,
        stride=stride).tiles
    if c % bc or k % bk:
        return ref.conv2d_wgrad_ref(x, g, (fh, fw, c, k), stride)
    # forward only reads the stride-reachable interior; clip the remainder
    x = x[:, :(oh - 1) * stride + fh, :(ow - 1) * stride + fw, :]
    if oh % by or ow % bx:
        by, bx = oh, ow  # ragged spatial: single tile

    def one_image(acc, xg):
        img, gi = xg
        for ty in range(0, oh, by):
            for tx in range(0, ow, bx):
                xt = jax.lax.dynamic_slice(
                    img, (ty * stride, tx * stride, 0),
                    ((by - 1) * stride + fh, (bx - 1) * stride + fw, c))
                gt = jax.lax.dynamic_slice(gi, (ty, tx, 0), (by, bx, k))
                acc += conv2d_wgrad_block(xt, gt, bc=bc, bk=bk,
                                          stride=stride,
                                          interpret=interpret)
        return acc, None

    # scan, not vmap+sum: one live fp32 dW carry instead of N partials
    init = jnp.zeros((fh, fw, c, k), jnp.float32)
    acc, _ = jax.lax.scan(one_image, init, (x, g))
    return acc


def conv2d_dgrad(g: jax.Array, w: jax.Array,
                 x_shape: tuple[int, ...], stride: int = 1,
                 tiles: tuple[int, int, int, int] | None = None,
                 interpret: bool = False) -> jax.Array:
    """dX[N,H,W,C] for y = conv2d(x, w, stride), NHWC cotangent g.

    Host side: dilate g by the stride, pad by the filter minus one, and
    rotate/transpose the weights; the remaining work is a stride-1 direct
    conv with (K -> C) channels, run through the forward Pallas kernel
    under the ``"conv2d_dgrad"`` schedule key.
    """
    from repro.tune import best_schedule

    n, h, wd, c = x_shape
    fh, fw, _, k = w.shape
    _, oh, ow, _ = g.shape
    if stride > 1:  # transposed conv: input dilation
        gd = jnp.zeros((n, (oh - 1) * stride + 1, (ow - 1) * stride + 1, k),
                       g.dtype)
        gd = gd.at[:, ::stride, ::stride, :].set(g)
    else:
        gd = g
    gp = jnp.pad(gd, ((0, 0), (fh - 1, fh - 1), (fw - 1, fw - 1), (0, 0)))
    w_t = w[::-1, ::-1].transpose(0, 1, 3, 2)        # (Fh, Fw, K, C)
    oh_d = (oh - 1) * stride + fh                    # == H minus remainder
    ow_d = (ow - 1) * stride + fw
    bx, by, bc, bk = tiles or best_schedule(
        "conv2d_dgrad", (ow_d, oh_d, k, c, fw, fh), g.dtype.name).tiles
    if k % bc or c % bk:
        return ref.conv2d_dgrad_ref(g, w, x_shape, stride)
    per_image = functools.partial(conv2d_tiled, w=w_t, bx=bx, by=by,
                                  bc=bc, bk=bk, stride=1,
                                  interpret=interpret)
    dx = jax.vmap(per_image)(gp)                     # (N, oh_d, ow_d, C)
    # rows/cols the strided forward never read have zero gradient
    return jnp.pad(dx, ((0, 0), (0, h - oh_d), (0, wd - ow_d), (0, 0)))
