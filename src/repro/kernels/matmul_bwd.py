"""Backward (dgrad) Pallas kernels for the blocked GEMM.

For ``C[M,N] = A[M,K] @ B[K,N]`` the two cotangents are themselves GEMMs
over the same data, just with one operand read transposed:

* ``dA[M,K] = g[M,N] @ B[K,N]^T``  — an NT GEMM (reduction over N);
* ``dB[K,N] = A[M,K]^T @ g[M,N]``  — a TN GEMM (reduction over M).

Both are lowered here as first-class Pallas kernels: the transposed
operand is *accessed* transposed via the BlockSpec index map plus an
in-register ``.T`` on the VMEM tile, never materialized in HBM.  Each
nest is the paper's GEMM loop nest with relabelled dims, so its schedule
comes from the same blocking optimizer under the op key
``"matmul_dgrad"`` with dims in the standard (M_out, N_out, K_reduce)
convention of the output being produced (see ``repro.tune.schedule``).

Grid order mirrors the forward kernel: reduction minor-most so the fp32
accumulator (the paper's OB) stays VMEM-resident across it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.matmul_blocked import hbm_bytes, vmem_bytes_required

__all__ = ["matmul_dgrad_a", "matmul_dgrad_b", "hbm_bytes",
           "vmem_bytes_required"]

# dgrad tiles stream two operand blocks and hold one fp32 accumulator,
# exactly like the forward kernel: the VMEM footprint model is shared,
# and so is the exact HBM accounting — both nests stream their two read
# operands with the reduction minor-most, so ``hbm_bytes`` applies with
# the ``"matmul_dgrad"`` (M_out, N_out, K_reduce) dims convention.


def _dgrad_a_kernel(g_ref, b_ref, o_ref, acc_ref, *, n_r: int):
    """dA tile += g_tile @ b_tile.T (reduction over the N tiles)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(g_ref[...], b_ref[...].T,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_r - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "br", "bo", "interpret"))
def matmul_dgrad_a(g: jax.Array, b: jax.Array, *, bm: int, br: int, bo: int,
                   interpret: bool = False) -> jax.Array:
    """dA[M,K] = g[M,N] @ B[K,N]^T, tiled (bm rows, br of N, bo of K)."""
    m, n = g.shape
    k, n2 = b.shape
    assert n == n2, (g.shape, b.shape)
    assert m % bm == 0 and n % br == 0 and k % bo == 0, \
        f"dgrad-A tiles ({bm},{br},{bo}) must divide ({m},{n},{k})"
    grid = (m // bm, k // bo, n // br)
    return pl.pallas_call(
        functools.partial(_dgrad_a_kernel, n_r=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, br), lambda i, j, r: (i, r)),
            pl.BlockSpec((bo, br), lambda i, j, r: (j, r)),
        ],
        out_specs=pl.BlockSpec((bm, bo), lambda i, j, r: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), g.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bo), jnp.float32)],
        interpret=interpret,
    )(g, b)


def _dgrad_b_kernel(a_ref, g_ref, o_ref, acc_ref, *, n_r: int):
    """dB tile += a_tile.T @ g_tile (reduction over the M tiles)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...].T, g_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_r - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "br", "bn", "interpret"))
def matmul_dgrad_b(a: jax.Array, g: jax.Array, *, bk: int, br: int, bn: int,
                   interpret: bool = False) -> jax.Array:
    """dB[K,N] = A[M,K]^T @ g[M,N], tiled (bk of K, br of M, bn of N)."""
    m, k = a.shape
    m2, n = g.shape
    assert m == m2, (a.shape, g.shape)
    assert k % bk == 0 and m % br == 0 and n % bn == 0, \
        f"dgrad-B tiles ({bk},{br},{bn}) must divide ({k},{m},{n})"
    grid = (k // bk, n // bn, m // br)
    return pl.pallas_call(
        functools.partial(_dgrad_b_kernel, n_r=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bk), lambda i, j, r: (r, i)),
            pl.BlockSpec((br, bn), lambda i, j, r: (r, j)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j, r: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
        interpret=interpret,
    )(a, g)
