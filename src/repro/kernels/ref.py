"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C[M,N] = A[M,K] @ B[K,N] with fp32 accumulation."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def conv2d_ref(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """Direct 2-D convolution (cross-correlation, VALID padding).

    x: (N, H, W, C)   w: (Fh, Fw, C, K)   ->   (N, H', W', K)
    """
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out.astype(x.dtype)


def conv2d_im2col(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """The Caffe-style lowering baseline (paper §2.2): explicit im2col
    followed by one GEMM.  Numerically identical to conv2d_ref; exists so
    tests can assert the two data layouts agree and so benchmarks can count
    the replicated lowered-matrix size."""
    n, h, wd, c = x.shape
    fh, fw, _, k = w.shape
    oh = (h - fh) // stride + 1
    ow = (wd - fw) // stride + 1
    patches = []
    for i in range(fh):
        for j in range(fw):
            patches.append(
                jax.lax.slice(x, (0, i, j, 0),
                              (n, i + oh * stride, j + ow * stride, c),
                              (1, stride, stride, 1)))
    lowered = jnp.concatenate(patches, axis=-1)          # (N,OH,OW,Fh*Fw*C)
    wmat = w.transpose(0, 1, 2, 3).reshape(fh * fw * c, k)
    out = jnp.einsum("nhwp,pk->nhwk", lowered.astype(jnp.float32),
                     wmat.astype(jnp.float32))
    return out.astype(x.dtype)


def conv2d_wgrad_ref(x: jax.Array, g: jax.Array,
                     w_shape: tuple[int, ...], stride: int = 1
                     ) -> jax.Array:
    """Oracle dW for ``conv2d_ref``: the transpose of the (linear) forward
    map w -> conv(x, w), evaluated on the cotangent g."""
    zero_w = jnp.zeros(w_shape, g.dtype)
    _, vjp = jax.vjp(lambda w: conv2d_ref(x, w, stride), zero_w)
    return vjp(g)[0]


def conv2d_dgrad_ref(g: jax.Array, w: jax.Array,
                     x_shape: tuple[int, ...], stride: int = 1
                     ) -> jax.Array:
    """Oracle dX for ``conv2d_ref``: transpose of x -> conv(x, w)."""
    zero_x = jnp.zeros(x_shape, g.dtype)
    _, vjp = jax.vjp(lambda x: conv2d_ref(x, w, stride), zero_x)
    return vjp(g)[0]


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True, scale: float | None = None,
                  logit_cap: float | None = None,
                  window: int | None = None) -> jax.Array:
    """Softmax attention oracle.  q,k,v: (Sq, D), (Skv, D), (Skv, D)."""
    sq, d = q.shape
    skv = k.shape[0]
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("qd,kd->qk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if logit_cap is not None:
        logits = logit_cap * jnp.tanh(logits / logit_cap)
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("qk,kd->qd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)
