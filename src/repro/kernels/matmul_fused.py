"""Epilogue-fused blocked GEMM Pallas kernel (wide and int8-weight).

``Y = act(A @ W + bias) * mul + residual`` in ONE kernel: the output
tile never leaves VMEM between the reduction and its pointwise tail, so
the activation round-trip and the residual add's extra pass — whole
(M, N) tensors of HBM traffic in the per-op chain — disappear.  This is
the kernel realization of ``core.fusion``'s always-fusible epilogue
edges; the tile schedule comes from the ``"matmul_fused"`` tune key
(``"matmul_w8"`` when the weight is int8 — the dtype-aware search from
PR 4 composes unchanged, the epilogue only adds streamed tiles).

Grid order matches :mod:`repro.kernels.matmul_blocked`: (m, n, k) with
k minor-most; the fp32 accumulator is the paper's OB held across the
whole reduction, and the epilogue runs exactly once per output block at
the last k step.  Epilogue operand tiles (bias row, mul/residual
blocks) are indexed (i, j) only, so Pallas fetches each exactly once
per output block — :func:`hbm_bytes` counts that traffic exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ACTIVATIONS = {
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def vmem_bytes_required(bm: int, bk: int, bn: int,
                        bytes_per_elem: int = 2,
                        w_bytes: int | None = None,
                        has_bias: bool = True,
                        n_extra: int = 2) -> int:
    """VMEM footprint of one grid step of :func:`matmul_fused`.

    The A and W tiles are streamed (double-buffered) at their own
    widths; the output block + fp32 accumulator stay resident; each
    epilogue operand adds a double-buffered streamed tile (bias: one
    (1, bn) fp32 row; mul/residual: one (bm, bn) block each).  The
    schedule filter sizes for the worst case (bias + mul + residual) so
    one cached schedule serves every epilogue combination.
    """
    wb = w_bytes or bytes_per_elem
    streamed = 2 * (bm * bk * bytes_per_elem + bk * bn * wb)
    resident = bm * bn * (bytes_per_elem + 4)
    epilogue = (2 * bn * 4 if has_bias else 0) + \
        n_extra * 2 * bm * bn * bytes_per_elem
    scale_row = 2 * bn * 4 if w_bytes is not None else 0
    return streamed + resident + epilogue + scale_row


def hbm_bytes(M: int, N: int, K: int, bm: int, bk: int, bn: int,
              bytes_per_elem: int = 2, w_bytes: int | None = None,
              has_bias: bool = False, has_mul: bool = False,
              has_residual: bool = False) -> int:
    """Exact HBM traffic of one :func:`matmul_fused` call.

    This is not a model estimate: it counts the blocks the grid
    actually transfers (Pallas skips a DMA only when consecutive grid
    steps map to the same block — with k minor-most that elides the
    output across the reduction, the A stream when the reduction is a
    single block, and the (i, j)-indexed epilogue tiles across k).  The
    benchmark's "measured DRAM bytes" column is this number for the
    executed schedule; ``tune.predicted_dram_bytes`` is the model's.
    """
    from repro.kernels.matmul_blocked import hbm_bytes as gemm_bytes
    gm, gn = M // bm, N // bn
    total = gemm_bytes(M, N, K, bm, bk, bn, bytes_per_elem, w_bytes)
    # (0, j)-indexed fp32 rows: constant across k, refetched per i-row
    # only when the row actually changes between i-rows (gn > 1)
    row = N * 4 * (gm if gn > 1 else 1)
    if w_bytes is not None:
        total += row                             # dequant scale row
    if has_bias:
        total += row
    if has_mul:
        total += M * N * bytes_per_elem
    if has_residual:
        total += M * N * bytes_per_elem
    return total


def _fused_kernel(*refs, n_k: int, act: str, has_scale: bool,
                  has_bias: bool, has_mul: bool, has_res: bool):
    it = iter(refs)
    a_ref, w_ref = next(it), next(it)
    s_ref = next(it) if has_scale else None
    bias_ref = next(it) if has_bias else None
    mul_ref = next(it) if has_mul else None
    res_ref = next(it) if has_res else None
    o_ref, acc_ref = next(it), next(it)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32) if has_scale else a_ref[...]
    w = w_ref[...].astype(jnp.float32) if has_scale else w_ref[...]
    acc_ref[...] += jnp.dot(a, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        y = acc_ref[...]
        if has_scale:           # w8: per-output-channel dequant scale
            y = y * s_ref[...]
        if has_bias:
            y = y + bias_ref[...]
        y = ACTIVATIONS[act](y)
        if has_mul:
            y = y * mul_ref[...].astype(jnp.float32)
        if has_res:
            y = y + res_ref[...].astype(jnp.float32)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("act", "bm", "bk", "bn",
                                             "interpret"))
def matmul_fused(a: jax.Array, w: jax.Array,
                 scale: jax.Array | None = None,
                 bias: jax.Array | None = None,
                 mul: jax.Array | None = None,
                 residual: jax.Array | None = None, *,
                 act: str = "none",
                 bm: int, bk: int, bn: int,
                 interpret: bool = False) -> jax.Array:
    """``act(A[M,K] @ W[K,N] (*scale) + bias) * mul + residual``.

    ``w`` int8 with fp32 ``scale`` (per-channel ``(N,)`` or scalar) is
    the quantized path — in-kernel dequant exactly as
    :mod:`repro.kernels.matmul_q`.  ``bias``: (N,); ``mul`` (the SwiGLU
    gating operand) and ``residual``: (M, N).  Dims must divide the
    tiles; ragged shapes take :func:`matmul_fused_ref` via
    ``kernels.ops``.
    """
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, (a.shape, w.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, \
        f"tiles ({bm},{bk},{bn}) must divide ({m},{k},{n})"
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}")
    grid = (m // bm, n // bn, k // bk)

    inputs: list[jax.Array] = [a, w]
    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))]
    row_spec = pl.BlockSpec((1, bn), lambda i, j, kk: (0, j))
    blk_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    if scale is not None:
        inputs.append(jnp.broadcast_to(
            jnp.asarray(scale, jnp.float32).reshape(1, -1), (1, n)))
        in_specs.append(row_spec)
    if bias is not None:
        inputs.append(jnp.asarray(bias, jnp.float32).reshape(1, n))
        in_specs.append(row_spec)
    if mul is not None:
        assert mul.shape == (m, n), mul.shape
        inputs.append(mul)
        in_specs.append(blk_spec)
    if residual is not None:
        assert residual.shape == (m, n), residual.shape
        inputs.append(residual)
        in_specs.append(blk_spec)

    return pl.pallas_call(
        functools.partial(_fused_kernel, n_k=grid[2], act=act,
                          has_scale=scale is not None,
                          has_bias=bias is not None,
                          has_mul=mul is not None,
                          has_res=residual is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*inputs)


def matmul_fused_ref(a: jax.Array, w: jax.Array,
                     scale: jax.Array | None = None,
                     bias: jax.Array | None = None,
                     mul: jax.Array | None = None,
                     residual: jax.Array | None = None, *,
                     act: str = "none") -> jax.Array:
    """jnp oracle with bit-comparable math: fp32 accumulate, scale then
    bias then activation then mul then residual, cast once at the end.
    The correctness oracle in tests, the ragged-shape fallback in
    ``kernels.ops``, and the off-TPU fast path (XLA fuses the epilogue
    itself there)."""
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}")
    if scale is not None:
        y = jnp.dot(a.astype(jnp.float32), w.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        y = y * jnp.asarray(scale, jnp.float32).reshape(1, -1)
    else:
        y = jnp.dot(a, w, preferred_element_type=jnp.float32)
    if bias is not None:
        y = y + jnp.asarray(bias, jnp.float32).reshape(1, -1)
    y = ACTIVATIONS[act](y)
    if mul is not None:
        y = y * mul.astype(jnp.float32)
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    return y.astype(a.dtype)
