"""Chrome-trace-format step-span tracer for the serving engines.

Emits the Trace Event Format that ``chrome://tracing`` and Perfetto
load: a JSON array of complete-duration events (``"ph": "X"``) with
microsecond timestamps, written one event per line so the file doubles
as line-oriented JSONL while staying a single valid JSON document
(the array is opened on construction and closed by :meth:`close`).

JAX-awareness is the engines' side of the contract: device dispatches
return before the work finishes, so a span around a ``jit`` call times
only host-side dispatch unless the engine fences with
``jax.block_until_ready`` — which it does ONLY when a tracer is
attached.  With tracing off the engines never construct span objects,
never fence, and pay nothing (see ``tests/test_obs.py``'s zero-sync
guard).
"""

from __future__ import annotations

import json
import os
import time


class _Span:
    """Context manager emitting one complete ('X') event on exit.

    Reused per-call (not pooled): creation is two attribute stores.
    """

    __slots__ = ("tracer", "name", "cat", "args", "t0")

    def __init__(self, tracer: "StepTracer", name: str, cat: str, args):
        self.tracer, self.name, self.cat, self.args = tracer, name, cat, args

    def __enter__(self):
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        tr = self.tracer
        ev = {"name": self.name, "ph": "X", "cat": self.cat,
              "ts": (self.t0 - tr.epoch_ns) / 1000.0,
              "dur": (t1 - self.t0) / 1000.0,
              "pid": tr.pid, "tid": 0}
        if self.args:
            ev["args"] = self.args
        tr._emit(ev)
        return False


class _NullSpan:
    """Shared no-op span for the tracing-off path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


def null_span(name: str, cat: str = "serve", args=None) -> _NullSpan:
    return NULL_SPAN


class StepTracer:
    """Writes Chrome-trace events to ``path``.

    Nested :meth:`span` calls produce properly-nested intervals (inner
    spans close — and therefore appear in the file — before their
    enclosing span; viewers nest by interval containment, not file
    order).  Timestamps are microseconds from a per-tracer epoch on a
    monotonic clock.
    """

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(self.path, "w")
        self._f.write("[\n")
        self._first = True
        self.pid = os.getpid()
        self.epoch_ns = time.perf_counter_ns()

    def _emit(self, ev: dict) -> None:
        if self._f is None:
            return
        if self._first:
            self._first = False
        else:
            self._f.write(",\n")
        self._f.write(json.dumps(ev, separators=(",", ":")))

    def span(self, name: str, cat: str = "serve", args=None) -> _Span:
        """``with tracer.span("plan"): ...`` — one 'X' event on exit."""
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "serve", args=None) -> None:
        """Zero-duration marker ('i' event, thread scope)."""
        ev = {"name": name, "ph": "i", "s": "t", "cat": cat,
              "ts": (time.perf_counter_ns() - self.epoch_ns) / 1000.0,
              "pid": self.pid, "tid": 0}
        if args:
            ev["args"] = args
        self._emit(ev)

    def counter(self, name: str, values: dict) -> None:
        """Counter-track sample ('C' event): ``values`` maps series name
        to number; Perfetto renders one stacked track per name."""
        self._emit({"name": name, "ph": "C",
                    "ts": (time.perf_counter_ns() - self.epoch_ns) / 1000.0,
                    "pid": self.pid, "tid": 0, "args": dict(values)})

    def close(self) -> None:
        """Close the JSON array and the file.  Idempotent."""
        if self._f is None:
            return
        self._f.write("\n]\n")
        self._f.close()
        self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
