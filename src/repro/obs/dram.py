"""Modeled-vs-measured DRAM accounting and schedule-cache telemetry.

The paper's contribution is an analytical model that *predicts* memory
traffic; this module closes the loop at serving time.  Every schedule
resolution that flows through ``repro.tune.best_schedule`` — which is
every tuned-op invocation, since ``kernels.ops`` consults it at jit
TRACE time — is observed by the active :class:`DramLedger`, which
records three things per op key:

* **model said X** — ``predicted_dram_bytes`` of the analytic top
  candidate for that spec (what the paper's search would pick today);
* **schedule cache says Y** — ``predicted_dram_bytes`` of the tiles the
  op actually ran with (a cache hit's persisted winner, or the same
  analytic tiles on a miss), and the ratio **Z = Y / X**;
* **cache hit or miss** — misses (resolutions that fell back to the
  in-process analytic default instead of a persisted, measured
  schedule) are counted in the registry and appended to a JSONL *miss
  log* that ``python -m repro.tune --from-telemetry <log>`` replays as
  tuning targets.  This is the fleet-telemetry → next-tuning-pass loop.

Attribution works on the jit trace/execute split.  ``best_schedule``
fires once per trace signature, not once per step, so the engine brackets
each jitted dispatch in a :meth:`DramLedger.scope` tagged with the jit
variant (``"decode[8]"``, ``"prefill[64]"``, ``"join[128]"``…).  The
first execution of a tag traces and registers the tag's per-execution
byte cost; every execution increments the tag's count, so per-step and
per-request aggregation is resolution-bytes × execution-count — no
device interaction, no per-op runtime hooks.

Only one ledger observes at a time (a contextvar set by ``scope``);
code running outside any scope is unobserved and pays a single None
check inside ``best_schedule``.
"""

from __future__ import annotations

import contextlib
import contextvars
import json
import os

from repro import tune
from repro.tune.schedule import OpSpec, Schedule

_ACTIVE: contextvars.ContextVar["DramLedger | None"] = \
    contextvars.ContextVar("repro_obs_dram_ledger", default=None)


def _dispatch(spec: OpSpec, schedule: Schedule) -> None:
    led = _ACTIVE.get()
    if led is not None:
        led.record(spec, schedule)


# one process-wide observer; which ledger (if any) hears about a
# resolution is decided by the scope contextvar above
tune.set_schedule_observer(_dispatch)


class DramLedger:
    """Per-op-key modeled-vs-measured DRAM byte accounting.

    ``registry`` (optional) receives ``schedule_cache.hits`` /
    ``schedule_cache.misses`` counters; ``miss_log`` (optional path)
    receives one JSONL line per distinct missed op key.
    """

    def __init__(self, registry=None, miss_log: str | None = None):
        self._device = None                 # resolved lazily (pulls in jax)
        self._tag: str | None = None        # active scope tag
        # key -> {"spec", "tiles", "source", "resolved": n, "used_bytes",
        #          "modeled_bytes"}
        self._ops: dict[str, dict] = {}
        self._tag_bytes: dict[str, int] = {}   # per-execution bytes by tag
        self._tag_ops: dict[str, set[str]] = {}
        self._execs: dict[str, int] = {}       # executions by tag
        self._step_hist: list[int] = []        # bytes attributed per step
        self._req_bytes: dict[int, float] = {}  # rid -> attributed bytes
        self._pending = 0                      # bytes since last attribute()
        self._logged: set[str] = set()
        self._miss_log_path = miss_log
        self._miss_f = None
        if registry is not None:
            self._m_hits = registry.counter("schedule_cache.hits")
            self._m_misses = registry.counter("schedule_cache.misses")
        else:
            self._m_hits = self._m_misses = None

    # -- observation ----------------------------------------------------------

    @contextlib.contextmanager
    def scope(self, tag: str):
        """Make this ledger the active observer, attributing any schedule
        resolutions inside to ``tag``, and count one execution of it."""
        token = _ACTIVE.set(self)
        prev = self._tag
        self._tag = tag
        try:
            yield self
        finally:
            self._tag = prev
            _ACTIVE.reset(token)
            self._execs[tag] = self._execs.get(tag, 0) + 1
            self._pending += self._tag_bytes.get(tag, 0)

    def record(self, spec: OpSpec, schedule: Schedule) -> None:
        """Observer callback from ``tune.best_schedule`` (trace time)."""
        if self._device is None:
            self._device = tune.device_kind()
        key = spec.key(self._device)
        ent = self._ops.get(key)
        if ent is None:
            ent = self._ops[key] = {
                "spec": spec,
                "tiles": schedule.tiles,
                "source": schedule.source,
                "resolved": 0,
                "used_bytes": self._bytes_of(spec, schedule.tiles),
                "modeled_bytes": self._modeled(spec),
            }
        ent["resolved"] += 1
        hit = schedule.source == "cache"
        if self._m_hits is not None:
            (self._m_hits if hit else self._m_misses).inc()
        if not hit and key not in self._logged:
            self._logged.add(key)
            self._log_miss(spec, schedule)
        tag = self._tag
        if tag is not None and ent["used_bytes"] is not None:
            self._tag_bytes[tag] = (self._tag_bytes.get(tag, 0)
                                    + ent["used_bytes"])
            self._tag_ops.setdefault(tag, set()).add(key)

    @staticmethod
    def _bytes_of(spec: OpSpec, tiles) -> int | None:
        try:
            return int(tune.predicted_dram_bytes(spec, tuple(tiles)))
        except ValueError:
            # non-dividing tiles: the kernel takes its oracle fallback,
            # which the blocking model cannot score
            return None

    def _modeled(self, spec: OpSpec) -> int | None:
        top = tune.candidates(spec)[0]
        return self._bytes_of(spec, top.tiles)

    def _log_miss(self, spec: OpSpec, schedule: Schedule) -> None:
        if self._miss_log_path is None:
            return
        if self._miss_f is None:
            d = os.path.dirname(self._miss_log_path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._miss_f = open(self._miss_log_path, "a")
        self._miss_f.write(json.dumps({
            "op": spec.op, "dims": list(spec.dims), "dtype": spec.dtype,
            "stride": spec.stride, "device": self._device,
            "fallback_tiles": list(schedule.tiles),
            "source": schedule.source,
        }) + "\n")
        self._miss_f.flush()

    # -- aggregation ----------------------------------------------------------

    def end_step(self, rids=()) -> int:
        """Close one engine step: bank the bytes its scopes accumulated
        into the per-step history and split them evenly across the step's
        active request ids.  Returns the step's byte total."""
        bytes_this_step = self._pending
        self._pending = 0
        self._step_hist.append(bytes_this_step)
        rids = list(rids)
        if rids and bytes_this_step:
            share = bytes_this_step / len(rids)
            for rid in rids:
                self._req_bytes[rid] = self._req_bytes.get(rid, 0.0) + share
        return bytes_this_step

    def report(self) -> dict:
        """JSON-safe modeled-vs-measured report.

        ``per_op[key]`` holds the "model said X, schedule cache says Y,
        ratio Z" triple plus how the tiles were sourced; ``per_tag``
        maps each jit-variant scope to its execution count and total
        bytes; ``per_step``/``per_request`` summarize attribution.
        """
        per_op = {}
        for key, ent in sorted(self._ops.items()):
            X, Y = ent["modeled_bytes"], ent["used_bytes"]
            per_op[key] = {
                "tiles": list(ent["tiles"]),
                "source": ent["source"],
                "resolved": ent["resolved"],
                "modeled_bytes": X,
                "used_bytes": Y,
                "ratio": (round(Y / X, 4) if X and Y is not None else None),
            }
        per_tag = {tag: {"executions": n,
                         "bytes_per_execution": self._tag_bytes.get(tag, 0),
                         "ops": sorted(self._tag_ops.get(tag, ()))}
                   for tag, n in sorted(self._execs.items())}
        steps = self._step_hist
        total = sum(b * n["executions"] for b, n in
                    ((self._tag_bytes.get(t, 0), v)
                     for t, v in per_tag.items()))
        out = {
            "device": self._device,
            "per_op": per_op,
            "per_tag": per_tag,
            "total_bytes": total,
            "per_step": {
                "steps": len(steps),
                "bytes_mean": (round(sum(steps) / len(steps), 1)
                               if steps else 0.0),
                "bytes_max": max(steps) if steps else 0,
            },
            "per_request": {
                "requests": len(self._req_bytes),
                "bytes_mean": (round(sum(self._req_bytes.values())
                                     / len(self._req_bytes), 1)
                               if self._req_bytes else 0.0),
                "by_rid": {str(r): round(v, 1)
                           for r, v in sorted(self._req_bytes.items())},
            },
        }
        return out

    def close(self) -> None:
        if self._miss_f is not None:
            self._miss_f.close()
            self._miss_f = None


def read_miss_log(path: str) -> list[dict]:
    """Parse a miss-log JSONL file into deduplicated tuning targets.

    Tolerates blank/corrupt lines (a crashed run truncates mid-line);
    each target dict has ``op``, ``dims``, ``dtype``, ``stride`` and is
    unique by that identity.
    """
    targets: list[dict] = []
    seen: set[tuple] = set()
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
                ident = (d["op"], tuple(d["dims"]),
                         d.get("dtype", "float32"), int(d.get("stride", 1)))
            except (ValueError, KeyError, TypeError):
                continue
            if ident in seen:
                continue
            seen.add(ident)
            targets.append({"op": ident[0], "dims": list(ident[1]),
                            "dtype": ident[2], "stride": ident[3]})
    return targets
