"""Per-op energy pricing for the kernel profiler (docs/observability.md).

The paper's energy model (``core.energy`` / ``core.hierarchy``) prices a
blocking string: every on-chip buffer at its size-dependent SRAM access
cost, the DRAM boundary at the fixed per-16-byte cost, plus the MAC
array.  The profiler needs that split for the schedules the kernels
*actually ran* — with one correction: the DRAM component is re-priced on
the kernel's measured HBM bytes (the grid's exact block transfers,
``kernels.*.hbm_bytes``) rather than the model's idealized stream, so
observed fidelity misses (a stale cached schedule moving more bytes than
the analytic winner would) show up in picojoules too.

Everything returns plain JSON-safe dicts; ops whose resolved tiles the
kernels cannot run directly (non-dividing — the oracle-fallback path)
price as ``None``, the same convention the DRAM ledger uses for bytes.
"""

from __future__ import annotations

from repro.core.energy import DRAM_PJ_PER_16B


def op_energy_pj(spec, tiles: tuple[int, ...],
                 dram_bytes: int | None) -> dict | None:
    """Energy split (pJ) of one kernel dispatch under ``tiles``.

    ``dram_bytes`` is the measured per-call HBM traffic attributed to
    the dispatch; the SRAM and MAC components come from the paper's
    model evaluated on the same blocking string the kernel executes
    (``tune.schedule_to_string``).  Returns ``None`` when the tiles do
    not divide the problem (the kernel took its fallback, so there is
    no blocking string to price).
    """
    from repro.tune import schedule_to_string
    from repro.tune.lowering import divides
    from repro.core.hierarchy import energy_custom

    if dram_bytes is None or not divides(spec, tiles):
        return None
    rep = energy_custom(schedule_to_string(spec, tiles))
    # measured-DRAM re-price at 320 pJ per 16-bit word (2 bytes)
    dram_pj = dram_bytes / 2.0 * DRAM_PJ_PER_16B
    sram_pj = max(rep.mem_pj - rep.dram_pj, 0.0)
    mac_pj = rep.mac_pj
    total = dram_pj + sram_pj + mac_pj
    macs = spec.problem().macs
    return {
        "dram_pj": dram_pj,
        "sram_pj": sram_pj,
        "mac_pj": mac_pj,
        "total_pj": total,
        "pj_per_mac": total / macs if macs else None,
    }
