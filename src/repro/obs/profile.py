"""Per-kernel roofline + energy profiler (``python -m repro.profile``).

:class:`KernelProfiler` extends the DRAM ledger's trace/execute-split
attribution (``obs.dram``) from model-predicted bytes to the kernels'
own exact grid-transfer accounting: every kernel in ``repro.kernels``
exports ``hbm_bytes`` — the block transfers its Pallas grid actually
issues, DMA elision included — and the profiler prices each observed
schedule resolution through the matching formula.  Per op key it then
derives:

* **wall time** — scope wall clock (the engine fences every scope when
  a tracer is attached, so scopes measure device time), attributed to
  the ops inside each scope proportionally to their per-execution HBM
  bytes (the memory-bound assumption the paper's model rests on);
* **dispatches** — dispatch *sites* in the traced program x scope
  executions, the same granularity the DRAM ledger attributes bytes
  at: a GEMM inside a ``lax.scan`` over stacked layers counts once per
  trace, not once per layer (resolutions fire at trace time);
* **exact HBM bytes** — per-call ``hbm_bytes`` x dispatch count;
* **achieved vs peak** — arithmetic intensity (2·MACs / bytes) against
  the :data:`~repro.core.tpu_adapter.TPU_V5E` roofline, reporting the
  achieved fraction of the intensity-limited ceiling;
* **energy** — the paper's model split (``obs.energy``): DRAM priced on
  the measured bytes, SRAM + MAC from the schedule's blocking string.

The **model-fidelity gate** compares the resolved tiles' kernel bytes
against the analytic winner's: a cached schedule moving more than
``fidelity_threshold`` extra traffic is appended to the miss log, where
``python -m repro.tune --from-telemetry`` picks it up for retuning —
stale or corrupted cache entries heal through the normal tuning loop.
"""

from __future__ import annotations

import time

from repro.core.tpu_adapter import TPU_V5E, TpuTarget
from repro.obs.dram import DramLedger
from repro.obs.energy import op_energy_pj
from repro.tune.schedule import OpSpec, Schedule


def kernel_hbm_bytes(spec: OpSpec, tiles: tuple[int, ...]) -> int | None:
    """Per-dispatch HBM bytes of the kernel serving ``spec`` at ``tiles``
    — the grid's exact block transfers under DMA elision, from the
    kernel's own exported accounting.  ``None`` for tiles the kernel
    cannot run directly (it would take its oracle fallback, whose
    traffic is XLA's business, not ours).

    Decode-attention ops price one (batch=1, kv-head=1) nest instance,
    matching the per-resolution granularity ``best_schedule`` observes
    (one resolution per call site per trace, vmapped batch/head dims
    outside).
    """
    from repro.tune.lowering import divides
    if not divides(spec, tiles):
        return None
    bpe = spec.itemsize
    if spec.op in ("matmul", "matmul_dgrad"):
        from repro.kernels.matmul_blocked import hbm_bytes
        M, N, K = spec.dims
        return hbm_bytes(M, N, K, *tiles, bytes_per_elem=bpe)
    if spec.op == "matmul_w8":
        from repro.kernels.matmul_q import hbm_bytes
        M, N, K = spec.dims
        return hbm_bytes(M, N, K, *tiles, a_bytes=bpe, w_bytes=1)
    if spec.op == "matmul_fused":
        from repro.kernels.matmul_fused import hbm_bytes
        M, N, K = spec.dims
        return hbm_bytes(M, N, K, *tiles, bytes_per_elem=bpe)
    if spec.op == "qkv_fused":
        from repro.kernels.qkv_fused import hbm_bytes
        M, Nkv, K, G = spec.dims
        return hbm_bytes(M, Nkv, K, G, *tiles, bytes_per_elem=bpe)
    if spec.op in ("flash_decode", "flash_decode_fp8"):
        from repro.kernels.flash_decode import hbm_bytes
        G, S, D = spec.dims
        (bkv,) = tiles
        kvb = 1 if spec.op == "flash_decode_fp8" else None
        return hbm_bytes(1, 1, G, D, S, bkv, bytes_per_elem=bpe,
                         kv_bytes=kvb)
    if spec.op == "flash_decode_oproj":
        from repro.kernels.flash_decode import oproj_hbm_bytes
        G, S, D, E = spec.dims
        (bkv,) = tiles
        return oproj_hbm_bytes(1, 1, G, D, E, S, bkv, bytes_per_elem=bpe)
    if spec.op == "conv2d_wgrad":
        from repro.kernels.conv2d_bwd import hbm_bytes
    else:
        from repro.kernels.conv2d_blocked import hbm_bytes
    X, Y, C, K, Fw, Fh = spec.dims
    return hbm_bytes(X, Y, C, K, Fw, Fh, *tiles, bytes_per_elem=bpe,
                     stride=spec.stride)


class KernelProfiler(DramLedger):
    """DRAM ledger + timed scopes + kernel-exact bytes + roofline/energy.

    Drop-in wherever a :class:`~repro.obs.dram.DramLedger` goes
    (``Obs(dram=KernelProfiler(...))``): the engines' existing
    ``obs.dram.scope(tag)`` brackets route here, so serving needs no
    changes to be profiled.  ``tracer`` (optional) receives per-step
    counter tracks (HBM bytes, energy); attach one to the same
    :class:`~repro.obs.Obs` bundle so the engine fences every scope and
    the wall clocks below measure device time, not dispatch time.
    """

    def __init__(self, registry=None, miss_log: str | None = None,
                 fidelity_threshold: float = 0.25,
                 target: TpuTarget = TPU_V5E, tracer=None):
        super().__init__(registry=registry, miss_log=miss_log)
        self.fidelity_threshold = fidelity_threshold
        self.target = target
        self.tracer = tracer
        self._wall_s: dict[str, float] = {}       # tag -> total scope wall
        self._tag_kbytes: dict[str, int] = {}     # tag -> kernel B / exec
        self._tag_op_counts: dict[str, dict[str, int]] = {}
        self._fid_flagged: set[str] = set()
        self._energy_pj_total = 0.0

    # -- observation ----------------------------------------------------------

    def scope(self, tag: str):
        """Timed version of the ledger scope (same attribution contract)."""
        import contextlib

        @contextlib.contextmanager
        def timed():
            t0 = time.perf_counter()
            try:
                with super(KernelProfiler, self).scope(tag):
                    yield self
            finally:
                self._wall_s[tag] = (self._wall_s.get(tag, 0.0)
                                     + time.perf_counter() - t0)
        return timed()

    def record(self, spec: OpSpec, schedule: Schedule) -> None:
        super().record(spec, schedule)
        key = spec.key(self._device)
        ent = self._ops[key]
        if "kernel_bytes" not in ent:
            from repro import tune
            resolved_b = kernel_hbm_bytes(spec, schedule.tiles)
            analytic = tune.candidates(spec)[0]
            ent["kernel_bytes"] = resolved_b
            ent["kernel_analytic_bytes"] = kernel_hbm_bytes(
                spec, analytic.tiles)
            ent["energy"] = op_energy_pj(spec, schedule.tiles, resolved_b)
            ent["macs"] = spec.problem().macs
        tag = self._tag
        if tag is not None and ent["kernel_bytes"] is not None:
            self._tag_kbytes[tag] = (self._tag_kbytes.get(tag, 0)
                                     + ent["kernel_bytes"])
            counts = self._tag_op_counts.setdefault(tag, {})
            counts[key] = counts.get(key, 0) + 1
        self._check_fidelity(key, ent, spec, schedule)

    def _check_fidelity(self, key: str, ent: dict, spec: OpSpec,
                        schedule: Schedule) -> None:
        """Measured-vs-modeled DRAM gate: resolved tiles moving more
        bytes than the analytic winner by over the threshold are
        appended to the miss log for ``tune --from-telemetry``."""
        if key in self._fid_flagged:
            return
        meas, model = ent["kernel_bytes"], ent["kernel_analytic_bytes"]
        if meas is None or not model:
            # fallback-path tiles never hit the miss log twice: the base
            # ledger already logged them as a plain cache miss
            return
        if meas / model > 1.0 + self.fidelity_threshold:
            self._fid_flagged.add(key)
            self._logged.discard(key)   # force the JSONL append
            self._log_miss(spec, schedule)
            self._logged.add(key)

    def end_step(self, rids=()) -> int:
        n = super().end_step(rids)
        if self.tracer is not None:
            self.tracer.counter("dram", {"bytes_per_step": n})
            self.tracer.counter(
                "energy", {"total_pj": round(self._total_energy_pj(), 1)})
        return n

    # -- aggregation ----------------------------------------------------------

    def _per_op_rollup(self) -> dict[str, dict]:
        """Total dispatches / bytes / wall seconds per op key, combining
        per-trace resolution counts with per-tag execution counts and
        byte-proportional wall-time shares."""
        out: dict[str, dict] = {
            key: {"dispatches": 0, "bytes": 0, "time_s": 0.0}
            for key in self._ops}
        for tag, counts in self._tag_op_counts.items():
            execs = self._execs.get(tag, 0) or 1
            tag_bytes = self._tag_kbytes.get(tag, 0)
            wall = self._wall_s.get(tag, 0.0)
            for key, n_per_exec in counts.items():
                ent = self._ops[key]
                kb = ent.get("kernel_bytes")
                if kb is None:
                    continue
                roll = out[key]
                roll["dispatches"] += n_per_exec * execs
                roll["bytes"] += kb * n_per_exec * execs
                if tag_bytes:
                    roll["time_s"] += wall * (kb * n_per_exec) / tag_bytes
        return out

    def _total_energy_pj(self) -> float:
        total = 0.0
        for key, roll in self._per_op_rollup().items():
            e = self._ops[key].get("energy")
            if e is not None:
                total += e["total_pj"] * roll["dispatches"]
        return total

    def roofline_report(self) -> dict:
        """JSON-safe roofline + energy report, one row per dispatched
        kernel variant.  ``peak_frac`` is achieved FLOP/s over the
        intensity-limited ceiling min(peak, AI x HBM bandwidth)."""
        t = self.target
        rows = {}
        totals = {"time_s": 0.0, "bytes": 0, "flops": 0,
                  "energy_pj": 0.0, "dispatches": 0}
        for key, roll in sorted(self._per_op_rollup().items()):
            ent = self._ops[key]
            if not roll["dispatches"]:
                continue
            flops = 2 * ent["macs"] * roll["dispatches"]
            ai = flops / roll["bytes"] if roll["bytes"] else None
            e = ent.get("energy")
            energy_pj = (e["total_pj"] * roll["dispatches"]
                         if e is not None else None)
            row = {
                "tiles": list(ent["tiles"]),
                "source": ent["source"],
                "dispatches": roll["dispatches"],
                "time_us": round(roll["time_s"] * 1e6, 1),
                "hbm_bytes": roll["bytes"],
                "flops": flops,
                "intensity_flops_per_byte": (round(ai, 3)
                                             if ai is not None else None),
                "fidelity_ratio": self._fidelity_ratio(ent),
                "energy_pj": (round(energy_pj, 1)
                              if energy_pj is not None else None),
                "energy_split": e,
            }
            if roll["time_s"] > 0 and ai is not None:
                achieved = flops / roll["time_s"]
                ceiling = min(t.peak_bf16_flops, ai * t.hbm_bytes_per_s)
                row["achieved_gflops"] = round(achieved / 1e9, 2)
                row["achieved_gbps"] = round(
                    roll["bytes"] / roll["time_s"] / 1e9, 2)
                row["peak_frac"] = round(achieved / ceiling, 4)
                row["bound"] = ("memory" if ai * t.hbm_bytes_per_s
                                < t.peak_bf16_flops else "compute")
            rows[key] = row
            totals["time_s"] += roll["time_s"]
            totals["bytes"] += roll["bytes"]
            totals["flops"] += flops
            totals["dispatches"] += roll["dispatches"]
            if energy_pj is not None:
                totals["energy_pj"] += energy_pj
        return {
            "target": {"name": t.name,
                       "peak_bf16_flops": t.peak_bf16_flops,
                       "hbm_bytes_per_s": t.hbm_bytes_per_s},
            "fidelity_threshold": self.fidelity_threshold,
            "fidelity_misses": sorted(self._fid_flagged),
            "per_op": rows,
            "totals": {
                "dispatches": totals["dispatches"],
                "time_us": round(totals["time_s"] * 1e6, 1),
                "hbm_bytes": totals["bytes"],
                "flops": totals["flops"],
                "energy_uj": round(totals["energy_pj"] / 1e6, 3),
            },
        }

    @staticmethod
    def _fidelity_ratio(ent: dict) -> float | None:
        meas, model = ent.get("kernel_bytes"), ent.get("kernel_analytic_bytes")
        if meas is None or not model:
            return None
        return round(meas / model, 4)

    def report(self) -> dict:
        out = super().report()
        out["roofline"] = self.roofline_report()
        return out

    def format_roofline(self) -> str:
        """Aligned-text roofline table through the one metrics formatter."""
        from repro.obs.metrics import format_metrics
        rep = self.roofline_report()
        tree = {}
        for key, row in rep["per_op"].items():
            tree[key] = {
                k: v for k, v in row.items()
                if k not in ("tiles", "energy_split") and v is not None}
            tree[key]["tiles"] = "x".join(str(t) for t in row["tiles"])
        tree["TOTAL"] = rep["totals"]
        return format_metrics({"roofline": tree}, sections=["roofline"])
