"""Zero-dependency metrics registry: counters, gauges, histograms.

The serving hot path reports into a :class:`MetricsRegistry` — plain
Python ints/floats behind attribute access, no locks, no I/O — and
anything that wants the numbers takes a :meth:`~MetricsRegistry.snapshot`
(a nested plain-dict tree, grouped by the dotted metric-name prefixes)
or serializes it with :meth:`~MetricsRegistry.to_json`.

Design constraints, in order:

1. **Hot-path cost is one attribute lookup + one int add.**  Engines
   hold direct references to their :class:`Counter`/:class:`Gauge`
   objects; ``registry.counter(name)`` is the registration path, not the
   increment path.
2. **Snapshots are plain data.**  ``snapshot()`` returns nothing but
   dicts, ints and floats, so it drops straight into a JSON benchmark
   record (``BENCH_serve.json``) or a ``--metrics-out`` file.
3. **One formatter.**  :func:`format_metrics` renders any nested
   dict-of-numbers tree — registry snapshots, the engines' stats-view
   dicts (``spec_stats()``/``prefix_stats()``), the DRAM ledger report —
   so every serve-mode summary prints through the same code path.

Histograms use fixed upper-bound buckets (Prometheus-style ``le``
semantics, implicit ``+inf`` tail) so ``observe`` is a bisect + add and
snapshots are mergeable across processes; :func:`hist_quantile`
recovers approximate percentiles by linear interpolation inside the
containing bucket.
"""

from __future__ import annotations

import bisect
import json

# default step-latency bucket bounds, in microseconds: ~100us (one host
# dispatch) up to 1s, roughly x2.5 per step
DEFAULT_US_BUCKETS = (100.0, 250.0, 500.0, 1_000.0, 2_500.0, 5_000.0,
                      10_000.0, 25_000.0, 50_000.0, 100_000.0,
                      250_000.0, 1_000_000.0)


class Counter:
    """Monotonic counter.  ``inc`` is the hot-path call."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Point-in-time value (pool occupancy, queue depth)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Fixed-bucket histogram with Prometheus ``le`` semantics.

    ``bounds`` are the finite upper bounds, strictly increasing; every
    observation lands in the first bucket whose bound is >= the value,
    or in the implicit ``+inf`` tail.  ``counts`` has
    ``len(bounds) + 1`` entries.
    """

    __slots__ = ("bounds", "counts", "total", "count")

    def __init__(self, bounds=DEFAULT_US_BUCKETS):
        bounds = tuple(float(b) for b in bounds)
        if not bounds or list(bounds) != sorted(set(bounds)):
            raise ValueError(
                f"histogram bounds must be strictly increasing: {bounds}")
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)
        self.total = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.total += v
        self.count += 1

    def snapshot(self) -> dict:
        buckets = {f"{b:g}": c for b, c in zip(self.bounds, self.counts)}
        buckets["+inf"] = self.counts[-1]
        return {"count": self.count, "sum": round(self.total, 3),
                "buckets": buckets}

    def quantile(self, q: float) -> float:
        return hist_quantile(self.snapshot(), q)


def hist_quantile(snap: dict, q: float) -> float:
    """Approximate quantile from a histogram *snapshot* (linear
    interpolation inside the containing bucket; the open ``+inf`` tail
    reports its lower bound).  ``q`` in [0, 1]."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1], got {q}")
    count = snap["count"]
    if count == 0:
        return 0.0
    items = list(snap["buckets"].items())
    rank = q * count
    seen = 0.0
    lo = 0.0
    for name, c in items:
        hi = float("inf") if name == "+inf" else float(name)
        if seen + c >= rank and c > 0:
            if hi == float("inf"):
                return lo
            frac = (rank - seen) / c
            return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        seen += c
        lo = hi if hi != float("inf") else lo
    return lo


class MetricsRegistry:
    """Name -> metric map with dotted-prefix grouping in snapshots.

    Names are dotted paths (``"prefix_cache.hits"``); a name can never
    be both a leaf and a group (``"a"`` and ``"a.b"`` conflict), which
    keeps the snapshot tree unambiguous.
    """

    def __init__(self):
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    def _register(self, name: str, kind, **kwargs):
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, kind):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {kind.__name__}")
            return m
        for other in self._metrics:
            if other.startswith(name + ".") or name.startswith(other + "."):
                raise ValueError(
                    f"metric name {name!r} conflicts with existing "
                    f"{other!r}: a name cannot be both leaf and group")
        m = kind(**kwargs)
        self._metrics[name] = m
        return m

    def counter(self, name: str) -> Counter:
        return self._register(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._register(name, Gauge)

    def histogram(self, name: str,
                  bounds=DEFAULT_US_BUCKETS) -> Histogram:
        return self._register(name, Histogram, bounds=bounds)

    def snapshot(self) -> dict:
        """Nested plain-dict tree: dotted names split into groups,
        counters/gauges as numbers, histograms as their snapshot dict."""
        out: dict = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            node = out
            *path, leaf = name.split(".")
            for part in path:
                node = node.setdefault(part, {})
            node[leaf] = (m.snapshot() if isinstance(m, Histogram)
                          else m.value)
        return out

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.snapshot(), indent=indent)


def _is_hist_snap(v) -> bool:
    return isinstance(v, dict) and set(v) == {"count", "sum", "buckets"}


def format_metrics(tree: dict, sections=None, indent: str = "") -> str:
    """Render any nested dict-of-numbers tree as aligned text lines.

    The ONE formatter every serve-mode summary goes through: registry
    snapshots, the engines' ``spec_stats()``/``prefix_stats()`` view
    dicts, and the DRAM ledger report all print here.  ``sections``
    optionally restricts the top-level groups rendered (in the given
    order).  Histogram snapshots render as p50/p95/p99 + count; float
    values in [0, 1] under names ending in ``rate`` render as percents.
    """
    lines: list[str] = []
    keys = list(sections) if sections is not None else sorted(tree)

    def walk(node: dict, prefix: str) -> None:
        flat = []
        for k in sorted(node):
            v = node[k]
            name = f"{prefix}{k}"
            if _is_hist_snap(v):
                flat.append((name, f"p50={hist_quantile(v, 0.5):.0f} "
                                   f"p95={hist_quantile(v, 0.95):.0f} "
                                   f"p99={hist_quantile(v, 0.99):.0f} "
                                   f"count={v['count']}"))
            elif isinstance(v, dict):
                walk(v, f"{name}.")
            elif isinstance(v, float):
                if k.endswith("rate") and 0.0 <= v <= 1.0:
                    flat.append((name, f"{v:.1%}"))
                else:
                    flat.append((name, f"{v:g}"))
            else:
                flat.append((name, str(v)))
        if flat:
            width = max(len(n) for n, _ in flat)
            for n, s in flat:
                lines.append(f"{indent}{n:<{width}}  {s}")

    for key in keys:
        if key not in tree:
            continue
        v = tree[key]
        walk(v if isinstance(v, dict) and not _is_hist_snap(v)
             else {key: v}, f"{key}." if isinstance(v, dict)
             and not _is_hist_snap(v) else "")
    return "\n".join(lines)
