"""Serving observability: metrics registry, step-span tracing, and
modeled-vs-measured DRAM accounting (docs/observability.md).

:class:`Obs` is the bundle the engines take (``PagedEngine(obs=...)``,
``DecodeEngine(obs=...)``): a :class:`~repro.obs.metrics.MetricsRegistry`
that the engine, scheduler and kv-cache report into; an optional
:class:`~repro.obs.trace.StepTracer` emitting Chrome-trace spans (the
engines fence with ``block_until_ready`` ONLY when a tracer is
attached); and a :class:`~repro.obs.dram.DramLedger` comparing the
analytical model's predicted DRAM bytes against what the schedule
cache actually resolved, per op key, while logging schedule-cache
misses for ``python -m repro.tune --from-telemetry``.

An engine constructed without an ``obs`` argument builds a private
``Obs()`` — registry and ledger always on (they are host-side integer
arithmetic), tracer off.
"""

from __future__ import annotations

import json
import os

from repro.obs.dram import DramLedger, read_miss_log
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               format_metrics, hist_quantile)
from repro.obs.profile import KernelProfiler, kernel_hbm_bytes
from repro.obs.trace import NULL_SPAN, StepTracer, null_span

__all__ = [
    "Counter", "DramLedger", "Gauge", "Histogram", "KernelProfiler",
    "MetricsRegistry", "NULL_SPAN", "Obs", "StepTracer", "format_metrics",
    "hist_quantile", "kernel_hbm_bytes", "null_span", "read_miss_log",
]


class Obs:
    """One observability bundle per engine (or shared across engines).

    ``trace`` / ``miss_log`` accept paths for convenience; pass a
    constructed :class:`StepTracer` / :class:`DramLedger` /
    :class:`MetricsRegistry` to share instances across engines.
    """

    def __init__(self, registry: MetricsRegistry | None = None,
                 trace: StepTracer | str | os.PathLike | None = None,
                 dram: DramLedger | None = None,
                 miss_log: str | None = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        if trace is None or isinstance(trace, StepTracer):
            self.tracer = trace
        else:
            self.tracer = StepTracer(trace)
        self.dram = dram if dram is not None else DramLedger(
            registry=self.registry, miss_log=miss_log)

    def snapshot(self) -> dict:
        """Registry snapshot plus the DRAM ledger's modeled-vs-measured
        report under ``"dram"`` — JSON-safe plain data."""
        snap = self.registry.snapshot()
        snap["dram"] = self.dram.report()
        return snap

    def write_metrics(self, path: str | os.PathLike) -> None:
        d = os.path.dirname(os.fspath(path))
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)
            f.write("\n")

    def close(self) -> None:
        if self.tracer is not None:
            self.tracer.close()
        self.dram.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
