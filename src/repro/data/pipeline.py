"""Deterministic, stateless-seekable synthetic data pipeline.

Every batch is a pure function of (seed, step): after a restart the
pipeline resumes at exactly the same batch — checkpoint/restart therefore
reproduces the optimizer trajectory bit-for-bit (fault tolerance relies on
this, DESIGN.md §5).

The token stream is a mixture of structured sequences (repeats, arithmetic
progressions, ngram chains) rather than iid noise so small models have
something learnable — quickstart/train examples show loss actually falling.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class TokenStream:
    """Seekable synthetic LM stream: markov chains + copy patterns."""

    def __init__(self, dc: DataConfig):
        self.dc = dc
        rng = np.random.default_rng(dc.seed)
        v = dc.vocab
        # a sparse markov transition table: each token has 4 likely successors
        self.successors = rng.integers(0, v, size=(v, 4), dtype=np.int32)

    def batch_at(self, step: int) -> dict:
        """Pure function of step: {tokens, labels} as numpy arrays."""
        dc = self.dc
        rng = np.random.default_rng((dc.seed << 32) ^ step)
        b, s, v = dc.global_batch, dc.seq_len, dc.vocab
        toks = np.empty((b, s), np.int32)
        toks[:, 0] = rng.integers(0, v, size=b)
        choice = rng.integers(0, 4, size=(b, s))
        noise = rng.random((b, s)) < 0.1
        rand = rng.integers(0, v, size=(b, s), dtype=np.int32)
        for t in range(1, s):
            nxt = self.successors[toks[:, t - 1], choice[:, t]]
            toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        labels = np.concatenate([toks[:, 1:], np.full((b, 1), -1, np.int32)],
                                axis=1)
        return {"tokens": toks, "labels": labels}


def make_batch(cfg: ModelConfig, seq_len: int, global_batch: int,
               step: int, seed: int = 0) -> dict:
    """Batch for any arch family (adds stub modality inputs as needed)."""
    vocab = cfg.vocab
    stream = TokenStream(DataConfig(vocab, seq_len, global_batch, seed))
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}
    rng = np.random.default_rng((seed << 16) ^ step ^ 0xABCD)
    if cfg.is_encdec:
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((global_batch, cfg.encoder_seq,
                                 cfg.d_model)).astype(np.float32) * 0.1,
            cfg.dtype)
    if cfg.prefix_tokens:
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((global_batch, cfg.prefix_tokens,
                                 cfg.d_model)).astype(np.float32) * 0.1,
            cfg.dtype)
    return batch
