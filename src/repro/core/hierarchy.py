"""Mapping buffers onto memories and computing total energy (paper §3.5).

Two modes:

* ``custom``  — co-designed hardware: every buffer gets its own SRAM/RF of
  exactly its size (DRAM above 16 MB).  This is the mode used for the
  DianNao-style studies (Figs. 5-8); an optional ``sram_budget_bytes``
  caps total on-chip SRAM: buffers that don't fit are spilled to DRAM,
  largest-and-least-accessed first.
* ``fixed``   — a given memory hierarchy (e.g. a Xeon's L1/L2/L3/DRAM).
  Buffers are packed greedily: repeatedly take the unpacked buffer with
  the highest access count into the lowest memory level with room; once a
  level overflows, that buffer and all later ones go to higher levels.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.access import BufferTraffic, TrafficReport, analyze
from repro.core.buffers import (Buffer, Operand, operand_bytes,
                                place_buffers)
from repro.core.energy import (DRAM_PJ_PER_16B, MAC_ENERGY_PJ,
                               access_energy_pj, sram_area_mm2,
                               DATAPATH_AREA_MM2)
from repro.core.loopnest import BlockingString


@dataclasses.dataclass(frozen=True)
class MemLevel:
    name: str
    capacity_bytes: int          # 0 -> unbounded (DRAM)
    energy_pj_per_16b: float

    @classmethod
    def sram(cls, name: str, capacity_bytes: int) -> "MemLevel":
        return cls(name, capacity_bytes, access_energy_pj(capacity_bytes))

    @classmethod
    def dram(cls, name: str = "DRAM") -> "MemLevel":
        return cls(name, 0, DRAM_PJ_PER_16B)


def xeon_hierarchy() -> list[MemLevel]:
    """The paper's evaluation platform (Xeon E5645, §4.1)."""
    return [MemLevel.sram("L1", 32 * 1024),
            MemLevel.sram("L2", 256 * 1024),
            MemLevel.sram("L3", 12 * 1024 * 1024),
            MemLevel.dram()]


def diannao_hierarchy() -> list[MemLevel]:
    """DianNao's split buffers (IB 2KB, KB 32KB, OB 2KB) + DRAM (§5.2)."""
    return [MemLevel.sram("IBuf", 2 * 1024),
            MemLevel.sram("KBuf", 32 * 1024),
            MemLevel.sram("OBuf", 2 * 1024),
            MemLevel.dram()]


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    string: BlockingString
    total_pj: float
    mem_pj: float
    mac_pj: float
    per_buffer_pj: dict[str, float]
    per_level_pj: dict[str, float]
    dram_pj: float
    sram_bytes: int
    area_mm2: float
    placements: dict[str, str]  # buffer name -> level name

    @property
    def pj_per_mac(self) -> float:
        return self.total_pj / self.string.problem.macs

    def summary(self) -> str:
        lines = [f"schedule: {self.string}",
                 f"total {self.total_pj/1e6:.3f} uJ  "
                 f"(mem {self.mem_pj/1e6:.3f} uJ, mac {self.mac_pj/1e6:.3f} "
                 f"uJ, dram {self.dram_pj/1e6:.3f} uJ)  "
                 f"{self.pj_per_mac:.3f} pJ/MAC, area {self.area_mm2:.2f} mm2"]
        for name, pj in sorted(self.per_buffer_pj.items(),
                               key=lambda kv: -kv[1]):
            lines.append(f"  {name:12s} {pj/1e6:10.4f} uJ "
                         f"({self.placements.get(name, '?')})")
        return "\n".join(lines)


def _words(elems: int, bytes_per_elem: int) -> float:
    """accesses in 16-bit words (the Table-3 unit).

    Mixed-precision nests pass each operand's own width here — a 1-byte
    quantized operand moves half the words of the paper's 16-bit data."""
    return elems * bytes_per_elem / 2.0


def energy_custom(s: BlockingString,
                  report: TrafficReport | None = None,
                  sram_budget_bytes: int | None = None,
                  broadcast_extra_pj: float = 0.0) -> EnergyReport:
    """Co-designed hardware: one memory per buffer, sized exactly.

    ``broadcast_extra_pj`` adds a per-16b-word surcharge on the outermost
    on-chip level's fills (used by the multicore model).
    """
    report = report or analyze(s)
    per_buffer: dict[str, float] = {}
    placements: dict[str, str] = {}
    per_level: dict[str, float] = {}
    dram_pj = 0.0
    sram_bytes = 0

    # decide spills under a budget: keep buffers with the highest
    # accesses-per-byte on chip first.
    onchip: dict[str, bool] = {}
    ranked = sorted(report.per_buffer,
                    key=lambda bt: -(bt.total_accesses /
                                     max(bt.buffer.size_elems, 1)))
    used = 0
    for bt in ranked:
        size = bt.buffer.size_bytes(s.problem)
        fits = (size <= 16 * 1024 * 1024 and
                (sram_budget_bytes is None or used + size <=
                 sram_budget_bytes))
        onchip[bt.buffer.name] = fits
        if fits:
            used += size

    for bt in report.per_buffer:
        b = bt.buffer
        size = b.size_bytes(s.problem)
        if onchip[b.name]:
            e_self = access_energy_pj(size)
            sram_bytes += size
        else:
            e_self = DRAM_PJ_PER_16B
        # serving reads below + receiving fills/writebacks happens here
        pj = _words(bt.total_accesses,
                    operand_bytes(s.problem, b.operand)) * e_self
        # the parent of the outermost buffer of each operand is DRAM; its
        # reads/writes on our behalf are DRAM accesses.
        per_buffer[b.name] = pj
        placements[b.name] = "DRAM" if not onchip[b.name] else \
            f"SRAM{size//1024}K" if size >= 1024 else f"RF{size}B"
        per_level[placements[b.name]] = per_level.get(placements[b.name],
                                                      0.0) + pj

    # DRAM traffic: the fills+writebacks of each operand's outermost ON-CHIP
    # buffer cross the DRAM boundary (plus all accesses of spilled buffers,
    # already costed at DRAM energy above).
    for op, elems in report.dram_accesses_by_operand.items():
        pj = _words(elems, operand_bytes(s.problem, op)) * DRAM_PJ_PER_16B
        dram_pj += pj
    per_level["DRAM"] = per_level.get("DRAM", 0.0) + dram_pj

    if broadcast_extra_pj:
        # surcharge on outermost-level fills (multicore broadcast)
        outer = {}
        for bt in report.per_buffer:
            outer[bt.buffer.operand] = bt  # last one per operand is outermost
        for bt in outer.values():
            per_buffer[bt.buffer.name] += _words(
                bt.parent_traffic,
                operand_bytes(s.problem, bt.buffer.operand)) * \
                broadcast_extra_pj

    mem_pj = sum(per_buffer.values()) + dram_pj
    mac_pj = s.problem.macs * MAC_ENERGY_PJ
    return EnergyReport(
        string=s, total_pj=mem_pj + mac_pj, mem_pj=mem_pj, mac_pj=mac_pj,
        per_buffer_pj=per_buffer, per_level_pj=per_level, dram_pj=dram_pj,
        sram_bytes=sram_bytes,
        area_mm2=sram_area_mm2(sram_bytes) + DATAPATH_AREA_MM2,
        placements=placements)


def pack_fixed(report: TrafficReport,
               levels: Sequence[MemLevel]) -> dict[str, MemLevel]:
    """Paper §3.5 greedy packing onto a fixed hierarchy."""
    problem = report.string.problem
    remaining = {lv.name: lv.capacity_bytes for lv in levels}
    order = sorted(report.per_buffer, key=lambda bt: -bt.total_accesses)
    placements: dict[str, MemLevel] = {}
    level_idx = 0
    for bt in order:
        size = bt.buffer.size_bytes(problem)
        while level_idx < len(levels) - 1 and \
                remaining[levels[level_idx].name] < size:
            level_idx += 1  # this and all subsequent buffers go higher
        lv = levels[level_idx]
        if lv.capacity_bytes:
            remaining[lv.name] -= size
        placements[bt.buffer.name] = lv
    return placements


def energy_fixed(s: BlockingString, levels: Sequence[MemLevel],
                 report: TrafficReport | None = None) -> EnergyReport:
    """Energy of a blocking on a fixed (e.g. CPU cache) hierarchy."""
    report = report or analyze(s)
    placements = pack_fixed(report, levels)
    per_buffer: dict[str, float] = {}
    per_level: dict[str, float] = {}
    dram_pj = 0.0
    sram_bytes = 0
    for bt in report.per_buffer:
        lv = placements[bt.buffer.name]
        pj = _words(bt.total_accesses,
                    operand_bytes(s.problem, bt.buffer.operand)) * \
            lv.energy_pj_per_16b
        per_buffer[bt.buffer.name] = pj
        per_level[lv.name] = per_level.get(lv.name, 0.0) + pj
        if lv.capacity_bytes:
            sram_bytes += bt.buffer.size_bytes(s.problem)
    for op, elems in report.dram_accesses_by_operand.items():
        dram_pj += _words(elems, operand_bytes(s.problem, op)) * \
            DRAM_PJ_PER_16B
    per_level["DRAM"] = per_level.get("DRAM", 0.0) + dram_pj
    mem_pj = sum(per_buffer.values()) + dram_pj
    mac_pj = s.problem.macs * MAC_ENERGY_PJ
    return EnergyReport(
        string=s, total_pj=mem_pj + mac_pj, mem_pj=mem_pj, mac_pj=mac_pj,
        per_buffer_pj=per_buffer, per_level_pj=per_level, dram_pj=dram_pj,
        sram_bytes=sram_bytes,
        area_mm2=sram_area_mm2(sram_bytes) + DATAPATH_AREA_MM2,
        placements={k: v.name for k, v in placements.items()})


def cache_accesses(s: BlockingString, levels: Sequence[MemLevel],
                   report: TrafficReport | None = None,
                   operand_weights: dict[Operand, int] | None = None,
                   ) -> dict[str, int]:
    """Access counts per fixed level — reproduces the paper's Fig. 3/4
    L2/L3 access-count comparison.

    Counts are CUMULATIVE down the hierarchy, matching hardware counters
    on inclusive caches: a request served by an L3-resident buffer also
    accesses L2 (allocation on the miss path), so accesses(L) includes the
    demand of every buffer living at L or further out.

    ``operand_weights`` multiplies each operand's accesses (default 1 =
    element counts).  Passing per-operand byte widths turns the same
    placement walk into byte traffic — the single accounting shared with
    ``tune.predicted_dram_bytes``, so the miss-path rules can never
    diverge between the count and byte ranks."""
    from repro.core.buffers import buffers_by_operand

    report = report or analyze(s)
    placements = pack_fixed(report, levels)
    level_idx = {lv.name: i for i, lv in enumerate(levels)}
    dram_idx = len(levels) - 1
    counts: dict[str, int] = {lv.name: 0 for lv in levels}
    traffic = {bt.buffer.name: bt for bt in report.per_buffer}
    by_op = buffers_by_operand([bt.buffer for bt in report.per_buffer])
    for op, chain in by_op.items():
        w = 1 if operand_weights is None else operand_weights[op]
        homes = [level_idx[placements[b.name].name] for b in chain]
        for i, b in enumerate(chain):
            bt = traffic[b.name]
            home = homes[i]
            parent = homes[i + 1] if i + 1 < len(chain) else dram_idx
            # demand served to the level below passes through this level
            # and every level between it and the datapath
            for lv in range(home, -1, -1):
                counts[levels[lv].name] += bt.reads_served * w
            # fills/writebacks travel the miss path up to the parent home
            for lv in range(min(home + 1, dram_idx), max(parent, home) + 1):
                counts[levels[lv].name] += bt.parent_traffic * w
    return counts
