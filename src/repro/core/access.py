"""Exact per-level access counts for a blocked loop nest (paper §3.4, Eq. 1).

The paper expresses per-level accesses through refetch rates ``RR_i`` (its
Table 2) and ``total = alpha * prod RR_i``.  We implement the same quantity
from first principles, which handles every loop order uniformly (including
the ``Fw``/``Fh``-outside orders the table elides):

For a buffer ``B`` of operand ``P`` allocated at string position ``p``,
its contents are a function of the indices of the loops *above* ``p`` whose
dimension indexes ``P``.  Reuse across an outer loop is captured only when
no content-changing loop lies between ``B`` and that outer loop, hence:

    fills(B) = footprint_P(extents below p) * prod_{q >= r*} iters(q)

where ``r*`` is the innermost loop above ``p`` whose dim indexes ``P``
(no such loop -> the buffer is filled exactly once).

Outputs additionally move partial sums: with addressing dims
``A = {X, Y, K, N}`` and reduction dims ``R = {C, Fw, Fh}``, a block is
written up at the end of each residency epoch and read back when a
reduction loop above an addressing loop revisits it:

    epochs  = prod_{q >= rA*} iters(q)        (rA* = first A-loop above p)
    blocks  = prod_{q > p, dim in A} iters(q)
    writes_up  = footprint * epochs
    reads_down = footprint * (epochs - blocks)   # first visit starts at 0

The halo of input blocks is refetched on every fill (the paper's
"refetches to overlapping regions of blocked tiles").
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.buffers import (Buffer, Operand, OPERAND_DIMS,
                                buffers_by_operand, place_buffers)
from repro.core.loopnest import BlockingString, Dim, Extents

OUTPUT_ADDR_DIMS = frozenset({Dim.X, Dim.Y, Dim.K, Dim.N})


@dataclasses.dataclass(frozen=True)
class BufferTraffic:
    """Traffic (elements) crossing the boundary just above one buffer."""

    buffer: Buffer
    fills: int          # elements written into this buffer from its parent
    writebacks: int     # elements written up to the parent (outputs only)
    reads_served: int   # elements this buffer serves to the level below it

    @property
    def parent_traffic(self) -> int:
        """Accesses the *parent* level performs on this buffer's behalf."""
        return self.fills + self.writebacks

    @property
    def total_accesses(self) -> int:
        """Accesses performed *at* this buffer (serve below + own fills)."""
        return self.reads_served + self.fills + self.writebacks


@dataclasses.dataclass(frozen=True)
class TrafficReport:
    string: BlockingString
    per_buffer: tuple[BufferTraffic, ...]
    dram_accesses_by_operand: dict[Operand, int]

    @property
    def dram_accesses(self) -> int:
        return sum(self.dram_accesses_by_operand.values())

    def accesses_at(self, buffer_name: str) -> int:
        for bt in self.per_buffer:
            if bt.buffer.name == buffer_name:
                return bt.total_accesses
        raise KeyError(buffer_name)


def _first_relevant_above(s: BlockingString, pos: int,
                          dims: frozenset[Dim]) -> int | None:
    for q in range(pos + 1, len(s.loops)):
        if s.loops[q].dim in dims and s.iterations(q) > 1:
            return q
    return None


def _prod_iters_from(s: BlockingString, start: int | None) -> int:
    if start is None:
        return 1
    return s.prod_iterations_from(start)


def _blocks_above(s: BlockingString, pos: int, dims: frozenset[Dim]) -> int:
    n = 1
    for q in range(pos + 1, len(s.loops)):
        if s.loops[q].dim in dims:
            n *= s.iterations(q)
    return n


def _read_fills(s: BlockingString, b: Buffer) -> int:
    """fills (elements) of a read-only operand buffer."""
    rel = OPERAND_DIMS[b.operand]
    r_star = _first_relevant_above(s, b.pos, rel)
    return b.size_elems * _prod_iters_from(s, r_star)


def _output_traffic(s: BlockingString, b: Buffer) -> tuple[int, int]:
    """(reads_down, writes_up) for an output buffer."""
    ra = _first_relevant_above(s, b.pos, OUTPUT_ADDR_DIMS)
    epochs = _prod_iters_from(s, ra)
    blocks = _blocks_above(s, b.pos, OUTPUT_ADDR_DIMS)
    writes_up = b.size_elems * epochs
    reads_down = b.size_elems * max(epochs - blocks, 0)
    return reads_down, writes_up


def analyze(s: BlockingString,
            buffers: Sequence[Buffer] | None = None) -> TrafficReport:
    """Compute traffic for every buffer implied by the blocking string."""
    bufs = list(buffers) if buffers is not None else place_buffers(s)
    by_op = buffers_by_operand(bufs)
    traffic: list[BufferTraffic] = []
    dram: dict[Operand, int] = {}

    for op, chain in by_op.items():
        # chain sorted inner -> outer; parent of the outermost is DRAM.
        fills_chain: list[int] = []
        wb_chain: list[int] = []
        for b in chain:
            if op is Operand.OUTPUT:
                reads_down, writes_up = _output_traffic(s, b)
                fills_chain.append(reads_down)
                wb_chain.append(writes_up)
            else:
                fills_chain.append(_read_fills(s, b))
                wb_chain.append(0)
        # reads each buffer serves below = the child's parent-side traffic;
        # the innermost buffer serves the datapath (1 access / MAC; 2 for
        # the output read-modify-write).
        macs = s.problem.macs
        demand0 = 2 * macs if op is Operand.OUTPUT else macs
        for i, b in enumerate(chain):
            served = demand0 if i == 0 else fills_chain[i - 1] + wb_chain[i - 1]
            traffic.append(BufferTraffic(b, fills_chain[i], wb_chain[i],
                                         served))
        dram[op] = fills_chain[-1] + wb_chain[-1] if chain else demand0
    return TrafficReport(s, tuple(traffic), dram)
