"""Blocking-schedule optimizer (paper §3.5).

The search space is (loop order) x (split sizes).  Following the paper:

* the *order* space is enumerated per blocking level (all permutations of
  the blockable dims at that level);
* for each order, the split sizes are optimized by coordinate descent over
  the divisor lattice of each dimension (the paper optimizes "parameters"
  per string);
* deep hierarchies are searched iteratively inner->outer with a beam of
  seeds (paper keeps the best 128 inner blockings, perturbs loop sizes and
  exchanges adjacent loops to create new seeds, then extends one level).

The objective is either co-designed-hardware energy (``mode="custom"``,
optionally area-budgeted) or energy/accesses on a fixed hierarchy
(``mode="fixed"``, e.g. a Xeon cache hierarchy).
"""

from __future__ import annotations

import dataclasses
import itertools
import random
from typing import Callable, Sequence

from repro.core.access import analyze
from repro.core.hierarchy import (EnergyReport, MemLevel, energy_custom,
                                  energy_fixed)
from repro.core.loopnest import (BlockingString, Dim, Loop, Problem,
                                 divisors, near_divisors)

BLOCK_DIMS = (Dim.X, Dim.Y, Dim.C, Dim.K)


@dataclasses.dataclass(frozen=True)
class OptResult:
    string: BlockingString
    report: EnergyReport

    @property
    def energy_pj(self) -> float:
        return self.report.total_pj

    @property
    def dram_accesses(self) -> int:
        """Total DRAM-boundary accesses (elements) of this schedule."""
        return analyze(self.string).dram_accesses

    def level0_extents(self):
        """Cumulative extents at the end of the innermost blocking level.

        The innermost level ends after the first occurrence of every
        blockable compute dim (X, C, K); the extents below that point are
        the level-0 tile a kernel should materialize on chip.  Used by the
        TPU lowering to turn an optimizer string into BlockSpec tiles.
        """
        s = self.string
        seen: set = set()
        for i, lp in enumerate(s.loops):
            seen.add(lp.dim)
            if {Dim.X, Dim.C, Dim.K} <= seen:
                return s.extents_below(i + 1)
        return s.extents_below(len(s.loops))


def ranked_level0_tiles(problem: Problem,
                        levels: Sequence[MemLevel],
                        align: dict[Dim, int] | None = None,
                        top: int = 8,
                        max_orders: int | None = None) -> list:
    """Ranked level-0 tile extents for a loop nest on a fixed hierarchy.

    The single candidate-ranking entry shared by forward AND backward
    kernel lowering (``core.tpu_adapter``): backward nests (dgrad/wgrad)
    are the same loop-nest family with dims relabelled, so they reuse
    this search + :meth:`OptResult.level0_extents` instead of growing
    their own.  Returns the per-schedule extents in energy rank order.
    """
    objective = make_objective("fixed", levels)
    results = optimize_exhaustive(problem, objective, n_levels=2, top=top,
                                  align=align, max_orders=max_orders)
    return [r.level0_extents() for r in results]


Objective = Callable[[BlockingString], EnergyReport]


def make_objective(mode: str = "custom",
                   levels: Sequence[MemLevel] | None = None,
                   sram_budget_bytes: int | None = None) -> Objective:
    if mode == "custom":
        return lambda s: energy_custom(s, sram_budget_bytes=sram_budget_bytes)
    if mode == "fixed":
        assert levels is not None, "fixed mode needs a hierarchy"
        return lambda s: energy_fixed(s, levels)
    raise ValueError(f"unknown mode {mode!r}")


# -- candidate construction ----------------------------------------------------


def _active_dims(problem: Problem) -> tuple[Dim, ...]:
    dims = [d for d in BLOCK_DIMS if problem.full_extent(d) > 1]
    if problem.N > 1:
        dims.append(Dim.N)
    return tuple(dims)


def _size_candidates(problem: Problem, d: Dim, lo: int, hi: int,
                     align: dict[Dim, int] | None,
                     max_count: int = 12) -> list[int]:
    """Divisors of the full extent within [lo, hi], multiples of ``lo``."""
    cands = [v for v in near_divisors(problem.full_extent(d), max_count * 2)
             if lo <= v <= hi and v % lo == 0 and hi % v == 0]
    if align and d in align:
        aligned = [v for v in cands if v % align[d] == 0 or v == hi or v == lo]
        if aligned:
            cands = aligned
    if not cands:
        cands = [hi]
    return sorted(set(cands))[:max_count * 2]


def build_string(level_orders: Sequence[Sequence[Dim]],
                 sizes: dict[tuple[int, Dim], int],
                 problem: Problem,
                 fw_fh_innermost: bool = True) -> BlockingString:
    """Assemble a BlockingString from per-level dim orders and split sizes.

    ``sizes[(lvl, d)]`` is the cumulative extent of dim ``d`` at level
    ``lvl``; the outermost level is forced to the full extent.
    """
    loops: list[Loop] = []
    if fw_fh_innermost:
        if problem.Fw > 1:
            loops.append(Loop(Dim.FW, problem.Fw))
        if problem.Fh > 1:
            loops.append(Loop(Dim.FH, problem.Fh))
    n_levels = len(level_orders)
    for lvl, order in enumerate(level_orders):
        for d in order:
            ext = (problem.full_extent(d) if lvl == n_levels - 1
                   else sizes.get((lvl, d), problem.full_extent(d)))
            loops.append(Loop(d, ext))
    # cover any dim never mentioned (Fw/Fh when not innermost, N, ...)
    covered = {lp.dim for lp in loops}
    for d in Dim:
        if d not in covered and problem.full_extent(d) > 1:
            loops.append(Loop(d, problem.full_extent(d)))
    return BlockingString(loops, problem)


def _initial_sizes(problem: Problem, dims: Sequence[Dim], n_levels: int,
                   align: dict[Dim, int] | None) -> dict[tuple[int, Dim], int]:
    """Geometric split heuristic: roughly equal ratios per level."""
    sizes: dict[tuple[int, Dim], int] = {}
    for d in dims:
        full = problem.full_extent(d)
        divs = divisors(full)
        for lvl in range(n_levels - 1):
            target = round(full ** ((lvl + 1) / n_levels))
            best = min(divs, key=lambda v: abs(v - target))
            lo = sizes.get((lvl - 1, d), 1)
            if best % lo != 0 or best < lo:
                best = lo
            sizes[(lvl, d)] = best
    return sizes


def coordinate_descent(level_orders: Sequence[Sequence[Dim]],
                       sizes: dict[tuple[int, Dim], int],
                       problem: Problem,
                       objective: Objective,
                       fw_fh_innermost: bool = True,
                       sweeps: int = 3) -> tuple[dict, float, BlockingString]:
    """Optimize split sizes for a fixed order by coordinate descent."""
    n_levels = len(level_orders)
    sizes = dict(sizes)

    def cost(sz) -> tuple[float, BlockingString]:
        s = build_string(level_orders, sz, problem, fw_fh_innermost)
        return objective(s).total_pj, s

    best_cost, best_string = cost(sizes)
    keys = [(lvl, d) for lvl in range(n_levels - 1)
            for d in level_orders[lvl]]
    for _ in range(sweeps):
        improved = False
        for key in keys:
            lvl, d = key
            lo = sizes.get((lvl - 1, d), 1) if lvl > 0 else 1
            hi = sizes.get((lvl + 1, d), problem.full_extent(d)) \
                if lvl + 1 < n_levels - 1 else problem.full_extent(d)
            for cand in _size_candidates(problem, d, lo, hi, None):
                if cand == sizes.get(key):
                    continue
                trial = dict(sizes)
                trial[key] = cand
                try:
                    c, s = cost(trial)
                except ValueError:
                    continue
                if c < best_cost:
                    best_cost, best_string, sizes = c, s, trial
                    improved = True
        if not improved:
            break
    return sizes, best_cost, best_string


# -- exhaustive (short strings) -------------------------------------------------


def optimize_exhaustive(problem: Problem,
                        objective: Objective,
                        n_levels: int = 2,
                        top: int = 32,
                        max_orders: int | None = None,
                        fw_fh_innermost: bool = True,
                        align: dict[Dim, int] | None = None,
                        ) -> list[OptResult]:
    """Enumerate all per-level orders; coordinate-descend sizes for each."""
    dims = _active_dims(problem)
    orders = list(itertools.permutations(dims))
    if max_orders:
        orders = orders[:max_orders]
    results: list[OptResult] = []
    seen: set = set()
    for combo in itertools.product(orders, repeat=n_levels):
        sizes = _initial_sizes(problem, dims, n_levels, align)
        _, cost, s = coordinate_descent(combo, sizes, problem, objective,
                                        fw_fh_innermost)
        if s in seen:
            continue
        seen.add(s)
        results.append(OptResult(s, objective(s)))
    results.sort(key=lambda r: r.energy_pj)
    return results[:top]


# -- iterative beam search (deep hierarchies, paper's fast method) --------------


def optimize_beam(problem: Problem,
                  objective: Objective,
                  n_levels: int = 3,
                  beam: int = 32,
                  perturbations: int = 8,
                  seed: int = 0,
                  fw_fh_innermost: bool = True,
                  align: dict[Dim, int] | None = None,
                  ) -> list[OptResult]:
    """Paper §3.5: optimize 2 levels exhaustively, then repeatedly add an
    outer level, re-optimizing with perturbed seeds."""
    rng = random.Random(seed)
    dims = _active_dims(problem)
    frontier = optimize_exhaustive(problem, objective, n_levels=2, top=beam,
                                   fw_fh_innermost=fw_fh_innermost,
                                   align=align)
    cur_levels = 2
    while cur_levels < n_levels:
        cur_levels += 1
        candidates: list[OptResult] = list(frontier)
        outer_orders = list(itertools.permutations(dims))
        for res in frontier[:beam]:
            inner = _decompose(res.string, problem, fw_fh_innermost)
            seeds = [inner] + [_perturb(inner, problem, rng)
                               for _ in range(perturbations)]
            for sd in seeds:
                for outer in rng.sample(outer_orders,
                                        min(len(outer_orders), 6)):
                    level_orders = list(sd["orders"]) + [outer]
                    sizes = dict(sd["sizes"])
                    # previous outermost level becomes a sized level: start
                    # it at its current full extents scaled down
                    lvl = len(sd["orders"]) - 1
                    for d in dims:
                        full = problem.full_extent(d)
                        lo = sizes.get((lvl - 1, d), 1)
                        cands = _size_candidates(problem, d, lo, full, align)
                        sizes[(lvl, d)] = rng.choice(cands)
                    try:
                        _, cost, s = coordinate_descent(
                            level_orders, sizes, problem, objective,
                            fw_fh_innermost, sweeps=2)
                    except ValueError:
                        continue
                    candidates.append(OptResult(s, objective(s)))
        dedup: dict = {}
        for r in candidates:
            dedup.setdefault(repr(r.string), r)
        frontier = sorted(dedup.values(), key=lambda r: r.energy_pj)[:beam]
    return frontier


def _decompose(s: BlockingString, problem: Problem,
               fw_fh_innermost: bool) -> dict:
    """Recover (level_orders, sizes) from a string built by build_string."""
    dims = _active_dims(problem)
    loops = [lp for lp in s.loops if lp.dim in dims]
    per_level = len(dims)
    orders: list[tuple[Dim, ...]] = []
    sizes: dict[tuple[int, Dim], int] = {}
    for lvl in range(0, len(loops) // per_level):
        chunk = loops[lvl * per_level:(lvl + 1) * per_level]
        orders.append(tuple(lp.dim for lp in chunk))
        for lp in chunk:
            sizes[(lvl, lp.dim)] = lp.extent
    return {"orders": orders, "sizes": sizes}


def _perturb(seed: dict, problem: Problem, rng: random.Random) -> dict:
    """Paper §3.5: random loop-size nudges + adjacent-loop exchanges."""
    orders = [list(o) for o in seed["orders"]]
    sizes = dict(seed["sizes"])
    # exchange two adjacent loops in a random level
    lvl = rng.randrange(len(orders))
    if len(orders[lvl]) >= 2:
        i = rng.randrange(len(orders[lvl]) - 1)
        orders[lvl][i], orders[lvl][i + 1] = orders[lvl][i + 1], orders[lvl][i]
    # nudge one size to an adjacent divisor
    keys = [k for k in sizes if k[0] < len(orders) - 1]
    if keys:
        k = rng.choice(keys)
        _, d = k
        divs = divisors(problem.full_extent(d))
        cur = sizes[k]
        idx = divs.index(cur) if cur in divs else 0
        step = rng.choice([-1, 1])
        sizes[k] = divs[max(0, min(len(divs) - 1, idx + step))]
    return {"orders": [tuple(o) for o in orders], "sizes": sizes}


def optimize(problem: Problem,
             n_levels: int = 2,
             mode: str = "custom",
             levels: Sequence[MemLevel] | None = None,
             sram_budget_bytes: int | None = None,
             beam: int = 32,
             top: int = 10,
             seed: int = 0,
             align: dict[Dim, int] | None = None) -> list[OptResult]:
    """One-call entry point: best ``top`` schedules for a layer."""
    objective = make_objective(mode, levels, sram_budget_bytes)
    if n_levels <= 2:
        return optimize_exhaustive(problem, objective, n_levels, top=top,
                                   align=align)
    return optimize_beam(problem, objective, n_levels, beam=beam, seed=seed,
                         align=align)[:top]
