"""Memory access-energy model (paper §3.4, Table 3).

Energies are pJ per 16-bit access, derived from CACTI at 45 nm, calibrated
against a commercial memory compiler (paper §4.2).  Below 1 KB the paper
uses standard-cell register files; we model those with a sqrt(size) roll-off
from the 1 KB SRAM point, floored at a latch-access cost.  Above 16 MB the
paper switches to DRAM at a flat 320 pJ/16b (Micron TN-41-01).

Area: paper Fig. 7 gives the two calibration points (8 MB = 45 mm^2 = 45x
DianNao baseline; 1 MB = 6x baseline) -> 5.625 mm^2 / MB of SRAM plus a
fixed ~0.85 mm^2 datapath.

Compute: the 256-MAC 16-bit datapath (DianNao-like, 45 nm) is modeled at
1.0 pJ / MAC (DianNao reports ~485 mW at 452 GOP/s ~ 1 pJ/op).
"""

from __future__ import annotations

import bisect
import math

# paper Table 3: pJ per 16 bits. rows: size in KB; columns: word width bits.
_SIZES_KB = [1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024]
_WIDTHS = [64, 128, 256, 512]
_TABLE = {
    1:    [1.20, 0.93, 0.69, 0.57],
    2:    [1.54, 1.37, 0.91, 0.68],
    4:    [2.11, 1.68, 1.34, 0.90],
    8:    [3.19, 2.71, 2.21, 1.33],
    16:   [4.36, 3.57, 2.66, 2.19],
    32:   [5.82, 4.80, 3.52, 2.64],
    64:   [8.10, 7.51, 5.79, 4.67],
    128:  [11.66, 11.50, 8.46, 6.15],
    256:  [15.60, 15.51, 13.09, 8.99],
    512:  [23.37, 23.24, 17.93, 15.76],
    1024: [36.32, 32.81, 28.88, 25.22],
}

DRAM_PJ_PER_16B = 320.0
DRAM_THRESHOLD_BYTES = 16 * 1024 * 1024  # >16MB -> DRAM
MAC_ENERGY_PJ = 1.0
REGFILE_FLOOR_PJ = 0.03  # single flop/latch read
SRAM_AREA_MM2_PER_MB = 45.0 / 8.0  # Fig. 7 calibration
DATAPATH_AREA_MM2 = 0.85


def _col(width_bits: int | None) -> int:
    if width_bits is None:
        return len(_WIDTHS) - 1  # widest = most efficient (paper §4.2)
    return _WIDTHS.index(width_bits)


import functools


@functools.lru_cache(maxsize=65536)
def sram_access_pj(size_bytes: float, width_bits: int | None = None) -> float:
    """Log-log interpolated SRAM access energy per 16-bit word."""
    col = _col(width_bits)
    kb = size_bytes / 1024.0
    pts = [(s, _TABLE[s][col]) for s in _SIZES_KB]
    if kb <= pts[0][0]:
        # register-file regime: sqrt(size) roll-off below 1 KB
        e = pts[0][1] * math.sqrt(max(kb, 1e-6) / pts[0][0])
        return max(e, REGFILE_FLOOR_PJ)
    if kb >= pts[-1][0]:
        # extrapolate with the last decade's log-log slope (1MB..16MB SRAM)
        (s0, e0), (s1, e1) = pts[-2], pts[-1]
        slope = math.log(e1 / e0) / math.log(s1 / s0)
        return e1 * (kb / s1) ** slope
    sizes = [p[0] for p in pts]
    i = bisect.bisect_right(sizes, kb) - 1
    (s0, e0), (s1, e1) = pts[i], pts[i + 1]
    t = math.log(kb / s0) / math.log(s1 / s0)
    return math.exp(math.log(e0) * (1 - t) + math.log(e1) * t)


def access_energy_pj(size_bytes: float, width_bits: int | None = None) -> float:
    """Access energy for a memory of ``size_bytes`` (SRAM/RF or DRAM)."""
    if size_bytes > DRAM_THRESHOLD_BYTES:
        return DRAM_PJ_PER_16B
    return sram_access_pj(size_bytes, width_bits)


def sram_area_mm2(size_bytes: float) -> float:
    return SRAM_AREA_MM2_PER_MB * (size_bytes / (1024.0 * 1024.0))


def broadcast_energy_pj(total_onchip_bytes: float) -> float:
    """Paper §3.4: broadcast cost ~= fetch from a memory the size of the
    total embedded memory the data must traverse."""
    return access_energy_pj(total_onchip_bytes)
