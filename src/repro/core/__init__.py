"""Core analytical blocking model (the paper's contribution).

Public API:

    Problem, BlockingString, Loop, Dim     — loop-nest IR
    place_buffers, analyze                 — buffer placement + traffic
    energy_custom, energy_fixed, optimize  — energy model + schedule search
    evaluate_multicore, best_scheme        — coarse-grain parallelism
    matmul_tiles, conv_tiles, flash_tiles  — TPU BlockSpec derivation
"""

from repro.core.loopnest import (BlockingString, Dim, Extents, Loop,
                                 Problem, divisors)
from repro.core.buffers import (Buffer, Operand, operand_bytes,
                                place_buffers, table2_refetch_rate)
from repro.core.access import TrafficReport, analyze
from repro.core.energy import (access_energy_pj, broadcast_energy_pj,
                               sram_area_mm2, MAC_ENERGY_PJ,
                               DRAM_PJ_PER_16B)
from repro.core.hierarchy import (EnergyReport, MemLevel, cache_accesses,
                                  diannao_hierarchy, energy_custom,
                                  energy_fixed, xeon_hierarchy)
from repro.core.optimizer import (OptResult, make_objective, optimize,
                                  optimize_beam, optimize_exhaustive)
from repro.core.multicore import (MulticoreReport, best_scheme,
                                  evaluate_multicore)
from repro.core.fusion import (Epilogue, FusedProblem, FusedTraffic,
                               FusionResult, fused_energy_pj,
                               fused_multicore_dram_bytes, optimize_fused)
from repro.core.gemm_lowering import (direct_blocking_accesses,
                                      gemm_lowering_accesses,
                                      lowered_gemm_problem)
from repro.core.tpu_adapter import (TPU_V5E, TpuTarget,
                                    conv_tile_candidates, conv_tiles,
                                    flash_tiles, layer_sharding_advice,
                                    matmul_tile_candidates, matmul_tiles)

__all__ = [
    "BlockingString", "Dim", "Extents", "Loop", "Problem", "divisors",
    "Buffer", "Operand", "operand_bytes", "place_buffers",
    "table2_refetch_rate",
    "TrafficReport", "analyze",
    "access_energy_pj", "broadcast_energy_pj", "sram_area_mm2",
    "MAC_ENERGY_PJ", "DRAM_PJ_PER_16B",
    "EnergyReport", "MemLevel", "cache_accesses", "diannao_hierarchy",
    "energy_custom", "energy_fixed", "xeon_hierarchy",
    "OptResult", "make_objective", "optimize", "optimize_beam",
    "optimize_exhaustive",
    "MulticoreReport", "best_scheme", "evaluate_multicore",
    "Epilogue", "FusedProblem", "FusedTraffic", "FusionResult",
    "fused_energy_pj", "fused_multicore_dram_bytes", "optimize_fused",
    "direct_blocking_accesses", "gemm_lowering_accesses",
    "lowered_gemm_problem",
    "TPU_V5E", "TpuTarget", "conv_tile_candidates", "conv_tiles",
    "flash_tiles", "layer_sharding_advice", "matmul_tile_candidates",
    "matmul_tiles",
]
