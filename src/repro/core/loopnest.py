"""Loop-nest IR for CNN-like computations (paper §3.1).

The convolutional layer is a 6-deep loop nest over (Fw, Fh, X, Y, C, K)
(7-deep with the batch dimension N).  A *blocking string* is an ordered
sequence of loops, innermost first, where each dimension may appear several
times (multi-level blocking).  Following the paper's notation, the value
attached to the i-th occurrence of a dimension is the *cumulative extent*
covered by that loop and everything below it: for ``X0=8, X1=64`` the inner
loop covers 8 output columns and the outer loop iterates ``64/8`` times.

A fully-connected layer (or any GEMM, e.g. a transformer projection) is the
degenerate conv ``Fw=Fh=1, Y=1`` with ``X=M`` (rows), ``C=K_reduce``,
``K=N_cols`` — see :func:`Problem.gemm`.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
import math
from typing import Iterable, Sequence


class Dim(enum.Enum):
    FW = "Fw"
    FH = "Fh"
    X = "X"
    Y = "Y"
    C = "C"
    K = "K"
    N = "N"  # batch of images / tokens

    def __repr__(self) -> str:  # compact reprs in blocking strings
        return self.value


# Which dimensions index each operand.  Inputs are indexed by X/Y via the
# sliding window (plus the halo), weights by (Fw, Fh, C, K), outputs by
# (X, Y, K, N).  N indexes inputs and outputs but not weights.
INPUT_DIMS = frozenset({Dim.X, Dim.Y, Dim.C, Dim.N, Dim.FW, Dim.FH})
WEIGHT_DIMS = frozenset({Dim.FW, Dim.FH, Dim.C, Dim.K})
OUTPUT_DIMS = frozenset({Dim.X, Dim.Y, Dim.K, Dim.N})
REDUCTION_DIMS = frozenset({Dim.C, Dim.FW, Dim.FH})


@dataclasses.dataclass(frozen=True)
class Problem:
    """Dimensions of one convolutional (or FC) layer.

    ``bytes_per_elem`` is the uniform element width (the paper uses 16-bit
    data throughout); mixed-precision nests override it per operand with
    ``input_bytes`` / ``weight_bytes`` / ``output_bytes`` (``None`` means
    "same as bytes_per_elem").  Element width is a first-class blocking
    parameter: the access/energy model counts traffic in bytes, so a
    1-byte weight operand lets twice the weight tile fit in the same
    buffer and shifts the optimum — exactly the lever quantization pulls.
    """

    X: int
    Y: int
    C: int
    K: int
    Fw: int = 1
    Fh: int = 1
    N: int = 1
    stride: int = 1
    bytes_per_elem: int = 2  # the paper uses 16-bit data throughout
    input_bytes: int | None = None    # activations (w8a8: 1)
    weight_bytes: int | None = None   # weights / KV stream (w8: 1, fp8: 1)
    output_bytes: int | None = None

    @classmethod
    def gemm(cls, M: int, N_cols: int, K_reduce: int, batch: int = 1,
             bytes_per_elem: int = 2,
             input_bytes: int | None = None,
             weight_bytes: int | None = None,
             output_bytes: int | None = None) -> "Problem":
        """A GEMM (FC layer / transformer projection) as a degenerate conv."""
        return cls(X=M, Y=1, C=K_reduce, K=N_cols, Fw=1, Fh=1, N=batch,
                   bytes_per_elem=bytes_per_elem, input_bytes=input_bytes,
                   weight_bytes=weight_bytes, output_bytes=output_bytes)

    @property
    def input_bpe(self) -> int:
        return self.input_bytes or self.bytes_per_elem

    @property
    def weight_bpe(self) -> int:
        return self.weight_bytes or self.bytes_per_elem

    @property
    def output_bpe(self) -> int:
        return self.output_bytes or self.bytes_per_elem

    def full_extent(self, d: Dim) -> int:
        return {Dim.X: self.X, Dim.Y: self.Y, Dim.C: self.C, Dim.K: self.K,
                Dim.FW: self.Fw, Dim.FH: self.Fh, Dim.N: self.N}[d]

    @property
    def macs(self) -> int:
        return (self.N * self.X * self.Y * self.C * self.K * self.Fw *
                self.Fh)

    @property
    def input_x(self) -> int:
        return (self.X - 1) * self.stride + self.Fw

    @property
    def input_y(self) -> int:
        return (self.Y - 1) * self.stride + self.Fh

    @property
    def input_elems(self) -> int:
        return self.N * self.input_x * self.input_y * self.C

    @property
    def weight_elems(self) -> int:
        return self.Fw * self.Fh * self.C * self.K

    @property
    def output_elems(self) -> int:
        return self.N * self.X * self.Y * self.K

    def total_bytes(self) -> int:
        return (self.input_elems * self.input_bpe +
                self.weight_elems * self.weight_bpe +
                self.output_elems * self.output_bpe)


@dataclasses.dataclass(frozen=True)
class Loop:
    """One level of one dimension.  ``extent`` is cumulative (paper §3.1)."""

    dim: Dim
    extent: int

    def __repr__(self) -> str:
        return f"{self.dim.value}{self.extent}"


@dataclasses.dataclass(frozen=True)
class Extents:
    """Cumulative extents covered below some point in the string."""

    X: int = 1
    Y: int = 1
    C: int = 1
    K: int = 1
    Fw: int = 1
    Fh: int = 1
    N: int = 1

    def get(self, d: Dim) -> int:
        return getattr(self, d.value if d.value in ("Fw", "Fh") else d.name)

    def with_dim(self, d: Dim, value: int) -> "Extents":
        field = d.value if d.value in ("Fw", "Fh") else d.name
        return dataclasses.replace(self, **{field: value})

    def input_footprint(self, stride: int = 1) -> int:
        """Input elements touched (with halo)."""
        ix = (self.X - 1) * stride + self.Fw
        iy = (self.Y - 1) * stride + self.Fh
        return self.N * ix * iy * self.C

    def weight_footprint(self) -> int:
        return self.Fw * self.Fh * self.C * self.K

    def output_footprint(self) -> int:
        return self.N * self.X * self.Y * self.K


class BlockingString:
    """An ordered (inner -> outer) sequence of loops covering a Problem."""

    def __init__(self, loops: Sequence[Loop], problem: Problem):
        self.loops: tuple[Loop, ...] = tuple(loops)
        self.problem = problem
        self._validate()
        self._precompute()

    def _precompute(self) -> None:
        """Cache per-position extents, trip counts and suffix products —
        the access model queries these millions of times during search."""
        n = len(self.loops)
        cur = {d: 1 for d in Dim}
        self._extents: list[Extents] = []
        self._iters: list[int] = []
        for lp in self.loops:
            self._extents.append(Extents(
                X=cur[Dim.X], Y=cur[Dim.Y], C=cur[Dim.C], K=cur[Dim.K],
                Fw=cur[Dim.FW], Fh=cur[Dim.FH], N=cur[Dim.N]))
            self._iters.append(lp.extent // cur[lp.dim])
            cur[lp.dim] = lp.extent
        self._extents.append(Extents(
            X=cur[Dim.X], Y=cur[Dim.Y], C=cur[Dim.C], K=cur[Dim.K],
            Fw=cur[Dim.FW], Fh=cur[Dim.FH], N=cur[Dim.N]))
        # suffix products of trip counts: _suffix[q] = prod_{i>=q} iters(i)
        self._suffix: list[int] = [1] * (n + 1)
        for q in range(n - 1, -1, -1):
            self._suffix[q] = self._iters[q] * self._suffix[q + 1]

    # -- construction helpers -------------------------------------------------

    @classmethod
    def parse(cls, text: str, problem: Problem) -> "BlockingString":
        """Parse ``"Fw3 Fh3 X8 C64 K16 X56 C256 K512"`` style strings."""
        loops = []
        for tok in text.split():
            for d in sorted(Dim, key=lambda d: -len(d.value)):
                if tok.startswith(d.value) and tok[len(d.value):].isdigit():
                    loops.append(Loop(d, int(tok[len(d.value):])))
                    break
            else:
                raise ValueError(f"cannot parse loop token {tok!r}")
        return cls(loops, problem)

    def _validate(self) -> None:
        cur: dict[Dim, int] = {d: 1 for d in Dim}
        for lp in self.loops:
            if lp.extent < cur[lp.dim]:
                raise ValueError(
                    f"loop {lp} shrinks dimension (have {cur[lp.dim]})")
            if lp.extent % cur[lp.dim] != 0:
                raise ValueError(
                    f"loop {lp} extent not a multiple of inner extent "
                    f"{cur[lp.dim]}")
            cur[lp.dim] = lp.extent
        for d in Dim:
            full = self.problem.full_extent(d)
            if cur[d] != full:
                raise ValueError(
                    f"dimension {d.value} covered to {cur[d]} != {full}; "
                    "string must cover the whole problem")

    # -- queries ---------------------------------------------------------------

    def __repr__(self) -> str:
        return " ".join(repr(l) for l in self.loops)

    def __len__(self) -> int:
        return len(self.loops)

    def __eq__(self, other: object) -> bool:
        return (isinstance(other, BlockingString)
                and self.loops == other.loops
                and self.problem == other.problem)

    def __hash__(self) -> int:
        return hash((self.loops, self.problem))

    def extents_below(self, pos: int) -> Extents:
        """Cumulative extents covered by loops strictly below ``pos``."""
        return self._extents[pos]

    def iterations(self, pos: int) -> int:
        """Trip count of the loop at ``pos``."""
        return self._iters[pos]

    def prod_iterations_from(self, start: int) -> int:
        """Product of trip counts of loops at positions >= ``start``."""
        return self._suffix[start]

    def total_iterations(self) -> int:
        return self._suffix[0]


# -- candidate generation ------------------------------------------------------

def divisors(n: int) -> list[int]:
    out = []
    i = 1
    while i * i <= n:
        if n % i == 0:
            out.append(i)
            if i != n // i:
                out.append(n // i)
        i += 1
    return sorted(out)


def near_divisors(n: int, max_count: int = 12) -> list[int]:
    """A trimmed set of divisors, biased toward powers of two & extremes."""
    divs = divisors(n)
    if len(divs) <= max_count:
        return divs
    keep = {1, n}
    pow2 = [d for d in divs if d & (d - 1) == 0]
    keep.update(pow2)
    # fill remaining slots evenly across the sorted divisor list
    step = max(1, len(divs) // max_count)
    keep.update(divs[::step])
    return sorted(keep)[:max_count] if len(keep) > max_count else sorted(keep)


def enumerate_orders(dims: Sequence[Dim]) -> Iterable[tuple[Dim, ...]]:
    """All distinct loop-dim orders (inner -> outer)."""
    seen = set()
    for perm in itertools.permutations(dims):
        if perm not in seen:
            seen.add(perm)
            yield perm
