"""TPU instantiation of the blocking model (DESIGN.md §3).

The paper's model is hierarchy-agnostic; on TPU v5e the hierarchy is
HBM (16 GiB, 819 GB/s) -> VMEM (~128 MiB/core) -> VREGs, and the MXU wants
matmul operands tiled to multiples of (8, 128) sublane x lane (128x128 for
full systolic utilization).  This module runs the paper's optimizer with
that hierarchy + alignment constraints and emits:

* ``matmul_tiles``  — (bm, bk, bn) BlockSpec tiles for the blocked-GEMM
  Pallas kernel (every transformer projection / FC layer);
* ``conv_tiles``    — (bx, by, bc, bk) tiles for the direct blocked-conv
  Pallas kernel;
* ``flash_tiles``   — (block_q, block_kv) for the attention kernel (the
  K/V tiles play the paper's KB role; the running softmax accumulator is
  the OB);
* ``sharding_advice`` — the §3.3 K-vs-XY partitioning rule mapped to
  tensor-vs-data parallelism for a layer's operand sizes.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings

from repro.core.hierarchy import MemLevel
from repro.core.loopnest import Dim, Problem, divisors
from repro.core.optimizer import ranked_level0_tiles


@dataclasses.dataclass(frozen=True)
class TpuTarget:
    name: str
    peak_bf16_flops: float
    hbm_bytes_per_s: float
    vmem_bytes: int
    ici_bytes_per_s_per_link: float
    mxu: tuple[int, int] = (128, 128)
    sublane: int = 8
    lane: int = 128
    hbm_bytes: int = 16 * 1024**3


TPU_V5E = TpuTarget(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    hbm_bytes_per_s=819e9,
    vmem_bytes=128 * 1024 * 1024,
    ici_bytes_per_s_per_link=50e9,
)


def default_vmem_budget(target: TpuTarget = TPU_V5E,
                        vmem_budget_bytes: int | None = None) -> int:
    """Working-set budget for tile derivation: 1/8 of VMEM unless
    overridden — headroom for Pallas pipeline buffers and the compiler.
    The single definition shared by the snap loops here and the candidate
    filter in ``repro.tune.lowering``."""
    return vmem_budget_bytes or target.vmem_bytes // 8


def _round_to(v: int, mult: int, lo: int, hi: int) -> int:
    v = max(lo, min(hi, (v // mult) * mult))
    return v if v >= mult else min(hi, mult)


def _pick_tile(extent: int, target: int, mult: int) -> int:
    """Largest tile <= target that is a multiple of ``mult`` and <= extent;
    prefers exact divisors of extent to avoid ragged tail blocks."""
    if extent <= mult:
        return extent
    cap = min(target, extent)
    aligned_divs = [d for d in divisors(extent) if d % mult == 0 and d <= cap]
    if aligned_divs:
        return max(aligned_divs)
    return _round_to(cap, mult, mult, extent)


def _matmul_fits(bm: int, bk: int, bn: int, bytes_per_elem: int,
                 budget: int, weight_bytes: int | None = None) -> bool:
    # lazy import: the kernel module (jax) owns its VMEM layout; core
    # stays importable without jax until tiles are actually derived.
    if weight_bytes is not None:
        from repro.kernels.matmul_q import vmem_bytes_required
        return vmem_bytes_required(bm, bk, bn, bytes_per_elem,
                                   weight_bytes) <= budget
    from repro.kernels.matmul_blocked import vmem_bytes_required
    return vmem_bytes_required(bm, bk, bn, bytes_per_elem) <= budget


def _snap_matmul(bm: int, bk: int, bn: int, M: int, N: int, K: int,
                 bytes_per_elem: int, budget: int,
                 target: TpuTarget,
                 weight_bytes: int | None = None) -> tuple[int, int, int]:
    """Snap an analytical (bm, bk, bn) to MXU alignment + VMEM fit."""
    # lanes on the minor (N, K) dims, sublanes on M
    bm = _pick_tile(M, max(bm, target.sublane), target.sublane)
    bn = _pick_tile(N, max(bn, target.lane), target.lane)
    bk = _pick_tile(K, max(bk, target.lane), target.lane)
    while not _matmul_fits(bm, bk, bn, bytes_per_elem, budget,
                           weight_bytes):
        # shrink the largest contributor
        if bk * (bm + bn) >= bm * bn and bk > target.lane:
            bk = max(target.lane, bk // 2)
        elif bm >= bn and bm > target.sublane:
            bm = max(target.sublane, bm // 2)
        elif bn > target.lane:
            bn = max(target.lane, bn // 2)
        else:
            break
    return bm, bk, bn


@functools.lru_cache(maxsize=512)
def matmul_tile_candidates(M: int, N: int, K: int, bytes_per_elem: int = 2,
                           vmem_budget_bytes: int | None = None,
                           target: TpuTarget = TPU_V5E,
                           top: int = 8,
                           weight_bytes: int | None = None,
                           ) -> tuple[tuple[int, int, int], ...]:
    """Ranked (bm, bk, bn) candidates for C[M,N] += A[M,K] @ B[K,N].

    The optimizer sees a 2-level hierarchy (VMEM working set, HBM above)
    and alignment candidates restricted to MXU multiples; each analytical
    winner is then snapped to hardware alignment and the VMEM budget.
    Order follows the optimizer's energy ranking; the autotuner
    (``repro.tune``) re-ranks by predicted DRAM traffic and measurement.

    ``weight_bytes`` gives the B operand its own element width (int8
    weights: 1) — the search then sizes the weight tile in those bytes
    and the VMEM fit uses the quantized kernel's footprint model.
    """
    budget = default_vmem_budget(target, vmem_budget_bytes)
    problem = Problem.gemm(M=M, N_cols=N, K_reduce=K,
                           bytes_per_elem=bytes_per_elem,
                           weight_bytes=weight_bytes)
    levels = [MemLevel.sram("VMEM", budget), MemLevel.dram("HBM")]
    align = {Dim.X: target.sublane, Dim.K: target.lane, Dim.C: target.lane}
    raw: list[tuple[int, int, int]] = []
    try:
        for e in ranked_level0_tiles(problem, levels, align=align, top=top):
            raw.append((e.X, e.C, e.K))          # (bm, bk, bn)
    except Exception as exc:
        warnings.warn(f"blocking search failed for GEMM {M}x{N}x{K} "
                      f"({exc!r}); using heuristic seed tiles")
    raw.append((256, 512, 256))                  # heuristic fallback seed
    out: list[tuple[int, int, int]] = []
    for bm, bk, bn in raw:
        cand = _snap_matmul(bm, bk, bn, M, N, K, bytes_per_elem, budget,
                            target, weight_bytes)
        if cand not in out:
            out.append(cand)
    return tuple(out[:top])


def matmul_tiles(M: int, N: int, K: int, bytes_per_elem: int = 2,
                 vmem_budget_bytes: int | None = None,
                 target: TpuTarget = TPU_V5E) -> tuple[int, int, int]:
    """Top analytical (bm, bk, bn) tile (see matmul_tile_candidates)."""
    return matmul_tile_candidates(M, N, K, bytes_per_elem,
                                  vmem_budget_bytes, target)[0]


def _conv_fits(bx: int, by: int, bc: int, bk: int, Fw: int, Fh: int,
               bytes_per_elem: int, budget: int, stride: int) -> bool:
    from repro.kernels.conv2d_blocked import vmem_bytes_required
    return vmem_bytes_required(bx, by, bc, bk, Fh, Fw,
                               bytes_per_elem, stride) <= budget


def _snap_conv(bx: int, by: int, bc: int, bk: int,
               X: int, Y: int, C: int, K: int, Fw: int, Fh: int,
               bytes_per_elem: int, budget: int,
               target: TpuTarget, stride: int) -> tuple[int, int, int, int]:
    bx = _pick_tile(X, max(bx, target.sublane), 1)
    by = _pick_tile(Y, by, 1)
    bc = _pick_tile(C, max(bc, min(C, target.lane)),
                    min(C, target.lane) if C >= target.lane else 1)
    bk = _pick_tile(K, max(bk, min(K, target.lane)),
                    min(K, target.lane) if K >= target.lane else 1)
    while not _conv_fits(bx, by, bc, bk, Fw, Fh, bytes_per_elem, budget,
                         stride):
        if bx >= by and bx > 8:
            bx = max(8, bx // 2)
        elif by > 1:
            by = max(1, by // 2)
        elif bk > target.lane:
            bk = max(target.lane, bk // 2)
        elif bc > target.lane:
            bc = max(target.lane, bc // 2)
        else:
            break
    return bx, by, bc, bk


@functools.lru_cache(maxsize=256)
def conv_tile_candidates(X: int, Y: int, C: int, K: int, Fw: int, Fh: int,
                         bytes_per_elem: int = 2,
                         vmem_budget_bytes: int | None = None,
                         target: TpuTarget = TPU_V5E, top: int = 8,
                         stride: int = 1,
                         ) -> tuple[tuple[int, int, int, int], ...]:
    """Ranked (bx, by, bc, bk) VMEM tiles for the direct blocked conv."""
    budget = default_vmem_budget(target, vmem_budget_bytes)
    problem = Problem(X=X, Y=Y, C=C, K=K, Fw=Fw, Fh=Fh, stride=stride,
                      bytes_per_elem=bytes_per_elem)
    levels = [MemLevel.sram("VMEM", budget), MemLevel.dram("HBM")]
    align = {Dim.K: target.lane, Dim.C: target.lane}
    raw: list[tuple[int, int, int, int]] = []
    try:
        for e in ranked_level0_tiles(problem, levels, align=align, top=top,
                                     max_orders=24):
            raw.append((e.X, e.Y, e.C, e.K))
    except Exception as exc:
        warnings.warn(f"blocking search failed for conv "
                      f"{(X, Y, C, K, Fw, Fh)} ({exc!r}); using heuristic "
                      "seed tiles")
    raw.append((X, Y, min(C, target.lane), min(K, target.lane)))
    out: list[tuple[int, int, int, int]] = []
    for bx, by, bc, bk in raw:
        cand = _snap_conv(bx, by, bc, bk, X, Y, C, K, Fw, Fh,
                          bytes_per_elem, budget, target, stride)
        if cand not in out:
            out.append(cand)
    return tuple(out[:top])


def conv_tiles(X: int, Y: int, C: int, K: int, Fw: int, Fh: int,
               bytes_per_elem: int = 2,
               vmem_budget_bytes: int | None = None,
               target: TpuTarget = TPU_V5E) -> tuple[int, int, int, int]:
    """Top analytical (bx, by, bc, bk) tile (see conv_tile_candidates)."""
    return conv_tile_candidates(X, Y, C, K, Fw, Fh, bytes_per_elem,
                                vmem_budget_bytes, target)[0]


def backward_tile_candidates(op: str, dims: tuple[int, ...],
                             bytes_per_elem: int = 2,
                             vmem_budget_bytes: int | None = None,
                             target: TpuTarget = TPU_V5E, top: int = 8,
                             stride: int = 1) -> tuple[tuple[int, ...], ...]:
    """Ranked tiles for the backward nests, reusing the forward searches.

    The backward passes are the same loop-nest families (the paper's
    analysis is indifferent to which operand is written), so no new
    search is grown: ``matmul_dgrad`` is a GEMM over the cotangent's
    (M, N, K); ``conv2d_dgrad`` is the transposed conv as a direct conv
    (channels swapped, stride folded into host dilation, hence stride 1
    here); ``conv2d_wgrad`` shares the forward conv's dims with (bx, by)
    blocking the spatial reduction.  Candidate ranking flows through
    ``core.optimizer.ranked_level0_tiles`` exactly as for the forward.
    """
    if op == "matmul_dgrad":
        M, N, K = dims
        return matmul_tile_candidates(M, N, K, bytes_per_elem,
                                      vmem_budget_bytes, target, top)
    if op not in ("conv2d_dgrad", "conv2d_wgrad"):
        raise ValueError(f"not a backward op: {op!r}")
    X, Y, C, K, Fw, Fh = dims
    return conv_tile_candidates(X, Y, C, K, Fw, Fh, bytes_per_elem,
                                vmem_budget_bytes, target, top,
                                stride=1 if op == "conv2d_dgrad" else stride)


@functools.lru_cache(maxsize=256)
def flash_decode_tile_candidates(groups: int, seq_kv: int, head_dim: int,
                                 bytes_per_elem: int = 2,
                                 vmem_budget_bytes: int | None = None,
                                 target: TpuTarget = TPU_V5E, top: int = 8,
                                 kv_bytes: int | None = None,
                                 ) -> tuple[tuple[int], ...]:
    """Ranked ``(block_kv,)`` candidates for the paged flash-decode kernel.

    Decode attention per (batch, kv-head) is the skinny GEMM
    ``out[G, D] = softmax(q[G, D] @ K^T[D, S]) @ V[S, D]`` — a
    memory-bound nest whose only free blocking choice is how much of the
    S-long KV stream is resident per step.  The optimizer search runs on
    that nest (C = the KV reduction dim); each winner's C extent is
    snapped to lane alignment, to a divisor of ``seq_kv`` (the kernel
    grid requires whole blocks), and to the kernel's VMEM model.  The
    chosen block doubles as the paged cache's page size.

    ``kv_bytes`` gives the streamed K/V pages their own element width
    (fp8 cache: 1); the q rows and the fp32 running state keep
    ``bytes_per_elem`` — an fp8 cache fits twice the page in the same
    VMEM, so the fp8-aware search can pick larger pages.
    """
    from repro.kernels.flash_decode import vmem_bytes_required
    budget = default_vmem_budget(target, vmem_budget_bytes)
    problem = Problem.gemm(M=groups, N_cols=head_dim, K_reduce=seq_kv,
                           bytes_per_elem=bytes_per_elem,
                           weight_bytes=kv_bytes)
    levels = [MemLevel.sram("VMEM", budget), MemLevel.dram("HBM")]
    align = {Dim.C: target.lane}
    raw: list[int] = []
    try:
        for e in ranked_level0_tiles(problem, levels, align=align, top=top):
            raw.append(e.C)
    except Exception as exc:
        warnings.warn(f"blocking search failed for flash_decode "
                      f"{(groups, seq_kv, head_dim)} ({exc!r}); using "
                      "heuristic seed block")
    raw.append(min(seq_kv, 512))                 # heuristic fallback seed
    out: list[tuple[int]] = []
    for bkv in raw:
        mult = target.lane if seq_kv >= target.lane else 1
        bkv = _pick_tile(seq_kv, max(bkv, mult), mult)
        while (vmem_bytes_required(bkv, groups, head_dim, bytes_per_elem,
                                   kv_bytes=kv_bytes) > budget
               and bkv > mult):
            bkv = max(mult, bkv // 2)
        # the kernel iterates whole pages: snap to a divisor of seq_kv
        if seq_kv % bkv:
            bkv = max(d for d in divisors(seq_kv) if d <= bkv)
        if (bkv,) not in out:
            out.append((bkv,))
    return tuple(out[:top])


@functools.lru_cache(maxsize=256)
def flash_tiles(seq_q: int, seq_kv: int, head_dim: int,
                bytes_per_elem: int = 2,
                vmem_budget_bytes: int | None = None,
                target: TpuTarget = TPU_V5E) -> tuple[int, int]:
    """(block_q, block_kv) for the streaming-softmax attention kernel.

    In the paper's vocabulary the KV tile is the kernel buffer (reused by
    every query block -> big tiles amortize HBM fetches) and the running
    (m, l, acc) state is the output buffer held across the KV loop.
    """
    budget = default_vmem_budget(target, vmem_budget_bytes)
    bq = _pick_tile(seq_q, 512, target.sublane)
    bkv = _pick_tile(seq_kv, 1024, target.lane if seq_kv >= target.lane
                     else 1)

    def fits(bq, bkv) -> bool:
        q = bq * head_dim * bytes_per_elem
        kv = 2 * bkv * head_dim * bytes_per_elem
        scores = bq * bkv * 4
        acc = bq * head_dim * 4 + 2 * bq * 4
        return q + kv + scores + acc <= budget
    while not fits(bq, bkv):
        if bkv >= bq and bkv > target.lane:
            bkv = max(target.lane, bkv // 2)
        elif bq > target.sublane:
            bq = max(target.sublane, bq // 2)
        else:
            break
    return bq, bkv


def layer_sharding_advice(weight_bytes: int, activation_bytes: int) -> str:
    """Paper §3.3 / §5.3 rule at mesh scale: shard (partition) the LARGE
    operand so the small one is the broadcast; sharing the large buffer
    makes its broadcast free."""
    return "model" if weight_bytes >= activation_bytes else "data"
