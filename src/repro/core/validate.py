"""Independent validation of the analytical access model.

``simulate_fills`` *executes* the blocked loop nest index space in program
order and tracks, for every buffer the placement rules allocate, the tuple
of relevant outer-loop indices that determines its contents.  Fills are
counted when that tuple changes (i.e. eviction/refill events are observed,
not derived from a closed-form product).  Agreement with
:func:`repro.core.access.analyze` is a strong check on the reuse/eviction
logic — the two implementations share only the buffer-placement rules.

Only practical for small problems (the trace has ``total_iterations``
steps); tests use reduced layer dims.
"""

from __future__ import annotations

import itertools

from repro.core.access import OUTPUT_ADDR_DIMS
from repro.core.buffers import OPERAND_DIMS, Operand, place_buffers
from repro.core.loopnest import BlockingString


def simulate_fills(s: BlockingString) -> dict[str, tuple[int, int]]:
    """Returns {buffer_name: (fill_elems, writeback_elems)} by simulation."""
    bufs = [b for b in place_buffers(s) if b.pos >= 0]
    n = len(s.loops)
    trip = [s.iterations(q) for q in range(n)]

    state = {}
    for b in bufs:
        rel = OPERAND_DIMS[b.operand]
        rel_pos = [q for q in range(b.pos + 1, n) if s.loops[q].dim in rel]
        if b.operand is Operand.OUTPUT:
            # the block leaves the buffer when its ADDRESSING key changes;
            # reduction loops accumulate in place (no writeback).
            addr_pos = [q for q in range(b.pos + 1, n)
                        if s.loops[q].dim in OUTPUT_ADDR_DIMS]
            state[b.name] = {
                "buffer": b, "addr_pos": addr_pos,
                "last_addr": None, "seen_addr": set(),
                "fills": 0, "writebacks": 0}
        else:
            state[b.name] = {"buffer": b, "rel_pos": rel_pos,
                             "last_key": None, "fills": 0, "writebacks": 0}

    # iterate the index space in execution order (outermost varies slowest)
    ranges = [range(trip[q]) for q in range(n - 1, -1, -1)]  # outer..inner
    for idx_outer_first in itertools.product(*ranges):
        idx = idx_outer_first[::-1]  # idx[q] = current index of loop q
        for st in state.values():
            b = st["buffer"]
            if b.operand is Operand.OUTPUT:
                addr = tuple(idx[q] for q in st["addr_pos"])
                if addr != st["last_addr"]:
                    if st["last_addr"] is not None:
                        st["writebacks"] += b.size_elems  # epoch ended
                    if addr in st["seen_addr"]:
                        st["fills"] += b.size_elems  # partials read back
                    st["seen_addr"].add(addr)
                    st["last_addr"] = addr
            else:
                key = tuple(idx[q] for q in st["rel_pos"])
                if key != st["last_key"]:
                    st["fills"] += b.size_elems
                    st["last_key"] = key
    # final epoch writeback for outputs
    for st in state.values():
        if st["buffer"].operand is Operand.OUTPUT and \
                st["last_addr"] is not None:
            st["writebacks"] += st["buffer"].size_elems
    return {name: (st["fills"], st["writebacks"])
            for name, st in state.items()}
