"""im2col + GEMM baseline access model (paper §2.2, Figs. 3-4).

Caffe-style implementations *lower* the 3-D convolution into a matrix
multiplication:

    weights  W  : (K, C*Fw*Fh)
    lowered  L  : (C*Fw*Fh, X*Y)     <- each input pixel replicated Fw*Fh x
    output   O  : (K, X*Y)

The lowering both (a) replicates input data ``Fw*Fh``-fold and (b) destroys
the sliding-window locality, so even a perfectly cache-blocked GEMM does
more cache traffic than direct blocked convolution.  We model the blocked
GEMM with the same analytical machinery (a GEMM is a degenerate conv) and
add the lowering pass traffic, giving the ATLAS/MKL-like curves of
Figs. 3-4.  MKL and ATLAS differ in their blocking quality; we model MKL
as a 2-level-blocked GEMM with register blocking and ATLAS as a more
conservative single-level cache blocking, which brackets the measured 2-8x
(L2) and 2-11x (L3) gaps in the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.access import analyze
from repro.core.hierarchy import MemLevel, cache_accesses, pack_fixed
from repro.core.loopnest import BlockingString, Dim, Loop, Problem
from repro.core.optimizer import make_objective, optimize_exhaustive


@dataclasses.dataclass(frozen=True)
class GemmLoweringReport:
    conv: Problem
    gemm: Problem
    lowering_write_elems: int      # building the lowered matrix
    lowering_read_elems: int       # reading the input while lowering
    cache_counts: dict[str, int]   # per-level accesses incl. lowering


def lowered_gemm_problem(p: Problem) -> Problem:
    """The GEMM the conv becomes after im2col."""
    return Problem.gemm(M=p.X * p.Y * p.N, N_cols=p.K,
                        K_reduce=p.C * p.Fw * p.Fh,
                        bytes_per_elem=p.bytes_per_elem)


def _blocked_gemm_string(g: Problem, levels: Sequence[MemLevel],
                         quality: str) -> BlockingString:
    """A representative blocked-GEMM schedule.

    ``quality='mkl'``: 2-level blocking tuned per hierarchy (good GEMM).
    ``quality='atlas'``: fixed NB=64ish single-level cache blocking.
    """
    objective = make_objective("fixed", levels)
    if quality == "mkl":
        res = optimize_exhaustive(g, objective, n_levels=2, top=1,
                                  max_orders=8)
        return res[0].string
    # ATLAS-like: one cache-blocking level with square-ish NB tiles
    from repro.core.loopnest import divisors

    def close_div(n: int, t: int) -> int:
        return min(divisors(n), key=lambda v: abs(v - t))

    mb = close_div(g.X, 64)
    nb = close_div(g.K, 64)
    kb = close_div(g.C, 64)
    loops = [Loop(Dim.C, kb), Loop(Dim.X, mb), Loop(Dim.K, nb),
             Loop(Dim.C, g.C), Loop(Dim.K, g.K), Loop(Dim.X, g.X)]
    if g.N > 1:
        loops.append(Loop(Dim.N, g.N))
    return BlockingString(loops, g)


def gemm_lowering_accesses(p: Problem, levels: Sequence[MemLevel],
                           quality: str = "mkl") -> GemmLoweringReport:
    """Cache accesses of lowering + blocked GEMM for conv layer ``p``."""
    g = lowered_gemm_problem(p)
    s = _blocked_gemm_string(g, levels, quality)
    counts = dict(cache_accesses(s, levels))

    # lowering pass: read every input pixel once per kernel position it
    # lands in (Fw*Fh), write the replicated matrix once.  These run
    # through the cache hierarchy; the write traffic is the lowered-matrix
    # size, which at CFwFh x XY rarely fits on chip -> charge to the level
    # that can hold it (usually L3/DRAM), reads stream through L1.
    lower_writes = g.X * g.C  # == X*Y*N * C*Fw*Fh elements
    lower_reads = lower_writes  # each written element is read from input
    lowered_bytes = lower_writes * p.bytes_per_elem
    home = len(levels) - 1
    for i, lv in enumerate(levels):
        if lv.capacity_bytes and lowered_bytes <= lv.capacity_bytes:
            home = i
            break
    # the lowering pass streams through every cache level up to where the
    # replicated matrix lives (cumulative counting, matching PAPI)
    for i in range(home + 1):
        counts[levels[i].name] = counts.get(levels[i].name, 0) + \
            lower_writes + lower_reads
    # GEMM then re-reads the lowered matrix from wherever it lives: already
    # accounted by the blocked-GEMM model's input traffic.
    return GemmLoweringReport(conv=p, gemm=g,
                              lowering_write_elems=lower_writes,
                              lowering_read_elems=lower_reads,
                              cache_counts=counts)


def direct_blocking_accesses(p: Problem, levels: Sequence[MemLevel],
                             n_levels: int = 2) -> dict[str, int]:
    """Our direct blocking's per-level cache accesses for comparison."""
    objective = make_objective("fixed", levels)
    res = optimize_exhaustive(p, objective, n_levels=n_levels, top=1)
    return dict(cache_accesses(res[0].string, levels))
