"""Coarse-grain parallelism model (paper §3.3, Fig. 2, Fig. 9).

Unrolling an outer loop of the blocking string across ``S`` cores:

* **K partitioning**  — unroll an outer ``K`` loop.  KB and OB are
  partitioned per-core (each 1/S the size -> cheaper accesses); IB stays
  global and every fill is a *broadcast* whose energy is modeled as an
  access to a memory the size of the total on-chip memory (paper §3.4).
* **XY partitioning** — unroll an outer ``X``/``Y`` loop.  IB and OB are
  partitioned; KB is global and broadcast.

A multi-layer CNN also pays a *shuffle* cost between layers when the next
layer needs data partitioned differently (for K partitioning the output
channels are scattered across cores and must be re-broadcast).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.access import analyze
from repro.core.buffers import (Operand, buffers_by_operand, operand_bytes,
                                place_buffers)
from repro.core.energy import (DRAM_PJ_PER_16B, access_energy_pj,
                               broadcast_energy_pj)
from repro.core.loopnest import BlockingString, Dim, Loop, Problem


PARTITION_SCHEMES = ("K", "XY")

# which operand stays global (broadcast) under each scheme
_BROADCAST_OPERAND = {"K": Operand.INPUT, "XY": Operand.WEIGHT}
# which dims get divided across cores
_PARTITION_DIMS = {"K": (Dim.K,), "XY": (Dim.X, Dim.Y)}


@dataclasses.dataclass(frozen=True)
class MulticoreReport:
    scheme: str
    cores: int
    string: BlockingString
    private_pj: float        # energy inside each core, summed over cores
    ll_ib_pj: float          # last-level IB
    ll_kb_pj: float          # last-level KB
    ll_ob_pj: float          # last-level OB
    dram_pj: float
    shuffle_pj: float
    broadcast_pj: float

    @property
    def onchip_pj(self) -> float:
        return (self.private_pj + self.ll_ib_pj + self.ll_kb_pj +
                self.ll_ob_pj + self.shuffle_pj + self.broadcast_pj)

    @property
    def total_pj(self) -> float:
        return self.onchip_pj + self.dram_pj

    @property
    def total_macs(self) -> int:
        # ``string`` is the per-core problem; all cores run concurrently
        return self.string.problem.macs * self.cores

    @property
    def pj_per_mac(self) -> float:
        return self.total_pj / self.total_macs


def _partition_candidates(s: BlockingString, scheme: str,
                          cores: int) -> list[BlockingString]:
    """All ways to divide one outer partitionable loop by ``cores`` (the
    unrolled loop disappears into space); the per-core problem shrinks on
    that dim.  The caller picks the cheapest — the paper unrolls whichever
    outer loop preserves the most reuse."""
    dims = _PARTITION_DIMS[scheme]
    problem = s.problem
    out: list[BlockingString] = []
    seen_dims: set[Dim] = set()
    for pos in range(len(s.loops) - 1, -1, -1):
        lp = s.loops[pos]
        if lp.dim in seen_dims:
            continue  # only the outermost occurrence of each dim
        if lp.dim not in dims or s.iterations(pos) % cores or \
                s.iterations(pos) < cores:
            continue
        seen_dims.add(lp.dim)
        field = {Dim.X: "X", Dim.Y: "Y", Dim.K: "K"}[lp.dim]
        sub_problem = dataclasses.replace(
            problem, **{field: problem.full_extent(lp.dim) // cores})
        new_loops = []
        for q, l2 in enumerate(s.loops):
            if l2.dim is lp.dim and \
                    l2.extent > sub_problem.full_extent(lp.dim):
                ext = max(l2.extent // cores,
                          s.extents_below(q).get(l2.dim))
                new_loops.append(Loop(l2.dim, ext))
            else:
                new_loops.append(l2)
        out.append(BlockingString(new_loops, sub_problem))
    if not out:
        raise ValueError(f"no outer {dims} loop divisible by {cores} "
                         f"cores in {s}")
    return out


def evaluate_multicore(s: BlockingString, scheme: str, cores: int,
                       layers: int = 1) -> MulticoreReport:
    """Total energy of ``cores`` cores running the blocking ``s``.

    The per-core blocking is ``s`` with the partitioned dim divided by S.
    The broadcast operand's last-level fills each pay the broadcast bus
    energy; the partitioned operands' last-level buffers shrink by S.
    """
    if scheme not in PARTITION_SCHEMES:
        raise ValueError(f"scheme must be one of {PARTITION_SCHEMES}")
    if cores > 1:
        cands = _partition_candidates(s, scheme, cores)
        reports = [_evaluate_partitioned(c, scheme, cores, layers)
                   for c in cands]
        return min(reports, key=lambda r: r.total_pj)
    return _evaluate_partitioned(s, scheme, cores, layers)


def _evaluate_partitioned(per_core: BlockingString, scheme: str,
                          cores: int, layers: int) -> MulticoreReport:
    report = analyze(per_core)
    problem = per_core.problem

    by_op = buffers_by_operand([bt.buffer for bt in report.per_buffer])
    last_level = {op: chain[-1] for op, chain in by_op.items() if chain}
    traffic = {bt.buffer.name: bt for bt in report.per_buffer}

    # total on-chip bytes across all cores (for broadcast distance and area)
    total_onchip = 0
    for op, chain in by_op.items():
        for b in chain:
            sz = b.size_bytes(problem)
            if sz <= 16 * 1024 * 1024:
                total_onchip += sz * (1 if b is last_level[op] and
                                      op is _BROADCAST_OPERAND[scheme]
                                      else cores)
    e_bcast = broadcast_energy_pj(total_onchip)

    private_pj = 0.0
    ll_pj = {Operand.INPUT: 0.0, Operand.WEIGHT: 0.0, Operand.OUTPUT: 0.0}
    broadcast_pj = 0.0

    for op, chain in by_op.items():
        # mixed-precision nests: each operand's words counted at its own
        # width, matching the per-operand buffer sizes fed to
        # access_energy_pj below
        bpe = operand_bytes(problem, op)
        for b in chain:
            bt = traffic[b.name]
            words = bt.total_accesses * bpe / 2.0
            size = b.size_bytes(problem)
            is_ll = b is last_level[op]
            shared = is_ll and op is _BROADCAST_OPERAND[scheme]
            if shared:
                # one shared structure; every fill it serves below is a
                # broadcast across the die (no surcharge at 1 core)
                ll_pj[op] += words * access_energy_pj(size)
                if cores > 1:
                    broadcast_pj += (bt.reads_served * bpe / 2.0) * e_bcast
                # the shared buffer serves all cores with one broadcast, so
                # reads_served is NOT multiplied by cores.
            elif is_ll:
                ll_pj[op] += cores * words * access_energy_pj(size)
            else:
                private_pj += cores * words * access_energy_pj(size)

    # DRAM traffic: partitioned operands stream disjoint data (cores x
    # per-core traffic = whole-problem traffic); the broadcast operand is
    # fetched once for all cores.
    dram_pj = 0.0
    for op, elems in report.dram_accesses_by_operand.items():
        mult = 1 if op is _BROADCAST_OPERAND[scheme] else cores
        dram_pj += (elems * operand_bytes(problem, op) / 2.0) * \
            DRAM_PJ_PER_16B * mult

    # shuffle: restoring the output layout for the next layer (K scheme
    # scatters channels across cores -> all-to-all once per layer)
    shuffle_pj = 0.0
    if cores > 1 and layers > 0 and scheme == "K":
        out_words = problem.output_elems * cores * problem.output_bpe / 2.0
        shuffle_pj = out_words * e_bcast * layers

    return MulticoreReport(
        scheme=scheme, cores=cores, string=per_core,
        private_pj=private_pj, ll_ib_pj=ll_pj[Operand.INPUT],
        ll_kb_pj=ll_pj[Operand.WEIGHT], ll_ob_pj=ll_pj[Operand.OUTPUT],
        dram_pj=dram_pj, shuffle_pj=shuffle_pj, broadcast_pj=broadcast_pj)


def best_scheme(s: BlockingString, cores: int) -> MulticoreReport:
    """Paper's rule, derived: share the LARGE buffer (its broadcast is then
    ~free relative to its access energy); partition the small ones."""
    reports = [evaluate_multicore(s, sch, cores) for sch in PARTITION_SCHEMES]
    return min(reports, key=lambda r: r.total_pj)


def sharding_advice(problem: Problem, s: BlockingString) -> str:
    """TPU translation of the scheme choice (DESIGN.md §3): K-partitioning
    == tensor-parallel (shard weights), XY == data/sequence parallel."""
    kb = problem.weight_elems * problem.weight_bpe
    ib = problem.input_elems * problem.input_bpe
    return "tensor_parallel" if kb >= ib else "data_parallel"
