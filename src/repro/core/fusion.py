"""Fused producer-consumer loop nests (inter-layer blocking).

PRs 1-4 block one loop nest at a time, so every op's output round-trips
through DRAM before the next op reads it.  Communication lower bounds
for CNN pipelines (Demmel & Dinh 2018) and fusion-aware design-space
exploration (Li et al. 2021) both locate the next order-of-magnitude
win *between* nests: pick a joint level-0 tile such that the producer's
output tile stays resident in the fast level and feeds the consumer
directly — the intermediate operand then contributes **zero** DRAM
traffic.

:class:`FusedProblem` models a chain of GEMM-family :class:`Problem`
stages where stage ``i``'s output tensor is stage ``i+1``'s input
tensor (same row dim M, the fused dimension).  Pointwise epilogues
(bias, activation, gating multiply, residual add) attach to each stage
as an :class:`Epilogue`: run standalone they round-trip the stage
output through DRAM; fused they only stream their extra operands.

Traffic accounting reuses the paper's machinery verbatim: every stage
is scored by ``core.hierarchy.cache_accesses`` on the blocking string
its kernel executes, with per-operand byte weights — the intermediate
operand is eliminated by zeroing its weight on *both* sides (producer
output, consumer input) when its fusion tile fits the level-0 budget
alongside both stages' working sets.  Buffer sizing is fusion-aware:
stages adjacent to a resident intermediate search under a budget
reduced by the resident tile.  Energy and multicore traffic get the
same correction (:func:`fused_energy_pj`, :func:`fused_multicore_pj`);
under K-partitioning the intermediate's channels are scattered across
cores while the consumer reduces over all of them, so fusion across
that boundary buys nothing — only XY partitioning keeps the win.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.buffers import Operand, operand_bytes
from repro.core.energy import DRAM_PJ_PER_16B, access_energy_pj
from repro.core.hierarchy import MemLevel, cache_accesses, energy_fixed
from repro.core.loopnest import (BlockingString, Dim, Loop, Problem,
                                 divisors)


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Pointwise tail of one stage (always fusible into its producer).

    ``extra_operands`` counts streamed same-shape-as-output tensors the
    epilogue reads (a residual add or a gating multiply each add one);
    ``bias`` adds one (N,)-row read.  ``act`` is informational (the
    kernels use it; the traffic model only cares about operand counts).
    """

    act: str = "none"
    bias: bool = False
    extra_operands: int = 0

    @property
    def is_trivial(self) -> bool:
        return (self.act == "none" and not self.bias
                and self.extra_operands == 0)


def _gemm_dims(p: Problem) -> tuple[int, int, int]:
    """(M, N, K) of a GEMM-family Problem (X=M, K=N_cols, C=K_reduce)."""
    return p.X, p.K, p.C


def _gemm_string(p: Problem, tiles: tuple[int, int, int]) -> BlockingString:
    """The blocking string the blocked-GEMM kernels execute: level-0
    (bk, bm, bn) VMEM block, then the grid with the reduction minor-most
    (mirrors ``tune.lowering.schedule_to_string``)."""
    M, N, K = _gemm_dims(p)
    bm, bk, bn = tiles
    return BlockingString(
        [Loop(Dim.C, bk), Loop(Dim.X, bm), Loop(Dim.K, bn),
         Loop(Dim.C, K), Loop(Dim.K, N), Loop(Dim.X, M)], p)


@dataclasses.dataclass(frozen=True)
class FusedTraffic:
    """DRAM-byte breakdown of one fused schedule."""

    tiles: tuple[tuple[int, int, int], ...]
    per_stage_bytes: tuple[int, ...]        # nest traffic, fused epilogues
    epilogue_bytes: tuple[int, ...]         # streamed extras (fused)
    intermediate_bytes: tuple[int, ...]     # per fusion edge; 0 = resident
    intermediate_resident: tuple[bool, ...]
    unfused_total_bytes: int                # same tiles, nothing fused

    @property
    def total_bytes(self) -> int:
        return (sum(self.per_stage_bytes) + sum(self.epilogue_bytes)
                + sum(self.intermediate_bytes))

    @property
    def savings_bytes(self) -> int:
        return self.unfused_total_bytes - self.total_bytes

    @property
    def savings_frac(self) -> float:
        return self.savings_bytes / max(self.unfused_total_bytes, 1)


@dataclasses.dataclass(frozen=True)
class FusedProblem:
    """A chain of GEMM stages sharing intermediates along the row dim.

    Stage ``i``'s output tensor (M x N_i) is stage ``i+1``'s input
    tensor, so consecutive stages must agree: ``stages[i].K ==
    stages[i+1].C`` (the produced width is the consumed reduction) and
    all stages share M (``X``) and batch (``N``).
    """

    stages: tuple[Problem, ...]
    epilogues: tuple[Epilogue, ...]

    def __post_init__(self):
        if len(self.stages) < 2:
            raise ValueError("a FusedProblem needs at least two stages")
        if len(self.epilogues) != len(self.stages):
            raise ValueError("one Epilogue per stage")
        for i, p in enumerate(self.stages):
            if p.Y != 1 or p.Fw != 1 or p.Fh != 1:
                raise ValueError(
                    f"stage {i} is not a GEMM-family nest: {p}")
            if p.X != self.stages[0].X or p.N != self.stages[0].N:
                raise ValueError(
                    f"stage {i} does not share the fused row dim "
                    f"(M={p.X}, expected {self.stages[0].X})")
        for i in range(len(self.stages) - 1):
            if self.stages[i].K != self.stages[i + 1].C:
                raise ValueError(
                    f"stage {i} produces width {self.stages[i].K} but "
                    f"stage {i + 1} consumes {self.stages[i + 1].C}")

    # -- constructors ---------------------------------------------------------

    @classmethod
    def pair(cls, producer: Problem, consumer: Problem,
             producer_epilogue: Epilogue | None = None,
             consumer_epilogue: Epilogue | None = None) -> "FusedProblem":
        return cls((producer, consumer),
                   (producer_epilogue or Epilogue(),
                    consumer_epilogue or Epilogue()))

    @classmethod
    def mlp(cls, M: int, d_model: int, d_ff: int,
            bytes_per_elem: int = 2, swiglu: bool = False,
            weight_bytes: int | None = None) -> "FusedProblem":
        """The transformer MLP block: up-projection (+ activation, + the
        gating multiply for SwiGLU) feeding the down-projection (+ the
        residual add).  ``weight_bytes=1`` models the w8-quantized
        variant (the PR 4 lever composes with fusion)."""
        up = Problem.gemm(M=M, N_cols=d_ff, K_reduce=d_model,
                          bytes_per_elem=bytes_per_elem,
                          weight_bytes=weight_bytes)
        down = Problem.gemm(M=M, N_cols=d_model, K_reduce=d_ff,
                            bytes_per_elem=bytes_per_elem,
                            weight_bytes=weight_bytes)
        return cls((up, down),
                   (Epilogue(act="silu" if swiglu else "gelu",
                             extra_operands=1 if swiglu else 0),
                    Epilogue(extra_operands=1)))   # residual add

    # -- geometry -------------------------------------------------------------

    @property
    def M(self) -> int:
        return self.stages[0].X

    def intermediate_elems(self, i: int) -> int:
        """Elements of the tensor between stage ``i`` and ``i+1``."""
        return self.stages[i].output_elems

    def intermediate_bpe(self, i: int) -> int:
        return self.stages[i].output_bpe

    def intermediate_tile_bytes(self, i: int, bm: int) -> int:
        """Level-0 bytes of the fusion tile: ``bm`` rows of the full
        intermediate width (the consumer reduces over all of it)."""
        return bm * self.stages[i].K * self.stages[i].N * \
            self.intermediate_bpe(i)

    def _stage_tile_bytes(self, i: int,
                          tiles: tuple[int, int, int]) -> int:
        """Streamed + resident working set of stage ``i``'s kernel step
        (mirrors ``kernels.matmul_blocked.vmem_bytes_required`` without
        importing jax into core)."""
        p = self.stages[i]
        bm, bk, bn = tiles
        streamed = 2 * (bm * bk * p.input_bpe + bk * bn * p.weight_bpe)
        resident = bm * bn * (p.output_bpe + 4)       # out + fp32 acc
        return streamed + resident

    def validate_tiles(self, tiles: Sequence[tuple[int, int, int]]) -> None:
        if len(tiles) != len(self.stages):
            raise ValueError("one (bm, bk, bn) tile per stage")
        bm0 = tiles[0][0]
        for i, (t, p) in enumerate(zip(tiles, self.stages)):
            bm, bk, bn = t
            M, N, K = _gemm_dims(p)
            if bm != bm0:
                raise ValueError(
                    f"stage {i} bm={bm} != shared fusion tile {bm0}")
            if M % bm or K % bk or N % bn:
                raise ValueError(
                    f"stage {i} tiles {t} do not divide dims "
                    f"{(M, N, K)}")

    def intermediate_fits(self, i: int,
                          tiles: Sequence[tuple[int, int, int]],
                          budget: int) -> bool:
        """True iff the fusion tile between stages ``i``/``i+1`` stays
        level-0 resident next to both stages' working sets."""
        bm = tiles[i][0]
        need = (self.intermediate_tile_bytes(i, bm)
                + self._stage_tile_bytes(i, tiles[i])
                + self._stage_tile_bytes(i + 1, tiles[i + 1]))
        return need <= budget

    # -- traffic --------------------------------------------------------------

    def _stage_operand_bytes(self, i: int, tiles: tuple[int, int, int],
                             budget: int) -> dict[Operand, int]:
        """One stage's DRAM bytes split per operand.

        ``cache_accesses`` is linear in its operand weights (the
        placement walk itself is weight-independent), so scoring each
        operand alone is an exact decomposition of the stage total —
        which is what lets the fusion model zero the intermediate on
        both sides without re-deriving the miss-path rules."""
        p = self.stages[i]
        s = _gemm_string(p, tiles)
        levels = [MemLevel.sram("VMEM", budget), MemLevel.dram("HBM")]
        out: dict[Operand, int] = {}
        for op in Operand:
            w = {o: (operand_bytes(p, o) if o is op else 0)
                 for o in Operand}
            out[op] = cache_accesses(s, levels, operand_weights=w)["HBM"]
        return out

    def _stage_dram_bytes(self, i: int, tiles: tuple[int, int, int],
                          budget: int) -> int:
        return sum(self._stage_operand_bytes(i, tiles, budget).values())

    def _epilogue_bytes(self, i: int, fused: bool) -> int:
        """Epilogue DRAM bytes.  Standalone (unfused) it re-reads and
        re-writes the stage output around the pointwise op; fused it
        only streams its extra operands (they are consumed tile-by-tile
        inside the producer's epilogue)."""
        ep = self.epilogues[i]
        p = self.stages[i]
        out_bytes = p.output_elems * p.output_bpe
        extras = ep.extra_operands * out_bytes
        bias = p.K * p.output_bpe if ep.bias else 0
        if fused:
            return extras + bias
        if ep.is_trivial:
            return 0
        return 2 * out_bytes + extras + bias    # read + write round-trip

    def unfused_dram_bytes(self, tiles: Sequence[tuple[int, int, int]],
                           budget: int) -> int:
        """The pair (chain) run as separate ops at the SAME tiles: every
        stage round-trips its output, every epilogue is a standalone
        pointwise pass."""
        self.validate_tiles(tiles)
        total = 0
        for i in range(len(self.stages)):
            total += self._stage_dram_bytes(i, tiles[i], budget)
            total += self._epilogue_bytes(i, fused=False)
        return total

    def _variant(self, tiles: Sequence[tuple[int, int, int]], budget: int,
                 resident: tuple[bool, ...],
                 ) -> tuple[tuple[int, ...], tuple[int, ...]]:
        """(per-stage bytes, per-edge intermediate bytes) for one choice
        of which fusion edges keep their intermediate level-0 resident.

        Fusion-aware buffer sizing: a stage adjacent to a resident
        intermediate is placed under a budget reduced by the resident
        tile — the VMEM pressure that can evict the weight tile and
        make fusion *lose* (docs/fusion.md)."""
        n = len(self.stages)
        per_stage: list[int] = []
        edge_io: list[list[int]] = [[0, 0] for _ in range(n - 1)]
        for i in range(n):
            eff = budget
            if i > 0 and resident[i - 1]:
                eff -= self.intermediate_tile_bytes(i - 1, tiles[i][0])
            if i < n - 1 and resident[i]:
                eff -= self.intermediate_tile_bytes(i, tiles[i][0])
            ob = self._stage_operand_bytes(i, tiles[i], max(eff, 1))
            stage = ob[Operand.WEIGHT]
            if i == 0:
                stage += ob[Operand.INPUT]
            else:
                edge_io[i - 1][1] = ob[Operand.INPUT]
            if i == n - 1:
                stage += ob[Operand.OUTPUT]
            else:
                edge_io[i][0] = ob[Operand.OUTPUT]
            per_stage.append(stage)
        inter = tuple(0 if resident[i] else sum(edge_io[i])
                      for i in range(n - 1))
        return tuple(per_stage), inter

    def traffic(self, tiles: Sequence[tuple[int, int, int]],
                budget: int,
                always_resident: bool = False) -> FusedTraffic:
        """DRAM bytes of the fused schedule (and the same-tile unfused
        baseline).  Epilogues always fuse.  An intermediate *may* be
        eliminated when its fusion tile fits level 0
        (:meth:`intermediate_fits`); by default the model keeps it
        resident only when that actually lowers total traffic —
        spilling the tile is always available to a fused kernel, so
        predicted fused bytes never exceed the unfused chain's.
        ``always_resident=True`` forces every fitting edge resident
        (the budget squeeze then shows exactly when fusion loses)."""
        self.validate_tiles(tiles)
        n = len(self.stages)
        fits = [self.intermediate_fits(i, tiles, budget)
                for i in range(n - 1)]
        free_edges = [i for i, f in enumerate(fits) if f]
        best: tuple[int, tuple, tuple, tuple] | None = None
        masks = ([(1 << len(free_edges)) - 1] if always_resident
                 else range(1 << len(free_edges)))
        for mask in masks:
            resident = [False] * (n - 1)
            for b, e in enumerate(free_edges):
                resident[e] = bool(mask >> b & 1)
            per_stage, inter = self._variant(tiles, budget,
                                             tuple(resident))
            total = sum(per_stage) + sum(inter)
            if best is None or total < best[0]:
                best = (total, per_stage, inter, tuple(resident))
        _, per_stage, inter, resident = best
        epi = tuple(self._epilogue_bytes(i, fused=True) for i in range(n))
        return FusedTraffic(
            tiles=tuple(tuple(t) for t in tiles),
            per_stage_bytes=per_stage,
            epilogue_bytes=epi,
            intermediate_bytes=inter,
            intermediate_resident=resident,
            unfused_total_bytes=self.unfused_dram_bytes(tiles, budget))

    def fused_dram_bytes(self, tiles: Sequence[tuple[int, int, int]],
                         budget: int) -> int:
        return self.traffic(tiles, budget).total_bytes


# -- energy & multicore (fusion-aware weighting) ------------------------------


def fused_energy_pj(fp: FusedProblem,
                    tiles: Sequence[tuple[int, int, int]],
                    budget: int) -> float:
    """Memory energy of the fused chain on a VMEM+DRAM hierarchy: the
    per-stage fixed-hierarchy energy, with each eliminated
    intermediate's DRAM round-trip re-priced at the on-chip level's
    access energy (the accesses still happen — in VMEM).

    Which intermediates count as eliminated comes from
    :meth:`FusedProblem.traffic`'s residency choice — NOT from the raw
    fits test — so the energy and byte models can never disagree about
    whether a fusion edge was taken."""
    fp.validate_tiles(tiles)
    resident = fp.traffic(tiles, budget).intermediate_resident
    levels = [MemLevel.sram("VMEM", budget), MemLevel.dram("HBM")]
    total = 0.0
    for i, p in enumerate(fp.stages):
        total += energy_fixed(_gemm_string(p, tiles[i]), levels).mem_pj
    vmem_pj = access_energy_pj(budget)
    for i in range(len(fp.stages) - 1):
        if resident[i]:
            words = (fp.intermediate_elems(i) * fp.intermediate_bpe(i)
                     / 2.0)
            # write-up + read-down round trip moves from DRAM to VMEM
            total -= 2 * words * DRAM_PJ_PER_16B
            total += 2 * words * vmem_pj
    return total


def fused_multicore_dram_bytes(fp: FusedProblem,
                               tiles: Sequence[tuple[int, int, int]],
                               budget: int, scheme: str,
                               cores: int) -> int:
    """DRAM bytes of the fused chain across ``cores`` (paper §3.3).

    XY partitioning splits the shared row dim M: each core owns a
    disjoint row slab of every stage AND of the intermediate, so the
    per-core fusion works and the intermediate is eliminated exactly as
    on one core.  K partitioning scatters stage ``i``'s output channels
    across cores while stage ``i+1`` reduces over all of them — the
    intermediate must be exchanged (the paper's shuffle), so fusion
    eliminates nothing across that boundary.
    """
    if scheme not in ("K", "XY"):
        raise ValueError(f"scheme must be 'K' or 'XY', got {scheme!r}")
    fp.validate_tiles(tiles)
    if scheme == "XY":
        # per-core: same chain with M/cores rows; total = cores x per-core
        if fp.M % cores:
            raise ValueError(f"M={fp.M} not divisible by {cores} cores")
        sub = FusedProblem(
            tuple(dataclasses.replace(p, X=p.X // cores)
                  for p in fp.stages), fp.epilogues)
        sub_tiles = [(min(t[0], sub.M), t[1], t[2]) for t in tiles]
        if any(sub.M % t[0] for t in sub_tiles):
            bm = max(d for d in divisors(sub.M) if d <= tiles[0][0])
            sub_tiles = [(bm, t[1], t[2]) for t in tiles]
        return cores * sub.fused_dram_bytes(sub_tiles, budget)
    # K scheme: per-stage traffic parallelizes, but every fusion edge is
    # forced through memory (count the intermediate even when it "fits")
    total = 0
    for i in range(len(fp.stages)):
        total += fp._stage_dram_bytes(i, tiles[i], budget)
        total += fp._epilogue_bytes(i, fused=True)
    return total


# -- joint schedule search ----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FusionResult:
    """One ranked joint schedule from :func:`optimize_fused`."""

    traffic: FusedTraffic

    @property
    def tiles(self) -> tuple[tuple[int, int, int], ...]:
        return self.traffic.tiles

    @property
    def fused_bytes(self) -> int:
        return self.traffic.total_bytes

    @property
    def unfused_bytes(self) -> int:
        return self.traffic.unfused_total_bytes

    @property
    def savings_bytes(self) -> int:
        return self.traffic.savings_bytes

    @property
    def savings_frac(self) -> float:
        return self.traffic.savings_frac

    def summary(self) -> str:
        res = "".join("R" if r else "-"
                      for r in self.traffic.intermediate_resident)
        return (f"tiles={self.tiles} fused={self.fused_bytes:.3e}B "
                f"unfused={self.unfused_bytes:.3e}B "
                f"saves {100 * self.savings_frac:.1f}% [{res}]")


def _aligned_divs(n: int, align: int, cap: int = 16) -> list[int]:
    divs = [d for d in divisors(n) if d % align == 0 or d == n]
    if not divs:
        divs = [n]
    return divs[-cap:]


def optimize_fused(fp: FusedProblem, budget: int,
                   m_align: int = 8, n_align: int = 128,
                   top: int = 8) -> list[FusionResult]:
    """Search joint level-0 tiles for the fused chain.

    The shared fusion tile ``bm`` couples the stages; given ``bm`` (and
    the budget squeeze of any resident intermediate) the per-stage
    (bk, bn) choices decouple, so each stage greedily minimizes its own
    walk — the paper's coordinate-descent shape specialized to the
    fusion structure.  Results are ranked by fused DRAM bytes.
    """
    results: list[FusionResult] = []
    for bm in _aligned_divs(fp.M, m_align):
        tiles: list[tuple[int, int, int]] = []
        feasible = True
        for i, p in enumerate(fp.stages):
            M, N, K = _gemm_dims(p)
            # budget squeeze: assume the adjacent intermediates resident
            squeeze = 0
            if i > 0:
                squeeze += fp.intermediate_tile_bytes(i - 1, bm)
            if i < len(fp.stages) - 1:
                squeeze += fp.intermediate_tile_bytes(i, bm)
            eff = max(budget - squeeze, 1)
            best: tuple[int, tuple[int, int, int]] | None = None
            for bk in _aligned_divs(K, min(n_align, K)):
                for bn in _aligned_divs(N, min(n_align, N)):
                    t = (bm, bk, bn)
                    if fp._stage_tile_bytes(i, t) > max(eff, budget // 4):
                        continue
                    cost = fp._stage_dram_bytes(i, t, budget)
                    if best is None or cost < best[0]:
                        best = (cost, t)
            if best is None:
                feasible = False
                break
            tiles.append(best[1])
        if not feasible:
            continue
        results.append(FusionResult(fp.traffic(tiles, budget)))
    results.sort(key=lambda r: (r.fused_bytes, -r.tiles[0][0]))
    return results[:top]
