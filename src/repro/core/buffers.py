"""Buffer placement for a blocking string (paper §3.2, Table 2).

Walking the string inner -> outer, every loop that *reuses* one operand
forces a buffer for that operand sized to the footprint of everything below:

* a new ``K`` loop reuses the **input** block across kernels  -> ``IB``
* a new ``C`` loop reduces into the same **outputs**          -> ``OB``
* a new ``X``/``Y`` (or ``N``) loop reuses the **weights**    -> ``KB``
* a new ``Fw``/``Fh`` loop reuses both inputs and outputs     -> ``IB`` + ``OB``

Level-0 registers for all three operands always exist below the innermost
loop (the datapath reads operands from somewhere).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterable

from repro.core.loopnest import (BlockingString, Dim, Extents, Problem,
                                 INPUT_DIMS, OUTPUT_DIMS, WEIGHT_DIMS)


class Operand(enum.Enum):
    INPUT = "IB"
    WEIGHT = "KB"
    OUTPUT = "OB"

    def __repr__(self) -> str:
        return self.value


OPERAND_DIMS = {
    Operand.INPUT: INPUT_DIMS,
    Operand.WEIGHT: WEIGHT_DIMS,
    Operand.OUTPUT: OUTPUT_DIMS,
}


def operand_bytes(problem: Problem, op: "Operand") -> int:
    """Element width of one operand — the single mixed-precision lookup
    shared by buffer sizing (here) and traffic/energy weighting
    (``core.hierarchy`` / ``core.access``)."""
    if op is Operand.INPUT:
        return problem.input_bpe
    if op is Operand.WEIGHT:
        return problem.weight_bpe
    return problem.output_bpe

# Which loop dimensions trigger a buffer for which operand when added above.
REUSE_RULES: dict[Dim, tuple[Operand, ...]] = {
    Dim.K: (Operand.INPUT,),
    Dim.C: (Operand.OUTPUT,),
    Dim.X: (Operand.WEIGHT,),
    Dim.Y: (Operand.WEIGHT,),
    Dim.N: (Operand.WEIGHT,),
    Dim.FW: (Operand.INPUT, Operand.OUTPUT),
    Dim.FH: (Operand.INPUT, Operand.OUTPUT),
}


@dataclasses.dataclass(frozen=True)
class Buffer:
    """One buffer in the hierarchy implied by a blocking string.

    ``pos`` is the string position the buffer sits *below* (the loop at
    ``pos`` is the one whose reuse this buffer captures).  ``pos == -1``
    denotes the level-0 register operand latches below everything.
    """

    operand: Operand
    pos: int
    size_elems: int
    extents: Extents  # extents covered below ``pos`` (the block it holds)

    def size_bytes(self, problem: Problem) -> int:
        return self.size_elems * operand_bytes(problem, self.operand)

    @property
    def name(self) -> str:
        return f"{self.operand.value}@{self.pos}"

    def __repr__(self) -> str:
        return f"{self.name}[{self.size_elems}]"


def _footprint(op: Operand, e: Extents, problem: Problem) -> int:
    if op is Operand.INPUT:
        return e.input_footprint(problem.stride)
    if op is Operand.WEIGHT:
        return e.weight_footprint()
    return e.output_footprint()


def place_buffers(s: BlockingString) -> list[Buffer]:
    """Paper §3.2 placement: returns buffers sorted inner -> outer.

    A buffer is only materialized when the loop actually provides reuse
    (trip count > 1) and when the buffer would be larger than what already
    exists for that operand below (placing an identical copy is pointless).
    """
    problem = s.problem
    bufs: list[Buffer] = []
    # level-0 operand registers (one element each, conceptually the datapath
    # latches); they anchor the access-count recursion.
    e0 = Extents()
    for op in Operand:
        bufs.append(Buffer(op, -1, 1, e0))
    largest: dict[Operand, int] = {op: 1 for op in Operand}

    for pos, lp in enumerate(s.loops):
        if s.iterations(pos) <= 1:
            continue  # degenerate loop: no reuse, no buffer
        below = s.extents_below(pos)
        for op in REUSE_RULES[lp.dim]:
            size = _footprint(op, below, problem)
            if size > largest[op]:
                bufs.append(Buffer(op, pos, size, below))
                largest[op] = size
    return bufs


def buffers_by_operand(bufs: Iterable[Buffer]) -> dict[Operand, list[Buffer]]:
    out: dict[Operand, list[Buffer]] = {op: [] for op in Operand}
    for b in bufs:
        out[b.operand].append(b)
    for op in out:
        out[op].sort(key=lambda b: b.pos)
    return out


def table2_refetch_rate(s: BlockingString, pos: int,
                        op: Operand) -> float:
    """Paper Table 2 refetch rates, for cross-checking the access model.

    Only defined for the (new-loop, buffer) pairs the table lists.
    """
    lp = s.loops[pos]
    below = s.extents_below(pos)
    p = s.problem
    if lp.dim is Dim.K and op is Operand.INPUT:
        ix = (below.X - 1) * p.stride + below.Fw
        iy = (below.Y - 1) * p.stride + below.Fh
        return (lp.extent * iy * ix) / (below.K * below.Y * below.X)
    if lp.dim is Dim.C and op is Operand.OUTPUT:
        return 2.0 * lp.extent / below.C
    if lp.dim in (Dim.X, Dim.Y, Dim.N) and op is Operand.WEIGHT:
        return lp.extent / below.get(lp.dim)
    raise ValueError(f"Table 2 has no entry for loop {lp} / {op}")
