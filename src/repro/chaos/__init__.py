"""Chaos/fault-injection harness for the paged serving stack.

    # CI fast lane: seeded engine schedule + a handful of sim schedules
    PYTHONPATH=src python -m repro.chaos --smoke

    # the acceptance bar: 200 randomized fault schedules
    PYTHONPATH=src python -m repro.chaos --schedules 200

Injectors (:mod:`repro.chaos.inject`) sit at seams the production code
already has — the page allocator, the step planner, the schedule cache,
the engine's NaN guard — and the runner (:mod:`repro.chaos.runner`)
drives randomized fault schedules while asserting the serving
invariants: zero page leaks, refcount = owners + tree refs, every
request terminal, survivors byte-exact.  See docs/robustness.md.
"""

from repro.chaos.inject import (CorruptScheduleCache, FlakyAllocator,
                                PlanChaos)
from repro.chaos.runner import engine_smoke, run_schedule, run_schedules

__all__ = [
    "CorruptScheduleCache",
    "FlakyAllocator",
    "PlanChaos",
    "engine_smoke",
    "run_schedule",
    "run_schedules",
]
