"""Randomized chaos runner: real scheduler + prefix tree under fault
schedules, with every invariant from the serving test suites asserted
at every step (docs/robustness.md).

Two layers, same philosophy as ``tests/test_serve_invariants.py``:

* :func:`run_schedule` drives the *production*
  :class:`~repro.serve.scheduler.Scheduler` +
  :class:`~repro.serve.kv_cache.PrefixCache` over a
  :class:`~repro.chaos.inject.FlakyAllocator` and
  :class:`~repro.chaos.inject.PlanChaos`, with random cancellations,
  TTLs and preemptions layered on.  Tokens come from a deterministic
  per-request oracle, so the fault-free run never has to execute: a
  survivor is byte-exact iff its output equals the oracle stream —
  which it only can be if the preempt/restore bookkeeping (prompt
  extension, ``prior_tokens`` accumulation, replay resume point) is
  exact.  Each fault *storm* eventually passes (injectors disabled,
  hostage pages released), after which the drain must terminate — the
  aging-liveness guarantee under transient faults.
* :func:`engine_smoke` runs the real :class:`~repro.serve.engine.
  PagedEngine` on a reduced model with NaN poisoning, preemption,
  cancellation and TTL expiry in one schedule, differential against a
  fault-free run — the byte-exactness bar with actual device tokens.

Invariants asserted (the PR 6/7 contracts, under faults):

* **no page leak** — ``in_use`` equals exactly the pages held by
  running requests, the prefix tree, and hostages, every step;
* **refcount accounting** — every page's refcount equals its running
  owners plus its tree reference (plus one if held hostage);
* **terminal status** — every submitted request ends in exactly one
  :class:`~repro.serve.lifecycle.RequestStatus`;
* **byte-exactness** — OK / PREEMPTED_RETRIED outputs equal the
  fault-free stream; TRUNCATED / DEADLINE_EXCEEDED / FAILED outputs
  are byte-exact *prefixes* of it;
* **liveness** — once the storm passes, the system drains in bounded
  steps.
"""

from __future__ import annotations

import numpy as np

from repro.chaos.inject import FlakyAllocator, PlanChaos
from repro.serve import kv_cache as KV
from repro.serve.lifecycle import EXACT_STATUSES, RequestStatus
from repro.serve.scheduler import Request, Scheduler


def oracle(rid: int, start: int, stop: int) -> np.ndarray:
    """Deterministic emitted-token stream for request ``rid``; the
    fault-free run by construction (greedy decode of a fixed model is a
    pure function of the prompt, which the rid stands in for)."""
    j = np.arange(start, stop, dtype=np.int64)
    return ((rid * 1009 + j * 31 + 7) % 97).astype(np.int32)


class ChaosSim:
    """One fault schedule over the production scheduler/tree/allocator.

    Mirrors the engine's step loop — expire sweep, admission, plan
    validation (dedupe + skip dead slots), advance, terminal sweep —
    with the model replaced by :func:`oracle` and faults injected
    between phases.  ``stats`` accumulates what was injected so the CLI
    can prove the schedule was not vacuously clean.
    """

    def __init__(self, rng, max_batch=3, page_size=4, n_pages=16,
                 max_seq=24, decode_chunk=2, prefill_chunk=4,
                 age_limit=4, max_retries=None, use_tree=True,
                 dup_rate=0.2, drop_rate=0.2, lie_rate=0.15):
        self.rng = rng
        self.alloc = FlakyAllocator(n_pages, rng, lie_rate=lie_rate)
        self.tree = KV.PrefixCache(self.alloc, page_size) if use_tree \
            else None
        self.sched = Scheduler(max_batch, page_size, self.alloc, max_seq,
                               age_limit=age_limit, prefix_cache=self.tree,
                               max_retries=max_retries)
        self.plan_chaos = PlanChaos(self.sched, rng, dup_rate=dup_rate,
                                    drop_rate=drop_rate)
        self.decode_chunk = decode_chunk
        self.prefill_chunk = prefill_chunk
        self.steps = 0
        self.prompts: dict[int, np.ndarray] = {}      # rid -> original
        self.budgets: dict[int, int] = {}             # rid -> orig_max_new
        self.terminal: dict[int, Request] = {}        # rid -> final req
        self.stats = {"preempts": 0, "cancels": 0, "ttl": 0,
                      "hostage_rounds": 0, "lies": 0, "dups": 0,
                      "drops": 0, "rollbacks": 0, "rejected": 0}

    # -- workload -------------------------------------------------------------

    def submit_random(self, rid: int, pool) -> None:
        """Prompt drawn from a template pool (so the tree really
        shares), with a random tail; sometimes a TTL, sometimes a
        priority — preemption needs both classes present."""
        rng = self.rng
        pre = pool[int(rng.integers(len(pool)))]
        tail = rng.integers(100, 197,
                            (int(rng.integers(0, self.sched.page_size)),))
        prompt = np.concatenate([pre, tail.astype(np.int32)])
        max_seq = self.sched.max_seq
        if len(prompt) >= max_seq:
            prompt = prompt[:max_seq - 1]
        n = int(rng.integers(1, max_seq - len(prompt) + 1))
        req = Request(rid, prompt, n,
                      priority=int(rng.integers(0, 2)))
        if rng.random() < 0.15:
            req.expire_step = self.steps + int(rng.integers(1, 40))
            self.stats["ttl"] += 1
        self.prompts[rid] = prompt
        self.budgets[rid] = n
        self.sched.submit(req)

    # -- engine-mirror helpers ------------------------------------------------

    def _prior_len(self, req: Request) -> int:
        return 0 if req.prior_tokens is None else len(req.prior_tokens)

    def _finish(self, req: Request) -> None:
        if req.failed:
            req.status = RequestStatus.FAILED
        elif req.done:
            req.status = (RequestStatus.PREEMPTED_RETRIED
                          if req.preempt_count else RequestStatus.OK)
        elif req.cancelled:
            req.status = RequestStatus.TRUNCATED
        else:
            req.status = RequestStatus.DEADLINE_EXCEEDED
        tail = oracle(req.rid, self._prior_len(req),
                      self._prior_len(req) + req.generated)
        req.output = tail if req.prior_tokens is None \
            else np.concatenate([req.prior_tokens, tail])
        assert req.rid not in self.terminal, \
            f"rid {req.rid} reached two terminal states"
        self.terminal[req.rid] = req

    def _inject(self) -> None:
        """One round of fault decisions (the storm)."""
        rng = self.rng
        if rng.random() < 0.1:
            self.alloc.take_hostages(int(rng.integers(1, 4)))
            self.stats["hostage_rounds"] += 1
        if self.alloc.hostages and rng.random() < 0.3:
            self.alloc.release_hostages()
        if rng.random() < 0.08:
            live = [r.rid for r in self.sched.waiting] + \
                   [r.rid for r in self.sched.running.values()]
            if live:
                self.sched.cancel(int(rng.choice(live)))
                self.stats["cancels"] += 1
        if rng.random() < 0.15 and self.sched.running:
            cands = [(s, r) for s, r in self.sched.running.items()
                     if r.max_new_tokens - r.generated > 0]
            if cands:
                slot, victim = cands[int(rng.integers(len(cands)))]
                emitted = oracle(victim.rid, self._prior_len(victim),
                                 self._prior_len(victim) + victim.generated)
                new = self.sched.preempt(slot, emitted)
                # restore identity: the replacement's prompt is the
                # original prompt plus everything emitted so far
                orig = self.prompts[new.rid]
                assert np.array_equal(new.prompt[:len(orig)], orig)
                assert np.array_equal(
                    new.prompt[len(orig):],
                    oracle(new.rid, 0, len(new.prior_tokens)))
                self.stats["preempts"] += 1

    def step(self, storm: bool = True) -> None:
        self.steps += 1
        for req in self.sched.expire(0, self.steps):
            self._finish(req)
        if storm:
            self._inject()
        for req in self.sched.admit():
            assert req.slot >= 0
            assert len(req.pages) == self.sched.pages_needed(req)
        for req in self.sched.take_rejected():
            self.stats["rejected"] += 1
            self._finish(req)
        planner = self.plan_chaos if storm else self.sched
        plan = planner.plan_step(self.decode_chunk, self.prefill_chunk)
        # the engine's plan validation: dedupe, skip dead/finished slots
        seen: set[int] = set()
        for s in plan.decode_slots:
            r = self.sched.running.get(s)
            if r is None or s in seen or not r.decode_ready \
                    or r.cancelled or r.expired(0, self.steps):
                continue
            seen.add(s)
            r.generated += min(self.decode_chunk,
                               r.max_new_tokens - r.generated)
        seen.clear()
        for s in plan.prefill_slots:
            r = self.sched.running.get(s)
            if r is None or r.prefill_done or r.cancelled \
                    or r.expired(0, self.steps):
                continue
            r.prefilled += min(self.prefill_chunk,
                               r.prompt_len - r.prefilled)
            if r.prefill_done:
                if r.generated == 0:
                    r.generated = 1
                self.sched.register_prefix(r)
        for s in [s for s, r in self.sched.running.items()
                  if r.done or r.cancelled or r.failed
                  or r.expired(0, self.steps)]:
            self._finish(self.sched.evict(s))
        self.check_pages()

    # -- invariants -----------------------------------------------------------

    def check_pages(self) -> None:
        from collections import Counter
        owners = Counter(pg for r in self.sched.running.values()
                         for pg in r.pages)
        hostages = Counter(self.alloc.hostages)
        tree_pages = self.tree.pages() if self.tree is not None else set()
        assert KV.SCRATCH_PAGE not in owners, "scratch page owned"
        assert KV.SCRATCH_PAGE not in tree_pages, "scratch page cached"
        for page in set(owners) | tree_pages | set(hostages):
            assert self.alloc.refcount(page) == \
                owners[page] + hostages[page] + (page in tree_pages), (
                    f"page {page}: refcount {self.alloc.refcount(page)} "
                    f"!= {owners[page]} owners + {hostages[page]} "
                    f"hostages + {int(page in tree_pages)} tree refs")
        held = set(owners) | tree_pages | set(hostages)
        assert self.alloc.in_use() == len(held), "page leak"
        assert len(self.sched.running) <= self.sched.max_batch

    def finalize(self) -> None:
        """End-of-schedule assertions: terminal coverage, byte-exact
        survivors, prefix-exact casualties, zero leaked pages."""
        missing = set(self.prompts) - set(self.terminal)
        assert not missing, f"rids never reached a terminal state: {missing}"
        for rid, req in self.terminal.items():
            full = oracle(rid, 0, self.budgets[rid])
            if req.status in EXACT_STATUSES:
                assert len(req.output) == self.budgets[rid], \
                    f"rid {rid}: short output with status {req.status}"
                assert np.array_equal(req.output, full), \
                    f"rid {rid}: survivor tokens diverged"
            else:
                assert np.array_equal(req.output,
                                      full[:len(req.output)]), \
                    f"rid {rid}: casualty tokens not a prefix"
        tree_pages = len(self.tree) if self.tree is not None else 0
        assert self.alloc.in_use() == tree_pages, "leak at drain"
        if self.tree is not None and len(self.tree):
            self.tree.evict(len(self.tree))
            assert len(self.tree) == 0
        assert self.alloc.available() == self.alloc.capacity, \
            "leak after tree drop"
        self.stats["lies"] = self.alloc.lies
        self.stats["dups"] = self.plan_chaos.dups
        self.stats["drops"] = self.plan_chaos.drops


def run_schedule(seed: int) -> dict:
    """One complete randomized fault schedule; returns its stats."""
    rng = np.random.default_rng(seed)
    page_size = int(rng.choice([2, 4]))
    sim = ChaosSim(
        rng,
        max_batch=int(rng.integers(1, 4)),
        page_size=page_size,
        # capacity must cover one max_seq request (8 pages + scratch)
        n_pages=int(rng.integers(9, 20)),
        max_seq=page_size * 8,
        decode_chunk=int(rng.integers(1, 4)),
        prefill_chunk=page_size,
        age_limit=int(rng.integers(2, 6)),
        max_retries=int(rng.integers(6, 12)) if rng.random() < 0.3
        else None,
        use_tree=bool(rng.random() < 0.8),
    )
    pool = [rng.integers(0, 97, (page_size * int(k),)).astype(np.int32)
            for k in (1, 2, 3)]
    n_requests = int(rng.integers(6, 20))
    for rid in range(n_requests):
        sim.submit_random(rid, pool)
        if rng.random() < 0.7:
            sim.step(storm=True)
    # the storm keeps raging a while with everything queued...
    for _ in range(int(rng.integers(0, 10))):
        if not sim.sched.has_work:
            break
        sim.step(storm=True)
    # ...then passes: injectors off, hostages home, drain must end
    sim.alloc.lie_rate = 0.0
    sim.alloc.release_hostages()
    budget = 80 * max(n_requests, 1)
    while sim.sched.has_work:
        sim.step(storm=False)
        budget -= 1
        assert budget > 0, (
            f"no drain after the storm passed: "
            f"waiting={[r.rid for r in sim.sched.waiting]} "
            f"running={sorted(sim.sched.running)}")
    sim.finalize()
    sim.stats["rollbacks"] = \
        sim.sched._m_rollbacks.value
    return sim.stats


def run_schedules(n: int, seed: int = 0) -> dict:
    """Run ``n`` independent schedules; returns aggregate stats."""
    total: dict[str, int] = {}
    for i in range(n):
        for k, v in run_schedule(seed + i).items():
            total[k] = total.get(k, 0) + v
    total["schedules"] = n
    return total


def engine_smoke(seed: int = 0, arch: str = "granite-3-8b") -> dict:
    """Real-engine chaos schedule: NaN poisoning, preemption,
    cancellation and TTL expiry in one run, differential against the
    fault-free engine.  Heavy imports stay local so ``repro.chaos``
    stays importable without a device."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models import transformer as T
    from repro.serve.engine import PagedEngine, PagedServeConfig

    cfg = dataclasses.replace(get_reduced(arch), dtype=jnp.float32)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
               for n in (11, 17, 9, 13)]

    def mk(**kw):
        return PagedEngine(cfg, params, PagedServeConfig(
            max_seq=64, max_batch=2, page_size=8, decode_chunk=4, **kw))

    ref = mk().generate(prompts, 8)
    eng = mk(prefix_cache=True, nan_guard=True, preempt=True)
    rids = [eng.submit(p, 8) for p in prompts[:3]]
    rid_ttl = eng.submit(prompts[3], 8, ttl_steps=2)
    done: dict[int, object] = {}
    steps, poisoned, preempted = 0, False, False
    while eng.has_work:
        steps += 1
        for r in eng.step():
            done[r.rid] = r
        running = list(eng.scheduler.running.values())
        if not poisoned and any(r.rid == rids[0] and r.decode_ready
                                for r in running):
            eng.inject_logit_fault(rids[0])
            poisoned = True
        if not preempted and steps >= 2:
            cands = [r for r in running if r.rid != rids[0]
                     and r.max_new_tokens - r.generated > 0]
            if cands:
                assert eng.preempt(max(cands, key=lambda r: r.rid).rid)
                preempted = True
        assert steps < 200, "engine chaos schedule failed to drain"
    assert poisoned and preempted, "schedule missed a fault arm"
    statuses = {}
    for i, rid in enumerate(rids + [rid_ttl]):
        req = done[rid]
        assert req.status is not None, f"rid {rid} not terminal"
        statuses[rid] = req.status
        if req.status in EXACT_STATUSES:
            assert np.array_equal(req.output, ref[i]), \
                f"rid {rid}: survivor tokens diverged"
        else:
            assert np.array_equal(req.output, ref[i][:len(req.output)]), \
                f"rid {rid}: casualty tokens not a prefix"
    assert statuses[rids[0]] is RequestStatus.FAILED
    assert any(s is RequestStatus.PREEMPTED_RETRIED
               for s in statuses.values())
    assert eng.scheduler.allocator.in_use() == len(eng.prefix_cache), \
        "pages leaked past the prefix tree"
    eng.shutdown()
    assert eng.scheduler.allocator.in_use() == 0, "leak after shutdown"
    return {"steps": steps,
            "statuses": {r: s.value for r, s in statuses.items()},
            "nan_trips":
                eng.obs.registry.counter("lifecycle.nan_guard_trips").value}
