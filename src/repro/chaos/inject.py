"""Fault injectors for the serving stack (docs/robustness.md).

Each injector lives at a seam the real system already has, so chaos
runs exercise the *production* failure paths rather than test doubles:

* :class:`FlakyAllocator` — a :class:`~repro.serve.kv_cache.PageAllocator`
  whose ``alloc`` may renege even though ``available()`` said yes (the
  disagreement :meth:`Scheduler._admit_one` must roll back from without
  leaking), and which can take pages *hostage* (a co-tenant grabbing
  HBM) to force genuine exhaustion, retries and preemption.
* :class:`PlanChaos` — wraps ``Scheduler.plan_step`` and duplicates or
  drops plan entries; the engine's plan validation must make duplicate
  entries idempotent and dropped entries merely late, never wrong.
* :class:`CorruptScheduleCache` — a schedule cache whose hits are
  deliberately pessimal tiles (moved here from ``repro.profile``, which
  re-exports it): still runnable, but strictly worse, exercising the
  profiler's model-fidelity gate.

NaN/Inf logit poisoning needs device cooperation and therefore lives on
the engine itself (``PagedEngine.inject_logit_fault``, guarded by
``nan_guard=True``); the chaos runner drives it from there.
"""

from __future__ import annotations

import dataclasses

from repro.serve.kv_cache import PageAllocator
from repro.serve.scheduler import StepPlan


class FlakyAllocator(PageAllocator):
    """Page allocator with injectable allocation failures.

    Two fault modes, composable:

    * **lie** — with probability ``lie_rate`` an ``alloc()`` raises
      ``MemoryError`` even though the free list is not empty.  The
      scheduler probes ``available()`` before attaching references, so
      a lie lands mid-admission and must trigger the rollback path
      (``sched.admit_rollbacks``) with zero leaked pages and the
      request still queued.
    * **hostages** — :meth:`take_hostages` really allocates pages and
      parks them (an external tenant squeezing the pool); the runner
      releases them later.  Hostage pages are owned by the injector, so
      invariant checks must count ``len(self.hostages)`` among the
      legitimate holders.

    ``fail_next`` forces the next ``n`` allocs to fail regardless of
    ``lie_rate`` — deterministic single-shot faults for unit tests.
    """

    def __init__(self, n_pages: int, rng=None, lie_rate: float = 0.0,
                 metrics=None):
        super().__init__(n_pages, metrics=metrics)
        self.rng = rng
        self.lie_rate = lie_rate
        self.fail_next = 0
        self.lies = 0
        self.hostages: list[int] = []

    def alloc(self) -> int:
        if self.fail_next > 0:
            self.fail_next -= 1
            self.lies += 1
            raise MemoryError("page pool exhausted (injected)")
        if self.lie_rate and self.rng is not None \
                and self.rng.random() < self.lie_rate:
            self.lies += 1
            raise MemoryError("page pool exhausted (injected)")
        return super().alloc()

    def take_hostages(self, n: int) -> int:
        """Genuinely allocate up to ``n`` pages and hold them; returns
        how many were taken (the pool may run dry first)."""
        took = 0
        for _ in range(n):
            try:
                self.hostages.append(PageAllocator.alloc(self))
            except MemoryError:
                break
            took += 1
        return took

    def release_hostages(self) -> int:
        """Free every hostage page; returns how many were released."""
        n = len(self.hostages)
        self.free_many(self.hostages)
        self.hostages = []
        return n


class PlanChaos:
    """Duplicate/drop corruption at the ``plan_step`` seam.

    The engine treats a :class:`~repro.serve.scheduler.StepPlan` as a
    *suggestion* it validates — a duplicated decode slot must not
    double-advance a request, and a dropped slot only delays it (decode
    priority re-lists it next step).  This wrapper makes both happen on
    purpose; install it in place of the scheduler for planning only::

        chaos = PlanChaos(scheduler, rng, dup_rate=.2, drop_rate=.2)
        plan = chaos.plan_step(decode_chunk, prefill_chunk)
    """

    def __init__(self, sched, rng, dup_rate: float = 0.0,
                 drop_rate: float = 0.0):
        self.sched = sched
        self.rng = rng
        self.dup_rate = dup_rate
        self.drop_rate = drop_rate
        self.dups = 0
        self.drops = 0

    def _mangle(self, slots: list[int]) -> list[int]:
        out: list[int] = []
        for s in slots:
            if self.drop_rate and self.rng.random() < self.drop_rate:
                self.drops += 1
                continue
            out.append(s)
            if self.dup_rate and self.rng.random() < self.dup_rate:
                self.dups += 1
                out.append(s)
        return out

    def plan_step(self, decode_chunk: int, prefill_chunk: int) -> StepPlan:
        plan = self.sched.plan_step(decode_chunk, prefill_chunk)
        return StepPlan(self._mangle(plan.decode_slots),
                        self._mangle(plan.prefill_slots))


class CorruptScheduleCache:
    """A schedule cache whose hits are deliberately pessimal.

    For ops matching ``match`` it returns the analytic winner with every
    halvable tile halved — still dividing, still runnable, but moving
    strictly more HBM bytes (smaller blocks mean more refetch under the
    grid's DMA elision).  Installed via ``tune.set_default_cache`` by
    ``repro.profile --corrupt`` to exercise the profiler's fidelity
    gate end to end.
    """

    def __init__(self, match: str):
        self.match = match

    def lookup(self, spec):
        from repro import tune
        if self.match not in spec.op:
            return None
        top = tune.candidates(spec)[0]
        tiles = tuple(t // 2 if t % 2 == 0 and t > 8 else t
                      for t in top.tiles)
        if tiles == tuple(top.tiles) or not tune.divides(spec, tiles):
            return None
        return dataclasses.replace(top, tiles=tiles, source="cache")

    def store(self, schedule):
        pass
