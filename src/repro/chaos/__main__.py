"""Chaos harness CLI (docs/robustness.md).

    # fast lane: one seeded real-engine schedule + 8 sim schedules
    PYTHONPATH=src python -m repro.chaos --smoke

    # acceptance bar: 200 randomized scheduler-level fault schedules
    PYTHONPATH=src python -m repro.chaos --schedules 200

Every schedule asserts the serving invariants in-line (an assertion
failure is the report); the CLI's own output just proves the schedules
were not vacuously clean — how many faults of each kind were injected.
"""

from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="serving chaos/fault-injection harness")
    ap.add_argument("--smoke", action="store_true",
                    help="seeded engine schedule + 8 sim schedules "
                         "(the CI fast-lane entry)")
    ap.add_argument("--schedules", type=int, default=None, metavar="N",
                    help="run N randomized scheduler-level fault "
                         "schedules (acceptance bar: 200)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if not args.smoke and args.schedules is None:
        args.schedules = 200

    from repro.chaos.runner import engine_smoke, run_schedules

    if args.smoke:
        res = engine_smoke(seed=args.seed)
        print(f"engine smoke: drained in {res['steps']} steps, "
              f"nan_guard trips={res['nan_trips']}, zero leaked pages")
        for rid, status in sorted(res["statuses"].items()):
            print(f"  rid {rid}: {status}")
        stats = run_schedules(8, seed=args.seed)
    else:
        stats = run_schedules(args.schedules, seed=args.seed)

    n = stats.pop("schedules")
    print(f"{n} randomized fault schedules passed "
          f"(zero page leaks, all requests terminal, "
          f"survivors byte-exact):")
    for k in sorted(stats):
        print(f"  {k:>14}: {stats[k]}")
    print("CHAOS PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
