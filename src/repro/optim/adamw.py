"""AdamW with decoupled weight decay, global-norm clipping and a
warmup+cosine schedule — pure-pytree implementation (no optax dependency).

Optimizer state is sharded like the parameters (the ``spec`` trees reuse
the parameter PartitionSpecs), so ZeRO-style sharding falls out of the
mesh: with parameters sharded over ``model``, the first/second moments are
too.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(c: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(c.warmup_steps, 1)
    t = (step - c.warmup_steps) / jnp.maximum(
        c.total_steps - c.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = c.min_lr_frac + (1 - c.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(math.pi * t))
    return c.lr * jnp.where(step < c.warmup_steps, warm, cos)


def init_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_specs(param_specs: Any) -> dict:
    from jax.sharding import PartitionSpec as P
    return {"mu": param_specs, "nu": param_specs, "step": P()}


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(c: AdamWConfig, params: Any, grads: Any,
                  state: dict) -> tuple[Any, dict, dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / (gnorm + 1e-9))
    lr = schedule(c, step)
    b1c = 1 - c.b1 ** step.astype(jnp.float32)
    b2c = 1 - c.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = c.b1 * mu + (1 - c.b1) * g
        nu = c.b2 * nu + (1 - c.b2) * g * g
        muh = mu / b1c
        nuh = nu / b2c
        delta = muh / (jnp.sqrt(nuh) + c.eps) + \
            c.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    new = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = treedef.unflatten([x[0] for x in new])
    new_state = {"mu": treedef.unflatten([x[1] for x in new]),
                 "nu": treedef.unflatten([x[2] for x in new]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
