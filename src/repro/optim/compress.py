"""int8 gradient compression with error feedback (DESIGN.md §5).

At multi-pod scale the inter-pod gradient all-reduce is the dominant
collective; quantizing gradients to int8 (per-tensor scale) cuts that
traffic 4x (bf16->int8 x2, plus the error-feedback residual lets the
optimizer tolerate the quantization).  The compressed representative is
applied *around* the pod-axis reduction: compress -> psum -> decompress.
Off-mesh this is a pure (de)quantization round-trip, used by tests.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compress(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any, residual: Any | None = None
                  ) -> tuple[Any, Any]:
    """Quantize a gradient pytree with error feedback.

    Returns (quantized_grads_as_f32, new_residual).  The caller reduces the
    quantized values; the residual (quantization error) is added to the
    NEXT step's gradients so no signal is permanently lost.
    """
    if residual is None:
        residual = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, r):
        total = g.astype(jnp.float32) + r
        q, scale = compress(total)
        deq = decompress(q, scale)
        return deq, total - deq

    pairs = jax.tree.map(one, grads, residual)
    deq = jax.tree.map(lambda p: p[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda p: p[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_res
