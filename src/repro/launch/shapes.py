"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs(cfg, shape, mesh)`` returns (args_shapes, args_shardings,
step_kind) for the function the dry-run lowers:

* train_*    -> train_step(params, opt_state, batch)
* prefill_*  -> prefill(params, tokens [, modality extras])
* decode_* / long_* -> decode_step(params, token, cache, pos)

Spec translation: model code writes PartitionSpecs with the canonical axis
names ("data", "model"); here they are rewritten per-mesh — "data" becomes
("pod", "data") on the multi-pod mesh, or None when the dimension cannot
be sharded (e.g. batch=1 long-context decode).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, Shape
from repro.launch.mesh import batch_divisor, data_axes
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import adamw


from repro.models.sharding import translate_spec, translate_tree


def axis_mapping(cfg: ModelConfig, shape: Shape, mesh,
                 parallelism: str = "tp_fsdp") -> dict[str, Any]:
    """How canonical axes map onto this mesh for this cell.

    ``tp_fsdp`` (default): "model" -> TP axis, "data" -> batch+FSDP.
    ``fsdp``: no tensor parallelism — the model axis is folded into data
    (pure ZeRO-3).  For dense models at large token batches this converts
    the per-layer activation all-reduces (O(tokens x d_model)) into weight
    all-gathers (O(params)), which is far less collective traffic when
    tokens/device x d >> params/device — the §Perf optimization for
    train_4k dense cells.
    """
    if parallelism == "fsdp":
        axes = tuple(mesh.axis_names)  # every axis carries batch + FSDP
        if shape.global_batch % mesh.size == 0:
            return {"model": None, "data": axes}
        return {"model": None, "data": ("data",)
                if shape.global_batch % mesh.shape.get("data", 1) == 0
                else None}
    mapping: dict[str, Any] = {"model": "model"}
    if shape.global_batch % batch_divisor(mesh) == 0:
        mapping["data"] = data_axes(mesh)
    elif shape.global_batch % mesh.shape.get("data", 1) == 0:
        mapping["data"] = ("data",)
    else:
        mapping["data"] = None  # batch too small to shard (long_500k b=1)
    return mapping


def shardings_of(tree_specs: Any, mesh, mapping: dict) -> Any:
    translated = translate_tree(tree_specs, mapping)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), translated,
        is_leaf=lambda x: isinstance(x, P))


def batch_shapes(cfg: ModelConfig, shape: Shape) -> tuple[dict, dict]:
    """(ShapeDtypeStructs, PartitionSpecs) for a training/prefill batch."""
    b, s = shape.global_batch, shape.seq_len
    shapes = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    specs = {"tokens": P("data", None), "labels": P("data", None)}
    if cfg.is_encdec:
        shapes["enc_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
        specs["enc_embeds"] = P("data", None, None)
    if cfg.prefix_tokens:
        shapes["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.prefix_tokens, cfg.d_model), cfg.dtype)
        specs["prefix_embeds"] = P("data", None, None)
    return shapes, specs


@dataclasses.dataclass
class Lowerable:
    """Everything needed to ``jax.jit(...).lower(...)`` one cell."""
    fn: Any
    args_shapes: tuple
    in_shardings: tuple
    out_shardings: Any
    kind: str


def input_specs(cfg: ModelConfig, shape_name: str, mesh,
                model_ax: int | None = None,
                parallelism: str = "tp_fsdp") -> Lowerable:
    shape = SHAPES[shape_name]
    if parallelism == "fsdp":
        model_ax = 1  # no TP: build specs with the model axis collapsed
    else:
        model_ax = model_ax or mesh.shape.get("model", 1)
    mapping = axis_mapping(cfg, shape, mesh, parallelism)

    pspecs = T.param_specs(cfg, model_ax)
    pshapes = T.param_shapes(cfg, model_ax)
    pshard = shardings_of(pspecs, mesh, mapping)

    if shape.kind == "train":
        from repro.train.loop import TrainConfig, make_train_step
        ostate_specs = adamw.state_specs(pspecs)
        oshapes = {
            "mu": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                pshapes),
            "nu": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                pshapes),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        oshard = shardings_of(ostate_specs, mesh, mapping)
        bshapes, bspecs = batch_shapes(cfg, shape)
        bshard = shardings_of(bspecs, mesh, mapping)
        step = make_train_step(cfg, TrainConfig())
        return Lowerable(
            fn=step,
            args_shapes=(pshapes, oshapes, bshapes),
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            kind="train")

    if shape.kind == "prefill":
        bshapes, bspecs = batch_shapes(cfg, shape)
        bshard = shardings_of(bspecs, mesh, mapping)
        max_seq = shape.seq_len + cfg.prefix_tokens  # VLM prefix included

        def prefill_fn(params, batch):
            return T.prefill(cfg, params, batch["tokens"], max_seq,
                             prefix_embeds=batch.get("prefix_embeds"),
                             enc_embeds=batch.get("enc_embeds"))

        cspecs = T.cache_specs(cfg, shape.global_batch, max_seq,
                               model_ax, cfg.encoder_seq)
        cshard = shardings_of(cspecs, mesh, mapping)
        return Lowerable(
            fn=prefill_fn,
            args_shapes=(pshapes, bshapes),
            in_shardings=(pshard, bshard),
            out_shardings=(None, cshard),
            kind="prefill")

    # decode: one new token against a seq_len KV cache
    b = shape.global_batch
    cshapes = T.cache_shapes(cfg, b, shape.seq_len, model_ax,
                             cfg.encoder_seq)
    cspecs = T.cache_specs(cfg, b, shape.seq_len, model_ax,
                           cfg.encoder_seq)
    cshard = shardings_of(cspecs, mesh, mapping)
    tok_shape = jax.ShapeDtypeStruct((b,), jnp.int32)
    tok_shard = shardings_of(P("data"), mesh, mapping)
    pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
    pos_shard = shardings_of(P(), mesh, mapping)

    def decode_fn(params, token, cache, pos):
        return T.decode_step(cfg, params, token, cache, pos)

    return Lowerable(
        fn=decode_fn,
        args_shapes=(pshapes, tok_shape, cshapes, pos_shape),
        in_shardings=(pshard, tok_shard, cshard, pos_shard),
        out_shardings=(None, cshard),
        kind="decode")
