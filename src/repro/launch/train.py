"""Distributed training launcher.

    python -m repro.launch.train --arch granite-3-8b --steps 100 \
        --reduced --ckpt-dir /tmp/ckpt --restore auto

On hardware this runs under ``jax.distributed.initialize()`` with the
production mesh; on this container it uses whatever devices exist (the
``--reduced`` configs train a real ~1-100M model on CPU).  Fault tolerance:
``--restore auto`` resumes from the newest valid checkpoint; the data
pipeline is stateless-seeked so the trajectory is bit-identical.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config, get_reduced
from repro.data.pipeline import make_batch
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.sharding import set_axis_mapping
from repro.obs import Obs, format_metrics
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--blocked-kernels", action="store_true",
                    help="route projections through the differentiable "
                         "blocked Pallas GEMMs (fwd + tuned dgrad "
                         "schedules; interpret mode off-TPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--restore", choices=["auto", "none"], default="none")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (requires 256 devices)")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="write the metrics snapshot (train gauges + "
                         "modeled-vs-measured DRAM report) as JSON — the "
                         "same flag serving has (docs/observability.md)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a Chrome-trace span timeline of every "
                         "train step (step/grad/checkpoint spans + "
                         "loss/throughput counter tracks)")
    ap.add_argument("--miss-log", metavar="PATH", default=None,
                    help="append schedule-cache misses as JSONL tuning "
                         "targets (meaningful with --blocked-kernels)")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_production_mesh() if args.production_mesh \
        else make_host_mesh()
    set_axis_mapping({"data": ("data",), "model": "model"}
                     if "model" in mesh.axis_names else
                     {"data": ("data",), "model": None})

    tc = TrainConfig(
        opt=AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                        total_steps=args.steps),
        grad_accum=args.grad_accum,
        compress_grads=args.compress_grads,
        blocked_linear=args.blocked_kernels,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)

    def batches():
        for step in range(args.steps):
            yield make_batch(cfg, args.seq_len, args.batch, step)

    obs = Obs(trace=args.trace, miss_log=args.miss_log)
    with mesh:
        result = train(cfg, tc, batches(), restore=args.restore == "auto",
                       obs=obs)
    print(f"final loss: {result['history'][-1]:.4f} "
          f"(start {result['history'][0]:.4f})")
    if args.metrics_out:
        obs.write_metrics(args.metrics_out)
        print(f"metrics snapshot -> {args.metrics_out}")
        snap = obs.snapshot()
        print(format_metrics({"train": snap.get("train", {})}))
    if args.trace:
        print(f"chrome trace -> {args.trace}")
    if args.miss_log:
        print(f"schedule-cache miss log -> {args.miss_log} "
              "(replay: python -m repro.tune --from-telemetry)")
    obs.close()


if __name__ == "__main__":
    main()
