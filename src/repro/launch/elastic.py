"""Elastic re-scaling: rebuild the mesh when the healthy device count
changes and reshard the checkpoint onto it.

At 1000+-node scale, slices fail; the recovery path is:
  1. the watchdog (train loop) or the platform reports a new device count;
  2. ``plan_mesh(n_devices)`` picks the largest (data, model) grid that
     preserves the model-axis divisibility constraints;
  3. the latest checkpoint is restored with the NEW model_ax — parameter
     *shapes* are mesh-independent in this framework (sharding is metadata,
     not layout), so restore is a pure resharding, and optimizer state
     follows the same specs.

``plan_mesh`` is deliberately pure/deterministic so every surviving host
computes the same plan without coordination.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    n_devices: int
    data: int
    model: int

    @property
    def shape(self) -> tuple[int, int]:
        return (self.data, self.model)


def _divisors_desc(n: int) -> list[int]:
    return sorted({d for i in range(1, int(n ** 0.5) + 1) if n % i == 0
                   for d in (i, n // i)}, reverse=True)


def plan_mesh(cfg: ModelConfig, n_devices: int,
              prefer_model: int = 16) -> MeshPlan:
    """Largest usable (data, model) grid for the surviving devices.

    model axis must divide the sharded dims (heads, d_ff, experts, vocab
    padding is adaptive) — we require it divides d_model-derived dims and
    prefer the configured size, shrinking by divisors when devices are
    lost."""
    for model in [m for m in _divisors_desc(prefer_model) if m >= 1]:
        if n_devices % model:
            continue
        data = n_devices // model
        if data < 1:
            continue
        # model axis must divide the ffn (and q-heads) sharding
        ffn = cfg.moe_d_ff or cfg.d_ff or cfg.d_model
        heads_ok = cfg.n_heads == 0 or cfg.n_heads % model == 0
        if ffn % model == 0 and heads_ok:
            return MeshPlan(n_devices, data, model)
    return MeshPlan(n_devices, n_devices, 1)


def make_elastic_mesh(plan: MeshPlan):
    devs = jax.devices()[:plan.n_devices]
    import numpy as np
    arr = np.array(devs).reshape(plan.shape)
    from jax.sharding import Mesh
    return Mesh(arr, ("data", "model"))


def reshard_checkpoint(cfg: ModelConfig, ckpt_dir: str, plan: MeshPlan):
    """Restore the newest checkpoint under the new mesh's model_ax."""
    from repro.models import transformer as T
    from repro.optim import adamw
    from repro.train import checkpoint as ckpt
    import numpy as np

    shapes = T.param_shapes(cfg, plan.model)
    template = {
        "params": jax.tree.map(
            lambda s: np.zeros(s.shape, s.dtype), shapes),
    }
    template["opt"] = {
        "mu": jax.tree.map(lambda s: np.zeros(s.shape, np.float32),
                           shapes),
        "nu": jax.tree.map(lambda s: np.zeros(s.shape, np.float32),
                           shapes),
        "step": np.zeros((), np.int32),
    }
    return ckpt.restore(ckpt_dir, template)
