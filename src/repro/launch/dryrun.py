import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM and unsupported collectives all surface here.
Artifacts (memory analysis, cost analysis, collective byte counts parsed
from the partitioned HLO) are written to experiments/dryrun/*.json; the
roofline benchmark reads them.

Usage:
    python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
    python -m repro.launch.dryrun --all --multi-pod both
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import axis_mapping, input_specs
from repro.models.sharding import set_axis_mapping

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "experiments", "dryrun")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4,
                "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "s64": 8, "u64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _bytes_of_shapes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-op-kind bytes moved by collectives (per device, from the
    partitioned module).  We count the tensor sizes on each collective
    instruction's definition line (output(s) of the op ~= payload)."""
    out = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        rhs = s.split("=", 1)[1]
        for op in COLLECTIVE_OPS:
            m = re.search(rf"\b{op}(-start)?\(", rhs)
            if m:
                # the result type annotation precedes the op name
                out[op] += _bytes_of_shapes(rhs[:m.start()])
                break
    return out


def _lower_compile(cfg, shape_name, mesh, parallelism="tp_fsdp"):
    t0 = time.time()
    low = input_specs(cfg, shape_name, mesh, parallelism=parallelism)
    with mesh:
        jitted = jax.jit(low.fn, in_shardings=low.in_shardings,
                         out_shardings=low.out_shardings)
        lowered = jitted.lower(*low.args_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return low, compiled, t_lower, t_compile


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, analysis: bool = True,
             parallelism: str = "tp_fsdp", remat: str | None = None,
             kv8: bool = False) -> dict:
    cfg = get_config(arch)
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if kv8:
        import jax.numpy as jnp
        cfg = dataclasses.replace(cfg, kv_cache_dtype=jnp.float8_e4m3fn)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mapping = axis_mapping(cfg, SHAPES[shape_name], mesh, parallelism)
    set_axis_mapping(mapping)

    # --- variant 1: deployable (lax.scan layers, Pallas kernels) --------
    # proves the sharding compiles; gives memory analysis + compile time.
    os.environ.pop("REPRO_UNROLL_SCAN", None)
    os.environ.pop("REPRO_REF_ATTENTION", None)
    low, compiled, t_lower, t_compile = _lower_compile(
        cfg, shape_name, mesh, parallelism)
    mem = compiled.memory_analysis()
    mem_stats = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        mem_stats[attr] = getattr(mem, attr, None)

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": low.kind,
        "parallelism": parallelism,
        "remat": remat or "block",
        "n_devices": mesh.size,
        "memory": mem_stats,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "ok": True,
    }

    # --- variant 2: analysis (unrolled layers, blocked-jnp attention) ---
    # XLA cost analysis counts while bodies once, so true per-device HLO
    # FLOPs/bytes and per-layer collective bytes come from unrolled
    # lowerings.  Unrolling the full 40-94 layer stacks takes ~8 min per
    # cell on this 1-core box, so we lower 1-cycle and 2-cycle models and
    # extrapolate linearly over the layer groups (exact: per-group cost is
    # layer-count linear; fixed embed/logit cost cancels in the delta).
    if analysis:
        os.environ["REPRO_UNROLL_SCAN"] = "1"
        os.environ["REPRO_REF_ATTENTION"] = "blocked"
        try:
            t0 = time.time()
            pattern = cfg.layer_pattern
            rem = cfg.n_layers % len(pattern)
            n_groups = cfg.n_layers // len(pattern)

            def measure(k_groups: int) -> dict:
                small = dataclasses.replace(
                    cfg, n_layers=k_groups * len(pattern) + rem)
                _, comp, _, _ = _lower_compile(small, shape_name, mesh,
                                               parallelism)
                cost = comp.cost_analysis() or {}
                coll = collective_bytes(comp.as_text())
                return {"flops": cost.get("flops", 0.0),
                        "bytes": cost.get("bytes accessed", 0.0),
                        "coll": coll}

            m1 = measure(1)
            if n_groups > 1:
                m2 = measure(2)
                scale = n_groups - 1
                flops = m1["flops"] + (m2["flops"] - m1["flops"]) * scale
                bytes_ = m1["bytes"] + (m2["bytes"] - m1["bytes"]) * scale
                coll = {k: int(m1["coll"][k] +
                               (m2["coll"][k] - m1["coll"][k]) * scale)
                        for k in m1["coll"]}
            else:
                flops, bytes_, coll = m1["flops"], m1["bytes"], m1["coll"]
            result.update({
                "flops": flops,
                "bytes_accessed": bytes_,
                "collective_bytes": coll,
                "collective_bytes_total": sum(coll.values()),
                "analysis_compile_s": round(time.time() - t0, 1),
                "analysis_method": "1/2-cycle linear extrapolation",
            })
        finally:
            os.environ.pop("REPRO_UNROLL_SCAN", None)
            os.environ.pop("REPRO_REF_ATTENTION", None)

    if verbose:
        f = result.get("flops")
        ba = result.get("bytes_accessed")
        cb = result.get("collective_bytes_total")
        print(f"[dryrun] {arch} x {shape_name} x {result['mesh']}: "
              + (f"flops={f:.3e} bytes={ba:.3e} coll={cb:.3e} "
                 if f is not None else "")
              + f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"  memory: {mem_stats}")
    return result


def artifact_path(arch: str, shape_name: str, multi_pod: bool,
                  parallelism: str = "tp_fsdp") -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    mesh = "2x16x16" if multi_pod else "16x16"
    safe = arch.replace("/", "_").replace(".", "_")
    suffix = "" if parallelism == "tp_fsdp" else f"__{parallelism}"
    return os.path.join(ARTIFACT_DIR,
                        f"{safe}__{shape_name}__{mesh}{suffix}.json")


def run_and_save(arch: str, shape_name: str, multi_pod: bool,
                 force: bool = False,
                 parallelism: str = "tp_fsdp",
                 remat: str | None = None, kv8: bool = False) -> dict:
    path = artifact_path(arch, shape_name, multi_pod, parallelism)
    if remat is not None:
        path = path.replace(".json", f"__remat_{remat}.json")
    if kv8:
        path = path.replace(".json", "__kv8.json")
    if not force and os.path.exists(path):
        with open(path) as f:
            r = json.load(f)
            if r.get("ok"):
                return r
    try:
        # roofline table is single-pod only (spec): multi-pod proves the
        # pod axis shards, no analysis variant needed.
        result = run_cell(arch, shape_name, multi_pod,
                          analysis=not multi_pod,
                          parallelism=parallelism, remat=remat, kv8=kv8)
    except Exception as e:  # record failures — they are bugs to fix
        traceback.print_exc()
        result = {"arch": arch, "shape": shape_name,
                  "mesh": "2x16x16" if multi_pod else "16x16",
                  "ok": False, "error": f"{type(e).__name__}: {e}"}
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["on", "off", "both"],
                    default="off")
    ap.add_argument("--parallelism", default="tp_fsdp",
                    choices=["tp_fsdp", "fsdp"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    pods = {"on": [True], "off": [False], "both": [False, True]}[
        args.multi_pod]
    if args.all:
        todo = cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    failures = []
    for arch, shape_name in todo:
        for mp in pods:
            r = run_and_save(arch, shape_name, mp, force=args.force,
                             parallelism=args.parallelism)
            if not r.get("ok"):
                failures.append((arch, shape_name, mp, r.get("error")))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"\nall {len(todo) * len(pods)} cells compiled OK")


if __name__ == "__main__":
    main()
