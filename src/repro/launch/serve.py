"""Serving launcher: batched generation with the decode engine.

    python -m repro.launch.serve --arch gemma2-9b --reduced \
        --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import transformer as T
from repro.models.sharding import set_axis_mapping
from repro.launch.mesh import make_host_mesh
from repro.serve.engine import DecodeEngine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    set_axis_mapping({"data": None, "model": None})
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = DecodeEngine(cfg, params,
                          ServeConfig(max_seq=args.max_seq,
                                      temperature=args.temperature))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)
    kwargs = {}
    if cfg.is_encdec:
        kwargs["enc_embeds"] = jax.numpy.asarray(
            rng.standard_normal((args.batch, cfg.encoder_seq,
                                 cfg.d_model)).astype(np.float32) * 0.1,
            cfg.dtype)
    if cfg.prefix_tokens:
        kwargs["prefix_embeds"] = jax.numpy.asarray(
            rng.standard_normal((args.batch, cfg.prefix_tokens,
                                 cfg.d_model)).astype(np.float32) * 0.1,
            cfg.dtype)

    t0 = time.perf_counter()
    out = engine.generate(prompts, args.gen, **kwargs)
    dt = time.perf_counter() - t0
    tps = args.batch * args.gen / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
