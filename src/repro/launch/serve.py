"""Serving launcher: static-batch or paged continuous-batching engine.

    # static batch (the baseline)
    python -m repro.launch.serve --arch gemma2-9b --reduced \
        --batch 4 --prompt-len 16 --gen 32

    # paged continuous batching (tuned KV page size, mixed prompt lengths)
    python -m repro.launch.serve --arch gemma2-9b --reduced --engine paged \
        --batch 8 --requests 16 --prompt-len 16 --mixed-lens --gen 32

    # quantized serving: int8 weights + fp8 KV page pool (page size from
    # the fp8-aware blocking model; docs/quantization.md)
    python -m repro.launch.serve --arch gemma2-9b --reduced --engine paged \
        --batch 8 --gen 32 --quantize w8fp8
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import transformer as T
from repro.models.sharding import set_axis_mapping
from repro.obs import Obs, format_metrics
from repro.serve.engine import (DecodeEngine, PagedEngine, PagedServeConfig,
                                ServeConfig)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", choices=("static", "paged"),
                    default="static")
    ap.add_argument("--batch", type=int, default=4,
                    help="static: batch size; paged: decode batch slots")
    ap.add_argument("--requests", type=int, default=0,
                    help="paged: total requests to stream (default: batch)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--mixed-lens", action="store_true",
                    help="paged: draw prompt lengths in [prompt_len/2, "
                         "prompt_len]")
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged: KV page size (0 -> tuned via the "
                         "flash_decode schedule key)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quantize", choices=("none", "w8", "fp8kv", "w8fp8"),
                    default="none",
                    help="w8: int8 projection weights (matmul_w8 kernel); "
                         "fp8kv: fp8 KV page pool (fp8 flash-decode + "
                         "fp8-aware page size); w8fp8: both")
    ap.add_argument("--fuse", action="store_true",
                    help="cross-op fused kernels on the hot path: "
                         "epilogue-fused MLP GEMMs, one-pass QKV, and "
                         "(paged) oproj-fused flash decode; composes "
                         "with --quantize (docs/fusion.md)")
    ap.add_argument("--prefill-chunk", type=int, default=-1,
                    help="paged: prefill chunk size in tokens (-1 -> "
                         "auto-sized from the VMEM blocking model, 0 -> "
                         "whole-prompt joins; attention-only stacks)")
    ap.add_argument("--spec", type=int, default=0,
                    help="paged: draft tokens per speculative "
                         "draft-verify decode step (0 -> off; greedy "
                         "only, attention-only stacks)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged: radix-tree prefix sharing — repeated "
                         "prompt prefixes reuse cached KV pages "
                         "(copy-on-write; attention-only stacks; "
                         "docs/serving.md)")
    ap.add_argument("--reuse-hint", type=float, default=0.5,
                    help="expected prompt-reuse rate for the "
                         "share-vs-stream page-size pricing (only "
                         "with --prefix-cache)")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="paged: per-request wall deadline in seconds; "
                         "requests past it finish DEADLINE_EXCEEDED "
                         "with whatever they emitted "
                         "(docs/robustness.md)")
    ap.add_argument("--preempt", action="store_true",
                    help="paged: allow preempt-with-restore when the "
                         "waiting head starves (greedy only; restored "
                         "requests replay only their unshared tail "
                         "with --prefix-cache)")
    ap.add_argument("--nan-guard", action="store_true",
                    help="paged: per-slot NaN/Inf logit guard — a "
                         "poisoned request FAILs alone instead of "
                         "wedging the batch")
    ap.add_argument("--degrade", action="store_true",
                    help="paged: graceful-degradation ladder driven by "
                         "the metrics registry (no_spec -> small_chunk "
                         "-> preempt)")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="write the metrics snapshot (registry + "
                         "modeled-vs-measured DRAM report) as JSON "
                         "(docs/observability.md)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a Chrome-trace (chrome://tracing / "
                         "Perfetto) span timeline of every engine step; "
                         "inserts block_until_ready fences, so traced "
                         "runs are NOT for throughput numbers")
    ap.add_argument("--miss-log", metavar="PATH", default=None,
                    help="append schedule-cache misses as JSONL tuning "
                         "targets for python -m repro.tune "
                         "--from-telemetry")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.quantize in ("fp8kv", "w8fp8"):
        cfg = dataclasses.replace(cfg,
                                  kv_cache_dtype=jax.numpy.float8_e4m3fn)
    set_axis_mapping({"data": None, "model": None})
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    if args.quantize in ("w8", "w8fp8"):
        from repro.quant import quantize_params, quantized_bytes
        params = quantize_params(params)
        qb, db = quantized_bytes(params)
        print(f"quantized projection weights: {qb / 1e6:.1f} MB "
              f"(same projections at bf16: {db / 1e6:.1f} MB)")
    rng = np.random.default_rng(0)
    obs = Obs(trace=args.trace, miss_log=args.miss_log)

    def finish_obs(engine) -> None:
        """Shared tail: one formatter for every serve-mode summary."""
        if args.metrics_out:
            engine.obs.write_metrics(args.metrics_out)
            print(f"metrics snapshot -> {args.metrics_out}")
            dram = engine.obs.snapshot()["dram"]
            lines = format_metrics({"dram": {
                k: {kk: v[kk] for kk in
                    ("modeled_bytes", "used_bytes", "ratio")}
                for k, v in dram["per_op"].items()}})
            if lines:
                print("modeled-vs-measured DRAM bytes per op key:")
                print(lines)
        if args.trace:
            print(f"chrome trace -> {args.trace}")
        if args.miss_log:
            print(f"schedule-cache miss log -> {args.miss_log} "
                  "(replay: python -m repro.tune --from-telemetry)")
        engine.obs.close()

    if args.engine == "paged":
        engine = PagedEngine(cfg, params, PagedServeConfig(
            max_seq=args.max_seq, max_batch=args.batch,
            page_size=args.page_size or None,
            temperature=args.temperature, fuse=args.fuse,
            prefill_chunk=None if args.prefill_chunk < 0
            else args.prefill_chunk,
            spec_decode=args.spec, prefix_cache=args.prefix_cache,
            reuse_hint=args.reuse_hint, preempt=args.preempt,
            nan_guard=args.nan_guard, degrade=args.degrade), obs=obs)
        n_req = args.requests or args.batch
        lo = max(1, args.prompt_len // 2) if args.mixed_lens \
            else args.prompt_len
        lens = rng.integers(lo, args.prompt_len + 1, n_req)
        prompts = [rng.integers(0, cfg.vocab, (int(L),), dtype=np.int32)
                   for L in lens]
        t0 = time.perf_counter()
        try:
            reqs = engine.generate(prompts, args.gen,
                                   deadline_s=args.deadline or None,
                                   return_requests=True)
        except KeyboardInterrupt:
            # Ctrl-C mid-generate: cancel everything, drain to terminal
            # statuses (freeing every page), and still report what ran
            print("\ninterrupted: draining in-flight requests ...")
            engine.shutdown()
            held = engine.scheduler.allocator.in_use()
            print(format_metrics({"lifecycle": engine.lifecycle_stats()}))
            print(f"page pool drained ({held} pages still held)")
            finish_obs(engine)
            return
        dt = time.perf_counter() - t0
        emitted = sum(r.emitted_total for r in reqs)
        tps = emitted / dt
        print(f"paged engine: page={engine.page_size} "
              f"chunk={engine.prefill_chunk} spec={engine.spec} "
              f"slots={args.batch} requests={n_req}"
              + (" fused" if args.fuse else ""))
        # every summary (spec, prefix cache, lifecycle, step latency)
        # renders through the one metrics formatter — no bespoke
        # f-strings
        sections = {}
        if engine.spec:
            sections["spec"] = engine.spec_stats()
        if engine.prefix_caching:
            sections["prefix_cache"] = engine.prefix_stats()
        if args.deadline or args.preempt or args.nan_guard \
                or args.degrade:
            sections["lifecycle"] = engine.lifecycle_stats()
        if sections:
            print(format_metrics(sections))
        statuses = sorted({r.status.value for r in reqs})
        print(f"generated {emitted} tokens over {n_req} requests in "
              f"{dt:.2f}s ({tps:.1f} tok/s), statuses: "
              f"{'/'.join(statuses)}")
        print("sample:", reqs[0].output[:16].tolist())
        finish_obs(engine)
        return

    engine = DecodeEngine(cfg, params,
                          ServeConfig(max_seq=args.max_seq,
                                      temperature=args.temperature,
                                      fuse=args.fuse), obs=obs)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)
    kwargs = {}
    if cfg.is_encdec:
        kwargs["enc_embeds"] = jax.numpy.asarray(
            rng.standard_normal((args.batch, cfg.encoder_seq,
                                 cfg.d_model)).astype(np.float32) * 0.1,
            cfg.dtype)
    if cfg.prefix_tokens:
        kwargs["prefix_embeds"] = jax.numpy.asarray(
            rng.standard_normal((args.batch, cfg.prefix_tokens,
                                 cfg.d_model)).astype(np.float32) * 0.1,
            cfg.dtype)

    t0 = time.perf_counter()
    out = engine.generate(prompts, args.gen, **kwargs)
    dt = time.perf_counter() - t0
    tps = args.batch * args.gen / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print("sample:", out[0, :16].tolist())
    finish_obs(engine)


if __name__ == "__main__":
    main()
