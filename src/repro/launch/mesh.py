"""Production meshes (DESIGN.md §5).

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
pure data parallelism (gradient all-reduce hierarchically scheduled by
XLA: reduce-scatter intra-pod, all-reduce inter-pod).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (tests / examples): data-only mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def data_axes(mesh) -> tuple[str, ...]:
    """The axes that carry the batch dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_divisor(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n
