"""Fault-tolerant checkpointing: sharded-tree save/restore with atomic
commit, content hashing and automatic latest-valid resolution.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json (tree structure +
sha256 of the array payload).  A checkpoint only becomes visible once its
manifest is written (write-tmp + rename = atomic on POSIX), so a crash
mid-save can never produce a checkpoint that ``latest_valid`` would pick.
Restore verifies the hash and falls back to the previous checkpoint on
corruption — restart-after-node-failure never sees torn state.
"""

from __future__ import annotations

import hashlib
import io
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> tuple[list[tuple[str, np.ndarray]], Any]:
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves_with_path:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub":  # ml_dtypes (bf16): store as f32
            arr = arr.astype(np.float32)
        out.append((key, arr))
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any,
         keep: int = 3) -> str:
    """Synchronous atomic save; prunes old checkpoints beyond ``keep``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    pairs, _ = _flatten(tree)
    buf = io.BytesIO()
    np.savez(buf, **{k: v for k, v in pairs})
    payload = buf.getvalue()
    digest = hashlib.sha256(payload).hexdigest()
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        f.write(payload)
    manifest = {"step": step, "sha256": digest,
                "keys": [k for k, _ in pairs],
                "dtypes": [str(v.dtype) for _, v in pairs],
                "shapes": [list(v.shape) for _, v in pairs]}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    _prune(ckpt_dir, keep)
    return final


_async_thread: threading.Thread | None = None


def save_async(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> None:
    """Double-buffered async save: device->host copy happens now, disk IO
    on a background thread (training continues)."""
    global _async_thread
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
    if _async_thread is not None:
        _async_thread.join()
    _async_thread = threading.Thread(
        target=save, args=(ckpt_dir, step, host_tree, keep), daemon=True)
    _async_thread.start()


def wait_async() -> None:
    global _async_thread
    if _async_thread is not None:
        _async_thread.join()
        _async_thread = None


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(_list_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def _list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name,
                                           "manifest.json")):
                out.append(int(name[5:]))
    return out


def _verify(path: str) -> bool:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with open(os.path.join(path, "arrays.npz"), "rb") as f:
            payload = f.read()
        return hashlib.sha256(payload).hexdigest() == manifest["sha256"]
    except (OSError, json.JSONDecodeError, KeyError):
        return False


def latest_valid(ckpt_dir: str) -> int | None:
    """Newest checkpoint that passes hash verification."""
    for s in sorted(_list_steps(ckpt_dir), reverse=True):
        if _verify(os.path.join(ckpt_dir, f"step_{s:08d}")):
            return s
    return None


def restore(ckpt_dir: str, template: Any, step: int | None = None) -> \
        tuple[Any, int]:
    """Restore into the structure of ``template``.  ``step=None`` -> newest
    valid.  Arrays whose shape changed (elastic re-slice) are zero-padded /
    truncated along each axis — see launch/elastic.py."""
    if step is None:
        step = latest_valid(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not _verify(path):
        raise IOError(f"checkpoint {path} failed hash verification")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(
        template)
    leaves = []
    for p, tmpl in leaves_with_path:
        key = jax.tree_util.keystr(p)
        tmpl = np.asarray(tmpl)
        arr = data[key]
        if arr.shape != tmpl.shape:
            arr = _reshape_like(arr, tmpl.shape)
        leaves.append(arr.astype(tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def _reshape_like(arr: np.ndarray, shape: tuple[int, ...]) -> np.ndarray:
    """Pad/crop each axis (elastic mesh re-slice support)."""
    if arr.ndim != len(shape):
        return np.zeros(shape, arr.dtype)
    slices = tuple(slice(0, min(a, b)) for a, b in zip(arr.shape, shape))
    out = np.zeros(shape, arr.dtype)
    out[slices] = arr[slices]
    return out
