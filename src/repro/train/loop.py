"""Training loop: jitted train_step with grad accumulation, checkpointing,
straggler watchdog and optional gradient compression.

``make_train_step`` builds the jitted step for a (cfg, mesh) pair with
donated params/opt-state (in-place updates on device).  Microbatching uses
``lax.scan`` over gradient-accumulation slices, so the same step function
serves both "fits in memory" and "needs accumulation" regimes.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.optim.compress import compress_tree
from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class TrainConfig:
    opt: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)
    grad_accum: int = 1
    compress_grads: bool = False
    ckpt_dir: str | None = None
    ckpt_every: int = 100
    log_every: int = 10
    straggler_factor: float = 3.0  # step slower than 3x median -> warn
    blocked_linear: bool = False   # projections through the blocked,
    #   custom-VJP Pallas GEMMs (fwd + dgrad kernels with tuned
    #   schedules); off by default — XLA's native dot is the baseline


def make_loss(cfg: ModelConfig, tc: TrainConfig | None = None) -> Callable:
    blocked = bool(tc and tc.blocked_linear)

    def loss(params, batch):
        from repro.kernels import ops
        # the toggle must be live while this fn is TRACED (the branch in
        # ops.linear is a Python-level one), hence inside the loss body
        with ops.blocked_linear(blocked):
            total, metrics = T.loss_fn(cfg, params, batch)
        return total, metrics
    return loss


def make_train_step(cfg: ModelConfig, tc: TrainConfig) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss = make_loss(cfg, tc)

    def train_step(params, opt_state, batch):
        if tc.grad_accum > 1:
            def micro(carry, mb):
                gsum, msum = carry
                (l, m), g = jax.value_and_grad(loss, has_aux=True)(
                    params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, msum + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape((tc.grad_accum,
                                     x.shape[0] // tc.grad_accum)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, ltot), _ = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / tc.grad_accum, grads)
            metrics = {"loss": ltot / tc.grad_accum}
        else:
            (l, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(params, batch)

        if tc.compress_grads:
            grads, _ = compress_tree(grads)

        params, opt_state, opt_m = adamw.apply_updates(
            tc.opt, params, grads, opt_state)
        metrics = dict(metrics)
        metrics.update(opt_m)
        return params, opt_state, metrics

    return train_step


class StepWatchdog:
    """Straggler mitigation hook: tracks step times, flags anomalies.

    On a real cluster the flag triggers microbatch rebalancing / slice
    eviction; here it logs (the decision logic is what we can test)."""

    def __init__(self, factor: float = 3.0):
        self.factor = factor
        self.times: list[float] = []
        self.flags: list[int] = []

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        window = sorted(self.times[-50:])
        median = window[len(window) // 2]
        slow = len(self.times) > 5 and dt > self.factor * median
        if slow:
            self.flags.append(step)
        return slow


def train(cfg: ModelConfig, tc: TrainConfig, batches, *,
          params=None, rng=None, restore: bool = False,
          log=print, obs=None) -> dict:
    """Single-host training driver (examples use this; launch/train.py
    wraps it with the mesh).

    ``obs`` (an :class:`repro.obs.Obs` bundle, optional) gets the same
    telemetry the serving engines emit: ``train.loss`` /
    ``train.tokens_per_s`` gauges and a ``train.step_us`` histogram in
    the registry, ``step``/``grad``/``checkpoint`` spans plus a
    throughput counter track in the tracer, and — with
    ``blocked_linear`` — every projection's schedule resolution in the
    DRAM ledger under the ``train_step`` scope.  The loop already
    fences every step on the loss, so spans time device work with or
    without a tracer attached.
    """
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    if params is None:
        params = T.init_params(cfg, rng)
    opt_state = adamw.init_state(params)
    start_step = 0
    if restore and tc.ckpt_dir:
        latest = ckpt.latest_valid(tc.ckpt_dir)
        if latest is not None:
            state, start_step = ckpt.restore(
                tc.ckpt_dir, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            log(f"restored checkpoint at step {start_step}")

    if obs is not None:
        from repro.obs import null_span
        span = obs.tracer.span if obs.tracer is not None else null_span
        g_loss = obs.registry.gauge("train.loss")
        g_tps = obs.registry.gauge("train.tokens_per_s")
        h_step = obs.registry.histogram("train.step_us")
        c_steps = obs.registry.counter("train.steps")

    step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))
    watchdog = StepWatchdog(tc.straggler_factor)
    history = []
    for step, batch in enumerate(batches, start=start_step):
        t0 = time.perf_counter()
        if obs is not None:
            with span(f"step {step}", cat="train",
                      args={"step": step}):
                with span("grad", cat="train"), \
                        obs.dram.scope("train_step"):
                    params, opt_state, metrics = step_fn(
                        params, opt_state, batch)
                    jax.block_until_ready(metrics["loss"])
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        slow = watchdog.observe(step, dt)
        if obs is not None:
            tok = batch.get("tokens") if isinstance(batch, dict) else None
            tokens = (tok.size if tok is not None else
                      max((x.size for x in jax.tree.leaves(batch)), default=0))
            tps = tokens / dt if dt > 0 else 0.0
            g_loss.set(float(metrics["loss"]))
            g_tps.set(round(tps, 1))
            h_step.observe(dt * 1e6)
            c_steps.inc()
            obs.dram.end_step()
            if obs.tracer is not None:
                obs.tracer.counter("train", {"loss": float(metrics["loss"]),
                                             "tokens_per_s": tps})
        if step % tc.log_every == 0 or slow:
            log(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics.get('grad_norm', 0)):.3f} "
                f"{dt*1e3:.0f}ms" + ("  [STRAGGLER]" if slow else ""))
        history.append(float(metrics["loss"]))
        if tc.ckpt_dir and (step + 1) % tc.ckpt_every == 0:
            if obs is not None:
                with span("checkpoint", cat="train",
                          args={"step": step + 1}):
                    ckpt.save_async(tc.ckpt_dir, step + 1,
                                    {"params": params, "opt": opt_state})
            else:
                ckpt.save_async(tc.ckpt_dir, step + 1,
                                {"params": params, "opt": opt_state})
    ckpt.wait_async()
    return {"params": params, "opt": opt_state, "history": history,
            "straggler_flags": watchdog.flags}
