"""Activation calibration: observed ranges -> quantization scales.

Weights are quantized from their own values (``quant.quantize``), but
activation scales (the ``a8`` half of w8a8) must come from *data* — the
ranges a layer actually sees.  The calibrator accumulates per-leaf
statistics over observation batches and emits scales compatible with
:mod:`repro.quant.quantize`.

Two estimators:

* ``absmax``     — running max of |x| (exact range, outlier-sensitive);
* ``ema_absmax`` — exponential moving average of the per-batch absmax
  (the standard PTQ smoothing for spiky activations; ``momentum``
  controls the horizon).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.quant.quantize import QUANT_DTYPES, _EPS


class AbsMaxCalibrator:
    """Running per-leaf activation-range tracker.

    ``observe(tree)`` folds one batch of activations (any pytree of
    arrays) into the running statistics; ``scales(dtype)`` returns the
    matching pytree of fp32 scalar scales.  Leaves are matched by tree
    structure, so observe the same structure every time.
    """

    def __init__(self, momentum: float | None = None):
        if momentum is not None and not 0.0 < momentum < 1.0:
            raise ValueError(f"momentum must be in (0, 1), got {momentum}")
        self.momentum = momentum
        self._absmax: Any = None
        self.n_batches = 0

    def observe(self, tree: Any) -> None:
        batch_max = jax.tree.map(
            lambda x: jnp.max(jnp.abs(x.astype(jnp.float32))), tree)
        if self._absmax is None:
            self._absmax = batch_max
        elif self.momentum is None:
            self._absmax = jax.tree.map(jnp.maximum, self._absmax,
                                        batch_max)
        else:
            m = self.momentum
            self._absmax = jax.tree.map(
                lambda old, new: m * old + (1.0 - m) * new,
                self._absmax, batch_max)
        self.n_batches += 1

    def scales(self, dtype: str = "int8") -> Any:
        """Per-leaf fp32 scales such that observed values quantize into
        the target dtype's representable range."""
        if self._absmax is None:
            raise ValueError("no batches observed yet")
        if dtype not in QUANT_DTYPES:
            raise ValueError(f"unknown quant dtype {dtype!r}; "
                             f"expected one of {sorted(QUANT_DTYPES)}")
        _, qmax = QUANT_DTYPES[dtype]
        return jax.tree.map(lambda a: a / qmax + _EPS, self._absmax)
