"""Fake-quant accuracy harness: quantized model vs its fp reference.

The contract the subsystem is tested against has two layers:

1. *kernel == fake-quant oracle* — the int8/fp8 Pallas kernels must
   reproduce the fp32 dequant-then-compute reference bit-for-bit in
   fp32 math (tests/test_quant.py);
2. *quantized model ~= fp model* — running the transformer with
   QuantizedTensor weights must track the original logits within the
   error the quantization itself introduces.  This module measures
   that: logit-level error and top-1 agreement over sample prompts.

``logit_report`` is cheap enough for tests on reduced configs and is
what ``benchmarks/quant_bench.py`` prints for the accuracy column.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def logit_report(cfg: Any, params: Any, qparams: Any,
                 tokens: Any) -> dict:
    """Compare full-sequence logits of ``params`` vs ``qparams``.

    ``tokens``: (B, S) int32 prompts.  Returns max/mean absolute logit
    error, the same normalized by the fp logit scale, and per-position
    top-1 agreement — the numbers a deployment gate would threshold.
    """
    from repro.models import transformer as T

    tokens = jnp.asarray(tokens, jnp.int32)

    @jax.jit
    def logits_of(p):
        h, _ = T.forward(cfg, p, tokens)
        return T.logits_fn(cfg, p, h).astype(jnp.float32)

    ref = np.asarray(logits_of(params))[..., :cfg.vocab]
    got = np.asarray(logits_of(qparams))[..., :cfg.vocab]
    err = np.abs(got - ref)
    agree = np.mean(np.argmax(got, -1) == np.argmax(ref, -1))
    denom = max(float(np.max(np.abs(ref))), 1e-9)
    return {
        "max_abs_err": float(np.max(err)),
        "mean_abs_err": float(np.mean(err)),
        "rel_err": float(np.max(err) / denom),
        "top1_agreement": float(agree),
    }
