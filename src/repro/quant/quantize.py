"""Quantized tensors: per-channel / per-tensor scales for int8 and fp8.

The representation is deliberately minimal — a narrow payload plus an
fp32 scale, registered as a jax pytree so quantized weights flow through
``jit`` / ``lax.scan`` / shardings exactly like plain arrays.  Everything
else in the subsystem (the dtype-aware blocking model, the Pallas
kernels, the serving engines) keys off the payload dtype's *itemsize*:
one byte per element is the whole point.

Scale conventions:

* ``reduce_axis=-2`` (default) — per-output-channel weight scales: for a
  projection ``W[K, N]`` the absmax reduces over the contraction dim K,
  leaving one fp32 scale per output channel ``(1, N)``.  A stacked
  ``lax.scan`` weight ``(G, K, N)`` gets ``(G, 1, N)`` — each scanned
  slice is exactly the 2-D case.
* ``reduce_axis=None`` — per-tensor: one scalar scale (shape all-ones).

``sum_k a[m,k] * (q[k,n] * s[n]) == s[n] * sum_k a[m,k] * q[k,n]`` —
the scale depends only on the *output* channel, which is what lets the
kernels accumulate the narrow payload in fp32 and apply the scale once
at the epilogue (``kernels/matmul_q.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

INT8_MAX = 127.0
FP8_MAX = 448.0        # float8_e4m3fn finfo.max
_EPS = 1e-12

QUANT_DTYPES = {
    "int8": (jnp.int8, INT8_MAX),
    "fp8": (jnp.float8_e4m3fn, FP8_MAX),
}


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """A narrow payload + fp32 dequantization scale (a jax pytree)."""

    q: Any              # int8 or float8_e4m3fn array
    scale: Any          # fp32, broadcastable to q.shape

    @property
    def shape(self) -> tuple[int, ...]:
        return self.q.shape

    @property
    def ndim(self) -> int:
        return self.q.ndim

    @property
    def dtype(self):
        return self.q.dtype

    def dequant(self, dtype: Any = jnp.float32) -> jax.Array:
        return (self.q.astype(jnp.float32) * self.scale).astype(dtype)

    # -- pytree protocol ------------------------------------------------------

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def quantize(x: jax.Array, dtype: str = "int8",
             reduce_axis: int | None = -2) -> QuantizedTensor:
    """Absmax-quantize ``x`` to int8 or fp8 (e4m3).

    ``reduce_axis`` is the axis the absmax reduces over (the contraction
    dim for weights, giving per-output-channel scales); ``None`` reduces
    everything (per-tensor scale).
    """
    if dtype not in QUANT_DTYPES:
        raise ValueError(f"unknown quant dtype {dtype!r}; "
                         f"expected one of {sorted(QUANT_DTYPES)}")
    target, qmax = QUANT_DTYPES[dtype]
    xf = x.astype(jnp.float32)
    axes = tuple(range(x.ndim)) if reduce_axis is None else (reduce_axis,)
    absmax = jnp.max(jnp.abs(xf), axis=axes, keepdims=True)
    scale = absmax / qmax + _EPS
    if dtype == "int8":
        q = jnp.clip(jnp.round(xf / scale), -INT8_MAX, INT8_MAX)
    else:
        q = xf / scale        # e4m3 round happens in the cast below
    return QuantizedTensor(q.astype(target), scale)


def fake_quant(x: jax.Array, dtype: str = "int8",
               reduce_axis: int | None = -2) -> jax.Array:
    """Quantize-dequantize round trip in ``x.dtype`` — the reference
    semantics every quantized kernel must match (see tests/test_quant.py
    and the :mod:`repro.quant.fakequant` accuracy harness)."""
    return quantize(x, dtype, reduce_axis).dequant(x.dtype)
