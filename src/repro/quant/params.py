"""Quantized-parameter containers for the transformer param tree.

``quantize_params`` walks a built parameter tree (``transformer.
init_params`` output, including ``lax.scan``-stacked layer groups) and
replaces the dense projection weights with :class:`QuantizedTensor`
leaves — int8 payload + per-output-channel fp32 scales.  Because
``QuantizedTensor`` is a pytree, the result drops into every existing
``jit``-ed path (engines, decode steps, prefill) unchanged; the matmul
sites dispatch through ``kernels.ops.linear``, which routes quantized
weights to the ``matmul_w8`` Pallas kernel (TPU / blocked-linear mode)
or the fp32 dequant oracle elsewhere.

What gets quantized: the attention projections (wq/wk/wv/wo) and the
dense MLP mats (w_up/w_down/w_gate).  What stays wide: norms and other
1-D leaves, embeddings / lm_head (tied embeddings serve double duty and
the vocab matmul is logit-accuracy-critical), MoE expert banks (their
einsum dispatch path doesn't route through ``ops.linear`` — recognized
by the sibling ``router`` leaf), and the recurrent/SSD mixers.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.quant.quantize import QuantizedTensor, quantize

QUANT_KEYS = frozenset({"wq", "wk", "wv", "wo",
                        "w_up", "w_down", "w_gate"})


def _quantizable(key: str, leaf: Any, keys: frozenset[str]) -> bool:
    return (key in keys
            and hasattr(leaf, "ndim") and leaf.ndim >= 2
            and jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating))


def quantize_params(params: Any, dtype: str = "int8",
                    keys: frozenset[str] = QUANT_KEYS) -> Any:
    """Replace projection-weight leaves with QuantizedTensor containers.

    Per-output-channel scales (absmax over the contraction dim), so a
    stacked ``(n_groups, K, N)`` weight gets ``(n_groups, 1, N)`` scales
    and each scanned slice is exactly the 2-D kernel layout.
    """
    def rec(node: Any) -> Any:
        if isinstance(node, dict):
            if "router" in node:          # MoE expert bank: keep wide
                return node
            # "cross" (enc-dec cross-attention) stays wide: its K/V
            # prefill path multiplies weights outside ops.linear
            return {k: (node[k] if k == "cross"
                        else quantize(v, dtype, reduce_axis=-2)
                        if _quantizable(k, v, keys) else rec(v))
                    for k, v in node.items()}
        if isinstance(node, list):
            return [rec(v) for v in node]
        if isinstance(node, tuple):
            return tuple(rec(v) for v in node)
        return node

    return rec(params)


def dequantize_params(params: Any, dtype: Any = None) -> Any:
    """Widen every QuantizedTensor leaf back to a dense array — the
    fake-quant reference tree: running the ORIGINAL model code on this
    tree defines the accuracy target for the quantized kernels."""
    def widen(leaf: Any) -> Any:
        if isinstance(leaf, QuantizedTensor):
            return leaf.dequant(dtype or jnp.float32)
        return leaf

    return jax.tree.map(widen, params,
                        is_leaf=lambda x: isinstance(x, QuantizedTensor))


def quantized_bytes(params: Any) -> tuple[int, int]:
    """(container_bytes, bf16_dense_bytes) over the QuantizedTensor
    leaves ONLY — the projection-weight storage the containers shrink.

    Unquantized leaves (norms, embeddings, MoE banks, ...) are excluded
    from BOTH totals, so the ratio compares the quantized projections'
    int8-payload+fp32-scale containers against the same projections at
    bf16 deployment width — not against whatever dtype the source tree
    happened to be built in.  Reported by benchmarks/quant_bench.py and
    ``launch/serve --quantize``.
    """
    q_total = 0
    d_total = 0
    leaves = jax.tree.leaves(
        params, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    for leaf in leaves:
        if isinstance(leaf, QuantizedTensor):
            q_total += leaf.q.size * leaf.q.dtype.itemsize + \
                leaf.scale.size * 4
            d_total += leaf.q.size * 2          # bf16 dense equivalent
    return q_total, d_total
