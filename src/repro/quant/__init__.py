"""Quantization subsystem (docs/quantization.md).

Element width is a first-class blocking parameter: the paper's access /
energy model counts traffic in *bytes*, so halving bytes-per-element
lets twice the tile fit in the same buffer and shifts the optimal
schedule.  This package supplies the quantized representations
(``quantize``), data-driven activation calibration (``calibrate``),
quantized-parameter containers for whole models (``params``), and the
fake-quant accuracy harness (``fakequant``); the dtype-aware model
lives in ``core`` (per-operand widths on ``loopnest.Problem``), the
kernels in ``kernels/matmul_q.py`` and the fp8 flash-decode variant,
and the schedule plumbing under the ``"matmul_w8"`` /
``"flash_decode_fp8"`` tune op keys.
"""

from repro.quant.calibrate import AbsMaxCalibrator
from repro.quant.fakequant import logit_report
from repro.quant.params import (QUANT_KEYS, dequantize_params,
                                quantize_params, quantized_bytes)
from repro.quant.quantize import (FP8_MAX, INT8_MAX, QuantizedTensor,
                                  fake_quant, quantize)

__all__ = [
    "AbsMaxCalibrator", "FP8_MAX", "INT8_MAX", "QUANT_KEYS",
    "QuantizedTensor", "dequantize_params", "fake_quant", "logit_report",
    "quantize", "quantize_params", "quantized_bytes",
]
