"""Shared helpers (no jax-device side effects at import)."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp


def scan_or_unroll(f, init, xs, length: int | None = None):
    """lax.scan, or an unrolled python loop when REPRO_UNROLL_SCAN=1.

    XLA's cost analysis counts a while-loop body ONCE regardless of trip
    count, so the roofline pass (launch/dryrun.py "analysis variant")
    lowers with unrolled loops to obtain true HLO FLOPs/bytes; the
    deployable variant keeps lax.scan for fast compiles.
    """
    if os.environ.get("REPRO_UNROLL_SCAN") != "1":
        return jax.lax.scan(f, init, xs, length=length)
    if xs is None:
        n = length
        slice_x = lambda i: None
    else:
        n = jax.tree.leaves(xs)[0].shape[0]
        slice_x = lambda i: jax.tree.map(lambda a: a[i], xs)
    carry = init
    ys = []
    for i in range(n):
        carry, y = f(carry, slice_x(i))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys
