"""gemma2-9b: local+global alternating attention, logit soft-capping
[arXiv:2408.00118].  head_dim=256 (decoupled from d_model/n_heads)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
    head_dim=256, d_ff=14336, vocab=256000,
    layer_pattern=("local", "global"), window=4096,
    attn_logit_cap=50.0, final_logit_cap=30.0,
)


def reduced() -> ModelConfig:
    return ModelConfig(name="gemma2-9b-smoke", family="dense",
                       n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=128, vocab=256,
                       layer_pattern=("local", "global"), window=8,
                       attn_logit_cap=50.0, final_logit_cap=30.0)
