"""granite-34b: dense llama-arch code model, MQA kv=1 [arXiv:2405.04324]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, mlp_kind="gelu",
)


def reduced() -> ModelConfig:
    return ModelConfig(name="granite-34b-smoke", family="dense",
                       n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
                       d_ff=128, vocab=256, mlp_kind="gelu")
