"""recurrentgemma-9b: RG-LRU + local attention, 1:2 ratio
[arXiv:2402.19427].  38 layers = (recurrent, recurrent, local) x 12 + 2."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    head_dim=256, d_ff=12288, vocab=256000,
    layer_pattern=("recurrent", "recurrent", "local"), window=2048,
    lru_width=4096,
)


def reduced() -> ModelConfig:
    return ModelConfig(name="recurrentgemma-smoke", family="hybrid",
                       n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
                       head_dim=16, d_ff=128, vocab=256,
                       layer_pattern=("recurrent", "recurrent", "local"),
                       window=8, lru_width=64)
