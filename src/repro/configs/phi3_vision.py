"""phi-3-vision-4.2b: phi3-mini backbone + CLIP frontend stub
[hf:microsoft/Phi-3-vision-128k-instruct].  The vision tower is a STUB:
input_specs() provides precomputed patch embeddings (prefix_tokens)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064,
    prefix_tokens=576,   # 24x24 CLIP patch grid
)


def reduced() -> ModelConfig:
    return ModelConfig(name="phi3-vision-smoke", family="vlm",
                       n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                       d_ff=128, vocab=256, prefix_tokens=16)
