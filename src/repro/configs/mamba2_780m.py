"""mamba2-780m: attention-free SSD (state-space duality)
[arXiv:2405.21060]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    layer_pattern=("ssd",), ssm_state=128, ssm_head_dim=64,
    ssm_expand=2, ssm_chunk=256,
)


def reduced() -> ModelConfig:
    return ModelConfig(name="mamba2-smoke", family="ssm",
                       n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
                       d_ff=0, vocab=256,
                       layer_pattern=("ssd",), ssm_state=16,
                       ssm_head_dim=16, ssm_expand=2, ssm_chunk=8)
