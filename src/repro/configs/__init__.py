"""Architecture registry: ``--arch <id>`` resolution + paper benchmarks.

``ARCHS`` maps arch id -> (full ModelConfig, reduced smoke ModelConfig).
``SHAPES`` maps shape id -> (seq_len, global_batch, kind).
``cells()`` yields every valid (arch, shape) dry-run cell (40 nominal,
long_500k skipped for full-attention archs per DESIGN.md §4).

``PAPER_LAYERS`` are the paper's own Table-4 benchmark problems.
"""

from __future__ import annotations

import dataclasses

from repro.core.loopnest import Problem
from repro.models.config import ModelConfig

from repro.configs import (gemma2_9b, glm4_9b, granite_3_8b, granite_34b,
                           mamba2_780m, phi3_vision, phi35_moe,
                           qwen3_moe_235b, recurrentgemma_9b,
                           seamless_m4t_medium)

_MODULES = {
    "granite-3-8b": granite_3_8b,
    "glm4-9b": glm4_9b,
    "granite-34b": granite_34b,
    "gemma2-9b": gemma2_9b,
    "qwen3-moe-235b-a22b": qwen3_moe_235b,
    "phi3.5-moe-42b-a6.6b": phi35_moe,
    "seamless-m4t-medium": seamless_m4t_medium,
    "mamba2-780m": mamba2_780m,
    "recurrentgemma-9b": recurrentgemma_9b,
    "phi-3-vision-4.2b": phi3_vision,
}

ARCHS: dict[str, ModelConfig] = {k: m.CONFIG for k, m in _MODULES.items()}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from "
                       f"{sorted(ARCHS)}")
    return ARCHS[arch]


def get_reduced(arch: str) -> ModelConfig:
    return _MODULES[arch].reduced()


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def long_context_ok(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic archs (DESIGN.md §4)."""
    return cfg.supports_long_context


def cells() -> list[tuple[str, str]]:
    """All valid (arch, shape) dry-run cells."""
    out = []
    for arch, cfg in ARCHS.items():
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not long_context_ok(cfg):
                continue
            out.append((arch, shape.name))
    return out


# --- the paper's own benchmark layers (Table 4) -----------------------------

PAPER_LAYERS: dict[str, Problem] = {
    "Conv1": Problem(X=256, Y=256, C=256, K=384, Fw=11, Fh=11),
    "Conv2": Problem(X=500, Y=375, C=32, K=48, Fw=9, Fh=9),
    "Conv3": Problem(X=32, Y=32, C=108, K=200, Fw=4, Fh=4),
    "Conv4": Problem(X=56, Y=56, C=128, K=256, Fw=3, Fh=3),
    "Conv5": Problem(X=28, Y=28, C=256, K=512, Fw=3, Fh=3),
    "FC1": Problem.gemm(M=1, N_cols=100, K_reduce=200, batch=16),
    "FC2": Problem.gemm(M=1, N_cols=4096, K_reduce=4096, batch=16),
}
