"""seamless-m4t-medium: encoder-decoder multimodal backbone
[arXiv:2308.11596].  The speech/text frontend is a STUB: input_specs()
provides precomputed frame embeddings for the encoder."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, mlp_kind="gelu",
    encoder_layers=12, encoder_seq=1024,
)


def reduced() -> ModelConfig:
    return ModelConfig(name="seamless-smoke", family="encdec",
                       n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                       d_ff=128, vocab=256,
                       encoder_layers=2, encoder_seq=16)
