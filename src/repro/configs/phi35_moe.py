"""phi3.5-moe-42b-a6.6b: MoE 16 experts top-2, GQA kv=8
[hf:microsoft/Phi-3.5-MoE-instruct]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=0, vocab=32064,
    n_experts=16, experts_per_token=2, moe_d_ff=6400,
)


def reduced() -> ModelConfig:
    return ModelConfig(name="phi35-moe-smoke", family="moe",
                       n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=0, vocab=256,
                       n_experts=4, experts_per_token=2, moe_d_ff=32)
