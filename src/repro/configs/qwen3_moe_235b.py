"""qwen3-moe-235b-a22b: MoE 128 experts top-8, GQA kv=4
[hf:Qwen/Qwen3-30B-A3B scaled]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    head_dim=128, d_ff=0, vocab=151936,
    n_experts=128, experts_per_token=8, moe_d_ff=1536,
)


def reduced() -> ModelConfig:
    return ModelConfig(name="qwen3-moe-smoke", family="moe",
                       n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=0, vocab=256,
                       n_experts=8, experts_per_token=2, moe_d_ff=32)
