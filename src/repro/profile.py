"""Per-kernel roofline + energy profiler CLI (docs/observability.md).

    # profile the seeded serving config, print the roofline table
    PYTHONPATH=src python -m repro.profile --smoke

    # machine-readable roofline + Chrome trace + full metrics snapshot
    PYTHONPATH=src python -m repro.profile --smoke --json /tmp/roofline.json \
        --trace /tmp/trace.json --metrics-out /tmp/metrics.json

    # fault injection: corrupt the cached matmul schedules and watch the
    # model-fidelity gate route them into the miss log for retuning
    PYTHONPATH=src python -m repro.profile --smoke --corrupt matmul \
        --miss-log /tmp/miss.jsonl
    PYTHONPATH=src python -m repro.tune --from-telemetry /tmp/miss.jsonl \
        --dry-run

Runs the paged serving engine on the same serving-scale reduced config
the serve benchmark uses, with a :class:`repro.obs.KernelProfiler` in
the ledger slot and a step tracer always attached (the engines fence
every scope when a tracer is present, so scope wall clocks measure
device time).  Every dispatched kernel variant gets measured wall time,
exact HBM bytes from the kernels' own grid-transfer accounting, achieved
vs peak arithmetic intensity on the TPU v5e roofline, and modeled pJ.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
import time


# the --corrupt fault injector now lives with the rest of the chaos
# harness; re-exported here because docs and tests imported it from
# repro.profile since PR 9
from repro.chaos.inject import CorruptScheduleCache  # noqa: F401,E402


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="per-kernel roofline + energy profiler")
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized workload (same serving-scale model)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--fuse", action="store_true", default=True,
                    help="profile the cross-op fused hot path (default: "
                         "on — the fused kernels are the schedule-driven "
                         "paths the profiler exists to observe)")
    ap.add_argument("--no-fuse", dest="fuse", action="store_false")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the roofline/energy report as JSON")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="Chrome-trace path (a temp file is used when "
                         "absent: the tracer must be attached so scopes "
                         "are device-fenced)")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="write the full metrics snapshot (registry + "
                         "DRAM + roofline) as JSON")
    ap.add_argument("--miss-log", metavar="PATH", default=None,
                    help="append schedule-cache misses AND fidelity-"
                         "gate hits as JSONL tuning targets for "
                         "python -m repro.tune --from-telemetry")
    ap.add_argument("--fidelity-threshold", type=float, default=0.25,
                    help="measured/modeled DRAM ratio above 1+threshold "
                         "sends the op to the miss log for retuning")
    ap.add_argument("--corrupt", metavar="OP", default=None,
                    help="fault injection: serve cache hits with "
                         "pessimal (halved) tiles for ops whose name "
                         "contains OP, e.g. --corrupt matmul")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests, args.gen = 3, 6
        args.prompt_len, args.max_seq, args.max_batch = 8, 32, 3

    # force the Pallas kernel paths (interpret mode off-TPU): the point
    # is observing the schedules the kernels dispatch, not throughput
    os.environ.setdefault("REPRO_FORCE_KERNELS", "1")

    # imports after arg parsing: --help must not pull in jax
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import tune
    from repro.configs import get_reduced
    from repro.models import transformer as T
    from repro.models.sharding import set_axis_mapping
    from repro.obs import KernelProfiler, MetricsRegistry, Obs, StepTracer
    from repro.serve.engine import PagedEngine, PagedServeConfig

    prev_cache = None
    if args.corrupt:
        prev_cache = tune.set_default_cache(
            CorruptScheduleCache(args.corrupt))

    # the serve benchmark's serving-scale reduced model: per-step compute
    # must dominate host dispatch for roofline numbers to mean anything
    cfg = dataclasses.replace(get_reduced(args.arch), dtype=jnp.float32,
                              d_model=256, n_layers=4, n_heads=8,
                              n_kv_heads=4, d_ff=1024, vocab=4096)
    set_axis_mapping({"data": None, "model": None})
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    trace_path = args.trace
    tmp_trace = None
    if trace_path is None:
        tmp_trace = tempfile.NamedTemporaryFile(
            suffix=".trace.json", delete=False)
        tmp_trace.close()
        trace_path = tmp_trace.name
    registry = MetricsRegistry()
    tracer = StepTracer(trace_path)
    profiler = KernelProfiler(
        registry=registry, miss_log=args.miss_log,
        fidelity_threshold=args.fidelity_threshold, tracer=tracer)
    obs = Obs(registry=registry, trace=tracer, dram=profiler)

    engine = PagedEngine(cfg, params, PagedServeConfig(
        max_seq=args.max_seq, max_batch=args.max_batch,
        fuse=args.fuse), obs=obs)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (args.prompt_len,),
                            dtype=np.int32) for _ in range(args.requests)]
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.gen)
    wall = time.perf_counter() - t0

    rep = profiler.roofline_report()
    n_ops = len(rep["per_op"])
    print(f"profiled {args.requests} requests x {args.gen} tokens "
          f"in {wall:.2f}s: {n_ops} kernel variants, "
          f"{rep['totals']['dispatches']} dispatches, "
          f"{rep['totals']['hbm_bytes'] / 1e6:.1f} MB HBM, "
          f"{rep['totals']['energy_uj']:.1f} uJ modeled "
          f"(traced -> {trace_path})")
    print(profiler.format_roofline())
    if rep["fidelity_misses"]:
        print(f"fidelity gate (>{1 + args.fidelity_threshold:.2f}x "
              "modeled DRAM): "
              + ", ".join(rep["fidelity_misses"]))
        if args.miss_log:
            print(f"  -> appended to {args.miss_log} (replay: "
                  "python -m repro.tune --from-telemetry "
                  f"{args.miss_log})")

    if args.json:
        d = os.path.dirname(args.json)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(rep, f, indent=1)
            f.write("\n")
        print(f"roofline report -> {args.json}")
    if args.metrics_out:
        obs.write_metrics(args.metrics_out)
        print(f"metrics snapshot -> {args.metrics_out}")
    obs.close()
    if prev_cache is not None:
        tune.set_default_cache(prev_cache)
    assert out.shape[0] == args.requests, out.shape


if __name__ == "__main__":
    main()
