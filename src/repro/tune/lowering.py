"""Lowering from the analytical blocking model to Pallas kernel schedules.

This is the ``core -> kernels`` bridge the optimizer output flows through:

1. :func:`candidates` runs the paper's schedule search for the op's loop
   nest on the TPU hierarchy (via ``core.tpu_adapter``), snaps each winner
   to MXU alignment + the VMEM budget, and drops candidates the kernels
   cannot execute directly (tile sizes must divide the problem dims, or
   ``kernels.ops`` would take its oracle fallback);
2. :func:`schedule_to_string` maps a concrete tile tuple back onto the
   blocking string the kernel's grid actually executes, so
3. :func:`predicted_dram_accesses` can score any candidate with the exact
   per-level access counts of paper §3.4 — the analytic rank the
   measurement harness then refines.

Backward ops (``matmul_dgrad`` / ``conv2d_dgrad`` / ``conv2d_wgrad``)
flow through the same three steps: their nests share the forward
families' access geometry (the model counts element touches of the same
three operands; which one is written does not change the counts), so the
candidate search and scoring are reused with relabelled dims — see
``core.tpu_adapter.backward_tile_candidates`` and docs/training.md.

``flash_decode`` (the serving nest, docs/serving.md) is a skinny GEMM
whose reduction dim is the KV length; its single tile ``(block_kv,)`` is
both the kernel's KV block and the paged cache's page size — see
``core.tpu_adapter.flash_decode_tile_candidates``.

The quantized variants (``matmul_w8`` / ``flash_decode_fp8``,
docs/quantization.md) reuse the same nests with a 1-byte weight / KV
stream: their specs' ``problem()`` carries per-operand byte widths, so
the candidate search, the VMEM fit (each quantized kernel's own
footprint model) and :func:`predicted_dram_bytes` all see the narrow
operand, while dims/tiles keep the wide ops' conventions.
"""

from __future__ import annotations

from repro.core.hierarchy import MemLevel, cache_accesses
from repro.core.loopnest import BlockingString, Dim, Loop
from repro.core.tpu_adapter import (TPU_V5E, TpuTarget,
                                    backward_tile_candidates,
                                    conv_tile_candidates,
                                    default_vmem_budget,
                                    flash_decode_tile_candidates,
                                    matmul_tile_candidates)
from repro.tune.schedule import (ATTN_OPS, FUSED_OPS, GEMM_OPS,
                                 NARROW_WEIGHT_BYTES, OpSpec, Schedule)

# the one budget rule, shared with the snap loops in core.tpu_adapter
vmem_budget = default_vmem_budget


def fits_vmem(spec: OpSpec, tiles: tuple[int, ...], budget: int) -> bool:
    """Check a tile tuple against the kernel's own VMEM footprint model.

    Each kernel family owns its footprint accounting: the forward GEMM
    model also covers the NT/TN dgrad kernels (same streamed-operands +
    resident-accumulator layout), the forward conv model covers dgrad
    (which runs the forward kernel), and the wgrad kernel has its own
    (resident dW block, streamed input/cotangent tiles).
    """
    if spec.op == "matmul_w8":
        from repro.kernels.matmul_q import vmem_bytes_required
        bm, bk, bn = tiles
        return vmem_bytes_required(bm, bk, bn, spec.itemsize,
                                   NARROW_WEIGHT_BYTES[spec.op]) <= budget
    if spec.op == "matmul_fused":
        # fused VMEM filter: sized for the worst epilogue (bias + mul +
        # residual) so one cached schedule serves every combination
        from repro.kernels.matmul_fused import vmem_bytes_required
        bm, bk, bn = tiles
        return vmem_bytes_required(bm, bk, bn, spec.itemsize) <= budget
    if spec.op == "qkv_fused":
        from repro.kernels.qkv_fused import vmem_bytes_required
        _, _, _, G = spec.dims
        bm, bk, bn = tiles
        return vmem_bytes_required(bm, bk, bn, G,
                                   spec.itemsize) <= budget
    if spec.op == "flash_decode_oproj":
        from repro.kernels.flash_decode import oproj_vmem_bytes_required
        G, _, D, E = spec.dims
        (bkv,) = tiles
        return oproj_vmem_bytes_required(bkv, G, D, E,
                                         spec.itemsize) <= budget
    if spec.op in GEMM_OPS:
        from repro.kernels.matmul_blocked import vmem_bytes_required
        bm, bk, bn = tiles
        return vmem_bytes_required(bm, bk, bn, spec.itemsize) <= budget
    if spec.op in ATTN_OPS:
        # priced at q_span=1 (single-position decode); chunked prefill
        # re-prices the winning block with its span via
        # serve.kv_cache.choose_prefill_chunk
        from repro.kernels.flash_decode import vmem_bytes_required
        G, _, D = spec.dims
        (bkv,) = tiles
        return vmem_bytes_required(
            bkv, G, D, spec.itemsize,
            kv_bytes=NARROW_WEIGHT_BYTES.get(spec.op)) <= budget
    if spec.op == "conv2d_wgrad":
        from repro.kernels.conv2d_bwd import vmem_bytes_required
    else:
        from repro.kernels.conv2d_blocked import vmem_bytes_required
    bx, by, bc, bk = tiles
    _, _, _, _, Fw, Fh = spec.dims
    return vmem_bytes_required(bx, by, bc, bk, Fh, Fw, spec.itemsize,
                               spec.stride) <= budget


def divides(spec: OpSpec, tiles: tuple[int, ...]) -> bool:
    """True iff the kernels can run these tiles without a fallback path."""
    if spec.op in GEMM_OPS:
        M, N, K = spec.dims
        bm, bk, bn = tiles
        return M % bm == 0 and K % bk == 0 and N % bn == 0
    if spec.op == "qkv_fused":
        M, Nkv, K, _ = spec.dims
        bm, bk, bn = tiles
        return M % bm == 0 and K % bk == 0 and Nkv % bn == 0
    if spec.op == "flash_decode_oproj":
        _, S, _, _ = spec.dims
        (bkv,) = tiles
        return S % bkv == 0
    if spec.op in ATTN_OPS:
        _, S, _ = spec.dims
        (bkv,) = tiles
        return S % bkv == 0
    X, Y, C, K, _, _ = spec.dims
    bx, by, bc, bk = tiles
    # bc/bk divisibility is a hard kernel assert; bx/by divisibility avoids
    # the single-spatial-tile fallback in the level-1 host loops.
    return C % bc == 0 and K % bk == 0 and X % bx == 0 and Y % by == 0


def schedule_to_string(spec: OpSpec,
                       tiles: tuple[int, ...]) -> BlockingString:
    """The blocking string the Pallas kernels execute for these tiles.

    Loop order mirrors the kernels exactly (inner -> outer):

    * matmul / matmul_dgrad: level-0 (bk, bm, bn) VMEM block, then the
      grid (m, n, k) with k minor-most (the fp32 accumulator is the OB
      held across C);
    * conv2d / conv2d_dgrad: Fw/Fh window loops inside the block, the
      (bx, by, bc, bk) VMEM block, then the kernel grid (k, c) with c
      minor-most, then the spatial halo tiles the host slices (X inside
      Y);
    * conv2d_wgrad: the spatial tile is the *innermost* reduction (one
      whole (bx, by) tile dots into the resident dW block per Fw/Fh
      step), then the channel blocks, then the (k, c) grid, then the
      host's spatial reduction tiles.
    """
    p = spec.problem()
    loops: list[Loop] = []
    if spec.op in GEMM_OPS:
        M, N, K = spec.dims
        bm, bk, bn = tiles
        loops = [Loop(Dim.C, bk), Loop(Dim.X, bm), Loop(Dim.K, bn),
                 Loop(Dim.C, K), Loop(Dim.K, N), Loop(Dim.X, M)]
    elif spec.op == "qkv_fused":
        # one grid step touches (G+2)*bn columns of the joint output
        # from a single A tile — the GEMM string over the joint width
        M, Nkv, K, G = spec.dims
        bm, bk, bn = tiles
        cols = (G + 2) * Nkv
        loops = [Loop(Dim.C, bk), Loop(Dim.X, bm),
                 Loop(Dim.K, (G + 2) * bn),
                 Loop(Dim.C, K), Loop(Dim.K, cols), Loop(Dim.X, M)]
    elif spec.op == "flash_decode_oproj":
        # the decode nest proper.  The fused projection's wo traffic is
        # independent of the KV block, so it cannot change the rank and
        # is deliberately absent here — E enters the schedule choice
        # only through the VMEM filter (the resident wo slab squeezes
        # the budget); the kernel's exact traffic lives in
        # flash_decode.oproj_hbm_bytes (benchmarked, not ranked)
        G, S, D, _ = spec.dims
        (bkv,) = tiles
        loops = [Loop(Dim.C, bkv), Loop(Dim.X, G), Loop(Dim.K, D),
                 Loop(Dim.C, S)]
    elif spec.op in ATTN_OPS:
        # one query block (all G rows, all D cols) resident; the grid
        # streams KV pages of block_kv — the running (m, l, acc) state is
        # the OB held across the whole C (KV) reduction.
        G, S, D = spec.dims
        (bkv,) = tiles
        loops = [Loop(Dim.C, bkv), Loop(Dim.X, G), Loop(Dim.K, D),
                 Loop(Dim.C, S)]
    elif spec.op == "conv2d_wgrad":
        X, Y, C, K, Fw, Fh = spec.dims
        bx, by, bc, bk = tiles
        loops = [Loop(Dim.X, bx), Loop(Dim.Y, by)]
        if Fw > 1:
            loops.append(Loop(Dim.FW, Fw))
        if Fh > 1:
            loops.append(Loop(Dim.FH, Fh))
        loops += [Loop(Dim.C, bc), Loop(Dim.K, bk),
                  Loop(Dim.C, C), Loop(Dim.K, K),
                  Loop(Dim.X, X), Loop(Dim.Y, Y)]
        return BlockingString(loops, p)
    else:
        X, Y, C, K, Fw, Fh = spec.dims
        bx, by, bc, bk = tiles
        if Fw > 1:
            loops.append(Loop(Dim.FW, Fw))
        if Fh > 1:
            loops.append(Loop(Dim.FH, Fh))
        loops += [Loop(Dim.X, bx), Loop(Dim.Y, by),
                  Loop(Dim.C, bc), Loop(Dim.K, bk),
                  Loop(Dim.C, C), Loop(Dim.K, K),
                  Loop(Dim.X, X), Loop(Dim.Y, Y)]
    return BlockingString(loops, p)


def predicted_dram_accesses(spec: OpSpec, tiles: tuple[int, ...],
                            vmem_budget_bytes: int | None = None,
                            target: TpuTarget = TPU_V5E) -> int:
    """HBM-boundary accesses (elements) of this schedule under the paper's
    access model with a VMEM-sized on-chip level (working sets that
    overflow the budget spill, exactly like the Fig. 3/4 methodology)."""
    if not divides(spec, tiles):
        raise ValueError(
            f"tiles {tiles} do not divide {spec.op} dims {spec.dims}; "
            "the kernels would take their oracle fallback, which the "
            "blocking model cannot score")
    budget = vmem_budget(target, vmem_budget_bytes)
    levels = [MemLevel.sram("VMEM", budget), MemLevel.dram("HBM")]
    s = schedule_to_string(spec, tiles)
    return cache_accesses(s, levels)[levels[-1].name]


def predicted_dram_bytes(spec: OpSpec, tiles: tuple[int, ...],
                         vmem_budget_bytes: int | None = None,
                         target: TpuTarget = TPU_V5E) -> int:
    """HBM-boundary traffic in BYTES, weighting each operand's accesses
    by its own element width (``core.buffers.operand_bytes``).

    Element *counts* are dtype-invariant — :func:`predicted_dram_accesses`
    reports the same number for a bf16 and an int8 weight stream — so
    this is the quantity that shows what quantization buys: the same
    schedule moves half (or a quarter) of the bytes.  Shares the exact
    placement walk of the access-count rank (``core.hierarchy.
    cache_accesses`` with per-operand byte weights), so the two ranks
    cannot disagree about the miss-path rules.
    """
    if not divides(spec, tiles):
        raise ValueError(
            f"tiles {tiles} do not divide {spec.op} dims {spec.dims}")
    from repro.core.buffers import Operand, operand_bytes
    budget = vmem_budget(target, vmem_budget_bytes)
    levels = [MemLevel.sram("VMEM", budget), MemLevel.dram("HBM")]
    s = schedule_to_string(spec, tiles)
    weights = {op: operand_bytes(s.problem, op) for op in Operand}
    return cache_accesses(s, levels,
                          operand_weights=weights)[levels[-1].name]


def _operand_level0_traffic(s: BlockingString, op, footprint: int) -> int:
    """Parent-side traffic (elements) of the outermost model buffer that
    fits the kernel's level-0 tile footprint for this operand.

    This is where the model and the kernel meet: a Pallas kernel holds
    exactly one level-0 block per operand in VMEM, so the DRAM-boundary
    traffic it generates is the fills+writebacks of the *largest* model
    buffer no bigger than that block — including the degenerate pos=-1
    register when no placed buffer fits (a streamed operand with no
    reuse), whose parent traffic is the full compulsory stream.
    """
    from repro.core.access import analyze
    from repro.core.buffers import buffers_by_operand, place_buffers
    rep = analyze(s)
    chain = buffers_by_operand(place_buffers(s))[op]     # inner -> outer
    fitting = [b for b in chain if b.size_elems <= footprint]
    pick = fitting[-1]
    for bt in rep.per_buffer:
        if bt.buffer.name == pick.name and bt.buffer.operand is op:
            return bt.parent_traffic
    raise KeyError(pick.name)


def _level0_footprints(s: BlockingString) -> dict:
    """Level-0 tile footprint (elements) per operand, read off the
    innermost extent of each dim in the blocking string."""
    from repro.core.buffers import OPERAND_DIMS, Operand
    inner: dict[Dim, int] = {}
    for loop in s.loops:
        inner.setdefault(loop.dim, loop.extent)
    out = {}
    for op in Operand:
        fp = 1
        for d in OPERAND_DIMS[op]:
            fp *= inner.get(d, 1)
        out[op] = fp
    return out


def level0_dram_bytes(spec: OpSpec, tiles: tuple[int, ...]) -> int:
    """The blocking model's level-0 HBM traffic (bytes) for the exact
    nest(s) the kernel executes with ``tiles`` — no finite-VMEM packing,
    no spill: per operand, the parent traffic of the outermost placed
    buffer that fits the kernel's level-0 block.

    This is the model-side half of the kernel-vs-model byte-agreement
    property (``tests/test_profile.py``): on exact-divisor shapes it
    equals the kernels' exported ``hbm_bytes`` bit for bit, because both
    count the same thing — the Pallas grid's block transfers under DMA
    elision.  Covers the GEMM family (incl. the fused/quantized
    variants' base streams) and ``flash_decode``; the conv nests carry
    halo refetch terms the kernels account for directly.
    """
    from repro.core.buffers import Operand, operand_bytes
    if not divides(spec, tiles):
        raise ValueError(
            f"tiles {tiles} do not divide {spec.op} dims {spec.dims}")
    if spec.op in ATTN_OPS:
        return _flash_decode_level0_bytes(spec, tiles)
    if spec.op not in GEMM_OPS and spec.op != "qkv_fused":
        raise ValueError(
            f"level0_dram_bytes covers the GEMM family and flash_decode, "
            f"not {spec.op!r}")
    s = schedule_to_string(spec, tiles)
    fps = _level0_footprints(s)
    return sum(_operand_level0_traffic(s, op, fps[op])
               * operand_bytes(s.problem, op) for op in Operand)


def _flash_decode_level0_bytes(spec: OpSpec, tiles: tuple[int, ...]) -> int:
    """Two-nest decomposition of the decode-attention kernel.

    The single-GEMM stand-in the tuner ranks with (INPUT = the G x S
    score matrix) cannot describe the kernel's real streams — the score
    block lives only in VMEM.  The kernel is two chained GEMMs sharing
    the KV block loop: ``scores = q @ K^T`` (count q and K; the score
    output is the VMEM intermediate) and ``out = P @ V`` (count V and
    the output; P is the same intermediate).  Per (batch, kv-head) row;
    scalar-prefetch block tables/lengths are excluded, matching the
    kernel's ``hbm_bytes``.
    """
    from repro.core.buffers import Operand, operand_bytes
    from repro.core.loopnest import Problem
    G, S, D = spec.dims
    (bkv,) = tiles
    kvb = NARROW_WEIGHT_BYTES.get(spec.op)
    p1 = Problem.gemm(M=G, N_cols=S, K_reduce=D,
                      bytes_per_elem=spec.itemsize, weight_bytes=kvb)
    s1 = BlockingString([Loop(Dim.C, D), Loop(Dim.X, G), Loop(Dim.K, bkv),
                         Loop(Dim.C, D), Loop(Dim.K, S), Loop(Dim.X, G)],
                        p1)
    p2 = Problem.gemm(M=G, N_cols=D, K_reduce=S,
                      bytes_per_elem=spec.itemsize, weight_bytes=kvb)
    s2 = BlockingString([Loop(Dim.C, bkv), Loop(Dim.X, G), Loop(Dim.K, D),
                         Loop(Dim.C, S), Loop(Dim.K, D), Loop(Dim.X, G)],
                        p2)
    total = 0
    for s, counted in ((s1, (Operand.INPUT, Operand.WEIGHT)),
                       (s2, (Operand.WEIGHT, Operand.OUTPUT))):
        fps = _level0_footprints(s)
        for op in counted:
            total += _operand_level0_traffic(s, op, fps[op]) \
                * operand_bytes(s.problem, op)
    if spec.op == "flash_decode_fp8":
        total += 2 * 4        # per-head dequant scale scalars, one row
    return total


def candidates(spec: OpSpec,
               vmem_budget_bytes: int | None = None,
               target: TpuTarget = TPU_V5E,
               top: int = 8) -> list[Schedule]:
    """Analytically-ranked kernel schedules for one op instance.

    Always returns at least one schedule.  When no snapped candidate
    divides the problem cleanly the top raw candidate is returned anyway
    (``kernels.ops`` will take its oracle fallback for it), with
    ``predicted_dram_accesses`` left unset.
    """
    budget = vmem_budget(target, vmem_budget_bytes)
    if spec.op in ("matmul", "matmul_w8", "matmul_fused"):
        M, N, K = spec.dims
        raw = matmul_tile_candidates(
            M, N, K, spec.itemsize, budget, target, top=top,
            weight_bytes=NARROW_WEIGHT_BYTES.get(spec.op))
    elif spec.op == "qkv_fused":
        # search the joint nest (one A stream, (G+2)*Nkv columns), then
        # express the winner's bn in per-projection columns, snapped to
        # a lane-aligned divisor of Nkv (integer division by G+2 would
        # silently drop the MXU alignment every other GEMM candidate
        # carries); the fused VMEM filter rejects what the joint
        # residents overflow
        from repro.core.loopnest import divisors
        M, Nkv, K, G = spec.dims
        joint = matmul_tile_candidates(M, (G + 2) * Nkv, K,
                                       spec.itemsize, budget, target,
                                       top=top)

        def per_projection(bn_joint: int) -> int:
            cap = max(bn_joint // (G + 2), 1)
            aligned = [d for d in divisors(Nkv)
                       if d <= cap and d % min(target.lane, Nkv) == 0]
            if aligned:
                return max(aligned)
            return max(d for d in divisors(Nkv) if d <= cap)

        raw = []
        for bm, bk, bn in joint:
            cand = (bm, bk, per_projection(bn))
            if cand not in raw:
                raw.append(cand)
        raw.append((min(M, 256), min(K, 512), min(Nkv, 128)))
    elif spec.op in ("flash_decode", "flash_decode_fp8"):
        G, S, D = spec.dims
        raw = flash_decode_tile_candidates(
            G, S, D, spec.itemsize, budget, target, top=top,
            kv_bytes=NARROW_WEIGHT_BYTES.get(spec.op))
    elif spec.op == "flash_decode_oproj":
        # same candidate family as flash_decode; ONLY the fusion delta
        # (wo slab + output accumulator) squeezes the budget — the base
        # decode residents are already accounted for inside the
        # flash_decode candidate search
        from repro.kernels.flash_decode import (oproj_vmem_bytes_required,
                                                vmem_bytes_required)
        G, S, D, E = spec.dims
        oproj_extra = (oproj_vmem_bytes_required(0, G, D, E, spec.itemsize)
                       - vmem_bytes_required(0, G, D, spec.itemsize))
        raw = flash_decode_tile_candidates(
            G, S, D, spec.itemsize, max(budget - oproj_extra, 1),
            target, top=top)
    elif spec.op == "conv2d":
        X, Y, C, K, Fw, Fh = spec.dims
        raw = conv_tile_candidates(X, Y, C, K, Fw, Fh, spec.itemsize,
                                   budget, target, top=top,
                                   stride=spec.stride)
    else:
        raw = backward_tile_candidates(spec.op, spec.dims, spec.itemsize,
                                       budget, target, top=top,
                                       stride=spec.stride)
    usable = [t for t in raw
              if divides(spec, t) and fits_vmem(spec, t, budget)]
    if not usable:
        return [Schedule(spec, raw[0], source="analytic")]
    scored = [Schedule(spec, t, source="analytic",
                       predicted_dram_accesses=predicted_dram_accesses(
                           spec, t, budget, target))
              for t in usable]
    # fewest predicted DRAM accesses first; break ties toward bigger
    # blocks (fewer grid steps -> less pipeline overhead) — EXCEPT for
    # flash_decode, where the KV stream touches every element once at any
    # block size (the model ties) and the tile doubles as the paged
    # cache's allocation granule: smaller pages waste fewer slots per
    # request and admit under a finer free-block budget.  The FUSED ops
    # rank byte-weighted (predicted_dram_bytes): their epilogue/joint
    # operands can carry different widths, and bytes — not element
    # counts — are what fusion eliminates.
    def tile_product(s: Schedule) -> int:
        prod = 1
        for t in s.tiles:
            prod *= t
        return prod
    page_like = spec.op in ATTN_OPS or spec.op == "flash_decode_oproj"
    sign = 1 if page_like else -1
    if spec.op in FUSED_OPS:
        scored.sort(key=lambda s: (predicted_dram_bytes(
            spec, s.tiles, budget, target), sign * tile_product(s)))
    else:
        scored.sort(key=lambda s: (s.predicted_dram_accesses,
                                   sign * tile_product(s)))
    return scored[:top]
