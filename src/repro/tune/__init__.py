"""Schedule autotuner: the paper's blocking optimizer driving the kernels.

The analytical model (``repro.core``) derives candidate blockings; this
package lowers them to concrete Pallas tile tuples, optionally times the
top few on the actual backend, and persists winners in a JSON cache so
every later process — including the default paths of ``kernels.ops`` —
gets tuned tiles for free.

Entry points:

* :func:`best_schedule` — cheap, never measures: cached schedule if one
  exists for this (op, shapes, dtype, device), else the analytic winner.
  This is what ``kernels.ops`` consults on every call with ``tiles=None``.
* :func:`tune_op` — the full loop: rank candidates analytically, time the
  top-N, persist the winner.  Run offline (``python -m repro.tune ...``)
  to pre-populate the cache; see ``docs/tuning.md``.
"""

from __future__ import annotations

import functools

from repro.core.tpu_adapter import TPU_V5E, TpuTarget
from repro.tune.cache import ScheduleCache, default_cache_path, device_kind
from repro.tune.lowering import (candidates, divides, fits_vmem,
                                 level0_dram_bytes,
                                 predicted_dram_accesses,
                                 predicted_dram_bytes,
                                 schedule_to_string, vmem_budget)
from repro.tune.schedule import OpSpec, Schedule

__all__ = [
    "OpSpec", "Schedule", "ScheduleCache", "best_schedule", "candidates",
    "default_cache_path", "describe_candidates", "device_kind",
    "level0_dram_bytes",
    "predicted_dram_accesses", "predicted_dram_bytes",
    "schedule_to_string", "set_default_cache", "set_schedule_observer",
    "tune_op",
]

_default_cache = ScheduleCache()


def set_default_cache(cache: ScheduleCache) -> ScheduleCache:
    """Swap the process-wide schedule cache; returns the previous one.

    The profiler's ``--corrupt`` fault injection uses this to plant a
    deliberately bad cached schedule and watch the fidelity gate catch
    it; tests use it to isolate cache state.  Also drops the analytic
    memo so the swap is visible to ops already traced once.
    """
    global _default_cache
    prev = _default_cache
    _default_cache = cache
    _derive.cache_clear()
    return prev

# Telemetry tap (repro.obs): one process-wide callable notified of every
# best_schedule resolution with ``(spec, schedule)``.  The observer runs
# at jit TRACE time — it must be cheap and must not call back into
# best_schedule.  ``None`` (the default) costs one comparison.
_SCHEDULE_OBSERVER = None


def set_schedule_observer(fn):
    """Install ``fn(spec, schedule)`` as the resolution observer;
    returns the previous observer (``None`` to uninstall)."""
    global _SCHEDULE_OBSERVER
    prev = _SCHEDULE_OBSERVER
    _SCHEDULE_OBSERVER = fn
    return prev


def describe_candidates(spec: OpSpec, **kwargs) -> str:
    """Human-readable ranked candidate table (CLI / example output)."""
    lines = []
    for i, s in enumerate(candidates(spec, **kwargs)):
        acc = (f"{s.predicted_dram_accesses:.3e}"
               if s.predicted_dram_accesses is not None else "n/a")
        lines.append(f"  #{i}: tiles={s.tiles}  "
                     f"predicted DRAM accesses={acc}")
    return "\n".join(lines)


@functools.lru_cache(maxsize=1024)
def _derive(spec: OpSpec, vmem_budget_bytes: int | None,
            target: TpuTarget) -> Schedule:
    return candidates(spec, vmem_budget_bytes, target)[0]


def best_schedule(op: str, dims: tuple[int, ...], dtype: str = "float32",
                  stride: int = 1,
                  cache: ScheduleCache | None = None,
                  vmem_budget_bytes: int | None = None,
                  target: TpuTarget = TPU_V5E) -> Schedule:
    """Cached-or-derived schedule for one op instance (never measures).

    ``dims`` is ``(M, N, K)`` for the GEMM ops (``"matmul"``,
    ``"matmul_dgrad"``) or output-space ``(X, Y, C, K, Fw, Fh)`` for the
    conv ops (``"conv2d"``, ``"conv2d_dgrad"``, ``"conv2d_wgrad"``) —
    see ``repro.tune.schedule`` for the backward dim conventions.  A
    cache hit (same op,
    shapes, dtype and device kind) wins outright; otherwise the analytic
    top candidate is derived in-process (memoized, not persisted — run
    :func:`tune_op` to measure and persist).
    """
    spec = OpSpec(op, tuple(dims), dtype, stride)
    hit = (cache or _default_cache).lookup(spec)
    if hit is not None and hit.spec == spec and (
            vmem_budget_bytes is None or
            fits_vmem(spec, hit.tiles,
                      vmem_budget(target, vmem_budget_bytes))):
        result = hit
    else:
        result = _derive(spec, vmem_budget_bytes, target)
    obs = _SCHEDULE_OBSERVER
    if obs is not None:
        obs(spec, result)
    return result


def tune_op(op: str, dims: tuple[int, ...], dtype: str = "float32",
            stride: int = 1,
            top_n: int = 3,
            measure: bool = True,
            interpret: bool | None = None,
            cache: ScheduleCache | None = None,
            persist: bool = True,
            vmem_budget_bytes: int | None = None,
            target: TpuTarget = TPU_V5E) -> Schedule:
    """Full tuning loop for one op instance; returns the winner.

    Candidates are ranked by the paper's predicted DRAM accesses; with
    ``measure=True`` the top ``top_n`` are also timed end-to-end (Pallas
    ``interpret=True`` off-TPU) and the fastest wins.  With
    ``persist=True`` the winner lands in the schedule cache under the
    current device kind, where :func:`best_schedule` — and therefore the
    default paths of ``kernels.ops`` — will find it.
    """
    from repro.tune import measure as measure_mod  # lazy: pulls in jax

    spec = OpSpec(op, tuple(dims), dtype, stride)
    ranked = candidates(spec, vmem_budget_bytes, target)
    # only time schedules the kernels can actually run: for non-dividing
    # tiles ops takes its oracle fallback, and timing the oracle would
    # persist a latency the kernel never achieved
    if measure and all(divides(spec, s.tiles) for s in ranked[:top_n]):
        ranked = measure_mod.measure_top(ranked, top_n=top_n,
                                         interpret=interpret)
    winner = ranked[0]
    if persist:
        (cache or _default_cache).store(winner)
    return winner
