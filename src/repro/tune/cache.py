"""Persistent JSON schedule cache.

One file holds every tuned schedule, keyed by
``op/shape/dtype/device-kind`` (see :meth:`repro.tune.schedule.OpSpec.key`).
The default location is ``$REPRO_TUNE_CACHE`` if set, else
``~/.cache/repro/schedules.json``; pass an explicit path to keep per-project
caches (e.g. one checked into a deployment repo and pre-populated offline
with ``python -m repro.tune``).

File format (version 1)::

    {"version": 1,
     "schedules": {"matmul/m4096n4096k4096/bfloat16/tpu": {...Schedule...}}}

Writes are read-modify-write through an adjacent temp file + ``os.replace``
so concurrent tuners cannot truncate each other's entries.
"""

from __future__ import annotations

import json
import os
import tempfile
import warnings

from repro.tune.schedule import OpSpec, Schedule

SCHEMA_VERSION = 1


def default_cache_path() -> str:
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "schedules.json")


def device_kind() -> str:
    """Backend tag used in cache keys; interpret-mode results are tagged
    ``cpu`` so they never masquerade as real-device timings."""
    try:
        import jax
        return jax.default_backend()
    except Exception:
        return "cpu"


class ScheduleCache:
    """Dict-of-Schedules with lazy load and atomic persistence."""

    def __init__(self, path: str | None = None):
        self.path = path or default_cache_path()
        self._loaded: dict[str, Schedule] | None = None

    # -- IO -------------------------------------------------------------------

    def _quarantine(self, why: str) -> None:
        """Move the unreadable file aside to ``<path>.corrupt`` so the
        next flush rebuilds a clean cache without destroying the
        evidence (a second corrupt file overwrites the first — the
        newest specimen is the one worth inspecting)."""
        quarantined = self.path + ".corrupt"
        try:
            os.replace(self.path, quarantined)
        except OSError:
            return              # raced away or unwritable dir: nothing to do
        warnings.warn(
            f"schedule cache {self.path} is corrupt ({why}); quarantined "
            f"to {quarantined} and rebuilding — retune with "
            f"`python -m repro.tune` to repopulate")

    def _read_file(self) -> dict[str, Schedule]:
        try:
            with open(self.path) as f:
                raw = json.load(f)
        except OSError:
            return {}           # no cache yet: cold start, not corruption
        except json.JSONDecodeError as e:
            self._quarantine(f"invalid JSON: {e}")
            return {}
        if not isinstance(raw, dict):
            self._quarantine(f"expected an object, got {type(raw).__name__}")
            return {}
        if raw.get("version") != SCHEMA_VERSION:
            return {}
        out: dict[str, Schedule] = {}
        for key, entry in raw.get("schedules", {}).items():
            try:
                # keep on-disk provenance (measured/analytic) intact;
                # lookup() tags what it hands out as "cache"
                out[key] = Schedule.from_json(entry)
            except (KeyError, ValueError, TypeError):
                continue  # skip corrupt entries, keep the rest usable
        return out

    def _entries(self) -> dict[str, Schedule]:
        if self._loaded is None:
            self._loaded = self._read_file()
        return self._loaded

    def _flush(self, entries: dict[str, Schedule]) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(self.path)),
                    exist_ok=True)
        payload = {"version": SCHEMA_VERSION,
                   "schedules": {k: s.to_json()
                                 for k, s in sorted(entries.items())}}
        fd, tmp = tempfile.mkstemp(
            dir=os.path.dirname(os.path.abspath(self.path)),
            suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- API ------------------------------------------------------------------

    def lookup(self, spec: OpSpec, device: str | None = None
               ) -> Schedule | None:
        hit = self._entries().get(spec.key(device or device_kind()))
        return hit.with_source("cache") if hit is not None else None

    def store(self, schedule: Schedule, device: str | None = None) -> str:
        """Persist (merging with whatever is on disk) and return the key."""
        key = schedule.spec.key(device or device_kind())
        entries = self._read_file()   # re-read: merge concurrent writers
        entries[key] = schedule
        self._flush(entries)
        self._loaded = entries
        return key

    def keys(self) -> list[str]:
        return sorted(self._entries())

    def invalidate(self) -> None:
        """Drop the in-memory view (next lookup re-reads the file)."""
        self._loaded = None
