"""Schedule data model for the autotuner.

An :class:`OpSpec` names one tunable operator instance — the op kind plus
the problem dimensions the kernels see:

* ``matmul``: ``dims = (M, N, K)`` for ``C[M,N] = A[M,K] @ B[K,N]``;
* ``conv2d``: ``dims = (X, Y, C, K, Fw, Fh)`` in the paper's output-space
  coordinates (X = output width, Y = output height), plus ``stride``.

Backward nests are ops of the same two families — the paper's blocking
analysis does not care which operand of the loop nest is written:

* ``matmul_dgrad``: a GEMM; ``dims = (M, N, K)`` of the *cotangent*
  output being produced (dA: ``(M, K_fwd, N_fwd)``; dB: ``(K_fwd,
  N_fwd, M_fwd)``), tiles in the usual (bm, bk, bn) roles;
* ``conv2d_dgrad``: the transposed conv as a direct conv — dims in *its*
  output space with channels swapped (``(W, H, K_fwd, C_fwd, Fw, Fh)``,
  stride 1 after host-side input dilation);
* ``conv2d_wgrad``: the forward conv's dims verbatim; the (bx, by)
  tiles block the spatial *reduction*, (bc, bk) the channel dims.

Serving adds one more memory-bound nest:

* ``flash_decode``: ``dims = (G, S, D)`` — per (batch, kv-head) decode
  attention where G query heads (the GQA group) stream over an S-long
  paged KV cache of head dim D.  The single tile is ``(block_kv,)``:
  the KV block of the flash-decode kernel AND the page size of the
  paged cache (``serve/kv_cache.py``), so the analytical model fixes
  both at once.  The same key also prices the *chunked-prefill span*:
  the kernel's VMEM model takes a ``q_span`` multiplier (q/output tiles
  scale with the span, the streamed KV block does not), and
  ``serve.kv_cache.choose_prefill_chunk`` grows the span in whole
  pages until the model says the q block stops fitting — page size and
  chunk size are two reads of one schedule.

Quantization adds dtype-aware variants of the two serving-critical
nests (docs/quantization.md).  Their SHAPE dims match the wide ops, but
their ``problem()`` carries per-operand byte widths, so the blocking
search sizes tiles against the narrow stream and the schedules land
under their own cache keys:

* ``matmul_w8``: ``dims = (M, N, K)``; the weight operand is int8
  (1 byte), activations/outputs at ``dtype``'s width — w8a16/w8a32;
* ``flash_decode_fp8``: ``dims = (G, S, D)``; the streamed K/V pages
  are fp8 (1 byte) while q and the fp32 running state keep ``dtype``.
  Its ``(block_kv,)`` is the FP8 page pool's page size.

A :class:`Schedule` is a concrete kernel configuration for that spec: the
Pallas tile tuple (``(bm, bk, bn)`` or ``(bx, by, bc, bk)``), where it came
from (``analytic`` / ``measured`` / ``cache`` / ``override``), the model's
predicted DRAM-boundary accesses, and — when timed — the measured latency.
Both serialize losslessly to the JSON dicts the schedule cache stores.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.loopnest import Problem

GEMM_OPS = ("matmul", "matmul_dgrad", "matmul_w8", "matmul_fused")
CONV_OPS = ("conv2d", "conv2d_dgrad", "conv2d_wgrad")
ATTN_OPS = ("flash_decode", "flash_decode_fp8")
# cross-op fusion (docs/fusion.md): kernels whose output tile absorbs
# the next op's work instead of round-tripping through HBM.
#
# * ``matmul_fused``: GEMM + bias/activation/mul/residual epilogue;
#   ``dims = (M, N, K)`` like any GEMM (the epilogue operands stream
#   (bm, bn) tiles — only the VMEM filter differs);
# * ``qkv_fused``: one weight-stationary pass over all three attention
#   projections; ``dims = (M, Nkv, K, G)`` where Nkv is the PER-
#   PROJECTION k/v width and G = Hq/Hkv (the q projection is G*Nkv
#   wide); tiles (bm, bk, bn) block Nkv, each grid step touching
#   (G+2)*bn output columns from ONE activation tile;
# * ``flash_decode_oproj``: flash-decode with the output projection's
#   row tile fused in; ``dims = (G, S, D, E)`` (E = d_model); the single
#   ``(block_kv,)`` tile is still the KV block AND the paged cache's
#   page size — a fusion-enabled cache sizes its pages under THIS key
#   because the resident wo slab + (1, E) accumulator squeeze the
#   VMEM budget the block competes for.
FUSED_OPS = ("matmul_fused", "qkv_fused", "flash_decode_oproj")
OPS = GEMM_OPS + CONV_OPS + ATTN_OPS + tuple(
    op for op in FUSED_OPS if op not in GEMM_OPS)
# quantized ops: the narrow operand (weights / KV pages) is 1 byte wide
# regardless of the spec's activation dtype
NARROW_WEIGHT_BYTES = {"matmul_w8": 1, "flash_decode_fp8": 1}
TILE_RANK = {op: (3 if op in GEMM_OPS else 4) for op in GEMM_OPS + CONV_OPS}
# flash_decode tunes ONE size: the KV block — which is also the paged
# cache's page size (serve/kv_cache.py), so cache layout and kernel
# schedule cannot disagree.  Same contract for the fp8 variant, under
# its own key (the fp8-aware search typically picks larger pages).
TILE_RANK["flash_decode"] = 1
TILE_RANK["flash_decode_fp8"] = 1
TILE_RANK["qkv_fused"] = 3
TILE_RANK["flash_decode_oproj"] = 1
# dims arity per op family (OpSpec validation)
_N_DIMS = {**{op: 3 for op in GEMM_OPS + ATTN_OPS},
           **{op: 6 for op in CONV_OPS},
           "qkv_fused": 4, "flash_decode_oproj": 4}


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One tunable operator instance (the cache-key identity)."""

    op: str
    dims: tuple[int, ...]
    dtype: str = "float32"
    stride: int = 1

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"unknown op {self.op!r}; expected one of {OPS}")
        want = _N_DIMS[self.op]
        if len(self.dims) != want:
            raise ValueError(
                f"{self.op} expects {want} dims, got {self.dims}")
        if any(d < 1 for d in self.dims) or self.stride < 1:
            raise ValueError(
                f"dims and stride must be >= 1, got dims={self.dims} "
                f"stride={self.stride}")
        object.__setattr__(self, "dims", tuple(int(d) for d in self.dims))

    @property
    def itemsize(self) -> int:
        try:
            return int(np.dtype(self.dtype).itemsize)
        except TypeError:
            # bfloat16 & friends live in ml_dtypes (a jax dependency)
            import ml_dtypes
            return int(np.dtype(getattr(ml_dtypes, self.dtype)).itemsize)

    def problem(self) -> Problem:
        """The spec as the paper's loop-nest Problem.

        Quantized ops carry per-operand widths: the weight operand of
        the GEMM nest (which is also the streamed K/V of the decode
        nest — see ``tune.lowering``) narrows to 1 byte, so the access
        model counts its traffic and sizes its buffers accordingly.
        """
        wb = NARROW_WEIGHT_BYTES.get(self.op)
        if self.op in GEMM_OPS:
            M, N, K = self.dims
            return Problem.gemm(M=M, N_cols=N, K_reduce=K,
                                bytes_per_elem=self.itemsize,
                                weight_bytes=wb)
        if self.op == "qkv_fused":
            # the joint nest: one activation stream feeding all
            # (G+2)*Nkv output columns (docs/fusion.md)
            M, Nkv, K, G = self.dims
            return Problem.gemm(M=M, N_cols=(G + 2) * Nkv, K_reduce=K,
                                bytes_per_elem=self.itemsize)
        if self.op == "flash_decode_oproj":
            # the KV stream dominates; the fused projection only squeezes
            # the VMEM budget (the candidate filter sees E, this doesn't)
            G, S, D, _ = self.dims
            return Problem.gemm(M=G, N_cols=D, K_reduce=S,
                                bytes_per_elem=self.itemsize)
        if self.op in ATTN_OPS:
            # decode attention per (batch, kv-head): the G query rows
            # stream over the S-long KV cache producing D outputs — a
            # skinny GEMM whose reduction dim (C in the paper's nest)
            # is the KV length being blocked.
            G, S, D = self.dims
            return Problem.gemm(M=G, N_cols=D, K_reduce=S,
                                bytes_per_elem=self.itemsize,
                                weight_bytes=wb)
        X, Y, C, K, Fw, Fh = self.dims
        return Problem(X=X, Y=Y, C=C, K=K, Fw=Fw, Fh=Fh,
                       stride=self.stride, bytes_per_elem=self.itemsize)

    def key(self, device_kind: str) -> str:
        """Stable cache key: ``op/dims/dtype/device``."""
        if self.op in GEMM_OPS:
            M, N, K = self.dims
            shape = f"m{M}n{N}k{K}"
        elif self.op == "qkv_fused":
            M, Nkv, K, G = self.dims
            shape = f"m{M}n{Nkv}k{K}g{G}"
        elif self.op == "flash_decode_oproj":
            G, S, D, E = self.dims
            shape = f"g{G}s{S}d{D}e{E}"
        elif self.op in ATTN_OPS:
            G, S, D = self.dims
            shape = f"g{G}s{S}d{D}"
        else:
            X, Y, C, K, Fw, Fh = self.dims
            shape = f"x{X}y{Y}c{C}k{K}f{Fw}x{Fh}s{self.stride}"
        return f"{self.op}/{shape}/{self.dtype}/{device_kind}"


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A concrete kernel schedule for one OpSpec."""

    spec: OpSpec
    tiles: tuple[int, ...]
    source: str = "analytic"
    predicted_dram_accesses: int | None = None
    measured_us: float | None = None

    def __post_init__(self):
        object.__setattr__(self, "tiles", tuple(int(t) for t in self.tiles))
        if len(self.tiles) != TILE_RANK[self.spec.op]:
            raise ValueError(
                f"{self.spec.op} schedule needs {TILE_RANK[self.spec.op]} "
                f"tile sizes, got {self.tiles}")

    def with_source(self, source: str) -> "Schedule":
        return dataclasses.replace(self, source=source)

    def to_json(self) -> dict:
        return {
            "op": self.spec.op,
            "dims": list(self.spec.dims),
            "dtype": self.spec.dtype,
            "stride": self.spec.stride,
            "tiles": list(self.tiles),
            "source": self.source,
            "predicted_dram_accesses": self.predicted_dram_accesses,
            "measured_us": self.measured_us,
        }

    @classmethod
    def from_json(cls, d: dict) -> "Schedule":
        spec = OpSpec(op=d["op"], dims=tuple(d["dims"]),
                      dtype=d.get("dtype", "float32"),
                      stride=int(d.get("stride", 1)))
        return cls(spec=spec, tiles=tuple(d["tiles"]),
                   source=d.get("source", "cache"),
                   predicted_dram_accesses=d.get("predicted_dram_accesses"),
                   measured_us=d.get("measured_us"))
