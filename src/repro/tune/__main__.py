"""Offline schedule tuning CLI — pre-populate the schedule cache.

    PYTHONPATH=src python -m repro.tune matmul 4096 4096 4096
    PYTHONPATH=src python -m repro.tune conv2d 56 56 128 256 3 3 \\
        --dtype bfloat16 --stride 1 --cache experiments/schedules.json
    PYTHONPATH=src python -m repro.tune --from-telemetry miss.jsonl

Prints the analytic candidate table, times the top-N (on device, or in
Pallas interpret mode off-TPU unless ``--no-measure``), and persists the
winner.  ``kernels.ops`` reads the *default* cache location
(``$REPRO_TUNE_CACHE``, else ``~/.cache/repro/schedules.json``) — when
tuning into a ``--cache`` override, point ``REPRO_TUNE_CACHE`` at that
file at run time.

``--from-telemetry LOG`` replays a serving miss log (the JSONL file a
``repro.obs.DramLedger`` writes for every schedule-cache miss — see
docs/observability.md) as tuning targets: each distinct (op, dims,
dtype, stride) the fleet fell back to analytic tiles for is tuned and
persisted, closing the telemetry → next-tuning-pass loop.  With
``--dry-run`` the targets are listed and validated but nothing is
measured or persisted.
"""

from __future__ import annotations

import argparse

from repro.tune import (OpSpec, ScheduleCache, describe_candidates,
                        device_kind, tune_op)


def _tune_one(spec: OpSpec, args, cache: ScheduleCache) -> None:
    print(f"tuning {spec.key(device_kind())}")
    print(describe_candidates(spec))
    winner = tune_op(spec.op, spec.dims, spec.dtype, spec.stride,
                     top_n=args.top_n, measure=not args.no_measure,
                     cache=cache)
    extra = (f"  {winner.measured_us:.0f} us/call"
             if winner.measured_us is not None else "")
    print(f"winner: tiles={winner.tiles} ({winner.source}){extra}")


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(prog="python -m repro.tune",
                                 description=__doc__)
    from repro.tune.schedule import OPS
    ap.add_argument("op", choices=OPS, nargs="?",
                    help="op to tune (omit with --from-telemetry)")
    ap.add_argument("dims", type=int, nargs="*",
                    help="GEMM ops (matmul, matmul_dgrad, matmul_w8, "
                         "matmul_fused): M N K; conv ops (conv2d, "
                         "conv2d_dgrad, conv2d_wgrad): X Y C K Fw Fh "
                         "(output-space X/Y; see docs/training.md for "
                         "the backward conventions); flash_decode[_fp8]: "
                         "G S D (GQA group size, max KV length, head "
                         "dim; see docs/serving.md and "
                         "docs/quantization.md); qkv_fused: M Nkv K G; "
                         "flash_decode_oproj: G S D E (E = d_model; "
                         "see docs/fusion.md)")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--stride", type=int, default=1)
    ap.add_argument("--top-n", type=int, default=3,
                    help="how many candidates to time")
    ap.add_argument("--no-measure", action="store_true",
                    help="persist the analytic winner without timing")
    ap.add_argument("--cache", default=None,
                    help="schedule cache path (default: "
                         "$REPRO_TUNE_CACHE or ~/.cache/repro)")
    ap.add_argument("--from-telemetry", metavar="LOG", default=None,
                    help="replay a serving miss log (JSONL, one "
                         "schedule-cache miss per line) as tuning targets")
    ap.add_argument("--dry-run", action="store_true",
                    help="with --from-telemetry: list and validate the "
                         "targets without measuring or persisting")
    args = ap.parse_args(argv)

    cache = ScheduleCache(args.cache)

    if args.from_telemetry is not None:
        from repro.obs.dram import read_miss_log
        targets = read_miss_log(args.from_telemetry)
        print(f"{len(targets)} distinct miss target(s) in "
              f"{args.from_telemetry}")
        for t in targets:
            spec = OpSpec(t["op"], tuple(t["dims"]), t["dtype"],
                          t["stride"])
            if args.dry_run:
                print(f"  would tune {spec.key(device_kind())}")
                continue
            _tune_one(spec, args, cache)
        if not args.dry_run and targets:
            print(f"persisted to {cache.path}")
        return

    if args.op is None or not args.dims:
        ap.error("op and dims are required (or use --from-telemetry LOG)")
    spec = OpSpec(args.op, tuple(args.dims), args.dtype, args.stride)
    _tune_one(spec, args, cache)
    print(f"persisted to {cache.path}")
    if args.cache:
        print("note: kernels.ops reads $REPRO_TUNE_CACHE (default "
              "~/.cache/repro/schedules.json); point it at this file "
              "to apply the schedule")


if __name__ == "__main__":
    main()
