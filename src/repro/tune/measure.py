"""Measurement harness: time one candidate schedule end-to-end.

Runs the real ``kernels.ops`` entry points (so spatial halo slicing, vmap
over batch, etc. are all included) with the candidate's tiles pinned, and
returns the best-of-N wall time in microseconds.  On CPU the kernels run
in Pallas ``interpret=True`` mode — useful as a correctness-preserving
tie-breaker in tests and CI, but *not* a TPU performance proxy; the
analytic DRAM-access rank from ``tune.lowering`` carries that signal.
"""

from __future__ import annotations

import time

import numpy as np

from repro.tune.schedule import Schedule


def _block(x) -> None:
    np.asarray(x)  # host transfer forces completion in both modes


def make_inputs(schedule: Schedule, seed: int = 0):
    """Representative operand arrays for the schedule's OpSpec.

    Backward ops get the operands their kernels actually stream:
    ``matmul_dgrad`` a cotangent (M, K_red) plus the transposed-read
    operand (N_out, K_red); ``conv2d_wgrad`` an input image plus the
    output-space cotangent.  ``conv2d_dgrad`` *is* a forward conv after
    the host-side dilation, so it measures as one.
    """
    import jax.numpy as jnp

    spec = schedule.spec
    rng = np.random.default_rng(seed)
    if spec.op == "flash_decode_oproj":
        # the flash_decode operands plus the per-head wo slab
        G, S, D, E = spec.dims
        (page,) = schedule.tiles
        n_blocks = -(-S // page)
        q = jnp.asarray(rng.normal(size=(1, 1, G, D)), spec.dtype)
        kp = jnp.asarray(rng.normal(size=(n_blocks, page, 1, D)),
                         spec.dtype)
        vp = jnp.asarray(rng.normal(size=(n_blocks, page, 1, D)),
                         spec.dtype)
        bt = jnp.asarray(rng.permutation(n_blocks)[None, :], jnp.int32)
        lengths = jnp.asarray([S], jnp.int32)
        wo = jnp.asarray(rng.normal(size=(1, G * D, E)) * 0.1,
                         spec.dtype)
        return q, kp, vp, bt, lengths, wo
    if spec.op == "qkv_fused":
        M, Nkv, K, G = spec.dims
        x = jnp.asarray(rng.normal(size=(M, K)), spec.dtype)
        wq = jnp.asarray(rng.normal(size=(K, G * Nkv)) * 0.1, spec.dtype)
        wk = jnp.asarray(rng.normal(size=(K, Nkv)) * 0.1, spec.dtype)
        wv = jnp.asarray(rng.normal(size=(K, Nkv)) * 0.1, spec.dtype)
        return x, wq, wk, wv
    if spec.op == "matmul_fused":
        # the MLP-block epilogue shape: bias + activation + residual
        M, N, K = spec.dims
        a = jnp.asarray(rng.normal(size=(M, K)), spec.dtype)
        w = jnp.asarray(rng.normal(size=(K, N)) * 0.1, spec.dtype)
        bias = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
        res = jnp.asarray(rng.normal(size=(M, N)), spec.dtype)
        return a, w, bias, res
    if spec.op in ("flash_decode", "flash_decode_fp8"):
        # one request, one kv head, paged cache laid out with THIS
        # schedule's block as the page size; a shuffled block table so
        # the gather is genuinely indirect.  The fp8 variant streams
        # 1-byte pages plus per-head dequant scales.
        G, S, D = spec.dims
        (page,) = schedule.tiles
        n_blocks = -(-S // page)
        page_dtype = (jnp.float8_e4m3fn if spec.op == "flash_decode_fp8"
                      else spec.dtype)
        q = jnp.asarray(rng.normal(size=(1, 1, G, D)), spec.dtype)
        kp = jnp.asarray(rng.normal(size=(n_blocks, page, 1, D)),
                         page_dtype)
        vp = jnp.asarray(rng.normal(size=(n_blocks, page, 1, D)),
                         page_dtype)
        bt = jnp.asarray(rng.permutation(n_blocks)[None, :], jnp.int32)
        lengths = jnp.asarray([S], jnp.int32)
        if spec.op == "flash_decode_fp8":
            ks = jnp.asarray(rng.uniform(0.5, 2.0, size=(1,)), jnp.float32)
            vs = jnp.asarray(rng.uniform(0.5, 2.0, size=(1,)), jnp.float32)
            return q, kp, vp, ks, vs, bt, lengths
        return q, kp, vp, bt, lengths
    if spec.op == "matmul_w8":
        M, N, K = spec.dims
        a = jnp.asarray(rng.normal(size=(M, K)), spec.dtype)
        w_q = jnp.asarray(rng.integers(-127, 128, size=(K, N)), jnp.int8)
        scale = jnp.asarray(rng.uniform(0.005, 0.05, size=(N,)),
                            jnp.float32)
        return a, w_q, scale
    if spec.op == "matmul_dgrad":
        M, N, K = spec.dims
        g = jnp.asarray(rng.normal(size=(M, K)), spec.dtype)
        b = jnp.asarray(rng.normal(size=(N, K)), spec.dtype)
        return g, b
    if spec.op == "matmul":
        M, N, K = spec.dims
        a = jnp.asarray(rng.normal(size=(M, K)), spec.dtype)
        b = jnp.asarray(rng.normal(size=(K, N)), spec.dtype)
        return a, b
    X, Y, C, K, Fw, Fh = spec.dims
    ih = (Y - 1) * spec.stride + Fh
    iw = (X - 1) * spec.stride + Fw
    x = jnp.asarray(rng.normal(size=(1, ih, iw, C)), spec.dtype)
    if spec.op == "conv2d_wgrad":
        g = jnp.asarray(rng.normal(size=(1, Y, X, K)) * 0.5, spec.dtype)
        return x, g
    w = jnp.asarray(rng.normal(size=(Fh, Fw, C, K)) * 0.5, spec.dtype)
    return x, w


def run_once(schedule: Schedule, inputs, interpret: bool | None = None):
    """Execute the schedule's op once and return the (blocked-on) result."""
    from repro.kernels import ops

    spec = schedule.spec
    interpret = ops.default_interpret() if interpret is None \
        else bool(interpret)
    if spec.op == "flash_decode_oproj":
        from repro.kernels.flash_decode import flash_decode_oproj
        q, kp, vp, bt, lengths, wo = inputs
        out = flash_decode_oproj(q, kp, vp, bt, lengths, wo,
                                 interpret=interpret)
    elif spec.op == "qkv_fused":
        from repro.kernels.qkv_fused import qkv_fused
        x, wq, wk, wv = inputs
        bm, bk, bn = schedule.tiles
        out = qkv_fused(x, wq, wk, wv, bm=bm, bk=bk, bn=bn,
                        interpret=interpret)[0]
    elif spec.op == "matmul_fused":
        a, w, bias, res = inputs
        out = ops.matmul_fused(a, w, bias=bias, act="gelu", residual=res,
                               tiles=schedule.tiles, use_kernel=True,
                               interpret=interpret)
    elif spec.op == "flash_decode":
        from repro.kernels.flash_decode import flash_decode
        q, kp, vp, bt, lengths = inputs
        out = flash_decode(q, kp, vp, bt, lengths, interpret=interpret)
    elif spec.op == "flash_decode_fp8":
        from repro.kernels.flash_decode import flash_decode_fp8
        q, kp, vp, ks, vs, bt, lengths = inputs
        out = flash_decode_fp8(q, kp, vp, ks, vs, bt, lengths,
                               interpret=interpret)
    elif spec.op == "matmul_w8":
        a, w_q, scale = inputs
        out = ops.matmul_w8(a, w_q, scale, tiles=schedule.tiles,
                            interpret=interpret)
    elif spec.op == "matmul_dgrad":
        from repro.kernels.matmul_bwd import matmul_dgrad_a
        g, b = inputs
        bm, br, bo = schedule.tiles
        out = matmul_dgrad_a(g, b, bm=bm, br=br, bo=bo,
                             interpret=interpret)
    elif spec.op == "matmul":
        a, b = inputs
        out = ops.matmul(a, b, tiles=schedule.tiles, interpret=interpret)
    elif spec.op == "conv2d_wgrad":
        from repro.kernels.conv2d_bwd import conv2d_wgrad
        x, g = inputs
        out = conv2d_wgrad(x, g, spec.dims[5], spec.dims[4],
                           stride=spec.stride, tiles=schedule.tiles,
                           interpret=interpret)
    else:  # conv2d and conv2d_dgrad (the latter is a forward nest)
        x, w = inputs
        out = ops.conv2d(x, w, stride=spec.stride, tiles=schedule.tiles,
                         interpret=interpret)
    _block(out)
    return out


def measure(schedule: Schedule, interpret: bool | None = None,
            iters: int = 3, warmup: int = 1, seed: int = 0) -> float:
    """Best-of-``iters`` latency (microseconds) for one schedule."""
    inputs = make_inputs(schedule, seed)
    for _ in range(warmup):
        run_once(schedule, inputs, interpret)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        run_once(schedule, inputs, interpret)
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


def measure_top(schedules: list[Schedule], top_n: int = 3,
                interpret: bool | None = None, iters: int = 3,
                ) -> list[Schedule]:
    """Time the first ``top_n`` schedules; return ALL schedules re-ranked
    (measured ones first, by latency; unmeasured keep their analytic
    order behind them)."""
    import dataclasses

    timed = [dataclasses.replace(s, measured_us=measure(s, interpret,
                                                        iters=iters),
                                 source="measured")
             for s in schedules[:top_n]]
    timed.sort(key=lambda s: s.measured_us)
    return timed + schedules[top_n:]
