"""Serving example: the paged continuous-batching engine next to the
static-batch baseline, across architecture families (dense GQA, hybrid
RG-LRU, pure SSM) — the paged engine streams ragged-length requests
through a fixed set of decode slots while the static engine must pad and
run in lock-step.

    PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve.engine import (DecodeEngine, PagedEngine, PagedServeConfig,
                                ServeConfig)


def main() -> None:
    rng = np.random.default_rng(0)
    for arch in ("granite-3-8b", "recurrentgemma-9b", "mamba2-780m"):
        cfg = dataclasses.replace(get_reduced(arch), dtype=jnp.float32)
        params = T.init_params(cfg, jax.random.PRNGKey(0))

        # ragged request stream: 6 requests through 2 decode slots
        prompts = [rng.integers(0, cfg.vocab, (int(L),), dtype=np.int32)
                   for L in rng.integers(4, 13, 6)]
        paged = PagedEngine(cfg, params,
                            PagedServeConfig(max_seq=64, max_batch=2))
        out = paged.generate(prompts, 16)

        # static baseline on the same-length slice, greedy must agree
        static = DecodeEngine(cfg, params, ServeConfig(max_seq=64))
        ref = static.generate(prompts[0][None, :], 16)[0]
        agree = bool(np.array_equal(out[0], ref))

        sampled = PagedEngine(cfg, params,
                              PagedServeConfig(max_seq=64, max_batch=2,
                                               temperature=0.8))
        out_t = sampled.generate(prompts, 16)
        print(f"{arch:20s} page={paged.page_size:3d} "
              f"greedy[0]={out[0, :8].tolist()} "
              f"matches-static={agree} "
              f"sampled[0]={out_t[0, :8].tolist()}")


if __name__ == "__main__":
    main()
