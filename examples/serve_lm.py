"""Batched serving example: train briefly, then serve generations with the
KV-cache decode engine (greedy + sampled), for a hybrid (RG-LRU) arch to
show the O(1)-state decode path.

    PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve.engine import DecodeEngine, ServeConfig


def main() -> None:
    for arch in ("granite-3-8b", "recurrentgemma-9b", "mamba2-780m"):
        cfg = dataclasses.replace(get_reduced(arch), dtype=jnp.float32)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        engine = DecodeEngine(cfg, params, ServeConfig(max_seq=64))
        prompts = np.tile(np.arange(8, dtype=np.int32), (4, 1)) \
            % cfg.vocab
        out = engine.generate(prompts, 24)
        engine_t = DecodeEngine(cfg, params,
                                ServeConfig(max_seq=64, temperature=0.8))
        out_t = engine_t.generate(prompts, 24)
        print(f"{arch:20s} greedy[0]={out[0, :8].tolist()} "
              f"sampled[0]={out_t[0, :8].tolist()}")


if __name__ == "__main__":
    main()
