"""Quickstart: the paper's blocking optimizer in five minutes.

Finds the optimal blocking for a VGG conv layer, prints the energy
breakdown, compares against the im2col+GEMM baseline, and shows the
TPU tiles the same model derives for a transformer projection.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (Problem, analyze, energy_custom, make_objective,
                        optimize_exhaustive, xeon_hierarchy,
                        direct_blocking_accesses, gemm_lowering_accesses,
                        matmul_tiles, flash_tiles)


def main() -> None:
    # ---- 1. a conv layer (VGG-D conv3_2, the paper's Conv4) ------------
    p = Problem(X=56, Y=56, C=128, K=256, Fw=3, Fh=3)
    print(f"Conv4: {p.macs/1e9:.2f} GMACs, weights "
          f"{p.weight_elems*2/1e6:.1f} MB")

    # ---- 2. find the optimal 2-level blocking --------------------------
    best = optimize_exhaustive(p, make_objective("custom"), n_levels=2,
                               top=3, max_orders=8)
    print("\ntop-3 schedules (custom hardware, energy/MAC):")
    for r in best:
        print(f"  {r.string}   {r.report.pj_per_mac:.3f} pJ/MAC")

    print("\nbest schedule energy breakdown:")
    print(best[0].report.summary())

    # ---- 3. the paper's headline: direct blocking vs GEMM lowering -----
    levels = xeon_hierarchy()
    ours = direct_blocking_accesses(p, levels)
    mkl = gemm_lowering_accesses(p, levels, "mkl").cache_counts
    print(f"\nL2 accesses: blocked={ours['L2']:.3e} "
          f"im2col+GEMM={mkl['L2']:.3e} "
          f"({mkl['L2']/ours['L2']:.1f}x more)")

    # ---- 4. the same model on TPU: Pallas tile derivation --------------
    print("\nTPU (v5e) tiles from the same blocking model:")
    print("  4096x4096x4096 GEMM  (bm,bk,bn) =",
          matmul_tiles(4096, 4096, 4096, 2))
    print("  32k-context attention (block_q, block_kv) =",
          flash_tiles(32768, 32768, 128, 2))


if __name__ == "__main__":
    main()
