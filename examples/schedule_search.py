"""Reproduce the paper's co-design study on one layer: sweep the SRAM
budget, watch the optimal hierarchy and blocking change, and print the
energy/area Pareto (paper Fig. 7 methodology).

    PYTHONPATH=src python examples/schedule_search.py [--layer Conv4]
"""

import argparse

from repro.configs import PAPER_LAYERS
from repro.core import make_objective, optimize_beam


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--layer", default="Conv4", choices=PAPER_LAYERS)
    ap.add_argument("--levels", type=int, default=3)
    args = ap.parse_args()
    p = PAPER_LAYERS[args.layer]
    print(f"{args.layer}: {p.macs/1e9:.2f} GMACs")
    print(f"{'budget':>8s} {'pJ/MAC':>8s} {'area mm2':>9s}  schedule")
    for budget_kb in (64, 256, 1024, 8192):
        obj = make_objective("custom",
                             sram_budget_bytes=budget_kb * 1024)
        best = optimize_beam(p, obj, n_levels=args.levels, beam=8,
                             perturbations=3)[0]
        r = best.report
        print(f"{budget_kb:6d}KB {r.pj_per_mac:8.3f} {r.area_mm2:9.2f}  "
              f"{best.string}")


if __name__ == "__main__":
    main()
