"""Schedule search, two ways.

Default (the paper's co-design study): sweep the SRAM budget on one
layer, watch the optimal hierarchy and blocking change, and print the
energy/area Pareto (paper Fig. 7 methodology).

    PYTHONPATH=src python examples/schedule_search.py [--layer Conv4]

``--tpu``: run the same analytical model through the Pallas schedule
autotuner instead — lower the layer to kernel tile candidates, rank them
by predicted HBM traffic, optionally time the top few (``--measure``,
interpret mode off-TPU), and persist the winner in the schedule cache
that ``repro.kernels.ops`` consults by default:

    PYTHONPATH=src python examples/schedule_search.py --layer Conv4 --tpu
"""

import argparse

from repro.configs import PAPER_LAYERS
from repro.core import make_objective, optimize_beam


def codesign_sweep(args) -> None:
    p = PAPER_LAYERS[args.layer]
    print(f"{args.layer}: {p.macs/1e9:.2f} GMACs")
    print(f"{'budget':>8s} {'pJ/MAC':>8s} {'area mm2':>9s}  schedule")
    for budget_kb in (64, 256, 1024, 8192):
        obj = make_objective("custom",
                             sram_budget_bytes=budget_kb * 1024)
        best = optimize_beam(p, obj, n_levels=args.levels, beam=8,
                             perturbations=3)[0]
        r = best.report
        print(f"{budget_kb:6d}KB {r.pj_per_mac:8.3f} {r.area_mm2:9.2f}  "
              f"{best.string}")


def tpu_tune(args) -> None:
    from repro.tune import OpSpec, ScheduleCache, describe_candidates, \
        tune_op

    p = PAPER_LAYERS[args.layer]
    if p.Fw == 1 and p.Fh == 1 and p.Y == 1:    # FC layer -> GEMM
        spec = OpSpec("matmul", (p.X * p.N, p.K, p.C), args.dtype)
    else:
        spec = OpSpec("conv2d", (p.X, p.Y, p.C, p.K, p.Fw, p.Fh),
                      args.dtype)
    print(f"{args.layer} as {spec.op}{spec.dims}: lowering the analytical "
          "winners to Pallas tiles")
    print(describe_candidates(spec))

    cache = ScheduleCache(args.cache) if args.cache else None
    winner = tune_op(spec.op, spec.dims, spec.dtype,
                     measure=args.measure, cache=cache)
    extra = (f", {winner.measured_us:.0f} us/call measured"
             if winner.measured_us is not None else "")
    print(f"\nwinner ({winner.source}{extra}): tiles={winner.tiles}")
    if args.cache:
        print(f"persisted to {args.cache}; point REPRO_TUNE_CACHE at it "
              "so kernels.ops picks it up")
    else:
        print("persisted: kernels.ops will use these tiles for this "
              "shape from now on")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--layer", default="Conv4", choices=PAPER_LAYERS)
    ap.add_argument("--levels", type=int, default=3)
    ap.add_argument("--tpu", action="store_true",
                    help="lower to Pallas tiles via the autotuner")
    ap.add_argument("--measure", action="store_true",
                    help="with --tpu: time the top candidates")
    ap.add_argument("--dtype", default="bfloat16")
    ap.add_argument("--cache", default=None,
                    help="with --tpu: schedule-cache path override")
    args = ap.parse_args()
    if args.tpu:
        tpu_tune(args)
    else:
        codesign_sweep(args)


if __name__ == "__main__":
    main()
