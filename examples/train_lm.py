"""End-to-end driver: train a ~20M-param granite-style LM for a few
hundred steps on CPU, with checkpointing and a restart drill.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses
import shutil
import tempfile

import jax

from repro.configs import get_reduced
from repro.data.pipeline import make_batch
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--blocked-kernels", action="store_true",
                    help="projections through the differentiable blocked "
                         "Pallas GEMMs (interpret mode on CPU: slow, "
                         "demonstrates the training path of ISSUE 2)")
    args = ap.parse_args()

    # scale the smoke config up to ~20M params (real training, CPU-sized)
    cfg = dataclasses.replace(
        get_reduced(args.arch), n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=1024, vocab=2048)
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    tc = TrainConfig(
        opt=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        blocked_linear=args.blocked_kernels,
        ckpt_dir=ckpt_dir, ckpt_every=50, log_every=10)

    def batches(start=0):
        for step in range(start, args.steps):
            yield make_batch(cfg, args.seq_len, args.batch, step)

    print(f"training {args.arch}-mini for {args.steps} steps "
          f"(ckpts -> {ckpt_dir})")
    result = train(cfg, tc, batches())
    h = result["history"]
    print(f"\nloss: {h[0]:.3f} -> {h[-1]:.3f} "
          f"({(1 - h[-1]/h[0])*100:.0f}% reduction)")
    assert h[-1] < h[0] * 0.8, "training did not converge"

    # restart drill: resume from the last checkpoint, confirm continuity
    print("\nrestart drill: resuming from newest checkpoint...")
    result2 = train(cfg, tc, batches(start=args.steps - args.steps % 50
                                     if args.steps % 50 else
                                     args.steps - 50),
                    restore=True)
    print("resumed OK")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
