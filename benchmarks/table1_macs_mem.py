"""Paper Table 1: computation / memory breakdown of AlexNet & VGGNet."""

from benchmarks import networks
from benchmarks.common import emit, timed


PAPER = {  # (GMACs, MB) as printed in Table 1
    "AlexNet Convs": (1.9, 2.0),
    "VGGNet-B Convs": (11.2, 19.0),
    "VGGNet-D Convs": (15.3, 29.0),
    "AlexNet FCs": (0.065, 130.0),
    "VGGNet-B FCs": (0.124, 247.0),
    "VGGNet-D FCs": (0.124, 247.0),
}


def rows() -> dict[str, tuple[float, float]]:
    nets = {
        "AlexNet Convs": networks.alexnet_convs(),
        "VGGNet-B Convs": networks.vgg_b_convs(),
        "VGGNet-D Convs": networks.vgg_d_convs(),
        "AlexNet FCs": networks.alexnet_fcs(),
        "VGGNet-B FCs": networks.vgg_fcs(),
        "VGGNet-D FCs": networks.vgg_fcs(),
    }
    out = {}
    for name, layers in nets.items():
        gmacs = sum(p.macs for p in layers) / 1e9
        mb = sum(p.weight_elems * p.bytes_per_elem for p in layers) / 1e6
        out[name] = (gmacs, mb)
    return out


def run() -> None:
    us, table = timed(rows)
    for name, (gmacs, mb) in table.items():
        pg, pm = PAPER[name]
        emit(f"table1/{name.replace(' ', '_')}", us / len(table),
             f"GMACs={gmacs:.3f}(paper {pg}) MB={mb:.1f}(paper {pm})")


if __name__ == "__main__":
    run()
