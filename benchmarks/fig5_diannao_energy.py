"""Paper Fig. 5: energy on DianNao's fixed buffers — baseline vs optimal.

Baseline: DianNao's own GEMM-ish schedule (Tn=Tk=16 inner tiles, x blocked
once so the input tile fits the 2KB IB — the paper applied the same fix).
Optimal: our optimizer searching loop orders/splits for the same fixed
hierarchy.  The paper reports 2-15x KB-energy reduction.
"""

from benchmarks.common import cached, emit, timed
from repro.configs import PAPER_LAYERS
from repro.core import (BlockingString, Dim, Loop, Problem,
                        diannao_hierarchy, energy_fixed, make_objective,
                        optimize_exhaustive)

CONVS = ["Conv1", "Conv2", "Conv3", "Conv4", "Conv5"]


def _div_le(n: int, cap: int) -> int:
    return max(v for v in range(1, min(cap, n) + 1) if n % v == 0)


def baseline_string(p: Problem) -> BlockingString:
    """DianNao pseudo-code: 16-in/16-out inner tiles, row-major outer.
    Of the plausible outer-loop orders we keep the CHEAPEST (a generous
    baseline makes the reported reduction conservative)."""
    from repro.core import energy_fixed, diannao_hierarchy
    c0 = _div_le(p.C, 16)
    k0 = _div_le(p.K, 16)
    # shrink the x block until the IB tile fits 2KB (paper §5.2)
    x0 = p.X
    while (x0 + p.Fw - 1) * p.Fh * c0 * p.bytes_per_elem > 2048 and x0 > 1:
        cands = [v for v in range(1, x0) if p.X % v == 0]
        if not cands:
            break
        x0 = max(cands)
    inner = [Loop(Dim.FW, p.Fw), Loop(Dim.FH, p.Fh),
             Loop(Dim.C, c0), Loop(Dim.K, k0), Loop(Dim.X, x0)]
    outers = [
        [Loop(Dim.K, p.K), Loop(Dim.C, p.C), Loop(Dim.X, p.X),
         Loop(Dim.Y, p.Y)],
        [Loop(Dim.C, p.C), Loop(Dim.K, p.K), Loop(Dim.X, p.X),
         Loop(Dim.Y, p.Y)],
        [Loop(Dim.X, p.X), Loop(Dim.Y, p.Y), Loop(Dim.C, p.C),
         Loop(Dim.K, p.K)],
        [Loop(Dim.C, p.C), Loop(Dim.X, p.X), Loop(Dim.Y, p.Y),
         Loop(Dim.K, p.K)],
    ]
    levels = diannao_hierarchy()
    cands = [BlockingString(inner + o, p) for o in outers]
    return min(cands, key=lambda s: energy_fixed(s, levels).total_pj)


def _group(report) -> dict[str, float]:
    groups = {"IB": 0.0, "KB": 0.0, "OB": 0.0}
    for name, pj in report.per_buffer_pj.items():
        groups[name.split("@")[0]] += pj
    groups["DRAM"] = report.dram_pj
    groups["total"] = report.total_pj
    return groups


def one_layer(layer: str) -> dict:
    p = PAPER_LAYERS[layer]
    levels = diannao_hierarchy()
    base = energy_fixed(baseline_string(p), levels)
    obj = make_objective("fixed", levels)
    best = optimize_exhaustive(p, obj, n_levels=2, top=1)[0]
    return {"baseline": _group(base), "optimal": _group(best.report),
            "schedule": repr(best.string)}


def run() -> None:
    for layer in CONVS:
        us, r = timed(lambda l=layer: cached(f"fig5/{l}",
                                             lambda: one_layer(l)))
        b, o = r["baseline"], r["optimal"]
        kb_red = b["KB"] / max(o["KB"], 1e-9)
        tot_red = b["total"] / max(o["total"], 1e-9)
        emit(f"fig5/{layer}", us,
             f"KB energy reduction {kb_red:.1f}x | total {tot_red:.1f}x | "
             f"optimal uJ={o['total']/1e6:.1f}")


if __name__ == "__main__":
    run()
