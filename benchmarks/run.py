"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (stdout).  Usage:

    PYTHONPATH=src python -m benchmarks.run [--only fig34,roofline]
"""

import argparse
import sys
import traceback

from benchmarks import (fig34_cache_accesses, fig5_diannao_energy,
                        fig67_codesign, fig9_multicore, kernel_bench,
                        roofline, table1_macs_mem)

SUITES = {
    "table1": table1_macs_mem.run,
    "fig34": fig34_cache_accesses.run,
    "fig5": fig5_diannao_energy.run,
    "fig67": fig67_codesign.run,
    "fig9": fig9_multicore.run,
    "kernels": kernel_bench.run,
    "roofline": roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            SUITES[name]()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
