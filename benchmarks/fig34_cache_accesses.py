"""Paper Figs. 3-4: L2/L3 cache accesses — blocked conv vs im2col+GEMM.

Reproduces the paper's claim: direct blocking does 2-8x fewer L2 accesses
(vs MKL/ATLAS-style GEMM after lowering) and 2-11x fewer L3 accesses, with
the advantage shrinking from Conv1 to Conv5 as windows shrink.
"""

from benchmarks.common import cached, emit, timed
from repro.configs import PAPER_LAYERS
from repro.core import (direct_blocking_accesses, gemm_lowering_accesses,
                        xeon_hierarchy)

CONVS = ["Conv1", "Conv2", "Conv3", "Conv4", "Conv5"]


def one_layer(layer: str) -> dict:
    p = PAPER_LAYERS[layer]
    levels = xeon_hierarchy()
    ours = direct_blocking_accesses(p, levels)
    mkl = gemm_lowering_accesses(p, levels, "mkl").cache_counts
    atlas = gemm_lowering_accesses(p, levels, "atlas").cache_counts
    return {"ours": ours, "mkl": mkl, "atlas": atlas}


def run() -> None:
    for layer in CONVS:
        us, r = timed(lambda l=layer: cached(f"fig34/{l}",
                                             lambda: one_layer(l)))
        ours, mkl, atlas = r["ours"], r["mkl"], r["atlas"]
        l2_mkl = mkl["L2"] / max(ours["L2"], 1)
        l2_atl = atlas["L2"] / max(ours["L2"], 1)
        l3_mkl = mkl["L3"] / max(ours["L3"], 1)
        l3_atl = atlas["L3"] / max(ours["L3"], 1)
        emit(f"fig34/{layer}", us,
             f"L2: mkl/ours={l2_mkl:.1f}x atlas/ours={l2_atl:.1f}x | "
             f"L3: mkl/ours={l3_mkl:.1f}x atlas/ours={l3_atl:.1f}x")


if __name__ == "__main__":
    run()
