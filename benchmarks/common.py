"""Shared benchmark helpers: schedule cache + CSV emission."""

from __future__ import annotations

import json
import os
import time
from typing import Callable

CACHE_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "bench_cache.json")


def _load_cache() -> dict:
    try:
        with open(CACHE_PATH) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def cached(key: str, fn: Callable[[], dict]) -> dict:
    """Memoize expensive schedule searches across benchmark runs."""
    cache = _load_cache()
    if key in cache:
        return cache[key]
    value = fn()
    cache = _load_cache()
    cache[key] = value
    os.makedirs(os.path.dirname(CACHE_PATH), exist_ok=True)
    with open(CACHE_PATH, "w") as f:
        json.dump(cache, f, indent=1)
    return value


_RECORDS: list[dict] = []


def emit(name: str, us_per_call: float, derived: str, **fields) -> None:
    """The driver's CSV contract: name,us_per_call,derived.

    Extra keyword ``fields`` (modeled/measured DRAM bytes, tok/s, ...)
    ride along into the JSON record only — the CSV line is unchanged.
    Every emit is collected so benchmarks can dump a machine-readable
    trajectory file (BENCH_kernels.json / BENCH_serve.json) via
    :func:`write_json`.
    """
    print(f"{name},{us_per_call:.1f},{derived}")
    _RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                     "derived": derived, **fields})


def write_json(path: str) -> None:
    """Dump every record emitted so far (one benchmark run) to ``path``
    — the cross-PR perf-trajectory contract."""
    with open(path, "w") as f:
        json.dump({"version": 1, "records": _RECORDS}, f, indent=1)
        f.write("\n")
    print(f"wrote {len(_RECORDS)} records to {path}")


def timed(fn: Callable) -> tuple[float, object]:
    t0 = time.perf_counter()
    out = fn()
    return (time.perf_counter() - t0) * 1e6, out
