"""Shared benchmark helpers: schedule cache + CSV emission."""

from __future__ import annotations

import json
import os
import time
from typing import Callable

CACHE_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "bench_cache.json")


def _load_cache() -> dict:
    try:
        with open(CACHE_PATH) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def cached(key: str, fn: Callable[[], dict]) -> dict:
    """Memoize expensive schedule searches across benchmark runs."""
    cache = _load_cache()
    if key in cache:
        return cache[key]
    value = fn()
    cache = _load_cache()
    cache[key] = value
    os.makedirs(os.path.dirname(CACHE_PATH), exist_ok=True)
    with open(CACHE_PATH, "w") as f:
        json.dump(cache, f, indent=1)
    return value


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The driver's CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn: Callable) -> tuple[float, object]:
    t0 = time.perf_counter()
    out = fn()
    return (time.perf_counter() - t0) * 1e6, out
