"""Shared benchmark helpers: schedule cache + CSV emission."""

from __future__ import annotations

import json
import os
import time
from typing import Callable

CACHE_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "bench_cache.json")


def _load_cache() -> dict:
    try:
        with open(CACHE_PATH) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def cached(key: str, fn: Callable[[], dict]) -> dict:
    """Memoize expensive schedule searches across benchmark runs."""
    cache = _load_cache()
    if key in cache:
        return cache[key]
    value = fn()
    cache = _load_cache()
    cache[key] = value
    os.makedirs(os.path.dirname(CACHE_PATH), exist_ok=True)
    with open(CACHE_PATH, "w") as f:
        json.dump(cache, f, indent=1)
    return value


_RECORDS: list[dict] = []


def emit(name: str, us_per_call: float, derived: str, **fields) -> None:
    """The driver's CSV contract: name,us_per_call,derived.

    Extra keyword ``fields`` (modeled/measured DRAM bytes, tok/s, ...)
    ride along into the JSON record only — the CSV line is unchanged.
    Every emit is collected so benchmarks can dump a machine-readable
    trajectory file (BENCH_kernels.json / BENCH_serve.json) via
    :func:`write_json`.
    """
    print(f"{name},{us_per_call:.1f},{derived}")
    _RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                     "derived": derived, **fields})


def write_json(path: str) -> None:
    """Dump every record emitted so far (one benchmark run) to ``path``
    — the cross-PR perf-trajectory contract."""
    with open(path, "w") as f:
        json.dump({"version": 1, "records": _RECORDS}, f, indent=1)
        f.write("\n")
    print(f"wrote {len(_RECORDS)} records to {path}")


def timed(fn: Callable) -> tuple[float, object]:
    t0 = time.perf_counter()
    out = fn()
    return (time.perf_counter() - t0) * 1e6, out


def latency_summary(step_times) -> tuple[str, dict]:
    """p50/p95/p99 of per-token step latencies (seconds).

    Returns the derived-string fragment (``"p50=..us p95=..us
    p99=..us"``) and the matching JSON fields (``p50_us``/``p95_us``/
    ``p99_us``) so every record reports the same three percentiles the
    same way.
    """
    import numpy as np
    p50, p95, p99 = np.percentile(np.asarray(step_times) * 1e6,
                                  [50, 95, 99])
    frag = f"p50={p50:.0f}us p95={p95:.0f}us p99={p99:.0f}us"
    fields = {"p50_us": round(p50, 1), "p95_us": round(p95, 1),
              "p99_us": round(p99, 1)}
    return frag, fields
