"""Quantization benchmark: modeled + measured wins vs the bf16 baseline.

Three axes (CSV contract ``name,us_per_call,derived``):

1. **w8 matmul** — the int8-weight GEMM under its dtype-aware schedule
   vs the bf16 GEMM under its own: modeled DRAM-boundary traffic in
   BYTES (per-operand widths through the paper's access model —
   ``tune.predicted_dram_bytes``) and measured interpret-mode wall time,
   with an allclose check against the fp32 fake-quant oracle.
2. **fp8 flash decode** — same comparison for the paged decode nest: the
   fp8 page pool streams at 1 byte/elem, and the fp8-aware search may
   pick a different page size than the bf16 one.
3. **decode tokens/sec** — PagedEngine end to end, quantized (int8
   weights + fp8 KV pool) vs the wide baseline on the same workload.

Wall-clock on CPU (Pallas interpret) is a machinery check, NOT a TPU
performance claim — the modeled byte ratios carry the hardware story
(docs/quantization.md).

    PYTHONPATH=src python -m benchmarks.quant_bench --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.configs import get_reduced
from repro.models import transformer as T
from repro.tune import OpSpec, best_schedule, predicted_dram_bytes


def bench_matmul_w8(dims: tuple[int, int, int]) -> None:
    from repro.kernels import ops
    from repro.kernels.matmul_q import matmul_w8_ref
    M, N, K = dims
    rng = np.random.default_rng(0)
    # measured and modeled agree on widths: bf16 activations both ways,
    # bf16 vs int8 weight stream
    a = jnp.asarray(rng.normal(size=(M, K)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(K, N)) * 0.1, jnp.bfloat16)

    wide = best_schedule("matmul", (M, N, K), "bfloat16")
    narrow = best_schedule("matmul_w8", (M, N, K), "bfloat16")
    wide_bytes = predicted_dram_bytes(wide.spec, wide.tiles)
    narrow_bytes = predicted_dram_bytes(narrow.spec, narrow.tiles)

    from repro.quant import quantize
    qt = quantize(w.astype(jnp.float32), "int8")
    us_w, _ = timed(lambda: np.asarray(
        ops.matmul(a, w, tiles=wide.tiles, interpret=True)))
    us_q, out = timed(lambda: np.asarray(
        ops.matmul_w8(a, qt.q, qt.scale.reshape(-1), tiles=narrow.tiles,
                      interpret=True)))
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(matmul_w8_ref(a, qt.q, qt.scale.reshape(-1)),
                   np.float32),
        rtol=2e-2, atol=2e-2)
    emit(f"quant/matmul_w8_{M}x{N}x{K}", us_q,
         f"modeled DRAM {narrow_bytes:.3e}B vs bf16 {wide_bytes:.3e}B "
         f"({wide_bytes / max(narrow_bytes, 1):.2f}x reduction) "
         f"tiles {narrow.tiles} vs {wide.tiles}; measured "
         f"{us_w / max(us_q, 1e-9):.2f}x wall vs bf16 kernel; "
         "allclose-vs-oracle OK")


def bench_flash_decode_fp8(dims: tuple[int, int, int]) -> None:
    from repro.kernels.flash_decode import (flash_decode, flash_decode_fp8,
                                            paged_attention_fp8_ref)
    G, S, D = dims
    rng = np.random.default_rng(1)
    wide = best_schedule("flash_decode", (G, S, D), "bfloat16")
    narrow = best_schedule("flash_decode_fp8", (G, S, D), "bfloat16")
    wide_bytes = predicted_dram_bytes(wide.spec, wide.tiles)
    narrow_bytes = predicted_dram_bytes(narrow.spec, narrow.tiles)

    def make_pool(page, dtype):
        nb = -(-S // page)
        kp = jnp.asarray(rng.normal(size=(nb + 1, page, 1, D)), dtype)
        vp = jnp.asarray(rng.normal(size=(nb + 1, page, 1, D)), dtype)
        bt = jnp.asarray(1 + rng.permutation(nb)[None, :], jnp.int32)
        return kp, vp, bt

    # measured matches modeled: the baseline pool streams bf16 pages,
    # the quantized pool fp8 pages; q rides at bf16 in both
    q = jnp.asarray(rng.normal(size=(1, 1, G, D)), jnp.bfloat16)
    lengths = jnp.asarray([S], jnp.int32)
    ones = jnp.ones(1, jnp.float32)

    kp, vp, bt = make_pool(wide.tiles[0], jnp.bfloat16)
    us_w, _ = timed(lambda: np.asarray(
        flash_decode(q, kp, vp, bt, lengths, interpret=True)))
    kp8, vp8, bt8 = make_pool(narrow.tiles[0], jnp.float8_e4m3fn)
    us_q, out = timed(lambda: np.asarray(
        flash_decode_fp8(q, kp8, vp8, ones, ones, bt8, lengths,
                         interpret=True)))
    ref = paged_attention_fp8_ref(q, kp8, vp8, ones, ones, bt8, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)
    emit(f"quant/flash_decode_fp8_g{G}s{S}d{D}", us_q,
         f"modeled DRAM {narrow_bytes:.3e}B vs bf16 {wide_bytes:.3e}B "
         f"({wide_bytes / max(narrow_bytes, 1):.2f}x reduction) "
         f"page {narrow.tiles[0]} vs {wide.tiles[0]}; measured "
         f"{us_w / max(us_q, 1e-9):.2f}x wall vs bf16 kernel; "
         "allclose-vs-oracle OK")


def bench_decode_tps(arch: str, smoke: bool) -> None:
    from repro.quant import quantize_params, quantized_bytes
    from repro.serve.engine import PagedEngine, PagedServeConfig
    cfg = dataclasses.replace(get_reduced(arch), dtype=jnp.float32)
    if not smoke:
        cfg = dataclasses.replace(cfg, d_model=256, n_layers=4,
                                  n_heads=8, n_kv_heads=4, d_ff=1024,
                                  vocab=4096)
    n_req, gen, max_seq, slots = (4, 6, 32, 2) if smoke else (12, 48, 128, 4)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (int(L),), dtype=np.int32)
               for L in rng.integers(4, 12, n_req)]
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype=jnp.float8_e4m3fn)
    qparams = quantize_params(params)
    qb, db = quantized_bytes(qparams)

    def tps(c, p):
        eng = PagedEngine(c, p, PagedServeConfig(max_seq=max_seq,
                                                 max_batch=slots))
        eng.generate(prompts, gen)             # warm the compile caches
        eng2 = PagedEngine(c, p, PagedServeConfig(max_seq=max_seq,
                                                  max_batch=slots))
        t0 = time.perf_counter()
        eng2.generate(prompts, gen)
        return n_req * gen / (time.perf_counter() - t0), eng2.page_size

    base_tps, base_page = tps(cfg, params)
    q_tps, q_page = tps(cfg8, qparams)
    emit("quant/decode_tps", 1e6 / max(q_tps, 1e-9),
         f"w8+fp8kv {q_tps:.1f} tok/s (page {q_page}) vs baseline "
         f"{base_tps:.1f} tok/s (page {base_page}) = "
         f"{q_tps / max(base_tps, 1e-9):.2f}x; projection weights "
         f"{qb / 1e6:.1f}MB vs bf16 {db / 1e6:.1f}MB")


def run(smoke: bool = False) -> None:
    if smoke:
        bench_matmul_w8((128, 128, 256))
        bench_flash_decode_fp8((4, 256, 64))
    else:
        bench_matmul_w8((512, 512, 1024))
        bench_flash_decode_fp8((8, 2048, 128))
    bench_decode_tps("granite-3-8b", smoke)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + workload for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
