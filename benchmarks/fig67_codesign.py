"""Paper Figs. 6-7: co-designed memory hierarchy + blocking.

Fig. 6: optimal (core + memory) vs DianNao-with-optimal-schedule — paper
reports >=13x energy reduction with an 8MB budget.
Fig. 7: the energy-vs-area Pareto under SRAM budgets — paper's 1MB point
gives ~10x at ~6x area.
"""

from benchmarks.common import cached, emit, timed
from repro.configs import PAPER_LAYERS
from repro.core import (diannao_hierarchy, energy_custom, make_objective,
                        optimize_beam, optimize_exhaustive)

CONVS = ["Conv1", "Conv2", "Conv3", "Conv4", "Conv5"]
BUDGETS = [128 * 1024, 512 * 1024, 1024 * 1024, 8 * 1024 * 1024]


def diannao_optimal_total(layer: str) -> float:
    from benchmarks.fig5_diannao_energy import one_layer
    return cached(f"fig5/{layer}", lambda: one_layer(layer))[
        "optimal"]["total"]


def codesign(layer: str, budget: int) -> dict:
    p = PAPER_LAYERS[layer]
    obj = make_objective("custom", sram_budget_bytes=budget)
    res = optimize_beam(p, obj, n_levels=4, beam=6, perturbations=2,
                        seed=0)[0]
    return {"total_pj": res.report.total_pj,
            "mem_pj": res.report.mem_pj,
            "mac_pj": res.report.mac_pj,
            "area_mm2": res.report.area_mm2,
            "schedule": repr(res.string)}


def run() -> None:
    # Fig. 6: 8MB budget vs DianNao-optimal
    for layer in CONVS:
        us, r = timed(lambda l=layer: cached(
            f"fig67/{l}/8M", lambda: codesign(l, 8 * 1024 * 1024)))
        ref = diannao_optimal_total(layer)
        emit(f"fig6/{layer}", us,
             f"codesign8MB reduction {ref / r['total_pj']:.1f}x "
             f"(area {r['area_mm2']:.1f}mm2)")
    # Fig. 7: Pareto for Conv1
    for budget in BUDGETS:
        us, r = timed(lambda b=budget: cached(
            f"fig67/Conv1/{b}", lambda: codesign("Conv1", b)))
        ref = diannao_optimal_total("Conv1")
        emit(f"fig7/Conv1_{budget//1024}KB", us,
             f"reduction {ref / r['total_pj']:.1f}x area "
             f"{r['area_mm2']:.1f}mm2")
    # Fig. 8: memory:compute ratio on the 8MB design
    for layer in CONVS:
        r = cached(f"fig67/{layer}/8M",
                   lambda l=layer: codesign(l, 8 * 1024 * 1024))
        emit(f"fig8/{layer}", 0.0,
             f"mem/mac energy ratio {r['mem_pj'] / r['mac_pj']:.2f} "
             f"(paper: < 1)")


if __name__ == "__main__":
    run()
