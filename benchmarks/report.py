"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

    PYTHONPATH=src python -m benchmarks.report > experiments/tables.md
"""

from __future__ import annotations

import json
import os

from benchmarks.roofline import (ART_DIR, analytic_hbm_bytes, load_cells,
                                 terms)
from repro.configs import ARCHS, SHAPES, cells, get_config


def dryrun_table() -> str:
    rows = ["| arch | shape | mesh | compile_s | args GiB/dev | "
            "temp GiB/dev | status |",
            "|---|---|---|---|---|---|---|"]
    for arch, shape in cells():
        for mesh in ("16x16", "2x16x16"):
            safe = arch.replace("/", "_").replace(".", "_")
            path = os.path.join(ART_DIR, f"{safe}__{shape}__{mesh}.json")
            if not os.path.exists(path):
                rows.append(f"| {arch} | {shape} | {mesh} | - | - | - | "
                            "pending |")
                continue
            with open(path) as f:
                r = json.load(f)
            if not r.get("ok"):
                rows.append(f"| {arch} | {shape} | {mesh} | - | - | - | "
                            f"FAIL {str(r.get('error'))[:60]} |")
                continue
            mem = r["memory"]
            args_g = (mem.get("argument_size_in_bytes") or 0) / 2**30
            temp_g = (mem.get("temp_size_in_bytes") or 0) / 2**30
            rows.append(
                f"| {arch} | {shape} | {mesh} | {r['compile_s']} | "
                f"{args_g:.2f} | {temp_g:.2f} | OK |")
    return "\n".join(rows)


def roofline_table() -> str:
    rows = ["| arch | shape | compute_ms | mem_ms | coll_ms | bottleneck |"
            " roofline frac | MODEL/HLO flops |",
            "|---|---|---|---|---|---|---|---|"]
    for rec in load_cells("16x16"):
        t = terms(rec)
        if t is None:
            rows.append(f"| {rec['arch']} | {rec['shape']} | - | - | - | "
                        "pending | - | - |")
            continue
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | "
            f"{t['compute_s']*1e3:.1f} | {t['memory_s']*1e3:.1f} | "
            f"{t['collective_s']*1e3:.1f} | {t['bottleneck']} | "
            f"{t['roofline_fraction']:.2f} | {t['model_hlo_ratio']:.2f} |")
    return "\n".join(rows)


def interesting_cells() -> str:
    """The three hillclimb candidates (worst frac / most collective-bound /
    most paper-representative)."""
    scored = []
    for rec in load_cells("16x16"):
        t = terms(rec)
        if t:
            scored.append((rec["arch"], rec["shape"], t))
    if not scored:
        return "(no analysed cells yet)"
    worst = min(scored, key=lambda x: x[2]["roofline_fraction"])
    coll = max(scored, key=lambda x: x[2]["collective_s"])
    out = [f"worst roofline fraction: {worst[0]} x {worst[1]} "
           f"(frac {worst[2]['roofline_fraction']:.2f})",
           f"most collective-bound: {coll[0]} x {coll[1]} "
           f"(coll {coll[2]['collective_s']*1e3:.1f} ms)",
           "paper-representative: granite-3-8b x train_4k "
           "(dense GEMM blocking + TP/FSDP)"]
    return "\n".join(out)


def perf_variants_table() -> str:
    """Optimized-variant artifacts (fsdp / remat / kv8 / MoE dispatch)."""
    import glob
    rows = ["| artifact | flops/dev | coll bytes/dev | args GiB/dev |",
            "|---|---|---|---|"]
    pats = ["*__fsdp*.json", "*__kv8.json", "*globalsort_baseline*.json"]
    seen = set()
    for pat in pats:
        for path in sorted(glob.glob(os.path.join(ART_DIR, pat))):
            if path in seen:
                continue
            seen.add(path)
            with open(path) as f:
                r = json.load(f)
            if not r.get("ok"):
                continue
            name = os.path.basename(path).replace(".json", "")
            args_g = (r["memory"].get("argument_size_in_bytes") or 0) / 2**30
            fl = r.get("flops")
            cb = r.get("collective_bytes_total")
            rows.append(f"| {name} | "
                        f"{fl:.3e} | " if fl else f"| {name} | - | ")
            rows[-1] = (f"| {name} | {fl:.3e} | {cb:.3e} | {args_g:.2f} |"
                        if fl is not None else
                        f"| {name} | - | - | {args_g:.2f} |")
    return "\n".join(rows)


def main() -> None:
    print("## Dry-run table\n")
    print(dryrun_table())
    print("\n## Roofline table (single-pod 16x16)\n")
    print(roofline_table())
    print("\n## Perf-variant artifacts (§Perf)\n")
    print(perf_variants_table())
    print("\n## Hillclimb candidates\n")
    print(interesting_cells())


if __name__ == "__main__":
    main()
