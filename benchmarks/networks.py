"""Full network definitions for the paper's Table 1 accounting."""

from repro.core.loopnest import Problem


def alexnet_convs() -> list[Problem]:
    """AlexNet [23] conv layers (ungrouped variant, 227x227 input)."""
    return [
        Problem(X=55, Y=55, C=3, K=96, Fw=11, Fh=11, stride=4),
        Problem(X=27, Y=27, C=96, K=256, Fw=5, Fh=5),
        Problem(X=13, Y=13, C=256, K=384, Fw=3, Fh=3),
        Problem(X=13, Y=13, C=384, K=384, Fw=3, Fh=3),
        Problem(X=13, Y=13, C=384, K=256, Fw=3, Fh=3),
    ]


def alexnet_fcs() -> list[Problem]:
    return [
        Problem.gemm(M=1, N_cols=4096, K_reduce=9216),
        Problem.gemm(M=1, N_cols=4096, K_reduce=4096),
        Problem.gemm(M=1, N_cols=1000, K_reduce=4096),
    ]


def _vgg_block(x: int, c_in: int, c_out: int, n: int) -> list[Problem]:
    out = [Problem(X=x, Y=x, C=c_in, K=c_out, Fw=3, Fh=3)]
    for _ in range(n - 1):
        out.append(Problem(X=x, Y=x, C=c_out, K=c_out, Fw=3, Fh=3))
    return out


def vgg_b_convs() -> list[Problem]:
    """VGGNet-B [35]: 2-2-2-2-2 conv layers."""
    return (_vgg_block(224, 3, 64, 2) + _vgg_block(112, 64, 128, 2) +
            _vgg_block(56, 128, 256, 2) + _vgg_block(28, 256, 512, 2) +
            _vgg_block(14, 512, 512, 2))


def vgg_d_convs() -> list[Problem]:
    """VGGNet-D (VGG-16) [35]: 2-2-3-3-3 conv layers."""
    return (_vgg_block(224, 3, 64, 2) + _vgg_block(112, 64, 128, 2) +
            _vgg_block(56, 128, 256, 3) + _vgg_block(28, 256, 512, 3) +
            _vgg_block(14, 512, 512, 3))


def vgg_fcs() -> list[Problem]:
    return [
        Problem.gemm(M=1, N_cols=4096, K_reduce=25088),
        Problem.gemm(M=1, N_cols=4096, K_reduce=4096),
        Problem.gemm(M=1, N_cols=1000, K_reduce=4096),
    ]
