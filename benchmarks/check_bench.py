"""Regression guard for the serving-benchmark trajectory file.

Compares a fresh ``serve_bench --smoke --json`` run against the
committed ``BENCH_serve.json`` and fails loudly when the paged engine
regresses.  Two kinds of checks, split by what CI can actually hold
stable:

* **exact** — the record names and the workload (``useful_tokens``)
  must match the committed file bit-for-bit: the smoke workload is
  seeded, so any drift means the benchmark or the scheduler changed
  semantics, not speed;
* **ratio** — absolute tok/s on a shared CI runner is noise, but the
  *paged/static speedup* is a same-process, same-machine ratio, so it
  must stay within ``--tolerance`` (default 0.5: flag halvings, ignore
  jitter) of the committed speedup.

Fields this guard doesn't know about (``metrics`` snapshots,
``p99_us``, whatever serve_bench grows next) are ignored on both
sides; a guarded field is only *required* in the fresh run when the
committed record carries it.  Record-schema additions therefore never
force an ``--update`` — only intentional baseline moves do.

The prefix-cache section (``serve_paged_prefix`` /
``serve_paged_noshare``) runs a *different* workload than
``serve_static``, so those records are excluded from the
paged/static loop and guarded by their own pair ratio
(prefix-vs-noshare) plus exact checks on the sharing counters:
``admitted_tokens_saved`` is deterministic host-side accounting
(exact match), and ``cache_hit_rate`` must stay positive and equal
to the committed value within 0.001.  A committed file from before
the prefix-cache schema migrates via ``--update``.

    # CI wiring (fresh run + guard):
    PYTHONPATH=src python -m benchmarks.serve_bench --smoke --fuse \\
        --json BENCH_serve.ci.json
    PYTHONPATH=src python -m benchmarks.check_bench \\
        --fresh BENCH_serve.ci.json

``--kernels`` guards the kernel-microbenchmark trajectory
(``BENCH_kernels.json``) instead, under the same split: the byte
fields (``measured_*_bytes`` / ``modeled_*_bytes`` / ``page_size``)
are deterministic grid-transfer and model accounting — exact match —
while ``us_per_call`` is interpret-mode wall clock on whatever CPU CI
landed on, so it is never compared.  The fused-beats-unfused byte
invariant (the fusion PR's headline) is re-asserted on the fresh run:

    PYTHONPATH=src python -m benchmarks.kernel_bench \\
        --json BENCH_kernels.ci.json
    PYTHONPATH=src python -m benchmarks.check_bench --kernels \\
        --fresh BENCH_kernels.ci.json

``--update`` rewrites the committed file from the fresh run instead of
checking (the explicit, reviewed way to move the baseline).
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
COMMITTED = os.path.join(REPO, "BENCH_serve.json")
COMMITTED_KERNELS = os.path.join(REPO, "BENCH_kernels.json")


def _records(path: str, role: str) -> dict[str, dict]:
    """Load a trajectory file, dying with an actionable message (not a
    traceback) when it is missing or malformed — the first thing a
    fresh checkout or a broken CI artifact hits."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        sys.exit(
            f"check_bench: {role} file {path!r} does not exist.\n"
            f"  fresh file:     generate with `python -m "
            f"benchmarks.serve_bench --smoke --json <path>` (or "
            f"kernel_bench --json with --kernels)\n"
            f"  committed file: commit one with `check_bench --fresh "
            f"<path> --update`, or point --committed at it")
    except json.JSONDecodeError as e:
        sys.exit(
            f"check_bench: {role} file {path!r} is not valid JSON "
            f"({e}).\n  regenerate it — a truncated file usually means "
            f"the benchmark run was interrupted before write_json ran")
    if not isinstance(doc, dict) or "records" not in doc:
        sys.exit(
            f"check_bench: {role} file {path!r} has no 'records' "
            f"field — it is not a benchmark trajectory file.  Expected "
            f"the JSON written by serve_bench/kernel_bench --json")
    recs = {}
    for r in doc["records"]:
        if "name" not in r:
            sys.exit(
                f"check_bench: {role} file {path!r} has a record "
                f"without a 'name' field — regenerate it with the "
                f"current benchmark code")
        recs[r["name"]] = r
    return recs


def _speedup(recs: dict[str, dict], name: str,
             base: str = "serve_static") -> float:
    return recs[name]["tok_s"] / max(recs[base]["tok_s"], 1e-9)


# reuse-workload records: not comparable to the serve_static baseline
PREFIX_SECTION = ("serve_paged_prefix", "serve_paged_noshare")
# pressure-workload record: not comparable to serve_static either; its
# scheduling counters are host-deterministic and exact-matched
PREEMPT_SECTION = "serve_paged_preempt"
PREEMPT_EXACT_FIELDS = ("preemptions", "restored_requests",
                        "admitted_tokens_saved")


def check(fresh_path: str, committed_path: str, tolerance: float) -> int:
    fresh = _records(fresh_path, "fresh")
    committed = _records(committed_path, "committed")
    failures: list[str] = []

    missing = sorted(set(committed) - set(fresh))
    if missing:
        failures.append(f"records missing from fresh run: {missing}")
    for name, ref in committed.items():
        if name not in fresh:
            continue
        got = fresh[name]
        # seeded workload: useful-token counts are exact, not timing
        if got.get("useful_tokens") != ref.get("useful_tokens"):
            failures.append(
                f"{name}: useful_tokens {got.get('useful_tokens')} != "
                f"committed {ref.get('useful_tokens')} — the workload "
                f"changed; rerun with --update if intentional")
        # only fields the committed record itself carries are required:
        # a freshly-added field (p99_us, metrics, ...) is ignored until
        # the baseline is explicitly moved with --update, so schema
        # growth in serve_bench never churns the committed file
        for field in ("tok_s", "p50_us", "p95_us", "p99_us"):
            if field in ref and field not in got:
                failures.append(f"{name}: field {field!r} missing")
    for name in committed:
        if name == "serve_static" or name in PREFIX_SECTION \
                or name == PREEMPT_SECTION or name not in fresh:
            continue
        ref_x = _speedup(committed, name)
        got_x = _speedup(fresh, name)
        floor = ref_x * (1.0 - tolerance)
        status = "ok" if got_x >= floor else "REGRESSION"
        print(f"{name}: speedup {got_x:.2f}x vs committed {ref_x:.2f}x "
              f"(floor {floor:.2f}x) {status}")
        if got_x < floor:
            failures.append(
                f"{name}: paged/static speedup {got_x:.2f}x fell below "
                f"{floor:.2f}x ({(1 - tolerance):.0%} of the committed "
                f"{ref_x:.2f}x)")

    # prefix-cache section: pair ratio + exact sharing counters
    if all(n in committed and n in fresh for n in PREFIX_SECTION):
        ref_x = _speedup(committed, "serve_paged_prefix",
                         base="serve_paged_noshare")
        got_x = _speedup(fresh, "serve_paged_prefix",
                         base="serve_paged_noshare")
        floor = ref_x * (1.0 - tolerance)
        status = "ok" if got_x >= floor else "REGRESSION"
        print(f"serve_paged_prefix: vs-noshare {got_x:.2f}x vs committed "
              f"{ref_x:.2f}x (floor {floor:.2f}x) {status}")
        if got_x < floor:
            failures.append(
                f"serve_paged_prefix: sharing speedup {got_x:.2f}x fell "
                f"below {floor:.2f}x of the committed {ref_x:.2f}x")
        got = fresh["serve_paged_prefix"]
        ref = committed["serve_paged_prefix"]
        if got.get("admitted_tokens_saved") != \
                ref.get("admitted_tokens_saved"):
            failures.append(
                f"serve_paged_prefix: admitted_tokens_saved "
                f"{got.get('admitted_tokens_saved')} != committed "
                f"{ref.get('admitted_tokens_saved')} — sharing "
                f"admission changed semantics; rerun with --update "
                f"if intentional")
        hr = got.get("cache_hit_rate", 0.0)
        if not hr > 0:
            failures.append(
                "serve_paged_prefix: cache_hit_rate is 0 — the reuse "
                "workload never hit the cache")
        if abs(hr - ref.get("cache_hit_rate", 0.0)) > 1e-3:
            failures.append(
                f"serve_paged_prefix: cache_hit_rate {hr} != committed "
                f"{ref.get('cache_hit_rate')}")

    # preemption/restore section: the whole point is the counters —
    # restored requests must exist and must have replayed only their
    # unshared tail, and the host-side scheduling that produces those
    # numbers is deterministic, so they exact-match the baseline
    if PREEMPT_SECTION in committed and PREEMPT_SECTION in fresh:
        got = fresh[PREEMPT_SECTION]
        ref = committed[PREEMPT_SECTION]
        for field in PREEMPT_EXACT_FIELDS:
            if field not in ref:
                continue
            if got.get(field) != ref[field]:
                failures.append(
                    f"{PREEMPT_SECTION}: {field} {got.get(field)} != "
                    f"committed {ref[field]} — preempt/restore "
                    f"scheduling changed semantics; rerun with "
                    f"--update if intentional")
        if not got.get("preemptions", 0) > 0:
            failures.append(
                f"{PREEMPT_SECTION}: preemptions is 0 — the pressure "
                f"workload never forced a preemption")
        if not got.get("admitted_tokens_saved", 0) > 0:
            failures.append(
                f"{PREEMPT_SECTION}: admitted_tokens_saved is 0 — "
                f"restores replayed everything instead of only the "
                f"unshared tail")
        print(f"{PREEMPT_SECTION}: preemptions="
              f"{got.get('preemptions')} restored="
              f"{got.get('restored_requests')} saved="
              f"{got.get('admitted_tokens_saved')}tok "
              f"{'ok' if not any(PREEMPT_SECTION in f for f in failures) else 'FAILED'}")

    if failures:
        print("\nbenchmark regression guard FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"benchmark guard OK: {len(committed)} records within "
          f"tolerance {tolerance}")
    return 0


# deterministic per-record fields of the kernel-bench trajectory: kernel
# grid-transfer accounting and model predictions, identical on any
# machine — required and exact-matched when the committed record has them
KERNEL_EXACT_FIELDS = ("measured_fused_bytes", "measured_unfused_bytes",
                       "modeled_fused_bytes", "modeled_unfused_bytes",
                       "page_size")


def check_kernels(fresh_path: str, committed_path: str) -> int:
    fresh = _records(fresh_path, "fresh")
    committed = _records(committed_path, "committed")
    failures: list[str] = []

    missing = sorted(set(committed) - set(fresh))
    if missing:
        failures.append(f"records missing from fresh run: {missing}")
    n_exact = 0
    for name, ref in committed.items():
        if name not in fresh:
            continue
        got = fresh[name]
        # same field-presence rule as the serving guard: only fields the
        # committed record carries are required, so kernel_bench can grow
        # its schema without churning the baseline
        for field in KERNEL_EXACT_FIELDS:
            if field not in ref:
                continue
            if got.get(field) != ref[field]:
                failures.append(
                    f"{name}: {field} {got.get(field)} != committed "
                    f"{ref[field]} — the kernel's grid transfers or the "
                    f"traffic model changed; rerun with --update if "
                    f"intentional")
            else:
                n_exact += 1
    # the fusion headline must hold on the fresh run itself, not just
    # match history: fused variants move strictly fewer bytes
    for name, got in sorted(fresh.items()):
        mf, mu = (got.get("measured_fused_bytes"),
                  got.get("measured_unfused_bytes"))
        if mf is not None and mu is not None and not mf < mu:
            failures.append(
                f"{name}: measured_fused_bytes {mf} is not below "
                f"unfused {mu} — fusion stopped saving traffic")

    if failures:
        print("\nkernel-benchmark guard FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"kernel-benchmark guard OK: {len(committed)} records, "
          f"{n_exact} deterministic byte fields exact")
    return 0


def list_guarded_fields() -> None:
    """Print every field the guard looks at, per record class — the
    answer to "what will make this fail?" without reading the source."""
    print("serving guard (BENCH_serve.json):")
    print("  every record:     useful_tokens (exact), and any of "
          "tok_s/p50_us/p95_us/p99_us the committed record carries "
          "(presence only)")
    print("  paged records:    tok_s ratio vs serve_static within "
          "--tolerance (except the sections below)")
    print(f"  {'/'.join(PREFIX_SECTION)}:")
    print("                    pair tok_s ratio, admitted_tokens_saved "
          "(exact), cache_hit_rate (>0, ±0.001)")
    print(f"  {PREEMPT_SECTION}:")
    print(f"                    {', '.join(PREEMPT_EXACT_FIELDS)} "
          f"(exact); preemptions > 0; admitted_tokens_saved > 0")
    print("kernel guard (BENCH_kernels.json, --kernels):")
    print(f"  every record:     {', '.join(KERNEL_EXACT_FIELDS)} (exact)")
    print("  fresh run:        measured_fused_bytes < "
          "measured_unfused_bytes")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--list", action="store_true",
                    help="print the guarded fields per record class "
                         "and exit")
    ap.add_argument("--fresh", metavar="PATH",
                    help="JSON written by a fresh serve_bench --smoke "
                         "--json (or, with --kernels, kernel_bench "
                         "--json) run")
    ap.add_argument("--kernels", action="store_true",
                    help="guard the kernel-microbenchmark trajectory "
                         "(BENCH_kernels.json): exact byte fields, no "
                         "timing ratios")
    ap.add_argument("--committed", default=None, metavar="PATH",
                    help="baseline to compare against (default: the "
                         "repo's BENCH_serve.json, or BENCH_kernels.json "
                         "with --kernels)")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="allowed relative drop in paged/static speedup "
                         "before failing (default 0.5; serving mode only)")
    ap.add_argument("--update", action="store_true",
                    help="replace the committed baseline with the fresh "
                         "run instead of checking")
    args = ap.parse_args()
    if args.list:
        list_guarded_fields()
        return
    if not args.fresh:
        ap.error("--fresh is required (or use --list to see what the "
                 "guard checks)")
    committed = args.committed or \
        (COMMITTED_KERNELS if args.kernels else COMMITTED)
    if args.update:
        shutil.copyfile(args.fresh, committed)
        print(f"updated {committed} from {args.fresh}")
        return
    if args.kernels:
        sys.exit(check_kernels(args.fresh, committed))
    sys.exit(check(args.fresh, committed, args.tolerance))


if __name__ == "__main__":
    main()
