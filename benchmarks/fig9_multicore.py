"""Paper Fig. 9: multicore scaling of Conv1 under KB-shared (XY) vs
IB-shared (K) partitioning, for the top-4 single-core schedules."""

from benchmarks.common import cached, emit, timed
from repro.configs import PAPER_LAYERS
from repro.core import (evaluate_multicore, make_objective,
                        optimize_exhaustive)


def top4_schedules() -> list[str]:
    def search():
        p = PAPER_LAYERS["Conv1"]
        res = optimize_exhaustive(p, make_objective("custom"), n_levels=2,
                                  top=4, max_orders=12)
        return {"schedules": [repr(r.string) for r in res]}
    return cached("fig9/top4", search)["schedules"]


def run() -> None:
    from repro.core import BlockingString
    p = PAPER_LAYERS["Conv1"]
    for si, text in enumerate(top4_schedules(), 1):
        s = BlockingString.parse(text, p)
        for scheme in ("K", "XY"):
            rows = []
            for cores in (1, 2, 4, 8):
                us, r = timed(lambda: evaluate_multicore(s, scheme, cores))
                rows.append(f"{cores}c={r.pj_per_mac:.2f}pJ")
            emit(f"fig9/sched{si}_{scheme}", us, " ".join(rows))


if __name__ == "__main__":
    run()
