"""§Roofline: three-term roofline per (arch x shape) from dry-run artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HBM_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

FLOPs and collective bytes come from the compiled (partitioned) HLO of the
analysis lowering (launch/dryrun.py).  HBM bytes use an analytic traffic
model (documented below): the CPU backend's ``bytes accessed`` counts
every unfused elementwise op — TPU fusion eliminates most of that traffic,
so raw HLO bytes are reported only as an upper bound (``hlo_bytes``).

Hardware (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import emit
from repro.configs import ARCHS, SHAPES, cells, get_config
from repro.core import TPU_V5E
from repro.models.config import ModelConfig

# hardware model shared with the kernel profiler (repro.core.TPU_V5E)
PEAK_FLOPS = TPU_V5E.peak_bf16_flops
HBM_BW = TPU_V5E.hbm_bytes_per_s
LINK_BW = TPU_V5E.ici_bytes_per_s_per_link
ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")


def analytic_hbm_bytes(cfg: ModelConfig, shape_name: str,
                       data_ax: int = 16, model_ax: int = 16) -> float:
    """Per-device HBM traffic per step (documented in EXPERIMENTS.md).

    train:   weights read twice (fwd+bwd) at the TP shard size, gradient +
             AdamW state at the FSDP shard size, layer activations saved
             once and re-read + one recompute pass (block remat), logits
             3 passes.
    prefill: weights once, activations twice, KV-cache written.
    decode:  active weights once + KV cache read once (the classic
             decode memory wall).
    """
    shape = SHAPES[shape_name]
    n_dev = data_ax * model_ax
    p_total = cfg.param_count()
    p_active = cfg.active_param_count()
    bpe = 2
    tokens_loc = shape.seq_len * shape.global_batch / data_ax
    if shape.kind == "decode":
        tokens_loc = shape.global_batch / max(
            data_ax if shape.global_batch >= data_ax else 1, 1)

    d = cfg.d_model
    layers = cfg.n_layers + cfg.encoder_layers
    act_pass = layers * tokens_loc * d * bpe

    # decode-cache size per device
    cache_bytes = 0.0
    for i in range(cfg.n_layers):
        m = cfg.mixer_for_layer(i)
        if m == "global":
            cache_bytes += (shape.global_batch * shape.seq_len *
                            cfg.n_kv_heads * cfg.head_dim * 2 * bpe)
        elif m == "local":
            cache_bytes += (shape.global_batch *
                            min(cfg.window or shape.seq_len, shape.seq_len)
                            * cfg.n_kv_heads * cfg.head_dim * 2 * bpe)
        elif m == "ssd":
            cache_bytes += shape.global_batch * (
                cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4 +
                (cfg.conv_width - 1) * (cfg.d_inner + 2 * cfg.ssm_state)
                * bpe)
        elif m == "recurrent":
            cache_bytes += shape.global_batch * cfg.lru_width * (4 + 3 * bpe)
    cache_loc = cache_bytes / n_dev

    vocab_loc = cfg.vocab / model_ax

    if shape.kind == "train":
        w = 2 * (p_active / model_ax) * bpe          # fwd + bwd reads
        opt = (p_total / n_dev) * (2 * bpe + 16 + 6)  # grads + moments
        act = 4 * act_pass                            # save/read/recompute
        logits = 3 * tokens_loc * vocab_loc * bpe
        return w + opt + act + logits
    if shape.kind == "prefill":
        w = (p_active / model_ax) * bpe
        act = 2 * act_pass
        return w + act + cache_loc
    # decode: one token
    w = (p_active / model_ax) * bpe
    return w + cache_loc


def load_cells(mesh: str = "16x16") -> list[dict]:
    out = []
    for arch, shape in cells():
        safe = arch.replace("/", "_").replace(".", "_")
        path = os.path.join(ART_DIR, f"{safe}__{shape}__{mesh}.json")
        if os.path.exists(path):
            with open(path) as f:
                out.append(json.load(f))
    return out


def terms(rec: dict) -> dict | None:
    if not rec.get("ok") or rec.get("flops") is None:
        return None
    cfg = get_config(rec["arch"])
    t_c = rec["flops"] / PEAK_FLOPS
    hbm = analytic_hbm_bytes(cfg, rec["shape"])
    t_m = hbm / HBM_BW
    t_x = rec["collective_bytes_total"] / LINK_BW
    dominant = max(("compute", t_c), ("memory", t_m),
                   ("collective", t_x), key=lambda kv: kv[1])
    shape = SHAPES[rec["shape"]]
    if shape.kind == "train":
        toks = shape.seq_len * shape.global_batch
    elif shape.kind == "prefill":
        toks = shape.seq_len * shape.global_batch
    else:
        toks = shape.global_batch
    model_flops = cfg.model_flops_per_token() * toks / 256  # per device
    if shape.kind != "train":
        model_flops /= 3  # fwd only (6ND counts fwd+bwd)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "bottleneck": dominant[0],
        "roofline_fraction": t_c / max(t_c, t_m, t_x),
        "model_hlo_ratio": model_flops / rec["flops"],
        "hlo_bytes_upper": rec.get("bytes_accessed"),
    }


def run() -> None:
    recs = load_cells()
    for rec in recs:
        t = terms(rec)
        if t is None:
            emit(f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
                 "missing-analysis")
            continue
        emit(f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
             f"compute={t['compute_s']*1e3:.1f}ms "
             f"mem={t['memory_s']*1e3:.1f}ms "
             f"coll={t['collective_s']*1e3:.1f}ms "
             f"bottleneck={t['bottleneck']} "
             f"frac={t['roofline_fraction']:.2f} "
             f"useful={t['model_hlo_ratio']:.2f}")


if __name__ == "__main__":
    run()
