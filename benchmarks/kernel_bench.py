"""Kernel micro-benchmarks: Pallas (interpret) vs oracle + model-predicted
traffic for the tile choices (analytic; wall-clock on CPU is NOT the TPU
story, so the derived column reports the model's DRAM-traffic ratio),
plus autotuned-vs-hardcoded tile comparisons on the same access model —
for the FORWARD kernels, (ISSUE 2) the custom-VJP BACKWARD nests, and
(ISSUE 4) the QUANTIZED variants (matmul_w8 under its dtype-aware
schedule key), so the BENCH json carries training- and quantization-cost
axes.  ``--dtype`` picks the activation dtype the forward-GEMM
comparisons (incl. matmul_w8) run at — float32 default, bfloat16
mirrors the TPU deployment width; the conv/backward/attention sections
stay float32."""

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core import (BlockingString, Dim, Loop, Problem, matmul_tiles)
from repro.kernels import ops, ref
from repro.tune import OpSpec, best_schedule, predicted_dram_accesses


def matmul_traffic_ratio(m, n, k) -> float:
    """Model-predicted HBM traffic under a VMEM-sized on-chip level:
    optimizer tile vs untiled GEMM (whose working set spills)."""
    from repro.core import MemLevel, cache_accesses
    levels = [MemLevel.sram("VMEM", 16 * 1024 * 1024), MemLevel.dram()]
    p = Problem.gemm(M=m, N_cols=n, K_reduce=k)
    bm, bk, bn = matmul_tiles(m, n, k, 2)
    tiled = BlockingString(
        [Loop(Dim.C, bk), Loop(Dim.X, bm), Loop(Dim.K, bn),
         Loop(Dim.C, k), Loop(Dim.K, n), Loop(Dim.X, m)], p)
    naive = BlockingString(
        [Loop(Dim.C, k), Loop(Dim.K, n), Loop(Dim.X, m)], p)
    naive_dram = cache_accesses(naive, levels)["DRAM"]
    tiled_dram = cache_accesses(tiled, levels)["DRAM"]
    return naive_dram / max(tiled_dram, 1)


# hardcoded tiles this benchmark shipped with before the autotuner; kept
# as the baseline the tuned schedules are compared against
DEFAULT_MATMUL_TILES = (64, 128, 128)
DEFAULT_CONV_TILES = (13, 13, 32, 64)
DEFAULT_CONV_DGRAD_TILES = (14, 14, 64, 32)


def tuned_vs_default(spec: OpSpec, default_tiles) -> tuple[tuple, str]:
    """Tuned tiles + a derived-column string comparing DRAM accesses."""
    sched = best_schedule(spec.op, spec.dims, spec.dtype,
                          stride=spec.stride)
    tuned = predicted_dram_accesses(spec, sched.tiles)
    default = predicted_dram_accesses(spec, default_tiles)
    verdict = "BEATS" if tuned < default else \
        "matches" if tuned == default else "LOSES-TO"
    return sched.tiles, (f"tuned {sched.tiles} {tuned:.3e} {verdict} "
                         f"default {default_tiles} {default:.3e} "
                         f"DRAM accesses ({sched.source})")


def run(dtype: str = "float32") -> None:
    rng = np.random.default_rng(0)
    jdt = getattr(jnp, dtype)
    # interpret-mode kernels accumulate fp32 either way; tolerances track
    # the activation width the comparison runs at
    rtol, atol = (2e-2, 2e-2) if dtype == "bfloat16" else (1e-3, 1e-3)
    # matmul: hardcoded-default tiles vs the autotuner's pick
    a = jnp.asarray(rng.normal(size=(256, 512)), jdt)
    b = jnp.asarray(rng.normal(size=(512, 256)), jdt)
    ref_out = np.asarray(ref.matmul_ref(a, b), np.float32)
    out = ops.matmul(a, b, tiles=DEFAULT_MATMUL_TILES, interpret=True)
    us, _ = timed(lambda: np.asarray(
        ops.matmul(a, b, tiles=DEFAULT_MATMUL_TILES, interpret=True)))
    ratio = matmul_traffic_ratio(4096, 4096, 4096)
    emit(f"kernel/matmul_256x512x256_{dtype}", us,
         f"model DRAM-traffic reduction (4k GEMM) {ratio:.1f}x")
    np.testing.assert_allclose(np.asarray(out, np.float32), ref_out,
                               rtol=rtol, atol=atol)

    mm_spec = OpSpec("matmul", (256, 256, 512), dtype)
    mm_tiles, derived = tuned_vs_default(mm_spec, DEFAULT_MATMUL_TILES)
    us, tuned_out = timed(lambda: np.asarray(
        ops.matmul(a, b, tiles=mm_tiles, interpret=True)))
    np.testing.assert_allclose(np.asarray(tuned_out, np.float32), ref_out,
                               rtol=rtol, atol=atol)
    emit(f"kernel/matmul_256x512x256_tuned_{dtype}", us, derived)

    # QUANTIZED variant: same dims, int8 weight stream, own schedule key
    # — the dtype-aware model ranks its tiles against 1-byte weights
    from repro.kernels.matmul_q import matmul_w8_ref
    from repro.quant import quantize
    w8_spec = OpSpec("matmul_w8", (256, 256, 512), dtype)
    w8_tiles, w8_derived = tuned_vs_default(w8_spec, DEFAULT_MATMUL_TILES)
    qt = quantize(b.astype(jnp.float32), "int8")
    scale = qt.scale.reshape(-1)
    us, q_out = timed(lambda: np.asarray(
        ops.matmul_w8(a, qt.q, scale, tiles=w8_tiles, interpret=True)))
    np.testing.assert_allclose(
        np.asarray(q_out, np.float32),
        np.asarray(matmul_w8_ref(a, qt.q, scale), np.float32),
        rtol=rtol, atol=atol)
    emit(f"kernel/matmul_w8_256x512x256_tuned_{dtype}", us, w8_derived)

    # matmul BACKWARD: the two dgrad nests (dA: (M,K,N); dB: (K,N,M)),
    # tuned vs the hardcoded default on predicted DRAM accesses, plus the
    # end-to-end jax.grad wall time through the custom-VJP Pallas kernels
    da_spec = OpSpec("matmul_dgrad", (256, 512, 256), "float32")
    _, da_derived = tuned_vs_default(da_spec, DEFAULT_MATMUL_TILES)
    db_spec = OpSpec("matmul_dgrad", (512, 256, 256), "float32")
    _, db_derived = tuned_vs_default(db_spec, DEFAULT_MATMUL_TILES)
    grad_fn = jax.grad(
        lambda a, b: jnp.sum(ops.matmul(a, b, interpret=True) ** 2),
        argnums=(0, 1))
    # backward stays float32 whatever --dtype drives the forward section
    af, bf = a.astype(jnp.float32), b.astype(jnp.float32)
    us, _ = timed(lambda: jax.tree.map(np.asarray, grad_fn(af, bf)))
    emit("kernel/matmul_256x512x256_bwd", us,
         f"dA {da_derived}; dB {db_derived}")

    # conv
    x = jnp.asarray(rng.normal(size=(1, 28, 28, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 32, 64)), jnp.float32)
    us, out = timed(lambda: np.asarray(
        ops.conv2d(x, w, tiles=DEFAULT_CONV_TILES, interpret=True)))
    np.testing.assert_allclose(out, ref.conv2d_ref(x, w), rtol=1e-2,
                               atol=1e-2)
    emit("kernel/conv_28x28x32x64", us, "allclose-vs-oracle OK")

    conv_spec = OpSpec("conv2d", (26, 26, 32, 64, 3, 3), "float32")
    cv_tiles, derived = tuned_vs_default(conv_spec, DEFAULT_CONV_TILES)
    us, tuned_out = timed(lambda: np.asarray(
        ops.conv2d(x, w, tiles=cv_tiles, interpret=True)))
    np.testing.assert_allclose(tuned_out, ref.conv2d_ref(x, w), rtol=1e-2,
                               atol=1e-2)
    emit("kernel/conv_28x28x32x64_tuned", us, derived)

    # conv BACKWARD: wgrad shares the forward dims; dgrad is the
    # transposed conv (28x28 output space, channels swapped)
    wg_spec = OpSpec("conv2d_wgrad", (26, 26, 32, 64, 3, 3), "float32")
    _, wg_derived = tuned_vs_default(wg_spec, DEFAULT_CONV_TILES)
    dg_spec = OpSpec("conv2d_dgrad", (28, 28, 64, 32, 3, 3), "float32")
    _, dg_derived = tuned_vs_default(dg_spec, DEFAULT_CONV_DGRAD_TILES)
    conv_grad = jax.grad(
        lambda x, w: jnp.sum(ops.conv2d(x, w, interpret=True) ** 2),
        argnums=(0, 1))
    us, _ = timed(lambda: jax.tree.map(np.asarray, conv_grad(x, w)))
    emit("kernel/conv_28x28x32x64_bwd", us,
         f"wgrad {wg_derived}; dgrad {dg_derived}")

    # attention
    q = jnp.asarray(rng.normal(size=(1, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    us, out = timed(lambda: np.asarray(
        ops.attention(q, k, v, tiles=(32, 32), interpret=True)))
    emit("kernel/flash_attn_128", us, "GQA causal OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dtype", default="float32",
                    choices=("float32", "bfloat16"),
                    help="activation dtype for the forward-GEMM "
                         "tuned-vs-default comparisons, incl. the "
                         "quantized matmul_w8 variant (int8 weight "
                         "stream either way); the conv/backward/"
                         "attention sections stay float32")
    args = ap.parse_args()
    run(dtype=args.dtype)


if __name__ == "__main__":
    main()
