"""Kernel micro-benchmarks: Pallas (interpret) vs oracle + model-predicted
traffic for the tile choices (analytic; wall-clock on CPU is NOT the TPU
story, so the derived column reports the model's DRAM-traffic ratio)."""

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core import (BlockingString, Dim, Loop, Problem, analyze,
                        matmul_tiles)
from repro.kernels import ops, ref


def matmul_traffic_ratio(m, n, k) -> float:
    """Model-predicted HBM traffic under a VMEM-sized on-chip level:
    optimizer tile vs untiled GEMM (whose working set spills)."""
    from repro.core import MemLevel, cache_accesses
    levels = [MemLevel.sram("VMEM", 16 * 1024 * 1024), MemLevel.dram()]
    p = Problem.gemm(M=m, N_cols=n, K_reduce=k)
    bm, bk, bn = matmul_tiles(m, n, k, 2)
    tiled = BlockingString(
        [Loop(Dim.C, bk), Loop(Dim.X, bm), Loop(Dim.K, bn),
         Loop(Dim.C, k), Loop(Dim.K, n), Loop(Dim.X, m)], p)
    naive = BlockingString(
        [Loop(Dim.C, k), Loop(Dim.K, n), Loop(Dim.X, m)], p)
    naive_dram = cache_accesses(naive, levels)["DRAM"]
    tiled_dram = cache_accesses(tiled, levels)["DRAM"]
    return naive_dram / max(tiled_dram, 1)


def run() -> None:
    rng = np.random.default_rng(0)
    # matmul
    a = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
    out = ops.matmul(a, b, tiles=(64, 128, 128), interpret=True)
    us, _ = timed(lambda: np.asarray(
        ops.matmul(a, b, tiles=(64, 128, 128), interpret=True)))
    ratio = matmul_traffic_ratio(4096, 4096, 4096)
    emit("kernel/matmul_256x512x256", us,
         f"model DRAM-traffic reduction (4k GEMM) {ratio:.1f}x")
    np.testing.assert_allclose(out, ref.matmul_ref(a, b), rtol=1e-3,
                               atol=1e-3)

    # conv
    x = jnp.asarray(rng.normal(size=(1, 28, 28, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 32, 64)), jnp.float32)
    us, out = timed(lambda: np.asarray(
        ops.conv2d(x, w, tiles=(13, 13, 32, 64), interpret=True)))
    np.testing.assert_allclose(out, ref.conv2d_ref(x, w), rtol=1e-2,
                               atol=1e-2)
    emit("kernel/conv_28x28x32x64", us, "allclose-vs-oracle OK")

    # attention
    q = jnp.asarray(rng.normal(size=(1, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    us, out = timed(lambda: np.asarray(
        ops.attention(q, k, v, tiles=(32, 32), interpret=True)))
    emit("kernel/flash_attn_128", us, "GQA causal OK")


if __name__ == "__main__":
    run()
